"""Quickstart: build an MDP, solve it with two methods, inspect the policy.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
from repro.core import IPIOptions, generators, solve

# A 10,000-state random MDP (GARNET family), discount 0.99.
mdp = generators.garnet(n=10_000, m=16, k=8, gamma=0.99, seed=0)

# Value iteration (the mdpsolver/pymdptoolbox baseline)...
r_vi = solve(mdp, IPIOptions(method="vi", atol=1e-8, dtype="float64",
                             max_outer=10_000))
print("VI        :", r_vi.summary())

# ...vs inexact policy iteration with a GMRES inner solver (madupite).
r_ipi = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-8,
                              dtype="float64"))
print("iPI-GMRES :", r_ipi.summary())

assert np.abs(r_vi.v - r_ipi.v).max() < 1e-5
print(f"\nSame certified solution; iPI used {r_ipi.outer_iterations} outer "
      f"iterations vs VI's {r_vi.outer_iterations}.")
print("optimal value of state 0:", r_ipi.v[0], "| action:", r_ipi.policy[0])
