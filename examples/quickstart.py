"""Quickstart on the user API: builders, options database, session layer.

    PYTHONPATH=src python examples/quickstart.py

Works on one device or many — the session auto-builds the mesh from the
visible devices (try XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
import json

import numpy as np

from repro.api import MDP, madupite_session

# A 10,000-state random MDP (GARNET family), discount 0.99.
mdp = MDP.from_generator("garnet", n=10_000, m=16, k=8, gamma=0.99, seed=0)

# One options database drives the solver, the placement and the outputs.
with madupite_session({"-atol": 1e-8, "-dtype": "float64",
                       "-file_stats": "/tmp/quickstart_stats.json"}) as s:
    # Value iteration (the mdpsolver/pymdptoolbox baseline)...
    r_vi = s.solve(mdp, method="vi", max_outer=10_000)
    print("VI        :", r_vi.summary())

    # ...vs inexact policy iteration with a GMRES inner solver (madupite).
    r_ipi = s.solve(mdp, method="ipi_gmres")
    print("iPI-GMRES :", r_ipi.summary())

    stats = s.stats

assert np.abs(r_vi.v - r_ipi.v).max() < 1e-5
print(f"\nSame certified solution; iPI used {r_ipi.outer_iterations} outer "
      f"iterations vs VI's {r_vi.outer_iterations}.")
print("optimal value of state 0:", r_ipi.v[0], "| action:", r_ipi.policy[0])

# The run statistics were also written via -file_stats (streamed JSONL by
# default: one O(1) appended line per solve; -file_stats_format json keeps
# the single-array format).
entries = [json.loads(line)
           for line in open("/tmp/quickstart_stats.json")]
assert [e["method"] for e in entries] == ["vi", "ipi_gmres"]
assert all(e["solves"][0]["converged"] for e in entries)
print(f"\nstats JSONL: {len(entries)} solves recorded, layout="
      f"{entries[0]['layout']} mesh={entries[0]['mesh']}")

# maxreward mode: read cost as reward, solve max_a (r + gamma P v).  It is
# exactly the negation of the mincost solve on negated costs.
reward = MDP.from_generator("garnet", n=2_000, m=8, k=6, gamma=0.99, seed=1,
                            mode="maxreward")
with madupite_session({"-atol": 1e-8, "-dtype": "float64"}) as s:
    r_max = s.solve(reward, method="vi", max_outer=10_000)
print("\nmaxreward :", r_max.summary())
print("best reward-to-go of state 0:", r_max.v[0])
