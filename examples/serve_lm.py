"""Serve a reduced-config architecture: batched prefill + greedy decode.

    PYTHONPATH=src python examples/serve_lm.py [arch]
"""
import sys
from repro.launch.serve import main

arch = sys.argv[1] if len(sys.argv) > 1 else "zamba2-1.2b"
raise SystemExit(main(["--arch", arch, "--smoke", "--batch", "4",
                       "--prompt-len", "32", "--gen", "12"]))
