"""Serve a reduced-config architecture: batched prefill + greedy decode.

This is the LM prefill/decode scaffold that used to live at
``repro.launch.serve`` (that entry point now serves MDP solves — see
``python -m repro.launch.serve --help``).

    PYTHONPATH=src python examples/serve_lm.py [arch]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.train.steps import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, t, g = args.batch, args.prompt_len, args.gen

    key = jax.random.PRNGKey(7)
    prompts = jax.random.randint(key, (b, t), 0, cfg.vocab_size, jnp.int32)
    extra = None
    if cfg.family == "vlm":
        extra = jax.random.normal(key, (b, cfg.n_patches, cfg.d_model),
                                  jnp.float32)
    if cfg.family == "encdec":
        extra = jax.random.normal(key, (b, cfg.encoder_len, cfg.d_model),
                                  jnp.float32)

    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    t0 = time.time()
    logits, cache = prefill(params, prompts, extra)

    # grow the attention caches to prompt+gen slots
    def pad_kv(path, x):
        names = [str(getattr(p, "key", "")) for p in path]
        if names and names[-1] in ("k", "v"):
            return jnp.pad(x, ((0, 0), (0, 0), (0, g), (0, 0), (0, 0)))
        return x
    cache = jax.tree_util.tree_map_with_path(pad_kv, cache)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    t1 = time.time()

    out = [tok]
    for _ in range(g - 1):
        tok, _, cache = decode(params, tok, cache)
        out.append(tok)
    gen = np.asarray(jnp.concatenate(out, axis=1))
    t2 = time.time()
    print(f"[serve_lm] arch={cfg.name} prefill={t1-t0:.3f}s "
          f"decode={(t2-t1)/max(g-1,1)*1e3:.1f}ms/tok")
    for i in range(min(b, 2)):
        print(f"[serve_lm] sample {i}: {gen[i][:12].tolist()}")
    assert np.isfinite(gen).all()
    return 0


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "zamba2-1.2b"
    raise SystemExit(main(["--arch", arch, "--smoke", "--batch", "4",
                           "--prompt-len", "32", "--gen", "12"]))
