"""The pluggable method surface: register a KSP, monitor a solve, stop on
the span seminorm (ISSUE 5 end-to-end demo).

    PYTHONPATH=src python examples/custom_solver.py

Works on one device or many (the session auto-builds the mesh; try
XLA_FLAGS=--xla_force_host_platform_device_count=8) — a user-registered
inner solver runs under whatever layout the session picks, including the
fleet-sharded ones.
"""

import numpy as np

from repro.api import MDP, madupite_session, register_ksp
from repro.core.solvers import richardson


# --- 1. a user inner solver: damped Richardson, registered as a KSP --------
# Contract: pure lax control flow, distributed reductions via `axes`,
# returns (x, iters, resnorm).  One call makes it selectable from Python,
# MADUPITE_OPTIONS and the CLI (as -ksp_type damped / -method ipi_damped).

def damped(matvec, b, x0, *, tol, maxiter, axes):
    return richardson(matvec, b, x0, tol=tol, maxiter=maxiter, axes=axes,
                      omega=0.9)


register_ksp("damped", damped)

mdp = MDP.from_generator("garnet", n=5_000, m=8, k=6, gamma=0.99, seed=0)

with madupite_session({"-dtype": "float64", "-atol": 1e-8}) as s:
    r_user = s.solve(mdp, ksp_type="damped")
    r_ref = s.solve(mdp, method="ipi_gmres")
assert r_user.converged
np.testing.assert_allclose(r_user.v, r_ref.v, atol=1e-6)
print(f"user ksp 'damped':  {r_user.summary()}")
print(f"reference (gmres):  {r_ref.summary()}\n")

# --- 2. monitor + span stopping on a long-mixing chain ---------------------
# -monitor streams one record per outer iteration out of the compiled
# lax.while_loop; -stop_criterion span certifies VI once the residual
# vector is nearly constant — far earlier than the sup-norm decay.
chain = MDP.from_generator("chain_walk", n=400, gamma=0.999)

records = []
with madupite_session({"-dtype": "float64", "-atol": 1e-8,
                       "-max_outer": 100_000}) as s:
    r_span = s.solve(chain, method="vi", stop_criterion="span",
                     monitor=records.append)
    r_atol = s.solve(chain, method="vi")
assert len(records) == r_span.outer_iterations + 1   # k=0 .. k_final
assert r_span.outer_iterations < r_atol.outer_iterations
assert np.array_equal(r_span.policy, r_atol.policy)
print(f"chain_walk VI, span stop: {r_span.outer_iterations} outers "
      f"(vs {r_atol.outer_iterations} with atol — "
      f"{r_atol.outer_iterations / r_span.outer_iterations:.0f}x fewer, "
      f"same policy)")
print(f"monitored {len(records)} records; last: k={records[-1]['k']} "
      f"res={records[-1]['res']:.2e} "
      f"elapsed={records[-1]['elapsed']:.3f}s\n")

# --- 3. a custom stopping criterion as a traced predicate ------------------
# Stop when the certified optimality gap res/(1-gamma) drops below 1e-4.
with madupite_session({"-dtype": "float64"}) as s:
    r_gap = s.solve(mdp, method="ipi_gmres",
                    stop_criterion=lambda m: m.res / (1 - m.gamma) <= 1e-4)
assert r_gap.converged and r_gap.gap_bound <= 1e-4
print(f"custom gap criterion: {r_gap.summary()}")
