"""End-to-end distributed solve with fault tolerance.

Solves a 250x250 slippery-maze MDP (62,500 states) sharded over 8 forced
host devices with checkpointing; demonstrates the restart path by solving
in two phases.

    PYTHONPATH=src python examples/solve_maze_distributed.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)

import shutil, tempfile
import numpy as np
from repro.core import IPIOptions, generators
from repro.core.driver import solve

mdp = generators.maze2d(size=250, gamma=0.999, slip=0.15)
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
ckpt = tempfile.mkdtemp(prefix="maze_")
try:
    # phase 1: budget-limited run, checkpointing every chunk ("preempted")
    r1 = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-8, max_outer=3,
                               dtype="float64"),
               mesh=mesh, layout="2d", checkpoint_dir=ckpt, chunk=1,
               verbose=True)
    print(f"preempted at outer={r1.outer_iterations}, res={r1.residual:.2e}")

    # phase 2: restart from the checkpoint and finish
    r2 = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-8,
                               dtype="float64"),
               mesh=mesh, layout="2d", checkpoint_dir=ckpt, verbose=True)
    print("finished:", r2.summary())

    # the greedy policy at the start cell should move toward the goal
    # (goal = last cell; actions: 0 stay, 1 N, 2 S, 3 E, 4 W)
    print("policy at cell (0,0):", r2.policy[0], "(expect 2=S or 3=E)")
    assert r2.converged and r2.policy[0] in (2, 3)
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
