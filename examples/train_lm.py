"""Train a reduced-config assigned architecture end-to-end (driver demo).

    PYTHONPATH=src python examples/train_lm.py [arch]
"""
import sys
from repro.launch.train import main

arch = sys.argv[1] if len(sys.argv) > 1 else "olmoe-1b-7b"
raise SystemExit(main(["--arch", arch, "--smoke", "--steps", "60",
                       "--batch", "8", "--seq", "64",
                       "--ckpt-dir", "/tmp/repro_train_demo"]))
