"""Epidemic (SIS) intervention policy — the paper's application-domain demo.

madupite's motivating applications include epidemiology (Steimle & Denton
2017).  We model an SIS process over a population of 50,000 (50,001 states),
with 6 intervention levels trading infection load against intervention cost,
solve it exactly with iPI-BiCGStab, and read out the certified optimal
intervention thresholds.

    PYTHONPATH=src python examples/epidemic_control.py
"""
import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
from repro.core import IPIOptions, generators, solve

POP = 500   # +-1 birth-death dynamics must traverse the state space
            # within the 1/(1-gamma) horizon for control to matter
mdp = generators.sis(pop=POP, n_actions=6, gamma=0.999)
print(f"SIS MDP: {mdp.n_global:,} states x {mdp.m_global} interventions")

r = solve(mdp, IPIOptions(method="ipi_bicgstab", atol=1e-8, dtype="float64"))
print(r.summary())
assert r.converged

# where does the optimal policy escalate interventions?
pol = r.policy
changes = np.where(np.diff(pol) != 0)[0]
print("\ninfection level -> optimal intervention level")
lo = 0
for c in list(changes[:12]) + [POP]:
    print(f"  {lo:6d} .. {c:6d} infected : level {pol[lo]}")
    lo = c + 1
    if lo > POP:
        break
print(f"\ncertified: ||v - v*||_inf <= {r.gap_bound:.2e}")
