"""Epidemic (SIS) intervention policy — the paper's application-domain demo.

madupite's motivating applications include epidemiology (Steimle & Denton
2017), and its signature construction mode is *MDPs defined by Python
callables*: the transition law and stage cost below are plain functions of
(state, action) — ``MDP.from_functions`` materializes each device's ELL
block from them shard-locally, so the model scales to populations far
beyond what a host-side tensor could hold.

We model an SIS process with 6 intervention levels trading infection load
against intervention cost, solve it with iPI-BiCGStab through the session
layer, and read out the certified optimal intervention thresholds.

The constructors below are written in ``jax.numpy`` over the (traced) row
indices, so ``MDP.from_functions`` auto-selects the *device* generator
pipeline: each shard's ELL block is computed inside a compiled program —
no host numpy in the loop.  Writing them in plain ``numpy`` would work
identically through the host-callback fallback, just slower at scale.

    PYTHONPATH=src python examples/epidemic_control.py
"""
import numpy as np

from repro.api import MDP, madupite_session

POP = 500   # +-1 birth-death dynamics must traverse the state space
            # within the 1/(1-gamma) horizon for control to matter
N_ACT = 6

# SIS birth-death chain: state i = #infected in [0, POP].  Infections up
# w.p. beta_a * i * (POP - i) / POP^2, recoveries down w.p. mu * i / POP;
# state 0 (eradicated) is absorbing.  Stronger actions cut the spread rate
# but cost more.
BETA = np.linspace(0.9, 0.05, N_ACT)
ACT_COST = np.linspace(0.0, 0.15, N_ACT)
MU = 0.3


def transitions(rows, a: int):
    """Vectorized P_fn: successor ids and probabilities for states `rows`
    under intervention level `a` (ELL rows: [up, down, stay]).  `rows` is
    a traced index array; `a` stays a static Python int."""
    import jax.numpy as jnp
    i = rows.astype(jnp.float32)
    up = jnp.clip(float(BETA[a]) * i * (POP - i) / POP**2, 0, 0.49)
    down = jnp.clip(MU * i / POP, 0, 0.49)
    up = jnp.where(rows == 0, 0.0, up)         # eradicated: absorbing
    down = jnp.where(rows == 0, 0.0, down)
    ids = jnp.stack([jnp.clip(rows + 1, 0, POP), jnp.clip(rows - 1, 0, POP),
                     rows], axis=-1)
    probs = jnp.stack([up, down, 1.0 - up - down], axis=-1)
    return ids.astype(jnp.int32), probs.astype(jnp.float32)


def stage_cost(rows, a: int):
    """Infection load + intervention cost (zero load once eradicated)."""
    import jax.numpy as jnp
    return (jnp.where(rows == 0, 0.0, 2.0 * rows / POP)
            + float(ACT_COST[a])).astype(jnp.float32)


mdp = MDP.from_functions(transitions, stage_cost, n=POP + 1, m=N_ACT,
                         nnz=3, gamma=0.999, vectorized=True)
print(f"SIS MDP: {mdp.n:,} states x {mdp.m} interventions "
      f"(defined by callables, materialized shard-locally via the "
      f"{mdp.materialization()} pipeline)")

with madupite_session({"-method": "ipi_bicgstab", "-atol": 1e-8,
                       "-dtype": "float64"}) as s:
    r = s.solve(mdp)
print(r.summary())
assert r.converged

# where does the optimal policy escalate interventions?
pol = r.policy
changes = np.where(np.diff(pol) != 0)[0]
print("\ninfection level -> optimal intervention level")
lo = 0
for c in list(changes[:12]) + [POP]:
    print(f"  {lo:6d} .. {c:6d} infected : level {pol[lo]}")
    lo = c + 1
    if lo > POP:
        break
print(f"\ncertified: ||v - v*||_inf <= {r.gap_bound:.2e}")
