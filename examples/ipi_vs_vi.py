"""The paper's headline claim as a runnable comparison: as gamma -> 1,
Krylov-accelerated inexact policy iteration decouples from the
1/(1-gamma) iteration blow-up that hits value iteration.

    PYTHONPATH=src python examples/ipi_vs_vi.py
"""
import jax
jax.config.update("jax_enable_x64", True)

from repro.core import IPIOptions, generators
from repro.core.driver import solve

print(f"{'gamma':>8} | {'VI iters':>9} | {'iPI outer':>9} | {'iPI inner':>9}")
print("-" * 46)
for gamma in (0.9, 0.99, 0.999, 0.9999):
    mdp = generators.chain_walk(n=1000, gamma=gamma)
    r_vi = solve(mdp, IPIOptions(method="vi", atol=1e-8, dtype="float64",
                                 max_outer=1_000_000), chunk=8192)
    r_ip = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-8,
                                 max_inner=3000, dtype="float64"))
    print(f"{gamma:>8} | {r_vi.outer_iterations:>9} | "
          f"{r_ip.outer_iterations:>9} | {r_ip.inner_iterations:>9}")
