"""The serving subsystem: batched solve-as-a-service over ``Session``.

Contract (ISSUE 8): N concurrent clients submitting ragged-shape MDPs get
results **bitwise-equal** to direct ``Session.solve`` (vi/mpi are
elementwise — no cross-lane arithmetic — so batching lanes cannot perturb
them); compatible arrivals inside the batching window coalesce into fewer
compiled dispatches than requests; admission control rejects with
machine-readable reasons instead of queueing unboundedly; per-iteration
monitor records stream back tagged with the submitting request's id;
drain finishes in-flight work.  The fleet-sharded path (shape buckets
spread over the mesh's fleet axis) runs on 8 forced host devices in a
subprocess, like tests/test_fleet.py.
"""

import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.api import MDP, Session
from repro.serve import AdmissionError, Server, slot_size
from repro.utils.lru import LRUCache

GAMMA = 0.9          # homogeneous: heterogeneous gammas take the traced-
                     # gamma path, which is not part of the bitwise contract
BASE = {"-method": "vi", "-atol": 1e-6, "-verbose": False}


def _garnet(n, seed):
    return MDP.from_generator("garnet", n=n, m=3, k=4, gamma=GAMMA,
                              seed=seed)


def _submit_all(server, mdps, **kw):
    """Submit from one thread per client, like real concurrent callers."""
    reqs = [None] * len(mdps)
    errs = [None] * len(mdps)

    def client(i):
        try:
            reqs[i] = server.submit(mdps[i], **kw)
        except Exception as e:  # noqa: BLE001
            errs[i] = e

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(len(mdps))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(e is None for e in errs), errs
    return reqs


# --------------------------------------------------------------------------- #
# bitwise parity + coalescing
# --------------------------------------------------------------------------- #

def test_concurrent_clients_bitwise_equal_and_coalesced():
    ns = [48, 64, 48, 64, 48, 48, 64, 48]
    mdps = [_garnet(n, seed=i) for i, n in enumerate(ns)]
    with Server({**BASE, "-serve_batch_window": 0.25}) as srv:
        reqs = _submit_all(srv, mdps)
        results = [r.result(timeout=600) for r in reqs]
        st = srv.stats()

    with Session(BASE) as sess:
        base = [sess.solve(m) for m in mdps]

    for i, (r, b) in enumerate(zip(results, base)):
        assert np.array_equal(np.asarray(r.v), np.asarray(b.v)), i
        assert np.array_equal(np.asarray(r.policy), np.asarray(b.policy)), i
        assert r.outer_iterations == b.outer_iterations, i
        assert np.array_equal(r.trace_residual, b.trace_residual,
                              equal_nan=True), i

    # batching coalesced: strictly fewer compiled dispatches than requests
    assert st["submitted"] == len(ns)
    assert st["completed"] == len(ns)
    assert st["dispatches"] < len(ns)
    assert st["dispatched_requests"] == len(ns)
    assert st["batch"]["max_size"] > 1
    # every dispatch is accounted against a program-cache slot
    pc = st["program_cache"]
    assert pc["hits"] + pc["misses"] == st["dispatches"]
    assert st["latency_s"]["p50"] > 0


def test_two_shape_buckets_dispatch_separately():
    # 48 vs 96 states: pad waste past 25% -> bucket_indices splits, so one
    # coalesced group still dispatches as two compiled programs
    ns = [48, 96, 48, 96, 48, 96]
    mdps = [_garnet(n, seed=10 + i) for i, n in enumerate(ns)]
    with Server({**BASE, "-serve_batch_window": 0.25}) as srv:
        reqs = _submit_all(srv, mdps)
        results = [r.result(timeout=600) for r in reqs]
        st = srv.stats()

    with Session(BASE) as sess:
        for i, (m, r) in enumerate(zip(mdps, results)):
            b = sess.solve(m)
            assert np.array_equal(np.asarray(r.v), np.asarray(b.v)), i

    assert st["dispatches"] >= 2           # one per shape bucket
    assert st["dispatches"] < len(ns)      # but still coalesced
    pads = {s["n_pad"] for s in st["program_cache"]["slots"]}
    assert pads == {48, 96}


def test_program_cache_warm_hits_and_slot_padding():
    mdps1 = [_garnet(48, seed=20 + i) for i in range(5)]
    mdps2 = [_garnet(48, seed=30 + i) for i in range(5)]
    with Server({**BASE, "-serve_batch_window": 0.1}) as srv:
        for r in _submit_all(srv, mdps1):
            r.result(timeout=600)
        for r in _submit_all(srv, mdps2):
            r.result(timeout=600)
        st = srv.stats()
    # both waves are 5 requests padded to the same mid2 fleet slot (6), so
    # the second dispatch reuses the warm program slot
    assert st["program_cache"]["hits"] >= 1
    assert st["padded_lanes"] >= 2
    slots = st["program_cache"]["slots"]
    assert any(s["fleet_slot"] == 6 and s["dispatches"] >= 2 for s in slots)


def test_slot_size_grids():
    ns = (1, 2, 3, 4, 5, 6, 7, 12, 13, 24, 25)
    assert [slot_size(n, "mid2", 64) for n in ns] == \
        [1, 2, 3, 4, 6, 6, 8, 12, 16, 24, 32]
    assert [slot_size(n, "pow2", 64) for n in (1, 3, 5, 9)] == [1, 4, 8, 16]
    assert slot_size(24, "exact", 64) == 24


def test_incompatible_overrides_do_not_batch():
    mdps = [_garnet(48, seed=40 + i) for i in range(4)]
    with Server({**BASE, "-serve_batch_window": 0.2}) as srv:
        reqs = [srv.submit(mdps[0], atol=1e-6),
                srv.submit(mdps[1], atol=1e-6),
                srv.submit(mdps[2], atol=1e-8),
                srv.submit(mdps[3], atol=1e-8)]
        results = [r.result(timeout=600) for r in reqs]
        st = srv.stats()
    assert st["dispatches"] == 2           # one per override signature
    assert st["batch"]["max_size"] == 2
    assert results[2].residual <= 1e-8


# --------------------------------------------------------------------------- #
# admission control
# --------------------------------------------------------------------------- #

def test_admission_rejects_too_large():
    with Server({**BASE, "-serve_max_states": 50}) as srv:
        srv.submit(_garnet(48, seed=0)).result(timeout=600)
        with pytest.raises(AdmissionError) as exc:
            srv.submit(_garnet(64, seed=1))
        assert exc.value.reason == "too_large"
        assert "-serve_max_states" in str(exc.value)
        st = srv.stats()
    assert st["rejected"] == {"too_large": 1}
    assert st["completed"] == 1


def test_admission_rejects_queue_full():
    # a long window keeps the first submits queued while the third arrives
    with Server({**BASE, "-serve_max_queue": 2,
                 "-serve_batch_window": 5.0}) as srv:
        r1 = srv.submit(_garnet(48, seed=50))
        r2 = srv.submit(_garnet(48, seed=51))
        with pytest.raises(AdmissionError) as exc:
            srv.submit(_garnet(48, seed=52))
        assert exc.value.reason == "queue_full"
        assert "-serve_max_queue" in str(exc.value)
        assert srv.drain(timeout=600)      # cuts the window short
        assert r1.done and r2.done
        st = srv.stats()
    assert st["rejected"] == {"queue_full": 1}
    assert st["completed"] == 2


def test_draining_and_closed_reject_submits():
    srv = Server(BASE)
    try:
        req = srv.submit(_garnet(48, seed=60))
        assert srv.drain(timeout=600)
        with pytest.raises(AdmissionError) as exc:
            srv.submit(_garnet(48, seed=61))
        assert exc.value.reason == "draining"
        assert req.result(timeout=1) is not None   # drained work finished
    finally:
        srv.close()
    with pytest.raises(AdmissionError) as exc:
        srv.submit(_garnet(48, seed=62))
    assert exc.value.reason == "closed"


def test_submit_rejects_batched_container_and_junk():
    from repro.core import generators, stack_mdps
    stacked = stack_mdps([generators.garnet(n=32, m=3, k=4, seed=s)
                          for s in range(2)])
    with Server(BASE) as srv:
        with pytest.raises(ValueError, match="one MDP per request"):
            srv.submit(MDP(stacked))
        with pytest.raises(TypeError, match="repro.api.MDP"):
            srv.submit("not an mdp")


# --------------------------------------------------------------------------- #
# monitor streams, result lookup, drain
# --------------------------------------------------------------------------- #

def test_monitor_streams_attributed_per_request():
    mdps = [_garnet(48, seed=70 + i) for i in range(4)]
    with Server({**BASE, "-serve_batch_window": 0.25}) as srv:
        reqs = _submit_all(srv, mdps, monitor=True)
        streams = {r.id: list(srv.stream(r)) for r in reqs}
        results = {r.id: r.result(timeout=600) for r in reqs}
        st = srv.stats()

    assert st["dispatches"] == 1           # all four shared one program
    for rid, recs in streams.items():
        assert recs, rid
        # every record carries the submitting request's id and the fleet
        # lane's own residual trajectory, one record per outer iteration;
        # the stream spans the whole bucket's run, so a lane that converged
        # early plateaus at its final residual while bucket-mates finish
        assert all(rec["request"] == rid for rec in recs)
        assert [rec["k"] for rec in recs] == list(range(len(recs)))
        res = np.array([rec["res"] for rec in recs])
        trace = np.asarray(results[rid].trace_residual)
        k = min(len(res), len(trace))
        assert np.array_equal(res[:k], trace[:k]), rid
        assert len(res) >= len(trace) - 1, rid


def test_stream_requires_monitor_flag():
    with Server(BASE) as srv:
        req = srv.submit(_garnet(48, seed=80))
        with pytest.raises(ValueError, match="monitor=True"):
            next(iter(srv.stream(req)))
        req.result(timeout=600)


def test_result_by_id_and_unknown_id():
    with Server(BASE) as srv:
        req = srv.submit(_garnet(48, seed=81))
        res = srv.result(req.id, timeout=600)
        assert res.converged
        with pytest.raises(KeyError, match="unknown"):
            srv.result(10 ** 9)


def test_drain_completes_in_flight_work():
    mdps = [_garnet(48, seed=90 + i) for i in range(5)]
    with Server({**BASE, "-serve_batch_window": 2.0}) as srv:
        reqs = _submit_all(srv, mdps)
        assert srv.drain(timeout=600)      # dispatches without the window
        assert all(r.done for r in reqs)
        assert all(r.result(timeout=1).converged for r in reqs)
        st = srv.stats()
        assert st["queue_depth"] == 0
        assert st["in_flight"] == 0
        assert st["draining"]


def test_close_fails_undispatched_requests():
    srv = Server({**BASE, "-serve_batch_window": 30.0})
    reqs = _submit_all(srv, [_garnet(48, seed=100 + i) for i in range(3)])
    srv.close(timeout=0.05)                # drain times out -> abandon
    failed = 0
    for r in reqs:
        try:
            r.result(timeout=600)
        except AdmissionError as e:
            assert e.reason == "closed"
            failed += 1
    # the scheduler may have dispatched some before the cutoff; whatever
    # was still queued must fail loudly rather than hang
    assert failed + sum(r._error is None for r in reqs) == 3


# --------------------------------------------------------------------------- #
# session-layer satellites: fleet-cache LRU, concurrent jsonl stats
# --------------------------------------------------------------------------- #

def test_lru_cache_eviction_and_counters():
    lru = LRUCache(2)
    assert lru.get("a") is None
    lru.put("a", 1)
    lru.put("b", 2)
    assert lru.get("a") == 1               # refresh 'a'
    assert lru.put("c", 3) == ("b", 2)     # LRU 'b' evicted
    assert lru.get("b") is None
    st = lru.stats()
    assert st == {"size": 2, "capacity": 2, "hits": 1, "misses": 2,
                  "evictions": 1, "hit_rate": 1 / 3}


def test_session_cache_stats_surface():
    # counters live-count in the fleet-sharded path (subprocess test below);
    # here just the surface: the LRU stats dict and the per-entry embedding
    mdps = [_garnet(32, seed=110 + i) for i in range(3)]
    with Session(BASE) as sess:
        sess.solve_fleet(mdps)
        cs = sess.cache_stats
        assert set(cs) == {"fleet", "run_chunk_programs"}
        assert {"size", "capacity", "hits", "misses", "evictions",
                "hit_rate"} <= set(cs["fleet"])
        assert "cache" in sess.stats[-1]["fleet"]


def test_concurrent_jsonl_stats_stay_valid(tmp_path):
    path = tmp_path / "stats.jsonl"
    opts = {**BASE, "-file_stats": str(path),
            "-file_stats_format": "jsonl"}
    mdps = [_garnet(32, seed=120 + i) for i in range(6)]
    with Session(opts) as sess:
        threads = [threading.Thread(target=sess.solve, args=(m,))
                   for m in mdps]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    lines = path.read_text().strip().splitlines()
    assert len(lines) == len(mdps)         # one line per solve, none torn
    entries = [json.loads(ln) for ln in lines]
    assert all(e["solves"][0]["converged"] for e in entries)


# --------------------------------------------------------------------------- #
# fleet-sharded serving (8 forced host devices, subprocess)
# --------------------------------------------------------------------------- #

_FLEET_SCRIPT = r"""
import os, threading
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import json
import numpy as np
from repro.api import MDP, Session
from repro.serve import Server

ns = [120, 180, 120, 180, 120, 120, 180, 120, 180, 120]
mdps = [MDP.from_generator("garnet", n=n, m=4, k=4, gamma=0.95, seed=i)
        for i, n in enumerate(ns)]
base_opts = {"-method": "vi", "-atol": 1e-8, "-dtype": "float64",
             "-verbose": False}

with Server({**base_opts, "-serve_batch_window": 0.5}) as srv:
    mesh, layout = srv.session.placement(fleet_size=8)
    reqs = [None] * len(mdps)
    def client(i):
        reqs[i] = srv.submit(mdps[i])
    ts = [threading.Thread(target=client, args=(i,))
          for i in range(len(mdps))]
    [t.start() for t in ts]
    [t.join() for t in ts]
    results = [r.result(timeout=600) for r in reqs]
    st = srv.stats()

# single-device replicated baseline: the fleet-sharded bitwise reference
# for the elementwise methods (tests/test_fleet.py contract)
with Session({**base_opts, "-layout": "single"}) as sess:
    base = [sess.solve(m) for m in mdps]

out = {
    "devices": jax.device_count(),
    "layout": layout,
    "dispatches": st["dispatches"],
    "completed": st["completed"],
    "bitwise_v": all(np.array_equal(np.asarray(a.v), np.asarray(b.v))
                     for a, b in zip(results, base)),
    "bitwise_pi": all(np.array_equal(np.asarray(a.policy),
                                     np.asarray(b.policy))
                      for a, b in zip(results, base)),
    "outer_eq": all(a.outer_iterations == b.outer_iterations
                    for a, b in zip(results, base)),
    "slots": st["program_cache"]["slots"],
}

# the session fleet-container LRU counts live on the deferred +
# fleet-sharded device-materialization path: same fleet twice -> warm hit
from repro.core.generators import garnet_functions
fmdps = [MDP.from_functions(**garnet_functions(n=160, m=4, k=4,
                                               gamma=0.95, seed=s))
         for s in range(4)]
with Session(base_opts) as s2:
    s2.solve_fleet(fmdps)
    c1 = dict(s2.cache_stats["fleet"])
    s2.solve_fleet(fmdps)
    c2 = dict(s2.cache_stats["fleet"])
    out["fleet_cache_first"] = c1
    out["fleet_cache_second"] = c2
    out["entry_has_cache"] = "cache" in s2.stats[-1]["fleet"]
print("RESULT " + json.dumps(out))
"""


def test_serve_fleet_sharded_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", _FLEET_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert out["devices"] == 8
    assert out["layout"] in ("fleet", "fleet2d")
    assert out["completed"] == 10
    assert out["dispatches"] < 10          # coalesced across clients
    assert out["bitwise_v"] and out["bitwise_pi"] and out["outer_eq"]
    assert out["fleet_cache_first"]["misses"] >= 1
    assert out["fleet_cache_first"]["hits"] == 0
    assert out["fleet_cache_second"]["hits"] >= 1
    assert out["entry_has_cache"]
