"""Optional-hypothesis shim: property tests skip cleanly when the dep is
absent instead of aborting the whole suite at collection.

Usage (instead of ``from hypothesis import given, settings, strategies``)::

    from hypothesis_compat import given, settings, st

With hypothesis installed (see ``requirements-dev.txt``) these are the real
objects; without it, ``@given``-decorated tests call
``pytest.importorskip("hypothesis")`` at run time and report as skipped,
while every non-property test in the module still runs.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``strategies``: any strategy constructor returns a
        placeholder (never drawn from — the test skips first)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            def _skipper(*a, **k):
                pytest.importorskip(
                    "hypothesis",
                    reason="property test needs hypothesis "
                           "(pip install -r requirements-dev.txt)")
            _skipper.__name__ = fn.__name__
            _skipper.__doc__ = fn.__doc__
            return _skipper
        return deco
