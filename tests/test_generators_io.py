"""Generators (distributed determinism) + offline MDP I/O."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import generators
from repro.core.io import load_mdp, save_mdp


@pytest.mark.parametrize("gen,kw", [
    (generators.garnet, dict(n=200, m=6, k=4)),
    (generators.maze2d, dict(size=9)),
    (generators.sis, dict(pop=99)),
    (generators.chain_walk, dict(n=123)),
])
def test_valid_probability_rows(gen, kw):
    gen(**kw).validate()


def test_blockwise_generation_matches_full():
    """Any row-range block must equal the same rows of the full instance
    (the property that lets each device generate only its shard)."""
    full = generators.maze2d(12, seed=3)
    lo, hi = 37, 91
    block = generators.maze2d(12, seed=3, rows=(lo, hi))
    np.testing.assert_array_equal(np.asarray(block.idx),
                                  np.asarray(full.idx)[lo:hi])
    np.testing.assert_array_equal(np.asarray(block.val),
                                  np.asarray(full.val)[lo:hi])
    np.testing.assert_array_equal(np.asarray(block.cost),
                                  np.asarray(full.cost)[lo:hi])


@settings(max_examples=10, deadline=None)
@given(n=st.integers(10, 300), m=st.integers(2, 8), k=st.integers(1, 6),
       seed=st.integers(0, 100))
def test_garnet_property(n, m, k, seed):
    mdp = generators.garnet(n, m, k, seed=seed)
    mdp.validate()
    idx = np.asarray(mdp.idx)
    assert idx.min() >= 0 and idx.max() < n


def test_io_roundtrip(tmp_path):
    mdp = generators.garnet(150, 5, 3, seed=2)
    save_mdp(str(tmp_path / "mdp"), mdp, n_blocks=4)
    back = load_mdp(str(tmp_path / "mdp"))
    np.testing.assert_array_equal(np.asarray(back.idx), np.asarray(mdp.idx))
    np.testing.assert_array_equal(np.asarray(back.val), np.asarray(mdp.val))
    assert back.gamma == mdp.gamma
    # partial (block-aligned worker) read
    part = load_mdp(str(tmp_path / "mdp"), rows=(40, 100))
    np.testing.assert_array_equal(np.asarray(part.idx),
                                  np.asarray(mdp.idx)[40:100])


def test_pipeline_determinism_and_restart():
    from repro.data.pipeline import SyntheticSource
    src = SyntheticSource(vocab_size=100, seq_len=16, global_batch=4, seed=1)
    b1 = src.next_batch(5)
    b2 = src.next_batch(5)          # same step -> identical (restart safety)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = src.next_batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
