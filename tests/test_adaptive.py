"""The adaptive solver driver (ISSUE 10): probe-based method
auto-selection, the stagnation/divergence supervisor with checkpointed
hot-swap, and preconditioned Krylov inner solves.

Covers: probe estimators on a known-spectrum instance (pure self-loops:
observed contraction == gamma exactly), the explainable rule table and its
escalation chain, supervisor patience semantics (isolated f32 residual
plateaus must NOT trigger), hot-swap parity (a diverging Chebyshev solve
resumes under the escalated method and still returns the certified
policy), preconditioned-vs-plain GMRES equality under
``-deterministic_dots``, the sticky ``diverged`` flag, ``-method auto``
through ``Session`` (stats record + per-family choice cache), and the
serve-side ``-serve_deadline_ms`` early dispatch.
"""

import dataclasses
import time

import numpy as np
import pytest

import jax

from repro.adaptive import (ProblemProfile, StagnationSupervisor, escalate,
                            explain, probe, select_method, solve_adaptive)
from repro.adaptive.driver import _rearm_checkpoint
from repro.adaptive.probe import estimate_contraction
from repro.api import MDP, Session
from repro.serve import Server
from repro.core import IPIOptions, generators
from repro.core.driver import solve
from repro.core.ipi import SolveState
from repro.utils import checkpoint as ckpt

jax.config.update("jax_enable_x64", True)


def _core(m):
    return m.core if hasattr(m, "core") else m._core


def selfloop(n=64, gamma=0.9):
    """Every state self-loops under its single action: P = I, so VI's
    residual decays by exactly gamma per iteration — a known spectrum."""
    idx = np.tile(np.arange(n, dtype=np.int32).reshape(n, 1, 1), (1, 1, 3))
    val = np.zeros((n, 1, 3), np.float32)
    val[:, :, 0] = 1.0
    cost = np.ones((n, 1), np.float32)
    return _core(MDP.from_arrays(idx=idx, val=val, cost=cost, gamma=gamma))


def prof(**kw):
    d = dict(n=100_000, gamma=0.9999, iters=8, res0=1.0, res=0.5,
             contraction=0.9999, span_ratio=0.5, converged=False)
    d.update(kw)
    return ProblemProfile(**d)


# --------------------------------------------------------------------------- #
# probe estimators                                                            #
# --------------------------------------------------------------------------- #

def test_probe_contraction_matches_known_spectrum():
    gamma = 0.9
    profile, v_probe = probe(selfloop(gamma=gamma),
                             IPIOptions(method="vi", atol=1e-12),
                             probe_iters=8)
    assert profile.iters == 8
    assert profile.res0 == pytest.approx(1.0)
    # P = I: the observed decay rate IS the discount
    assert profile.contraction == pytest.approx(gamma, abs=5e-3)
    assert not profile.converged
    assert np.asarray(v_probe).shape[-1] == 64


def test_probe_converged_flag_and_warm_start():
    profile, _ = probe(selfloop(gamma=0.9),
                       IPIOptions(method="vi", atol=10.0), probe_iters=4)
    assert profile.converged
    c = select_method(profile)
    assert c.method == "vi" and "probe" in c.reason


def test_estimate_contraction_degenerate_traces():
    assert estimate_contraction(np.array([])) == 0.0
    assert estimate_contraction(np.array([1.0])) == 0.0
    assert estimate_contraction(np.array([1.0, np.nan, np.inf])) == 0.0
    tr = 0.5 ** np.arange(10)
    assert estimate_contraction(tr) == pytest.approx(0.5, abs=1e-6)


# --------------------------------------------------------------------------- #
# rule table + escalation chain                                               #
# --------------------------------------------------------------------------- #

def test_rule_table_selections():
    assert select_method(prof(converged=True)).method == "vi"
    assert select_method(prof(contraction=0.75)).method == "vi"
    assert select_method(prof(contraction=0.85)).method == "mpi"
    assert select_method(prof(contraction=0.99)).method == "mpi"
    span = select_method(prof(span_ratio=0.01))
    assert (span.method, span.stop_criterion) == ("vi", "span")
    # small ill-conditioned instances stay on mpi (Richardson sweeps cross
    # the state space many times over below KRYLOV_MIN_N)
    assert select_method(prof(n=1_000)).method == "mpi"
    hard = select_method(prof())
    assert (hard.method, hard.pc_type) == ("ipi_gmres", "jacobi")
    # jacobi is elementwise, hence legal under deterministic dots too
    det = select_method(prof(), deterministic_dots=True)
    assert (det.method, det.pc_type) == ("ipi_gmres", "jacobi")
    assert hard.reason.startswith("[ill-conditioned]")


def test_explain_marks_first_match():
    text = explain(prof())
    assert "-> ill-conditioned" in text
    assert "no match" in text


def test_escalation_chain():
    nxt = escalate("mpi")
    assert (nxt.method, nxt.pc_type) == ("ipi_gmres", "jacobi")
    nxt = escalate("ipi_gmres")
    assert (nxt.method, nxt.pc_type) == ("ipi_bicgstab", "jacobi")
    assert escalate("ipi_bicgstab").method == "vi"
    assert escalate("vi") is None
    # out-of-chain methods land on the chain head
    assert escalate("ipi_chebyshev").method == "mpi"
    # deterministic chain skips bicgstab (its reductions reorder)
    assert escalate("ipi_gmres", deterministic_dots=True).method == "vi"


# --------------------------------------------------------------------------- #
# supervisor                                                                  #
# --------------------------------------------------------------------------- #

def _info(res, res_prev, k=64, kp=0, div=False):
    return dict(k=k, res=res, k_prev=kp, res_prev=res_prev, diverged=div)


def test_supervisor_patience_requires_consecutive_crawl():
    sup = StagnationSupervisor(0.99, atol=1e-6, patience=2)
    assert not sup(_info(1.0, 1.0))        # first flat chunk: streak of 1
    assert sup(_info(1.0, 1.0))            # second consecutive: trigger
    assert sup.triggered and "stagnation" in sup.reason


def test_supervisor_healthy_chunk_resets_streak():
    sup = StagnationSupervisor(0.99, patience=2)
    assert not sup(_info(1.0, 1.0))
    assert not sup(_info(0.1, 1.0))        # healthy: streak resets
    assert not sup(_info(1.0, 1.0))        # an isolated f32 plateau again
    assert not sup.triggered


def test_supervisor_divergence_immediate_and_atol_guard():
    sup = StagnationSupervisor(0.99, patience=5)
    assert sup(_info(1.0, 1.0, div=True))  # patience does not gate -divtol
    assert "diverged" in sup.reason
    guard = StagnationSupervisor(0.99, atol=1.0, patience=1)
    assert not guard(_info(2.0, 2.0))      # within 4*atol: plateau != stall


# --------------------------------------------------------------------------- #
# guards                                                                      #
# --------------------------------------------------------------------------- #

def test_driver_rejects_virtual_method_and_bad_checkpoint_mode():
    core = generators.chain_walk(64, gamma=0.9)
    with pytest.raises(ValueError, match="virtual"):
        solve(core, IPIOptions(method="auto"))
    with pytest.raises(ValueError, match="checkpoint_mode"):
        solve(core, IPIOptions(method="vi"), checkpoint_mode="bogus")


def test_bjacobi_rejected_under_deterministic_dots():
    with pytest.raises(ValueError, match="bjacobi"):
        IPIOptions(method="ipi_gmres", pc_type="bjacobi",
                   deterministic_dots=True)


# --------------------------------------------------------------------------- #
# preconditioned Krylov                                                       #
# --------------------------------------------------------------------------- #

def test_jacobi_gmres_matches_plain_under_deterministic_dots():
    # garnet: random costs give generic argmin margins far above the
    # certified value gap, so the greedy policy is unique and must agree
    # across inner-solver variants (a chain's near-tied boundary actions
    # would not)
    core = generators.garnet(256, 5, 4, gamma=0.95, seed=3)
    base = dict(atol=1e-5, max_outer=2000, max_inner=256,
                deterministic_dots=True)
    plain = solve(core, IPIOptions(method="ipi_gmres", **base))
    pc = solve(core, IPIOptions(method="ipi_gmres", pc_type="jacobi",
                                **base))
    ref = solve(core, IPIOptions(method="vi", **base))
    assert plain.converged and pc.converged
    assert np.array_equal(pc.policy, ref.policy)
    assert np.array_equal(plain.policy, ref.policy)
    assert pc.residual <= base["atol"] and plain.residual <= base["atol"]
    # right preconditioning keeps stopping semantics: same certificate
    assert np.max(np.abs(pc.v - plain.v)) <= pc.gap_bound + plain.gap_bound


# --------------------------------------------------------------------------- #
# diverged flag + hot-swap parity                                             #
# --------------------------------------------------------------------------- #

def _cheby_opts(**kw):
    # safeguard off: the monotone VI-fallback would otherwise clamp the
    # mis-bracketed Chebyshev iteration into a stall instead of letting it
    # genuinely diverge past -divtol
    d = dict(method="ipi_chebyshev", atol=1e-3, max_outer=3000,
             max_inner=64, divtol=10.0, safeguard=False)
    d.update(kw)
    return IPIOptions(**d)


def test_chebyshev_divergence_sets_sticky_flag():
    core = generators.chain_walk(400, gamma=0.99)
    r = solve(core, _cheby_opts())
    assert r.diverged and not r.converged
    assert "DIVERGED" in r.summary()


def test_hot_swap_resumes_and_certifies():
    core = generators.chain_walk(400, gamma=0.99)
    ref = solve(core, IPIOptions(method="vi", atol=1e-3, max_outer=20_000))
    assert ref.converged
    r, rep = solve_adaptive(core, _cheby_opts())
    assert r.converged and not r.diverged
    # the certificate, not bitwise policy: chain boundary actions are
    # near-tied within the gap bound, so assert value agreement within the
    # summed certified gaps and policy agreement away from the ties
    assert np.max(np.abs(r.v - ref.v)) <= r.gap_bound + ref.gap_bound
    assert np.mean(r.policy == ref.policy) >= 0.95
    assert rep.methods[0] == "ipi_chebyshev" and len(rep.methods) >= 2
    assert rep.swaps and rep.swaps[0]["from_method"] == "ipi_chebyshev"
    # the swap resumed the checkpointed state, not a fresh solve
    assert rep.swaps[0]["resumed"] or "NaN" not in rep.swaps[0]["reason"]


# --------------------------------------------------------------------------- #
# checkpoint re-arm                                                           #
# --------------------------------------------------------------------------- #

def _state(nan=False, res=0.5, res0=0.1):
    v = np.full(8, np.nan if nan else 1.0, np.float32)
    return SolveState(
        v=v, tv=v.copy(), pi=np.zeros(8, np.int32), res=np.float32(res),
        k=np.int32(10), inner_total=np.int32(0),
        trace_res=np.zeros(4, np.float32),
        trace_inner=np.zeros(4, np.int32), res0=np.float32(res0),
        span=np.float32(0.0), done=np.bool_(False),
        diverged=np.bool_(True), n_true=np.int32(8),
        win=np.zeros(0, np.float32))


def test_rearm_clears_diverged_and_resets_res0(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 10, _state(), meta={})
    assert _rearm_checkpoint(d)
    tree, step, _ = ckpt.restore(d, _state())
    assert step == 10
    assert not bool(np.asarray(tree.diverged))
    # res0 re-arms at the resume-point residual so -divtol measures anew
    assert float(tree.res0) == pytest.approx(0.5)


def test_rearm_discards_nan_state(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 10, _state(nan=True), meta={})
    assert not _rearm_checkpoint(d)
    assert ckpt.latest_step(d) is None     # poisoned files were removed
    assert not _rearm_checkpoint(str(tmp_path / "missing"))


# --------------------------------------------------------------------------- #
# Session integration: -method auto                                           #
# --------------------------------------------------------------------------- #

def test_session_auto_records_choice_and_caches_probe():
    m = MDP.from_generator("chain_walk", n=256, gamma=0.99)
    with Session({"-atol": 1e-3, "-max_outer": 2000}) as s:
        r1 = s.solve(m, method="auto")
        a1 = s.stats[-1]["adaptive"]
        assert r1.converged
        assert a1["profile"] is not None
        assert a1["choice"]["method"] in ("vi", "mpi")
        assert a1["choice"]["reason"]
        assert s.stats[-1]["solves"][0]["diverged"] is False
        r2 = s.solve(m, method="auto")
        a2 = s.stats[-1]["adaptive"]
        # same (n, m, gamma, mode) family: the cached choice skips the probe
        assert a2["profile"] is None
        assert a2["choice"]["method"] == a1["choice"]["method"]
        assert np.array_equal(r1.policy, r2.policy)


def test_session_fleet_auto_resolves_per_bucket():
    mdps = [MDP.from_generator("chain_walk", n=128, gamma=0.95),
            MDP.from_generator("chain_walk", n=128, gamma=0.95)]
    with Session({"-atol": 1e-4, "-max_outer": 2000}) as s:
        rs = s.solve_fleet(mdps, method="auto")
        assert all(r.converged for r in rs)
        auto = s.stats[-1]["fleet"]["auto"]
        assert auto and auto[0]["method"] != "auto"
        assert auto[0]["reason"]


# --------------------------------------------------------------------------- #
# serve deadline                                                              #
# --------------------------------------------------------------------------- #

def test_serve_deadline_preempts_batch_window():
    m = MDP.from_generator("garnet", n=48, m=3, k=4, gamma=0.9, seed=0)
    base = {"-method": "vi", "-atol": 1e-6,
            "-serve_batch_window": 5.0, "-serve_deadline_ms": 100.0}
    with Server(base) as srv:
        t0 = time.monotonic()
        r = srv.submit(m).result(timeout=120)
        elapsed = time.monotonic() - t0
    assert r.converged
    # the 100 ms deadline must cut the 5 s linger well short
    assert elapsed < 2.5
