"""Pallas kernels (interpret mode) vs the pure-jnp oracles in ref.py.

Sweeps shapes and dtypes per kernel; hypothesis drives random shape/content
cases on top of the fixed grid.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels import bellman_ell, dense_backup, spmv_ell
from repro.kernels import ops


def _ell(rng, n, m, k, ncols, dtype):
    idx = rng.integers(0, ncols, (n, m, k)).astype(np.int32)
    val = rng.random((n, m, k)).astype(dtype)
    cost = rng.random((n, m)).astype(dtype)
    v = rng.random(ncols).astype(dtype)
    return (jnp.asarray(idx), jnp.asarray(val), jnp.asarray(cost),
            jnp.asarray(v))


@pytest.mark.parametrize("n,m,k,ncols", [
    (8, 2, 1, 16), (100, 5, 4, 100), (257, 7, 8, 333), (512, 3, 2, 64)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_ell_backup_matches_ref(n, m, k, ncols, dtype):
    rng = np.random.default_rng(0)
    idx, val, cost, v = _ell(rng, n, m, k, ncols, dtype)
    a, b = bellman_ell.ell_backup(idx, val, cost, 0.9, v, interpret=True)
    ra, rb = ref.ell_backup(idx, val, cost, 0.9, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ra), rtol=3e-6)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(rb))


@pytest.mark.parametrize("n,k,ncols", [(8, 1, 8), (100, 4, 55), (300, 8, 300)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_spmv_matches_ref(n, k, ncols, dtype):
    rng = np.random.default_rng(1)
    idx = jnp.asarray(rng.integers(0, ncols, (n, k)).astype(np.int32))
    val = jnp.asarray(rng.random((n, k)).astype(dtype))
    x = jnp.asarray(rng.random(ncols).astype(dtype))
    y = spmv_ell.ell_matvec(idx, val, x, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.ell_matvec(idx, val, x)),
                               rtol=3e-6)


@pytest.mark.parametrize("n,m,ncols", [(8, 2, 8), (64, 4, 200), (130, 3, 700)])
def test_dense_backup_matches_ref(n, m, ncols):
    rng = np.random.default_rng(2)
    p = rng.random((n, m, ncols)).astype(np.float32)
    p /= p.sum(-1, keepdims=True)
    cost = jnp.asarray(rng.random((n, m)).astype(np.float32))
    v = jnp.asarray(rng.random(ncols).astype(np.float32))
    a, b = dense_backup.dense_backup(jnp.asarray(p), cost, 0.9, v,
                                     interpret=True)
    ra, rb = ref.dense_backup(jnp.asarray(p), cost, 0.9, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ra), rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(rb))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 64), m=st.integers(1, 8), k=st.integers(1, 6),
       ncols=st.integers(1, 80), gamma=st.floats(0.1, 0.999),
       seed=st.integers(0, 999))
def test_ell_backup_property(n, m, k, ncols, gamma, seed):
    rng = np.random.default_rng(seed)
    idx, val, cost, v = _ell(rng, n, m, k, ncols, np.float32)
    a, b = bellman_ell.ell_backup(idx, val, cost, gamma, v, interpret=True)
    ra, rb = ref.ell_backup(idx, val, cost, gamma, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ra), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(rb))


def test_ops_dispatch_consistency():
    """ops.* must give identical results across implementations."""
    rng = np.random.default_rng(3)
    idx, val, cost, v = _ell(rng, 64, 4, 3, 64, np.float32)
    out_x = ops.ell_backup(idx, val, cost, 0.95, v, impl="xla")
    out_p = ops.ell_backup(idx, val, cost, 0.95, v, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out_x[0]), np.asarray(out_p[0]),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out_x[1]), np.asarray(out_p[1]))


def test_argmin_tiebreak_smallest_action():
    """Deterministic tie-break: duplicate optimal actions -> smallest id."""
    n, m, k, ncols = 16, 4, 2, 16
    idx = jnp.zeros((n, m, k), jnp.int32)
    val = jnp.ones((n, m, k), jnp.float32) / k
    cost = jnp.ones((n, m), jnp.float32)       # all actions identical
    v = jnp.zeros((ncols,), jnp.float32)
    _, pi = bellman_ell.ell_backup(idx, val, cost, 0.9, v, interpret=True)
    assert (np.asarray(pi) == 0).all()
