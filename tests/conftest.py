import jax

# PETSc (madupite's substrate) is double precision; the MDP solver tests
# exercise the f64 path.  LM modules are dtype-explicit so this is safe.
# NOTE: deliberately NOT setting xla_force_host_platform_device_count here —
# smoke tests and benches must see 1 device (the dry-run sets 512 itself).
jax.config.update("jax_enable_x64", True)
