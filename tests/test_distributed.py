"""Distributed solve correctness: multi-(fake-)device == single device.

Runs the real shard_map path on 8 forced host devices in a subprocess
(device count must be set before jax initializes, so these tests shell out).
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, json
from repro.core import generators, solve, IPIOptions

mdp = generators.garnet(n=997, m=11, k=6, gamma=0.99, seed=7)
opts = IPIOptions(method="ipi_gmres", atol=1e-8, dtype="float64")
r_single = solve(mdp, opts)
out = {}
from repro.launch.mesh import mesh_kwargs
mesh = jax.make_mesh((4, 2), ("data", "model"), **mesh_kwargs(2))
for layout in ("1d", "2d"):
    r = solve(mdp, opts, mesh=mesh, layout=layout)
    out[layout] = dict(
        dv=float(np.abs(r.v - r_single.v).max()),
        dpi=int((r.policy != r_single.policy).sum()),
        converged=bool(r.converged),
        outer=int(r.outer_iterations),
        outer_single=int(r_single.outer_iterations))
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("layout", ["1d", "2d"])
def test_distributed_matches_single_device(dist_results, layout):
    r = dist_results[layout]
    assert r["converged"]
    assert r["dv"] < 1e-10, r
    assert r["dpi"] == 0, r
    assert r["outer"] == r["outer_single"], "iteration path must be identical"
