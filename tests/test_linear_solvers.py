"""Inner (Krylov/Richardson) solvers vs numpy LU, incl. hypothesis sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.comm import Axes
from repro.core.solvers import anderson, bicgstab, chebyshev, gmres, \
    richardson

AXES = Axes()


def _mdp_like_system(n, gamma, seed):
    """A = I - gamma * P with P row-stochastic: the exact structure the
    inner solvers face (nonsymmetric, diagonally dominant for gamma < 1)."""
    rng = np.random.default_rng(seed)
    p = rng.random((n, n))
    p /= p.sum(1, keepdims=True)
    a = np.eye(n) - gamma * p
    b = rng.random(n)
    return a, b


@pytest.mark.parametrize("solver,kw", [
    (gmres, dict(restart=25)), (bicgstab, {}), (richardson, {}),
    (anderson, dict(window=5))])
@pytest.mark.parametrize("gamma", [0.5, 0.95, 0.999])
def test_solves_mdp_system(solver, kw, gamma):
    a, b = _mdp_like_system(150, gamma, seed=1)
    x_true = np.linalg.solve(a, b)
    aj = jnp.asarray(a)
    maxiter = 200000 if solver is richardson else 5000
    x, iters, res = solver(lambda v: aj @ v, jnp.asarray(b),
                           jnp.zeros(150, jnp.float64), tol=1e-10,
                           maxiter=maxiter, axes=AXES, **kw)
    assert float(res) <= 1e-10
    np.testing.assert_allclose(np.asarray(x), x_true, atol=1e-8)


@pytest.mark.parametrize("gamma", [0.5, 0.9])
def test_chebyshev_solves_mdp_system(gamma):
    """Chebyshev on [1-gamma, 1+gamma]: exact where the (near-)real-spectrum
    assumption holds (bulk eigenvalues of the dense random P are tiny at
    moderate gamma; the gamma -> 1 complex-bulk regime is covered by the
    divergence-guard test below)."""
    a, b = _mdp_like_system(150, gamma, seed=1)
    x_true = np.linalg.solve(a, b)
    aj = jnp.asarray(a)
    x, iters, res = chebyshev(lambda v: aj @ v, jnp.asarray(b),
                              jnp.zeros(150, jnp.float64), tol=1e-10,
                              maxiter=5000, axes=AXES,
                              lo=1 - gamma, hi=1 + gamma)
    assert float(res) <= 1e-10
    assert int(iters) < 5000
    np.testing.assert_allclose(np.asarray(x), x_true, atol=1e-8)


def test_chebyshev_divergence_guard_bails_early():
    """On a spectrum far outside the target interval the residual grows;
    the PETSc-style divtol must stop the sweep long before maxiter so the
    outer safeguard gets a cheap rejection."""
    rng = np.random.default_rng(0)
    q, _ = np.linalg.qr(rng.random((40, 40)))
    # eigenvalues on a ring of radius 1 around 1: worst case for the
    # interval iteration
    ang = np.linspace(0, 2 * np.pi, 20, endpoint=False)
    blocks = [np.array([[1 + np.cos(t), -np.sin(t)],
                        [np.sin(t), 1 + np.cos(t)]]) for t in ang]
    a = q @ (np.kron(np.eye(20), np.zeros((2, 2))) +
             np.block([[blocks[i] if i == j else np.zeros((2, 2))
                        for j in range(20)] for i in range(20)])) @ q.T
    aj = jnp.asarray(a)
    b = jnp.asarray(rng.random(40))
    x, iters, res = chebyshev(lambda v: aj @ v, b,
                              jnp.zeros(40, jnp.float64), tol=1e-12,
                              maxiter=100000, axes=AXES, lo=0.9, hi=1.1,
                              divtol=1e4)
    assert int(iters) < 100000    # bailed out, did not spin to the cap


@settings(max_examples=25, deadline=None)
@given(n=st.integers(5, 60), gamma=st.floats(0.1, 0.99),
       seed=st.integers(0, 10_000))
def test_gmres_property(n, gamma, seed):
    """For any row-stochastic P and gamma<1, GMRES solves (I-gamma P)x=b."""
    a, b = _mdp_like_system(n, gamma, seed)
    aj = jnp.asarray(a)
    x, _, res = gmres(lambda v: aj @ v, jnp.asarray(b),
                      jnp.zeros(n, jnp.float64), tol=1e-9, maxiter=2000,
                      axes=AXES, restart=min(n, 30))
    true_res = np.linalg.norm(b - a @ np.asarray(x))
    assert true_res <= 1e-6 * max(1.0, np.linalg.norm(b))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(5, 60), gamma=st.floats(0.1, 0.99),
       seed=st.integers(0, 10_000))
def test_bicgstab_property(n, gamma, seed):
    a, b = _mdp_like_system(n, gamma, seed)
    aj = jnp.asarray(a)
    x, _, res = bicgstab(lambda v: aj @ v, jnp.asarray(b),
                         jnp.zeros(n, jnp.float64), tol=1e-9, maxiter=4000,
                         axes=AXES)
    true_res = np.linalg.norm(b - a @ np.asarray(x))
    assert true_res <= 1e-6 * max(1.0, np.linalg.norm(b))


def test_gmres_zero_rhs():
    aj = jnp.eye(10, dtype=jnp.float64)
    x, iters, res = gmres(lambda v: aj @ v, jnp.zeros(10, jnp.float64),
                          jnp.zeros(10, jnp.float64), tol=1e-12, maxiter=10,
                          axes=AXES, restart=5)
    assert float(res) == 0.0 and np.asarray(x).max() == 0.0


def test_warm_start_exact_solution_is_noop():
    a, b = _mdp_like_system(40, 0.9, seed=3)
    x_true = np.linalg.solve(a, b)
    aj = jnp.asarray(a)
    for solver, kw in [(gmres, dict(restart=10)), (bicgstab, {}),
                       (richardson, {})]:
        x, iters, res = solver(lambda v: aj @ v, jnp.asarray(b),
                               jnp.asarray(x_true), tol=1e-8, maxiter=100,
                               axes=AXES, **kw)
        assert int(iters) == 0, solver.__name__
