"""Tile-autotuner cache lifecycle and the XLA flag bundles.

The tuner is trace-time Python: the dispatch layer asks it for a tile
choice while building a jaxpr, and the answer must be stable across
processes (persisted JSON), survive a corrupted cache file, and be fully
inert under ``-kernel_tune off``.
"""

import json
import os
import warnings

import pytest

from repro.kernels import tuning
from repro.utils import xla_flags


@pytest.fixture(autouse=True)
def _isolated_tuner(tmp_path):
    """Every test runs against its own cache file and leaves the
    process-wide tuner state as it found it."""
    prev_enabled, prev_path = tuning.enabled(), tuning.cache_path()
    tuning.reset(cache_path=str(tmp_path / "autotune.json"))
    yield
    tuning.reset(cache_path=prev_path)
    tuning.configure(enabled=prev_enabled)


# A shape comfortably above MIN_TUNE_ELEMS so tune() actually measures.
BIG = dict(n=1 << 20, m=4, k=4)


def _tune(bench, *, candidates=(8, 16, 32), default=16, **over):
    kw = dict(BIG, **over)
    return tuning.tune("ell_backup_blocked", "cpu", kw["n"], kw["m"],
                       kw["k"], "float32", candidates, default, bench)


def test_round_trip_persists_and_reloads():
    calls = []

    def bench(cand):
        calls.append(cand)
        return {8: 3.0, 16: 1.0, 32: 2.0}[cand]

    assert _tune(bench) == 16
    assert calls, "bench was never invoked"
    # same key again: served from memory, no re-measurement
    calls.clear()
    assert _tune(bench) == 16
    assert not calls
    # a fresh process (reset) with the same cache file: served from disk
    path = tuning.cache_path()
    assert os.path.exists(path)
    tuning.reset(cache_path=path)
    assert _tune(bench) == 16
    assert not calls
    blob = json.load(open(path))
    [entry] = blob["entries"].values()
    assert entry["choice"] == 16
    assert set(entry["timings_s"]) == {"8", "16", "32"}


def test_n_bucket_shares_entries_across_close_sizes():
    assert tuning.n_bucket(1) == 1
    assert tuning.n_bucket(1000) == 1024
    assert tuning.n_bucket(1024) == 1024
    assert tuning.n_bucket(1025) == 2048
    k1 = tuning.cache_key("k", "cpu", 900_000, 4, 4, "float32")
    k2 = tuning.cache_key("k", "cpu", 1_000_000, 4, 4, "float32")
    assert k1 == k2
    assert k1 != tuning.cache_key("k", "cpu", 2_000_000, 4, 4, "float32")


def test_corrupt_cache_file_recovers(tmp_path):
    path = tuning.cache_path()
    with open(path, "w") as f:
        f.write("{not json")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert tuning.lookup("whatever") is None
        assert tuning.lookup("whatever") is None  # warns only once
    assert sum("unreadable" in str(x.message) for x in w) == 1
    # the next successful tune overwrites the corrupt file
    assert _tune(lambda c: float(c)) == 8
    assert json.load(open(path))["entries"]


def test_disabled_returns_default_and_writes_nothing():
    tuning.configure(enabled=False)
    calls = []
    assert _tune(lambda c: calls.append(c) or 1.0, default=42) == 42
    assert not calls
    assert not os.path.exists(tuning.cache_path())


def test_small_problem_skips_measurement():
    calls = []
    got = _tune(lambda c: calls.append(c) or 1.0, n=128, m=4, k=4,
                default=99)
    assert got == 99 and not calls


def test_tune_inside_trace_falls_back_to_default():
    """When the dispatch layer is traced inside an enclosing jit, the tuner
    must not try to time candidates (they would be staged into the trace) —
    it returns the default and records nothing, so a later eager call can
    still tune the shape."""
    import jax

    calls = []

    def traced(x):
        got = _tune(lambda c: calls.append(c) or 1.0, default=16)
        return x * got

    assert float(jax.jit(traced)(2.0)) == 32.0
    assert not calls
    assert not os.path.exists(tuning.cache_path())
    # eager call afterwards tunes for real
    assert _tune(lambda c: {8: 3.0, 16: 2.0, 32: 1.0}[c]) == 32
    assert os.path.exists(tuning.cache_path())


def test_failing_candidate_is_skipped():
    def bench(cand):
        if cand == 8:
            raise RuntimeError("boom")
        return {16: 2.0, 32: 1.0}[cand]

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert _tune(bench) == 32


def test_session_options_drive_tuner(tmp_path):
    from repro.api import Session

    path = str(tmp_path / "elsewhere.json")
    with Session({"-kernel_tune": "off", "-kernel_tune_cache": path}):
        assert tuning.enabled() is False
        assert tuning.cache_path() == path
    with Session({"-kernel_tune": "on"}):
        assert tuning.enabled() is True


# --------------------------------------------------------------------------- #
# XLA flag bundles                                                            #
# --------------------------------------------------------------------------- #

def test_bundles_render_and_merge_idempotently():
    for name in xla_flags.bundle_names():
        rendered = xla_flags.render(name)
        assert all(tok.startswith("--") and "=" in tok
                   for tok in rendered.split())
    merged = xla_flags.merged_flags("cpu-single", "--foo=bar")
    assert merged.startswith("--foo=bar")
    for flag, value in xla_flags.bundle("cpu-single").items():
        assert f"--{flag}={value}" in merged
    # re-merging replaces the bundle's own tokens instead of duplicating them
    again = xla_flags.merged_flags("cpu-single", merged)
    assert again.split().count("--foo=bar") == 1
    assert len(again.split()) == len(merged.split())


def test_unknown_bundle_raises_with_available_names():
    with pytest.raises(KeyError, match="cpu-single"):
        xla_flags.bundle("no-such-bundle")


def test_apply_bundle_sets_env():
    env = {"XLA_FLAGS": "--keep=me"}
    xla_flags.apply_bundle("cpu-host", env=env)
    assert "--keep=me" in env["XLA_FLAGS"]
    for flag, value in xla_flags.bundle("cpu-host").items():
        assert f"--{flag}={value}" in env["XLA_FLAGS"]


def test_session_applies_bundle_option():
    from repro.api import Session

    # with the backend already initialized, Session must warn (flags cannot
    # take effect in this process) yet still set the env var
    import jax

    jax.devices()
    prev = os.environ.get("XLA_FLAGS")
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            with Session({"-xla_flag_bundle": "cpu-host"}):
                pass
        assert any("backend" in str(x.message).lower() for x in w)
        for flag, value in xla_flags.bundle("cpu-host").items():
            assert f"--{flag}={value}" in os.environ.get("XLA_FLAGS", "")
    finally:
        if prev is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = prev
