"""Cross-layout / cross-impl parity of full solves (multi-device leg).

Two end-to-end invariants of the tiled-kernel rewrite:

* **impl invariance under sharding** — the same fleet solved under the
  ``xla``, ``blocked`` and ``pallas_interpret`` kernel implementations must
  produce identical policies (and bit-identical values: every impl pins
  the same rounding, see :mod:`repro.kernels.ref`), on the replicated, 1d
  and fleet layouts alike;
* **anderson deterministic dots** — with ``deterministic_dots=True`` the
  Anderson inner solver composes its Gram/projection/combine reductions
  lane-at-a-time (like deterministic GMRES), so a fleet-sharded solve is
  bit-for-bit equal to the replicated layout at matched state-shard count.

Runs only when the process already has multiple devices (the CI
multidevice leg forces 8 host devices); single-device runs are covered by
tests/test_kernels_tiled.py.
"""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >=4 devices (CI forces 8 host devices)")


def _mdps():
    from repro.core import generators

    return [generators.garnet(n=120, m=5, k=4, gamma=0.95, seed=s)
            for s in range(5)]


def _bitequal_results(rs, base, *, label):
    for a, b in zip(rs, base):
        np.testing.assert_array_equal(a.policy, b.policy, err_msg=label)
        np.testing.assert_array_equal(
            np.asarray(a.v).view(np.uint8), np.asarray(b.v).view(np.uint8),
            err_msg=label)
        assert a.outer_iterations == b.outer_iterations, label
        assert np.array_equal(a.trace_residual, b.trace_residual,
                              equal_nan=True), label


@multidevice
@pytest.mark.parametrize("method", ["vi", "ipi_gmres"])
def test_fleet_solve_impl_invariant(method):
    from repro.core import IPIOptions
    from repro.core.driver import solve_many
    from repro.launch.mesh import make_fleet_mesh

    mdps = _mdps()
    mesh = make_fleet_mesh(4)
    results = {}
    for impl in ("xla", "blocked", "pallas_interpret"):
        opts = IPIOptions(method=method, atol=1e-8, dtype="float64",
                          impl=impl, max_outer=20000)
        rs = solve_many(mdps, opts, mesh=mesh, layout="fleet")
        assert all(r.converged for r in rs), impl
        results[impl] = rs
    base = results["xla"]
    for impl, rs in results.items():
        _bitequal_results(rs, base, label=f"{method}/{impl}")


@multidevice
def test_1d_sharded_solve_impl_invariant():
    from repro.core import IPIOptions, generators
    from repro.core.driver import solve
    from repro.launch.mesh import make_host_mesh

    mdp = generators.garnet(n=240, m=5, k=4, gamma=0.95, seed=1)
    mesh = make_host_mesh((4, 1))
    results = {}
    for impl in ("xla", "blocked", "pallas_interpret"):
        r = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-8,
                                  dtype="float64", impl=impl,
                                  max_outer=20000),
                  mesh=mesh, layout="1d")
        assert r.converged, impl
        results[impl] = r
    base = results["xla"]
    for impl, r in results.items():
        _bitequal_results([r], [base], label=impl)


@multidevice
def test_anderson_fleet_matches_replicated_bitwise():
    """deterministic_dots pins every Anderson reduction order, so the
    fleet-sharded solve is bit-equal to the replicated baseline at matched
    state-shard count (both shard states 2-way; only the fleet-lane
    batching differs)."""
    from repro.core import IPIOptions
    from repro.core.driver import solve_many
    from repro.launch.mesh import make_fleet_mesh, make_host_mesh

    mdps = _mdps()
    opts = IPIOptions(method="ipi_anderson", atol=1e-8, dtype="float64",
                      max_outer=20000, deterministic_dots=True)
    base = solve_many(mdps, opts, mesh=make_host_mesh((2, 1)), layout="1d")
    fleet = solve_many(mdps, opts, mesh=make_fleet_mesh(4), layout="fleet")
    assert all(r.converged for r in base)
    _bitequal_results(fleet, base, label="anderson/fleet")


@multidevice
def test_anderson_deterministic_still_converges_plain():
    """Sanity: deterministic composition changes only the reduction order,
    not the mathematics — plain replicated solves still reach the optimum
    and report the same iteration counts as the default composition."""
    from repro.core import IPIOptions
    from repro.core.driver import solve_many

    mdps = _mdps()
    det = solve_many(mdps, IPIOptions(method="ipi_anderson", atol=1e-8,
                                      dtype="float64", max_outer=20000,
                                      deterministic_dots=True))
    plain = solve_many(mdps, IPIOptions(method="ipi_anderson", atol=1e-8,
                                        dtype="float64", max_outer=20000))
    for a, b in zip(det, plain):
        assert a.converged and b.converged
        np.testing.assert_array_equal(a.policy, b.policy)
        np.testing.assert_allclose(a.v, b.v, rtol=0, atol=1e-9)
