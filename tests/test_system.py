"""End-to-end behaviour tests for the whole system (paper workflow)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IPIOptions, generators, solve


def test_end_to_end_epidemic_control():
    """The paper's target workflow: model a control problem as an MDP, solve
    it with a tailored method, get a certified policy."""
    mdp = generators.sis(pop=300, n_actions=5, gamma=0.99)
    r = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-8,
                              dtype="float64"))
    assert r.converged
    # certified optimality gap
    assert r.gap_bound < 1e-5
    # sanity of the control law: at tiny infection levels strong (costly)
    # interventions cannot be optimal under these costs
    assert r.policy[0] == 0


def test_method_choice_matters():
    """madupite's raison d'etre: no single method dominates; the user-
    selectable inner solver wins on conditioning-limited instances."""
    hard = generators.chain_walk(n=400, gamma=0.9995)
    r_mpi = solve(hard, IPIOptions(method="mpi", mpi_sweeps=50, atol=1e-6,
                                   max_outer=3000, dtype="float64"))
    r_gm = solve(hard, IPIOptions(method="ipi_gmres", atol=1e-6,
                                  max_outer=100, dtype="float64"))
    assert r_gm.converged
    total_mpi = r_mpi.outer_iterations * 50 + r_mpi.inner_iterations
    assert r_gm.inner_iterations < total_mpi / 3


def test_lm_training_reduces_loss():
    """Substrate end-to-end: 30 steps on a reduced arch reduce the loss."""
    from repro.configs import get_smoke_config, get_train_config
    from repro.data.pipeline import SyntheticSource
    from repro.models import build_model
    from repro.train.optimizer import init_opt_state
    from repro.train.steps import make_train_step

    import dataclasses
    cfg = get_smoke_config("stablelm-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # higher lr than the production config: 30 memorization steps must bite
    # through the lr warmup
    tcfg = dataclasses.replace(get_train_config("stablelm-3b"),
                               learning_rate=3e-2)
    src = SyntheticSource(cfg.vocab_size, 32, 8, seed=0)
    step_fn = jax.jit(make_train_step(model, tcfg, n_microbatches=2))
    opt = init_opt_state(params, tcfg)
    losses = []
    # fixed batch -> loss must drop steadily (memorization sanity)
    batch = src.next_batch(0)
    for step in range(30):
        params, opt, m = step_fn(params, opt, jnp.int32(step), batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_solve_cli(tmp_path):
    from repro.launch.solve import main
    rc = main(["--instance", "maze2d", "--size", "16", "--method",
               "ipi_bicgstab", "--atol", "1e-7", "--single-device",
               "--ckpt-dir", str(tmp_path / "ck")])
    assert rc == 0


def test_train_cli(tmp_path):
    from repro.launch.train import main
    rc = main(["--arch", "mamba2-130m", "--smoke", "--steps", "6",
               "--batch", "4", "--seq", "32", "--ckpt-dir",
               str(tmp_path / "t"), "--ckpt-every", "3"])
    assert rc == 0
    # restart from checkpoint
    rc = main(["--arch", "mamba2-130m", "--smoke", "--steps", "8",
               "--batch", "4", "--seq", "32", "--ckpt-dir",
               str(tmp_path / "t")])
    assert rc == 0


def test_serve_cli():
    from repro.launch.serve import main
    rc = main(["--requests", "6", "--instance", "garnet",
               "--n-choices", "48,64", "--m", "4", "--k", "4",
               "--rate", "200", "--window", "0.05",
               "--option", "method=vi", "--option", "atol=1e-6"])
    assert rc == 0


def test_serve_cli_workload_file(tmp_path):
    import json

    from repro.launch.serve import main
    wl = tmp_path / "wl.jsonl"
    wl.write_text("\n".join(json.dumps(s) for s in [
        {"instance": "garnet", "n": 48, "m": 4, "k": 4, "seed": 1,
         "gamma": 0.9, "monitor": True},
        {"instance": "garnet", "n": 48, "m": 4, "k": 4, "seed": 2,
         "gamma": 0.9},
        {"instance": "garnet", "n": 64, "m": 4, "k": 4, "seed": 3,
         "gamma": 0.9, "overrides": {"-atol": 1e-6}},
    ]) + "\n")
    rc = main(["--workload", str(wl), "--rate", "0", "--window", "0.05",
               "--option", "method=vi"])
    assert rc == 0


def test_serve_lm_example():
    import importlib.util
    import pathlib
    path = pathlib.Path(__file__).resolve().parents[1] / "examples" \
        / "serve_lm.py"
    spec = importlib.util.spec_from_file_location("serve_lm_example", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    rc = mod.main(["--arch", "olmoe-1b-7b", "--smoke", "--batch", "2",
                   "--prompt-len", "16", "--gen", "4"])
    assert rc == 0
