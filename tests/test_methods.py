"""The pluggable method registry, stopping criteria, and monitors (ISSUE 5).

Covers: user-registered KSPs round-tripping through env/CLI option
ingestion, live-registry validation with difflib suggestions, the new
builtin inner solvers as outer methods, span/rtol/custom stopping
criteria, monitor record streaming, jsonl stats streaming, and README
table sync (registry = single source of truth)."""

import json
import os

import numpy as np
import pytest

import jax

from repro.api import (MDP, Options, OptionTypeError, Session, method_names,
                       method_table, ksp_names, ksp_table, option_table,
                       register_ksp, register_method,
                       register_stop_criterion, stop_names, stop_table,
                       unregister_ksp, unregister_method,
                       unregister_stop_criterion)
from repro.core import IPIOptions, generators, methods
from repro.core.driver import solve
from repro.core.solvers import richardson

jax.config.update("jax_enable_x64", True)

README = os.path.join(os.path.dirname(__file__), "..", "README.md")


@pytest.fixture
def garnet():
    return generators.garnet(n=120, m=5, k=4, gamma=0.95, seed=0)


# --------------------------------------------------------------------------- #
# Registry basics                                                             #
# --------------------------------------------------------------------------- #

def test_builtin_registries():
    for m in ("vi", "mpi", "ipi_richardson", "ipi_gmres", "ipi_bicgstab",
              "pi", "ipi_chebyshev", "ipi_anderson"):
        assert m in method_names()
    for k in ("richardson", "gmres", "bicgstab", "chebyshev", "anderson"):
        assert k in ksp_names()
    assert set(stop_names(builtin_only=True)) >= {"atol", "rtol", "span"}


def test_register_ksp_user_solver_selectable_everywhere(garnet):
    """A user KSP registered once is selectable from Python overrides, the
    MADUPITE_OPTIONS environment and --option CLI ingestion, and matches
    the reference solution."""
    def myrich(matvec, b, x0, *, tol, maxiter, axes):
        return richardson(matvec, b, x0, tol=tol, maxiter=maxiter,
                          axes=axes, omega=0.9)

    register_ksp("myrich", myrich)
    try:
        # live options validation: the auto-method is selectable
        assert "ipi_myrich" in method_names()
        env = Options.from_sources(env={"MADUPITE_OPTIONS":
                                        "-ksp_type myrich"})
        assert env.to_ipi().method == "ipi_myrich"
        cli = Options().ingest_cli(["ksp_type=myrich"])
        assert cli.to_ipi().method == "ipi_myrich"
        with Session({"-dtype": "float64", "-layout": "single"}) as s:
            r = s.solve(garnet, ksp_type="myrich", atol=1e-9)
            ref = s.solve(garnet, method="ipi_gmres", atol=1e-9)
        assert r.converged
        np.testing.assert_allclose(r.v, ref.v, atol=1e-7)
        np.testing.assert_array_equal(r.policy, ref.policy)
    finally:
        unregister_ksp("myrich")
    assert "ipi_myrich" not in method_names()
    with pytest.raises(OptionTypeError):
        Options({"-ksp_type": "myrich"})


def test_register_method_custom_policy(garnet):
    """register_method composes an existing KSP with a different inner
    policy (here: near-exact PI on richardson sweeps)."""
    register_method("my_pi", ksp="richardson", inner="tight",
                    safeguarded=False)
    try:
        r = solve(garnet, IPIOptions(method="my_pi", atol=1e-8,
                                     dtype="float64", max_inner=10000))
        assert r.converged
    finally:
        unregister_method("my_pi")


def test_registry_duplicate_and_builtin_guards():
    with pytest.raises(ValueError, match="builtin"):
        register_ksp("gmres", lambda *a, **k: None)
    with pytest.raises(ValueError, match="builtin"):
        unregister_method("vi")
    with pytest.raises(ValueError, match="inner"):
        register_method("broken", ksp=None, inner="forcing")
    with pytest.raises(ValueError, match="unknown ksp"):
        register_method("broken", ksp="nope", inner="forcing")


def test_overwrite_reregistration_clears_compiled_caches(garnet):
    """Hot-swapping a KSP with overwrite=True must retrace: registry
    lookups happen at trace time, so a stale compiled program would keep
    running the old solver."""
    def fn_a(mv, b, x0, *, tol, maxiter, axes):
        return richardson(mv, b, x0, tol=tol, maxiter=maxiter, axes=axes)

    def fn_b(mv, b, x0, *, tol, maxiter, axes):
        return richardson(mv, b, x0, tol=tol, maxiter=maxiter, axes=axes,
                          omega=0.5)

    register_ksp("swap", fn_a)
    try:
        opts = IPIOptions(method="ipi_swap", atol=1e-7, dtype="float64")
        r_a = solve(garnet, opts)
        register_ksp("swap", fn_b, overwrite=True, auto_method=False)
        r_b = solve(garnet, opts)   # same static opts: must NOT reuse fn_a
        assert r_a.converged and r_b.converged
        assert r_a.inner_iterations != r_b.inner_iterations
    finally:
        unregister_ksp("swap")


def test_unknown_names_get_live_suggestions():
    """Satellite: difflib suggestions drawn from the LIVE registry, in both
    the options DB and IPIOptions itself (no frozen-tuple duplicate)."""
    with pytest.raises(OptionTypeError, match="ipi_gmres"):
        Options({"-method": "ipi_gmers"})
    with pytest.raises(ValueError, match="ipi_gmres"):
        IPIOptions(method="ipi_gmers")
    with pytest.raises(ValueError, match="span"):
        IPIOptions(stop_criterion="spam")
    register_ksp("weird_user_solver",
                 lambda mv, b, x0, *, tol, maxiter, axes:
                 richardson(mv, b, x0, tol=tol, maxiter=maxiter, axes=axes))
    try:
        with pytest.raises(ValueError, match="ipi_weird_user_solver"):
            IPIOptions(method="ipi_weird_user_solvr")
    finally:
        unregister_ksp("weird_user_solver")


def test_deterministic_dots_validates_against_ksp_capability():
    IPIOptions(method="ipi_chebyshev", deterministic_dots=True)  # legal
    # anderson gained a deterministic composition (lane-at-a-time Gram /
    # projection, ordered combines, fixed-order solve) — legal now too
    IPIOptions(method="ipi_anderson", deterministic_dots=True)
    with pytest.raises(ValueError, match="bicgstab"):
        IPIOptions(method="ipi_bicgstab", deterministic_dots=True)


# --------------------------------------------------------------------------- #
# Stopping criteria                                                           #
# --------------------------------------------------------------------------- #

def test_span_stops_strictly_earlier_same_policy():
    """Acceptance criterion: -stop_criterion span converges in strictly
    fewer outer iterations than atol on chain_walk, same returned policy."""
    mdp = generators.chain_walk(300, gamma=0.999)
    kw = dict(method="vi", atol=1e-8, dtype="float64", max_outer=100000)
    r_atol = solve(mdp, IPIOptions(**kw))
    r_span = solve(mdp, IPIOptions(stop_criterion="span", **kw))
    assert r_atol.converged and r_span.converged
    assert r_span.outer_iterations < r_atol.outer_iterations, \
        (r_span.outer_iterations, r_atol.outer_iterations)
    np.testing.assert_array_equal(r_span.policy, r_atol.policy)
    # converged span results are midpoint-corrected: the returned value
    # carries the gamma*sp/(2(1-gamma)) certificate, so it must agree with
    # the atol-converged value within the sum of both gap bounds
    assert np.abs(r_span.v - r_atol.v).max() <= \
        r_span.gap_bound + r_atol.gap_bound
    assert r_span.gap_bound <= 0.999 * 1e-8 / (2 * (1 - 0.999)) * (1 + 1e-9)


def test_span_masks_mesh_padding_single_device():
    """Mesh-pad rows are absorbing states with residual exactly 0; left in
    the span min they erase the early-certification benefit.  A padded
    single-device solve must stop at the same outer count as unpadded (the
    cross-layout case runs in tests/test_fleet.py)."""
    from repro.core import ipi as ipi_mod
    from repro.core import partition
    from repro.core.comm import Axes
    mdp = generators.chain_walk(301, gamma=0.999)
    opts = IPIOptions(method="vi", atol=1e-8, dtype="float64",
                      max_outer=100000, stop_criterion="span")
    r = solve(mdp, opts)
    padded = partition.pad_mdp(mdp, n_mult=8, m_mult=1)   # 301 -> 304
    assert padded.n_global == 304
    st = ipi_mod.init_state(padded, Axes(), opts, n_true=301)
    st = ipi_mod.solve_chunk(padded, st, 100000, opts=opts, axes=Axes())
    assert int(st.k) == r.outer_iterations
    assert bool(st.done)


def test_rtol_criterion(garnet):
    r = solve(garnet, IPIOptions(method="vi", stop_criterion="rtol",
                                 rtol=1e-3, dtype="float64",
                                 max_outer=20000))
    assert r.converged
    res0 = float(r.trace_residual[0])
    assert r.residual <= 1e-3 * res0
    assert float(r.trace_residual[r.outer_iterations - 1]) > 1e-3 * res0


def test_atol_criterion_unchanged_results(garnet):
    """The registry/criterion refactor must not change the default path:
    converged flag, iterate count and traces equal the atol semantics."""
    r = solve(garnet, IPIOptions(method="ipi_gmres", atol=1e-9,
                                 dtype="float64"))
    assert r.converged and r.residual <= 1e-9
    assert float(r.trace_residual[r.outer_iterations - 1]) > 1e-9


def test_custom_stop_criterion_name_and_callable(garnet):
    register_stop_criterion("five_outers", lambda m: m.k >= 5)
    try:
        r = solve(garnet, IPIOptions(method="vi", dtype="float64",
                                     stop_criterion="five_outers"))
        assert r.outer_iterations == 5 and r.converged
        # callable path through the session (ad-hoc registration)
        with Session({"-dtype": "float64", "-layout": "single"}) as s:
            r2 = s.solve(garnet, method="vi",
                         stop_criterion=lambda m: m.res <= 1e-3)
        assert r2.converged and r2.residual <= 1e-3
        assert float(r2.trace_residual[r2.outer_iterations - 1]) > 1e-3
    finally:
        unregister_stop_criterion("five_outers")


def test_custom_criterion_can_read_span(garnet):
    """Ad-hoc predicates get span metrics by default (needs_span=True) —
    a criterion reading m.span must see real values, not +inf."""
    with Session({"-dtype": "float64", "-layout": "single"}) as s:
        r = s.solve(garnet, method="vi", max_outer=20000,
                    stop_criterion=lambda m: m.span <= 1e-6)
    assert r.converged and r.outer_iterations < 20000


def test_adhoc_criterion_name_is_stable():
    fn = lambda m: m.k >= 2
    n1 = methods.adhoc_stop_criterion(fn)
    n2 = methods.adhoc_stop_criterion(fn)
    assert n1 == n2
    other = methods.adhoc_stop_criterion(lambda m: m.k >= 3)
    assert other != n1
    unregister_stop_criterion(n1)
    unregister_stop_criterion(other)


# --------------------------------------------------------------------------- #
# Monitors                                                                    #
# --------------------------------------------------------------------------- #

def test_monitor_streams_one_record_per_outer_iteration(garnet):
    records = []
    with Session({"-dtype": "float64", "-layout": "single"}) as s:
        r = s.solve(garnet, method="ipi_gmres", atol=1e-9,
                    monitor=records.append)
    # k=0 record plus one per outer iteration, in order, no duplicates
    assert [rec["k"] for rec in records] == list(range(
        r.outer_iterations + 1))
    assert records[0]["inner"] == 0
    np.testing.assert_allclose(
        [rec["res"] for rec in records], r.trace_residual, rtol=1e-12)
    assert [rec["inner"] for rec in records[1:]] == list(r.trace_inner)
    assert all(rec["elapsed"] >= 0 for rec in records)


def test_monitor_chunk_mode_matches_stream_record_for_record(garnet):
    """``-monitor_mode chunk`` drains the device traces once per run-chunk
    instead of one ``jax.debug.callback`` host sync per outer iteration —
    the reconstructed records must equal the stream record-for-record
    (``k`` / ``res`` / ``inner``; ``elapsed`` is delivery timing, not
    compared).  vi at 1e-9 runs ~400 outers, i.e. several 64-iteration
    chunks, so the per-chunk drain boundaries are really exercised."""
    stream, chunk = [], []
    with Session({"-dtype": "float64", "-layout": "single"}) as s:
        r1 = s.solve(garnet, method="vi", atol=1e-9, monitor=stream.append)
        r2 = s.solve(garnet, method="vi", atol=1e-9, monitor=chunk.append,
                     monitor_mode="chunk")
    assert r1.outer_iterations == r2.outer_iterations > 64
    assert len(chunk) == len(stream) == r1.outer_iterations + 1
    for a, b in zip(stream, chunk):
        assert a["k"] == b["k"]
        assert a["res"] == b["res"]      # same device trace value, exactly
        assert a["inner"] == b["inner"]
        assert b["elapsed"] >= 0


def test_monitor_lands_in_stats_with_history(garnet, tmp_path):
    p = tmp_path / "stats.jsonl"
    with Session({"-dtype": "float64", "-layout": "single",
                  "-monitor": True, "-file_stats": str(p)}) as s:
        r = s.solve(garnet, method="vi", atol=1e-6)
        entry = s.stats[-1]
    assert len(entry["monitor"]) == r.outer_iterations + 1
    assert entry["solves"][0]["trace_residual"] == \
        [float(x) for x in r.trace_residual]
    assert entry["solves"][0]["trace_inner"] == [int(x) for x in
                                                 r.trace_inner]
    on_disk = json.loads(p.read_text().splitlines()[0])
    assert len(on_disk["monitor"]) == r.outer_iterations + 1


def test_monitor_disabled_no_records(garnet):
    with Session({"-dtype": "float64", "-layout": "single"}) as s:
        s.solve(garnet, method="vi", atol=1e-6)
        assert "monitor" not in s.stats[-1]


def test_monitor_exception_does_not_kill_solve(garnet, capsys):
    """A raising user monitor must not abort the solve — records are
    dropped with a warning (k=0 host record included)."""
    def bad(rec):
        raise KeyError("boom")
    with Session({"-dtype": "float64", "-layout": "single"}) as s:
        r = s.solve(garnet, method="vi", atol=1e-6, monitor=bad)
    assert r.converged
    assert "callback error" in capsys.readouterr().out


def test_monitor_false_overrides_session_monitor(garnet, capsys):
    """monitor=False must disable a session-level -monitor for this call."""
    with Session({"-dtype": "float64", "-layout": "single",
                  "-monitor": True}) as s:
        s.solve(garnet, method="vi", atol=1e-6, monitor=False)
        assert "monitor" not in s.stats[-1]
    assert "[monitor]" not in capsys.readouterr().out


# --------------------------------------------------------------------------- #
# Stats streaming (satellite: -file_stats O(solves^2) fix)                    #
# --------------------------------------------------------------------------- #

def test_file_stats_jsonl_streams_appends(garnet, tmp_path):
    p = tmp_path / "stats.jsonl"
    with Session({"-dtype": "float64", "-layout": "single",
                  "-file_stats": str(p), "-atol": 1e-6}) as s:
        sizes = []
        for _ in range(3):
            s.solve(garnet, method="vi")
            sizes.append(p.stat().st_size)
    lines = p.read_text().splitlines()
    assert len(lines) == 3
    per_solve = [sizes[0], sizes[1] - sizes[0], sizes[2] - sizes[1]]
    # appends are O(1) per solve: every increment is one entry, not the
    # re-serialized accumulated list
    assert max(per_solve) < 1.5 * min(per_solve)
    assert [json.loads(ln)["method"] for ln in lines] == ["vi"] * 3


def test_file_stats_json_array_format_available(garnet, tmp_path):
    p = tmp_path / "stats.json"
    with Session({"-dtype": "float64", "-layout": "single",
                  "-file_stats": str(p), "-file_stats_format": "json",
                  "-atol": 1e-6}) as s:
        s.solve(garnet, method="vi")
        s.solve(garnet, method="vi")
    entries = json.loads(p.read_text())
    assert isinstance(entries, list) and len(entries) == 2


# --------------------------------------------------------------------------- #
# Docs sync (satellite: registry is the single source of truth)               #
# --------------------------------------------------------------------------- #

def test_readme_tables_generated_from_registry():
    text = open(README).read()
    assert option_table() in text, \
        "README option table drifted; regenerate with repro.api.option_table()"
    assert method_table() in text, \
        "README method table drifted; regenerate with repro.api.method_table()"
    assert ksp_table() in text, \
        "README ksp table drifted; regenerate with repro.api.ksp_table()"
    assert stop_table() in text, \
        "README stop-criterion table drifted; regenerate with " \
        "repro.api.stop_table()"
