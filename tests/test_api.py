"""The user API (ISSUE 3): MDP builders, options database, session layer.

Covers the options database contract (typed validation, env/CLI ingestion
precedence, lossless IPIOptions round-trip), maxreward-vs-mincost parity
(negated-cost equivalence, bit-for-bit on vi/mpi), function-defined MDPs,
session placement + outputs, ragged-fleet bucketing, the deprecation shims
and the rewired CLI.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.api import (MDP, Options, OptionTypeError, Session,
                       UnknownOptionError, bucket_indices, madupite_session,
                       option_table)
from repro.core import generators
from repro.core.driver import solve as driver_solve
from repro.core.driver import solve_many as driver_solve_many
from repro.core.ipi import IPIOptions
from repro.core.mdp import EllMDP


# --------------------------------------------------------------------------- #
# Options database                                                            #
# --------------------------------------------------------------------------- #

def test_options_defaults_match_ipi_defaults():
    assert Options().to_ipi() == IPIOptions()


def test_options_ipi_roundtrip_lossless():
    ipi = IPIOptions(method="ipi_bicgstab", mode="maxreward", atol=1e-6,
                     max_outer=123, max_inner=7, forcing_eta=0.2, restart=5,
                     omega=0.9, mpi_sweeps=11, safeguard=False,
                     impl="pallas_interpret", dtype="float64", halo=3,
                     gather_dtype="float32")
    assert Options.from_ipi(ipi).to_ipi() == ipi
    # and the reverse direction: a database round-trips through IPIOptions
    db = Options({"-atol": 1e-5, "-method": "mpi", "-mpi_sweeps": 9})
    again = Options.from_ipi(db.to_ipi())
    assert again.get("-atol") == 1e-5
    assert again.get("-method") == "mpi"
    assert again.get("-mpi_sweeps") == 9


def test_options_unknown_key_names_it():
    with pytest.raises(UnknownOptionError, match=r"-atoll.*-atol"):
        Options().set("-atoll", 1e-6)
    with pytest.raises(UnknownOptionError):
        Options().get("-no_such_thing")


def test_options_bad_type_names_key():
    with pytest.raises(OptionTypeError, match="-max_outer"):
        Options().set("-max_outer", "many")
    with pytest.raises(OptionTypeError, match="-atol"):
        Options().set("-atol", -1.0)           # validator: must be > 0
    with pytest.raises(OptionTypeError, match="-method"):
        Options().set("-method", "newton")     # choices
    with pytest.raises(OptionTypeError, match="-safeguard"):
        Options().set("-safeguard", "maybe")   # bool coercion
    # cross-field validation surfaces as an options error too
    with pytest.raises(OptionTypeError, match="gather_dtype"):
        Options({"-dtype": "float32", "-gather_dtype": "float64"}).to_ipi()


def test_options_string_coercion():
    o = Options()
    o.set("-atol", "1e-6")
    o.set("-max_outer", "250")
    o.set("-safeguard", "false")
    o.set("-impl", "none")                     # nullable: "none" -> None
    assert o.get("-atol") == 1e-6
    assert o.get("-max_outer") == 250
    assert o.get("-safeguard") is False
    assert o.get("-impl") is None
    # keys work with or without the leading dash
    assert o.get("atol") == 1e-6


def test_options_env_cli_user_precedence():
    env = {"MADUPITE_OPTIONS": "-method vi -atol=1e-4 -max_outer 900"}
    o = Options.from_sources(env=env, cli=["-atol=1e-5", "chunk=32"])
    assert o.get("-method") == "vi"        # env only
    assert o.get("-atol") == 1e-5          # cli beats env
    assert o.get("-max_outer") == 900
    assert o.get("-chunk") == 32
    o.set("-atol", 1e-7)                   # user beats cli
    assert o.get("-atol") == 1e-7
    # and a late low-precedence ingest does not clobber the user value
    o.ingest_env(env)
    assert o.get("-atol") == 1e-7


def test_options_env_missing_value():
    with pytest.raises(OptionTypeError, match="missing a value"):
        Options.from_sources(env={"MADUPITE_OPTIONS": "-method"})
    with pytest.raises(OptionTypeError, match="key=value"):
        Options.from_sources(cli=["atol"])


def test_options_ksp_type_sugar():
    o = Options({"-ksp_type": "bicgstab"})
    assert o.to_ipi().method == "ipi_bicgstab"
    assert Options({"-ksp_type": "none"}).to_ipi().method == "vi"
    # explicit -method wins over the sugar
    o2 = Options({"-ksp_type": "gmres", "-method": "mpi"})
    assert o2.to_ipi().method == "mpi"


def test_option_table_renders_all_keys():
    table = option_table()
    for key in ("-method", "-mode", "-layout", "-fleet_bucketing",
                "-file_stats"):
        assert key in table


# --------------------------------------------------------------------------- #
# maxreward mode                                                              #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("method", ["vi", "mpi"])
def test_maxreward_matches_negated_mincost_bitwise(method):
    """max_a (r + gamma P v) must be exactly the negation of
    min_a (-r + gamma P v): values bit-for-bit, policies and iteration
    paths identical."""
    mdp = generators.garnet(n=150, m=5, k=4, gamma=0.95, seed=3)
    neg = EllMDP(idx=mdp.idx, val=mdp.val, cost=-np.asarray(mdp.cost),
                 gamma=mdp.gamma, n_global=mdp.n_global,
                 m_global=mdp.m_global)
    kw = dict(atol=1e-9, dtype="float64", max_outer=20000)
    r_max = driver_solve(mdp, IPIOptions(method=method, mode="maxreward",
                                         **kw))
    r_min = driver_solve(neg, IPIOptions(method=method, mode="mincost",
                                         **kw))
    np.testing.assert_array_equal(r_max.v, -r_min.v)          # bit-for-bit
    np.testing.assert_array_equal(r_max.policy, r_min.policy)
    assert r_max.outer_iterations == r_min.outer_iterations
    np.testing.assert_array_equal(r_max.trace_residual, r_min.trace_residual)


def test_maxreward_krylov_and_fleet():
    """Krylov methods and the batched engine honor the mode too (values to
    tolerance, policies exact)."""
    mdps = [generators.garnet(n=100, m=4, k=3, gamma=0.9, seed=s)
            for s in (0, 1)]
    negs = [EllMDP(idx=m.idx, val=m.val, cost=-np.asarray(m.cost),
                   gamma=m.gamma, n_global=m.n_global, m_global=m.m_global)
            for m in mdps]
    kw = dict(atol=1e-9, dtype="float64")
    r_max = driver_solve_many(mdps, IPIOptions(method="ipi_gmres",
                                               mode="maxreward", **kw))
    r_min = driver_solve_many(negs, IPIOptions(method="ipi_gmres", **kw))
    for a, b in zip(r_max, r_min):
        np.testing.assert_array_equal(a.policy, b.policy)
        np.testing.assert_allclose(a.v, -b.v, atol=1e-8)


def test_mode_validated():
    with pytest.raises(ValueError, match="mode"):
        IPIOptions(mode="minimize")
    with pytest.raises(ValueError, match="mode"):
        MDP.from_generator("garnet", n=20, m=2, k=2, mode="bogus")


# --------------------------------------------------------------------------- #
# MDP builders                                                                #
# --------------------------------------------------------------------------- #

def _chain_fns(n):
    def P_fn(s, a):
        left, right = max(s - 1, 0), min(s + 1, n - 1)
        fwd, bwd = (left, right) if a == 0 else (right, left)
        return [fwd, bwd], [0.7, 0.3]

    def g_fn(s, a):
        return 0.0 if s == 0 else 1.0

    return P_fn, g_fn


def test_from_functions_matches_generator():
    n = 60
    P_fn, g_fn = _chain_fns(n)
    fmdp = MDP.from_functions(P_fn, g_fn, n, 2, nnz=2, gamma=0.99)
    assert fmdp.deferred and fmdp.n == n and fmdp.m == 2
    ref = generators.chain_walk(n=n, gamma=0.99)
    opts = IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64")
    r1 = driver_solve(fmdp.build(), opts)
    r2 = driver_solve(ref, opts)
    np.testing.assert_array_equal(r1.policy, r2.policy)
    np.testing.assert_allclose(r1.v, r2.v, atol=1e-8)


def test_from_functions_vectorized_matches_scalar():
    n = 40

    def P_vec(rows, a):
        left = np.clip(rows - 1, 0, n - 1)
        right = np.clip(rows + 1, 0, n - 1)
        fwd, bwd = (left, right) if a == 0 else (right, left)
        return (np.stack([fwd, bwd], -1),
                np.broadcast_to(np.array([0.7, 0.3]), (len(rows), 2)))

    def g_vec(rows, a):
        return np.where(rows == 0, 0.0, 1.0)

    P_fn, g_fn = _chain_fns(n)
    a = MDP.from_functions(P_vec, g_vec, n, 2, nnz=2, gamma=0.99,
                           vectorized=True).build()
    b = MDP.from_functions(P_fn, g_fn, n, 2, nnz=2, gamma=0.99).build()
    np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx))
    np.testing.assert_array_equal(np.asarray(a.val), np.asarray(b.val))
    np.testing.assert_array_equal(np.asarray(a.cost), np.asarray(b.cost))


def test_from_functions_rejects_bad_successors():
    def P_fn(s, a):
        return [s, s + 999], [0.5, 0.5]      # out of range

    mdp = MDP.from_functions(P_fn, lambda s, a: 1.0, 10, 1, nnz=2,
                             gamma=0.9)
    with pytest.raises(ValueError, match="successor ids"):
        mdp.build()


def test_from_functions_rejects_successors_in_padding_range():
    """Successor ids in [n, n_pad_to) must be rejected too — on a padded
    (sharded) materialization they would silently route probability mass
    into the zero-value padding states."""
    def P_fn(s, a):
        return [min(s + 1, 10)], [1.0]       # id 10 == n: out of range

    mdp = MDP.from_functions(P_fn, lambda s, a: 1.0, 10, 1, nnz=1,
                             gamma=0.9)
    with pytest.raises(ValueError, match="successor ids"):
        # padded block: rows 0..11, pad target 12 — id 10 < 12 but >= n
        mdp._block(np.arange(12), np.arange(1), n_pad_to=12, m_pad_to=1)


def test_from_functions_pad_sign_follows_solve_mode():
    """A per-solve mode override must flip the never-greedy padding sign
    of function-backed materialization (padded actions carry +BIG under
    argmin but -BIG under argmax)."""
    P_fn, g_fn = _chain_fns(8)
    mdp = MDP.from_functions(P_fn, g_fn, 8, 2, nnz=2, gamma=0.9)  # mincost
    _, _, cost = mdp._block(np.arange(8), np.arange(4), n_pad_to=8,
                            m_pad_to=4, mode="maxreward")
    assert (cost[:, 2:] < 0).all()           # solve-mode sign, not builder's
    _, _, cost = mdp._block(np.arange(8), np.arange(4), n_pad_to=8,
                            m_pad_to=4)
    assert (cost[:, 2:] > 0).all()


def _chain_jnp(n):
    """The chain constructors written in jax.numpy: jit-able -> the device
    generator pipeline."""
    import jax.numpy as jnp

    def P_fn(rows, a):
        left = jnp.clip(rows - 1, 0, n - 1)
        right = jnp.clip(rows + 1, 0, n - 1)
        fwd, bwd = (left, right) if a == 0 else (right, left)
        return (jnp.stack([fwd, bwd], -1).astype(jnp.int32),
                jnp.broadcast_to(jnp.asarray([0.7, 0.3], jnp.float32),
                                 (rows.shape[0], 2)))

    def g_fn(rows, a):
        return jnp.where(rows == 0, 0.0, 1.0).astype(jnp.float32)

    return P_fn, g_fn


def _chain_np_vec(n):
    def P_fn(rows, a):
        left = np.clip(rows - 1, 0, n - 1)
        right = np.clip(rows + 1, 0, n - 1)
        fwd, bwd = (left, right) if a == 0 else (right, left)
        return (np.stack([fwd, bwd], -1),
                np.broadcast_to(np.array([0.7, 0.3]), (len(rows), 2)))

    def g_fn(rows, a):
        return np.where(rows == 0, 0.0, 1.0)

    return P_fn, g_fn


def test_from_functions_pipeline_auto_detection():
    """jnp constructors trace -> device; numpy constructors fail tracing ->
    host; explicit pins and the -mdp_materialize option override."""
    n = 24
    P_j, g_j = _chain_jnp(n)
    P_n, g_n = _chain_np_vec(n)
    jm = MDP.from_functions(P_j, g_j, n, 2, nnz=2, vectorized=True)
    nm = MDP.from_functions(P_n, g_n, n, 2, nnz=2, vectorized=True)
    sm = MDP.from_functions(*_chain_fns(n), n, 2, nnz=2)  # python scalars
    assert jm.materialization() == "device"
    assert nm.materialization() == "host"
    assert sm.materialization() == "host"
    # option forces host; device pin / option on numpy raises with a reason
    assert jm.materialization("host") == "host"
    with pytest.raises(ValueError, match="do not trace"):
        nm.materialization("device")
    pinned = MDP.from_functions(P_n, g_n, n, 2, nnz=2, vectorized=True,
                                device=True)
    with pytest.raises(ValueError, match="do not trace"):
        pinned.build()
    # device=False pin beats a device option
    off = MDP.from_functions(P_j, g_j, n, 2, nnz=2, vectorized=True,
                             device=False)
    assert off.materialization("device") == "host"


def test_from_functions_device_build_bitwise_matches_host():
    """The two pipelines must produce identical tables — and match the
    reference generator."""
    n = 60
    P_j, g_j = _chain_jnp(n)
    md = MDP.from_functions(P_j, g_j, n, 2, nnz=2, gamma=0.99,
                            vectorized=True)
    dev = md.build("device")
    host = md.build("host")
    ref = generators.chain_walk(n=n, gamma=0.99)
    for f in ("idx", "val", "cost"):
        np.testing.assert_array_equal(np.asarray(getattr(dev, f)),
                                      np.asarray(getattr(host, f)),
                                      err_msg=f)
        np.testing.assert_array_equal(np.asarray(getattr(dev, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f)


def test_from_functions_device_scalar_constructors():
    """Scalar jit-able constructors (traced s, static a) vmap to the same
    tables as vectorized ones."""
    import jax.numpy as jnp
    n = 40

    def P_s(s, a):
        left = jnp.maximum(s - 1, 0)
        right = jnp.minimum(s + 1, n - 1)
        fwd, bwd = (left, right) if a == 0 else (right, left)
        return (jnp.stack([fwd, bwd]).astype(jnp.int32),
                jnp.asarray([0.7, 0.3], jnp.float32))

    def g_s(s, a):
        return jnp.where(s == 0, 0.0, 1.0)

    ms = MDP.from_functions(P_s, g_s, n, 2, nnz=2)
    assert ms.materialization() == "device"
    mv = MDP.from_functions(*_chain_jnp(n), n, 2, nnz=2, vectorized=True)
    a, b = ms.build(), mv.build()
    for f in ("idx", "val", "cost"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


def test_from_functions_device_block_padding_matches_host():
    """The compiled block builder must reproduce the host ``_block``
    padding bit-for-bit: absorbing self-loop rows, never-greedy action
    columns under both solve modes."""
    import jax.numpy as jnp
    from repro.api.mdp import _device_builder
    n = 6
    md = MDP.from_functions(*_chain_jnp(n), n, 2, nnz=2, vectorized=True)
    for mode in ("mincost", "maxreward"):
        f = _device_builder(md._spec, 8, (0, 1, 2, 3), mode)
        dev = [np.asarray(x) for x in f(jnp.int32(0))]
        host = md._block(np.arange(8), np.arange(4), n_pad_to=8,
                         m_pad_to=4, mode=mode)
        for d, h, name in zip(dev, host, ("idx", "val", "cost")):
            np.testing.assert_array_equal(d, h, err_msg=f"{mode}/{name}")
    big = dev[2][:, 2:]
    assert (big < 0).all()          # maxreward ran last: -BIG padding


def test_from_functions_device_wrong_shape_named():
    """A traced constructor returning the wrong number of nnz slots fails
    with an error naming the expected shape."""
    import jax.numpy as jnp
    n = 10

    def P_bad(rows, a):
        return (jnp.zeros((rows.shape[0], 3), jnp.int32),
                jnp.zeros((rows.shape[0], 3), jnp.float32))

    md = MDP.from_functions(P_bad, lambda rows, a: jnp.zeros(rows.shape[0]),
                            n, 1, nnz=2, vectorized=True, device=True)
    with pytest.raises(ValueError, match="must return shape"):
        md.build()


def test_from_functions_scalar_validation_names_offender():
    """The scalar host path must reject ids/probs length mismatches and
    non-stochastic rows, naming the offending (s, a)."""
    def P_mismatch(s, a):
        return [s, min(s + 1, 9)], [1.0]          # 2 ids, 1 prob

    with pytest.raises(ValueError, match=r"s=0, a=0.*2 successor ids but 1"):
        MDP.from_functions(P_mismatch, lambda s, a: 0.0, 10, 1,
                           nnz=2).build("host")

    def P_nonstoch(s, a):
        return [s, min(s + 1, 9)], [0.5, 0.1]     # sums to 0.6

    with pytest.raises(ValueError, match=r"s=0, a=0.*sum to 0.6"):
        MDP.from_functions(P_nonstoch, lambda s, a: 0.0, 10, 1,
                           nnz=2).build("host")


def test_from_functions_vectorized_validation_names_offender():
    def P_bad(rows, a):
        probs = np.broadcast_to(np.array([0.7, 0.3]), (len(rows), 2)).copy()
        probs[3] = [0.7, 0.7]                     # row 3 sums to 1.4
        return (np.stack([rows, rows], -1), probs)

    m = MDP.from_functions(P_bad, lambda rows, a: np.zeros(len(rows)),
                           10, 1, nnz=2, vectorized=True)
    with pytest.raises(ValueError, match=r"s=3, a=0.*sum to 1.4"):
        m.build()


def test_from_generator_deferred():
    """deferred=True builds on the jit-able FN_REGISTRY constructors;
    maze2d / chain_walk reproduce the host generators bit-for-bit and
    every family validates."""
    ref = generators.maze2d(size=6)
    dm = MDP.from_generator("maze2d", deferred=True, size=6)
    assert dm.deferred and dm.materialization() == "device"
    built = dm.build()
    for f in ("idx", "val", "cost"):
        np.testing.assert_array_equal(np.asarray(getattr(built, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f)
    for name, kw in (("chain_walk", dict(n=50, gamma=0.95)),
                     ("sis", dict(pop=40)),
                     ("garnet", dict(n=30, m=3, k=4, seed=1))):
        MDP.from_generator(name, deferred=True, **kw).build().validate()
    with pytest.raises(ValueError, match="deferred families"):
        MDP.from_generator("nope", deferred=True)


def test_mdp_evict_and_session_close_evicts(tmp_path):
    """Session.close must drop the mesh-keyed device shards of builders it
    placed (reused builders otherwise pin dead meshes' device memory)."""
    import jax
    from repro.launch.mesh import mesh_kwargs
    mesh = jax.make_mesh((1, 1), ("data", "model"), **mesh_kwargs(2))
    md = MDP.from_functions(*_chain_jnp(32), 32, 2, nnz=2, gamma=0.9,
                            vectorized=True)
    with Session({"-method": "vi", "-atol": 1e-5, "-layout": "1d"},
                 mesh=mesh) as s:
        r = s.solve(md)
        assert r.converged
        assert any(k[0] == mesh for k in md._device_cache)
    assert not any(k[0] == mesh for k in md._device_cache)
    # evict() without a mesh clears everything, returning the count
    md.build()
    assert md.evict() >= 1 and not md._device_cache


def test_place_function_fleet_single_device():
    """place_function_fleet on a 1-device fleet mesh: batched container
    with per-instance tables (heterogeneous n and gamma), solvable by
    solve_many, matching per-instance host builds."""
    import jax
    from repro.api import place_function_fleet
    from repro.core.driver import solve_many as dsm
    from repro.launch.mesh import mesh_kwargs
    mesh = jax.make_mesh((1, 1), ("fleet", "data"), **mesh_kwargs(2))
    mdps = [MDP.from_functions(*_chain_jnp(n), n, 2, nnz=2, gamma=g,
                               vectorized=True)
            for n, g in ((40, 0.9), (35, 0.95))]
    batched = place_function_fleet(mdps, mesh, "fleet")
    assert batched.batch == 2 and batched.n_global == 40
    assert batched.gamma == (0.9, 0.95)
    opts = IPIOptions(method="vi", atol=1e-9, dtype="float64")
    rs = dsm(batched, opts, mesh=mesh, layout="fleet")
    for m, r in zip(mdps, rs):
        # mixed gammas run the traced-gamma fleet path: values to fp
        # tolerance (policies exact), as in tests/test_batch.py
        want = driver_solve(m.build(), opts)
        np.testing.assert_allclose(r.v[:m.n], want.v, atol=1e-12)
        np.testing.assert_array_equal(r.policy[:m.n], want.policy)
    # guards: non-fleet layout, non-deferred instances, mismatched nnz
    with pytest.raises(ValueError, match="fleet layouts"):
        place_function_fleet(mdps, mesh, "1d")
    with pytest.raises(ValueError, match="function-backed"):
        place_function_fleet(
            [MDP(generators.garnet(n=10, m=2, k=2))], mesh, "fleet")
    import jax.numpy as jnp

    def P3(rows, a):       # valid nnz=3 chain (third slot zero-padded)
        i2, p2 = _chain_jnp(40)[0](rows, a)
        return (jnp.concatenate([i2, jnp.zeros((rows.shape[0], 1),
                                               jnp.int32)], -1),
                jnp.concatenate([p2, jnp.zeros((rows.shape[0], 1),
                                               jnp.float32)], -1))

    odd = MDP.from_functions(P3, _chain_jnp(40)[1], 40, 2, nnz=3,
                             vectorized=True)
    with pytest.raises(ValueError, match="share the action count and nnz"):
        place_function_fleet([mdps[0], odd], mesh, "fleet")


def test_session_fleet_container_cached_until_close():
    """Repeated solve_fleet calls on the same deferred fleet must reuse the
    device-materialized container (warm serving skips construction);
    close() drops it."""
    import jax
    from repro.launch.mesh import mesh_kwargs
    mesh = jax.make_mesh((1, 1), ("fleet", "data"), **mesh_kwargs(2))
    mdps = [MDP.from_functions(*_chain_jnp(30), 30, 2, nnz=2, gamma=0.9,
                               vectorized=True) for _ in range(2)]
    with Session({"-method": "vi", "-atol": 1e-6, "-dtype": "float64"},
                 mesh=mesh) as s:
        r1 = s.solve_fleet(mdps)
        assert len(s._fleet_cache) == 1
        batched = next(iter(s._fleet_cache.values()))
        r2 = s.solve_fleet(mdps)
        assert next(iter(s._fleet_cache.values())) is batched  # reused
        np.testing.assert_array_equal(r1[0].v, r2[0].v)
    assert not s._fleet_cache


def test_deterministic_dots_solves_match():
    """-deterministic_dots must not change convergence — same solution to
    tolerance, still converged (bit-level layout parity is covered on the
    8-device mesh in test_fleet.py)."""
    mdp = generators.garnet(n=150, m=5, k=4, gamma=0.95, seed=3)
    kw = dict(atol=1e-9, dtype="float64")
    r0 = driver_solve(mdp, IPIOptions(method="ipi_gmres", **kw))
    r1 = driver_solve(mdp, IPIOptions(method="ipi_gmres",
                                      deterministic_dots=True, **kw))
    assert r0.converged and r1.converged
    np.testing.assert_allclose(r0.v, r1.v, atol=1e-8)
    np.testing.assert_array_equal(r0.policy, r1.policy)
    # and the option threads through the database
    assert Options({"-deterministic_dots": True}).to_ipi().deterministic_dots
    # bicgstab has no deterministic path: rejected, not silently ignored
    with pytest.raises(ValueError, match="ipi_bicgstab"):
        IPIOptions(method="ipi_bicgstab", deterministic_dots=True)


def test_from_arrays_and_validation():
    g = generators.garnet(n=30, m=3, k=3, gamma=0.9, seed=0)
    m = MDP.from_arrays(idx=g.idx, val=g.val, cost=g.cost, gamma=0.9)
    assert m.n == 30 and m.m == 3
    bad_val = np.asarray(g.val) * 2.0         # rows no longer sum to 1
    with pytest.raises(AssertionError):
        MDP.from_arrays(idx=g.idx, val=bad_val, cost=g.cost, gamma=0.9)
    with pytest.raises(ValueError, match="idx\\+val|cost"):
        MDP.from_arrays(cost=g.cost, gamma=0.9)


def test_from_file_roundtrips_mode(tmp_path):
    g = generators.garnet(n=24, m=3, k=3, gamma=0.9, seed=1)
    MDP(g, mode="maxreward").save(str(tmp_path / "mdp"))
    loaded = MDP.from_file(str(tmp_path / "mdp"))
    assert loaded.mode == "maxreward"
    np.testing.assert_array_equal(np.asarray(loaded.build().cost),
                                  np.asarray(g.cost))


# --------------------------------------------------------------------------- #
# Session layer                                                               #
# --------------------------------------------------------------------------- #

def test_session_solve_matches_driver(tmp_path):
    mdp = generators.garnet(n=200, m=6, k=4, gamma=0.95, seed=0)
    opts = IPIOptions(method="ipi_gmres", atol=1e-8, dtype="float64")
    ref = driver_solve(mdp, opts)
    stats = tmp_path / "stats.json"
    pol = tmp_path / "policy.npy"
    cost = tmp_path / "value.npy"
    with madupite_session({"-method": "ipi_gmres", "-atol": 1e-8,
                           "-dtype": "float64", "-layout": "single",
                           "-file_stats": str(stats),
                           "-file_policy": str(pol),
                           "-file_cost": str(cost)}) as s:
        r = s.solve(mdp)
    np.testing.assert_array_equal(r.policy, ref.policy)
    np.testing.assert_array_equal(r.v, ref.v)
    # default stats format is jsonl: one streamed line per solve
    entries = [json.loads(ln) for ln in stats.read_text().splitlines()]
    assert len(entries) == 1
    assert entries[0]["method"] == "ipi_gmres"
    assert entries[0]["solves"][0]["converged"] is True
    assert entries[0]["solves"][0]["n"] == 200
    np.testing.assert_array_equal(np.load(pol), ref.policy)
    np.testing.assert_array_equal(np.load(cost), ref.v)


def test_session_per_call_overrides_and_mdp_mode():
    mdp = MDP.from_generator("garnet", n=80, m=4, k=3, gamma=0.9, seed=2,
                             mode="maxreward")
    with Session({"-dtype": "float64", "-layout": "single"}) as s:
        r_vi = s.solve(mdp, method="vi", atol=1e-6)
        r_gm = s.solve(mdp, method="ipi_gmres", atol=1e-9)
        assert s.stats[0]["method"] == "vi"
        assert s.stats[0]["mode"] == "maxreward"    # builder mode threaded
        np.testing.assert_array_equal(r_vi.policy, r_gm.policy)
    with pytest.raises(RuntimeError, match="closed"):
        s.solve(mdp)


def test_session_rejects_unknown_override():
    with Session() as s:
        with pytest.raises(UnknownOptionError):
            s.solve(generators.garnet(n=20, m=2, k=2, seed=0), atoll=1e-6)


def test_session_fleet_layout_needs_devices():
    import jax
    if len(jax.devices()) > 1:
        pytest.skip("single-device guard")
    with Session({"-layout": "fleet"}) as s:
        with pytest.raises(ValueError, match="one device"):
            s.placement()


# --------------------------------------------------------------------------- #
# Ragged-fleet bucketing                                                      #
# --------------------------------------------------------------------------- #

def test_bucket_indices_policies():
    assert bucket_indices([], policy="auto") == []
    assert bucket_indices([100, 200, 50], policy="off") == [[0, 1, 2]]
    # near-equal sizes: one bucket (the homogeneous fast path)
    assert bucket_indices([100, 100, 110, 105]) == [[0, 1, 3, 2]]
    # wildly ragged: split
    buckets = bucket_indices([50, 55, 60, 400, 410])
    assert buckets == [[0, 1, 2], [3, 4]]
    # every index exactly once
    flat = sorted(i for b in buckets for i in b)
    assert flat == [0, 1, 2, 3, 4]
    with pytest.raises(ValueError, match="policy"):
        bucket_indices([1], policy="greedy")


def test_solve_fleet_bucketed_matches_independent():
    """A ragged fleet (n=60 vs n=400) solves per-bucket and returns
    results in input order, matching independent solves exactly."""
    mdps = [generators.garnet(n=n, m=4, k=3, gamma=0.9, seed=i)
            for i, n in enumerate([400, 60, 64, 390])]
    opts = IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64")
    singles = [driver_solve(m, opts) for m in mdps]
    with Session({"-method": "ipi_gmres", "-atol": 1e-9,
                  "-dtype": "float64", "-layout": "single"}) as s:
        fleet = s.solve_fleet(mdps)
        rec = s.stats[-1]
    assert rec["fleet"]["size"] == 4
    assert sorted(map(sorted, rec["fleet"]["buckets"])) == [[0, 3], [1, 2]]
    for b, (got, want) in enumerate(zip(fleet, singles)):
        assert got.converged, f"instance {b}"
        np.testing.assert_array_equal(got.policy, want.policy,
                                      err_msg=f"instance {b}")
        np.testing.assert_allclose(got.v, want.v, atol=1e-9)
        assert got.outer_iterations == want.outer_iterations


def test_solve_fleet_bucketing_off_single_program():
    mdps = [generators.garnet(n=n, m=3, k=3, gamma=0.9, seed=i)
            for i, n in enumerate([50, 300])]
    with Session({"-fleet_bucketing": "off", "-atol": 1e-8,
                  "-dtype": "float64", "-layout": "single"}) as s:
        rs = s.solve_fleet(mdps)
        assert s.stats[-1]["fleet"]["buckets"] == [[0, 1]]
    assert all(r.converged for r in rs)
    assert len(rs[0].v) == 50 and len(rs[1].v) == 300


def test_solve_fleet_rejects_mixed_modes():
    a = MDP.from_generator("garnet", n=20, m=2, k=2, seed=0)
    b = MDP.from_generator("garnet", n=20, m=2, k=2, seed=1,
                           mode="maxreward")
    with Session() as s:
        with pytest.raises(ValueError, match="mode"):
            s.solve_fleet([a, b])


# --------------------------------------------------------------------------- #
# Back-compat shims + CLI                                                     #
# --------------------------------------------------------------------------- #

def test_core_solve_shims_deprecated_but_working():
    import repro.core as core
    mdp = generators.garnet(n=40, m=3, k=3, gamma=0.9, seed=0)
    with pytest.warns(DeprecationWarning, match="repro.api"):
        r = core.solve(mdp, IPIOptions(method="vi", atol=1e-6))
    assert r.converged
    with pytest.warns(DeprecationWarning, match="repro.api"):
        rs = core.solve_many([mdp, mdp], IPIOptions(method="vi", atol=1e-6))
    assert all(x.converged for x in rs)


def test_cli_options_database(tmp_path):
    from repro.launch.solve import main
    stats = tmp_path / "cli.json"
    rc = main(["--instance", "maze2d", "--size", "8", "--single-device",
               "--option", "method=vi", "--option", "atol=1e-6",
               "--option", "file_stats_format=json",   # compat array format
               "--option", f"file_stats={stats}"])
    assert rc == 0
    entries = json.loads(stats.read_text())
    assert entries[0]["method"] == "vi"
    assert entries[0]["layout"] == "single"


def test_cli_env_ingestion(tmp_path, monkeypatch):
    from repro.launch.solve import main
    monkeypatch.setenv("MADUPITE_OPTIONS", "-method vi -atol 1e-5")
    stats = tmp_path / "env.json"
    rc = main(["--instance", "maze2d", "--size", "8", "--single-device",
               "--option", f"file_stats={stats}"])
    assert rc == 0
    # default jsonl: one line per solve
    assert json.loads(stats.read_text().splitlines()[0])["method"] == "vi"
