"""Fleet-sharded solve layouts (instances x states) vs the replicated path.

The contract (ISSUE 2): ``solve_many`` under ``layout="fleet"`` /
``"fleet2d"`` shards the instance dim over the mesh's leading ``fleet``
axis and must produce per-instance results matching the replicated path —
bit-for-bit (values AND residual traces) for the elementwise method family
(vi / mpi: no cross-lane arithmetic anywhere), and with exact policies /
iteration paths plus ulp-level values for the Krylov methods (XLA batches
their inner dot products over the device-local lane count, so fp
association differs by vmap width).  Fleet checkpoints are mesh-agnostic:
stored unpadded, so a fleet interrupted on a 4-way fleet axis resumes on a
2-way one.

Multi-device paths run the real shard_map on 8 forced host devices in a
subprocess (device count must be set before jax initializes).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

_SCRIPT = r"""
import os, tempfile, shutil
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, json
from repro.core import generators, solve_many, IPIOptions
from repro.launch.mesh import make_fleet_mesh

# B=5 deliberately does NOT divide the 4-way fleet axis (exercises fleet
# padding with zero-cost dummy instances).
mdps = [generators.garnet(n=120, m=5, k=4, gamma=0.95, seed=s)
        for s in range(5)]
out = {}


def compare(rs, base):
    return dict(
        dv=max(float(np.abs(a.v - b.v).max()) for a, b in zip(rs, base)),
        dpi=sum(int((a.policy != b.policy).sum()) for a, b in zip(rs, base)),
        outer_eq=all(a.outer_iterations == b.outer_iterations
                     for a, b in zip(rs, base)),
        inner_eq=all(a.inner_iterations == b.inner_iterations
                     for a, b in zip(rs, base)),
        trace_res_eq=all(np.array_equal(a.trace_residual, b.trace_residual,
                                        equal_nan=True)
                         for a, b in zip(rs, base)),
        trace_inner_eq=all(np.array_equal(a.trace_inner, b.trace_inner)
                           for a, b in zip(rs, base)),
        converged=all(r.converged for r in rs),
        n_results=len(rs))


for method in ("vi", "ipi_gmres"):
    opts = IPIOptions(method=method, atol=1e-8, dtype="float64",
                      max_outer=20000)
    base = solve_many(mdps, opts)
    for layout, fleet in (("fleet", 4), ("fleet2d", 2)):
        mesh = make_fleet_mesh(fleet, layout=layout)
        rs = solve_many(mdps, opts, mesh=mesh, layout=layout)
        out[f"{method}/{layout}"] = compare(rs, base)

# mixed-gamma fleet: traced-gamma path under fleet sharding (the static
# per-instance gamma tuple is global; each fleet shard slices its block)
gmdps = [generators.garnet(n=100, m=5, k=4, gamma=g, seed=1)
         for g in (0.9, 0.95, 0.98, 0.99)]
opts = IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64")
rs = solve_many(gmdps, opts, mesh=make_fleet_mesh(4), layout="fleet")
out["mixed_gamma"] = compare(rs, solve_many(gmdps, opts))

# pad_fleet=False: incompatible B must raise an actionable ValueError
# before any device work, not a shape error inside shard_map
try:
    solve_many(mdps, IPIOptions(method="vi", atol=1e-6),
               mesh=make_fleet_mesh(4), layout="fleet", pad_fleet=False)
    out["pad_error"] = None
except ValueError as e:
    out["pad_error"] = str(e)

# deterministic_dots: fleet-sharded Krylov must be BIT-FOR-BIT equal to the
# replicated layout at matched state-shard count (both runs shard states
# 2-way; only the fleet-lane batching differs — the association hazard the
# flag pins).  Baselines replicate the fleet over a plain mesh.
from repro.launch.mesh import make_host_mesh
opts_det = IPIOptions(method="ipi_gmres", atol=1e-8, dtype="float64",
                      max_outer=20000, deterministic_dots=True)
det_base = solve_many(mdps, opts_det, mesh=make_host_mesh((2, 1)),
                      layout="1d")
det_fleet = solve_many(mdps, opts_det, mesh=make_fleet_mesh(4),
                       layout="fleet")
out["det_dots"] = compare(det_fleet, det_base)
det_base2 = solve_many(mdps, opts_det, mesh=make_host_mesh((2, 2)),
                       layout="2d")
det_fleet2 = solve_many(mdps, opts_det,
                        mesh=make_fleet_mesh(2, layout="fleet2d"),
                        layout="fleet2d")
out["det_dots_2d"] = compare(det_fleet2, det_base2)

# device-side from_functions: sharded placement must match the host
# callbacks bit-for-bit on 1d and 2d layouts, mincost and maxreward
# padding (n=501 pads to 504/8 shards)
from repro.api import MDP, Session
from repro.core.generators import chain_walk_functions


def fn_mdp(nn, gamma=0.99):
    # the canonical jit-able chain constructors; no device pin, so the
    # materialize="host"/"device" comparisons below exercise both pipelines
    spec = chain_walk_functions(nn, gamma=gamma)
    return MDP.from_functions(spec["P_fn"], spec["g_fn"], nn, 2, nnz=2,
                              gamma=gamma, vectorized=True)


for layout, shape in (("1d", (8, 1)), ("2d", (4, 2))):
    mesh = make_host_mesh(shape)
    for mode in ("mincost", "maxreward"):
        fm = fn_mdp(501)
        dev = fm.place(mesh, layout, mode=mode, materialize="device")
        host = fm.place(mesh, layout, mode=mode, materialize="host")
        out[f"fn_place/{layout}/{mode}"] = dict(
            bitwise=all(
                np.array_equal(np.asarray(getattr(dev, f)),
                               np.asarray(getattr(host, f)))
                for f in ("idx", "val", "cost")),
            n_to=dev.n_global, m_to=dev.m_global)

# function-backed fleet under layout="fleet" (Session path): every device
# materializes only its owned instances' row blocks; results must match
# the replicated path of host-built instances (vi: bit-for-bit)
fn_mdps = [fn_mdp(300, 0.95), fn_mdp(280, 0.95), fn_mdp(300, 0.95)]
vi = IPIOptions(method="vi", atol=1e-9, dtype="float64", max_outer=20000)
rep = solve_many([m.build(materialize="host") for m in fn_mdps], vi)
with Session({"-method": "vi", "-atol": 1e-9, "-dtype": "float64",
              "-max_outer": 20000}) as sess:
    fl = sess.solve_fleet(fn_mdps)
    fleet_layout = sess.stats[-1]["layout"]
out["fn_fleet"] = dict(
    layout=fleet_layout,
    dv=max(float(np.abs(a.v - b.v).max()) for a, b in zip(fl, rep)),
    dpi=sum(int((a.policy != b.policy).sum()) for a, b in zip(fl, rep)),
    lens=[len(r.v) for r in fl],
    converged=all(r.converged for r in fl))

# device-fleet checkpoints must record the TRUE B and n (not the padded
# container shapes): interrupt the Session's device-materialized fleet on
# the fleet mesh, then resume on the replicated host-built path
d2 = tempfile.mkdtemp(prefix="fnfleet_ck_")
try:
    with Session({"-method": "ipi_gmres", "-atol": 1e-9,
                  "-dtype": "float64", "-max_outer": 2,
                  "-checkpoint_dir": d2, "-chunk": 1}) as sess:
        part = sess.solve_fleet(fn_mdps)
    full = IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64",
                      max_outer=20000)
    hosts = [m.build(materialize="host") for m in fn_mdps]
    resumed = solve_many(hosts, full, checkpoint_dir=d2, chunk=16)
    base_u = solve_many(hosts, full)
    out["fn_fleet_elastic"] = dict(
        interrupted=bool(not any(r.converged for r in part)),
        dv=max(float(np.abs(a.v - b.v).max())
               for a, b in zip(resumed, base_u)),
        converged=all(r.converged for r in resumed))
except ValueError as e:
    out["fn_fleet_elastic"] = dict(error=str(e))
finally:
    shutil.rmtree(d2, ignore_errors=True)

# elastic fleet restart: checkpoint on a 4-way fleet axis, resume on 2-way
opts = IPIOptions(method="ipi_gmres", atol=1e-8, dtype="float64")
base = solve_many(mdps, opts)
d = tempfile.mkdtemp(prefix="fleet_ck_")
try:
    short = IPIOptions(method="ipi_gmres", atol=1e-8, dtype="float64",
                       max_outer=2)
    part = solve_many(mdps, short, mesh=make_fleet_mesh(4), layout="fleet",
                      checkpoint_dir=d, chunk=1)
    resumed = solve_many(mdps, opts, mesh=make_fleet_mesh(2),
                         layout="fleet", checkpoint_dir=d, chunk=16)
    out["elastic"] = compare(resumed, base)
    out["elastic"]["interrupted"] = bool(not any(r.converged for r in part))
finally:
    shutil.rmtree(d, ignore_errors=True)

# monitors under the fleet-sharded layouts (ISSUE 5): the lead-shard
# gating must yield exactly ONE host record per outer iteration (no
# per-device duplicate callbacks), with per-instance rows gathered over
# the fleet axis and trimmed to the true B (not the padded 8)
for layout, fleet in (("fleet", 4), ("fleet2d", 2)):
    recs = []
    mopts = IPIOptions(method="vi", atol=1e-8, dtype="float64",
                       max_outer=20000, monitor=True)
    rs_m = solve_many(mdps, mopts, mesh=make_fleet_mesh(fleet,
                                                        layout=layout),
                      layout=layout, monitor=recs.append)
    ks = [r["k"] for r in recs]
    out[f"monitor/{layout}"] = dict(
        n_records=len(recs),
        ks_contiguous=ks == list(range(len(ks))),
        unique=len(set(ks)) == len(ks),
        k_max=max(ks),
        outer_max=max(r.outer_iterations for r in rs_m),
        rows=len(recs[-1]["res"]),
        converged=all(r.converged for r in rs_m))

# span-seminorm stopping compiled into the fleet-sharded loop: bit-equal
# to the replicated span run (vi), strictly fewer outers than atol
vi_kw = dict(method="vi", atol=1e-8, dtype="float64", max_outer=20000)
rs_atol = solve_many(mdps, IPIOptions(**vi_kw))
rs_span_rep = solve_many(mdps, IPIOptions(stop_criterion="span", **vi_kw))
rs_span = solve_many(mdps, IPIOptions(stop_criterion="span", **vi_kw),
                     mesh=make_fleet_mesh(4), layout="fleet")
out["span_fleet"] = dict(
    converged=all(r.converged for r in rs_span),
    dv=max(float(np.abs(a.v - b.v).max())
           for a, b in zip(rs_span, rs_span_rep)),
    outer_eq=all(a.outer_iterations == b.outer_iterations
                 for a, b in zip(rs_span, rs_span_rep)),
    strictly_fewer=all(a.outer_iterations < b.outer_iterations
                       for a, b in zip(rs_span, rs_atol)),
    same_policy=all((a.policy == b.policy).all()
                    for a, b in zip(rs_span, rs_atol)))

# span with NON-divisible n: mesh padding appends residual-0 absorbing
# rows which must be masked out of the span min (n=301 pads to 304 on 8
# shards) — sharded outer count must equal the replicated one
from repro.core.driver import solve as driver_solve
cw = generators.chain_walk(301, gamma=0.999)
sp = IPIOptions(method="vi", atol=1e-8, dtype="float64",
                max_outer=100000, stop_criterion="span")
r_cw_rep = driver_solve(cw, sp)
r_cw_sh = driver_solve(cw, sp, mesh=make_host_mesh((8, 1)), layout="1d")
out["span_nondivisible"] = dict(
    rep_outer=r_cw_rep.outer_iterations, sh_outer=r_cw_sh.outer_iterations,
    converged=r_cw_rep.converged and r_cw_sh.converged,
    dpi=int((r_cw_rep.policy != r_cw_sh.policy).sum()))

# acceptance: a USER-registered ksp (env-ingested -ksp_type) runs under
# the fleet-sharded layout and matches the replicated path
from repro.api import Options, register_ksp
from repro.core.solvers import richardson as _rich
register_ksp("myrich",
             lambda mv, b, x0, *, tol, maxiter, axes:
             _rich(mv, b, x0, tol=tol, maxiter=maxiter, axes=axes,
                   omega=0.9))
os.environ["MADUPITE_OPTIONS"] = "-ksp_type myrich"
uopts = Options.from_sources(
    values={"-atol": 1e-8, "-dtype": "float64",
            "-max_outer": 20000}).to_ipi()
u_rep = solve_many(mdps, uopts)
u_fleet = solve_many(mdps, uopts, mesh=make_fleet_mesh(4), layout="fleet")
out["user_ksp_fleet"] = dict(
    method=uopts.method,
    converged=all(r.converged for r in u_fleet),
    dv=max(float(np.abs(a.v - b.v).max())
           for a, b in zip(u_fleet, u_rep)),
    dpi=sum(int((a.policy != b.policy).sum())
            for a, b in zip(u_fleet, u_rep)))

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def fleet_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("layout", ["fleet", "fleet2d"])
def test_fleet_sharded_bit_for_bit_elementwise(fleet_results, layout):
    """vi has no cross-lane arithmetic: fleet-sharded values and residual
    traces must equal the replicated path exactly (non-divisible B=5
    included — the dummy pad instances must never leak into results)."""
    r = fleet_results[f"vi/{layout}"]
    assert r["converged"] and r["n_results"] == 5
    assert r["dv"] == 0.0, r
    assert r["dpi"] == 0 and r["trace_res_eq"] and r["trace_inner_eq"], r
    assert r["outer_eq"] and r["inner_eq"], r


@pytest.mark.parametrize("layout", ["fleet", "fleet2d"])
def test_fleet_sharded_krylov_parity(fleet_results, layout):
    """ipi_gmres: identical iteration path and policies; values agree to
    ulp-level (batched dot association differs by device-local lane
    count)."""
    r = fleet_results[f"ipi_gmres/{layout}"]
    assert r["converged"]
    assert r["dv"] < 1e-12, r
    assert r["dpi"] == 0, r
    assert r["outer_eq"] and r["inner_eq"] and r["trace_inner_eq"], r


def test_fleet_sharded_mixed_gamma(fleet_results):
    r = fleet_results["mixed_gamma"]
    assert r["converged"]
    assert r["dv"] < 1e-8, r
    assert r["dpi"] == 0 and r["outer_eq"], r


@pytest.mark.parametrize("key", ["det_dots", "det_dots_2d"])
def test_deterministic_dots_bit_for_bit_across_layouts(fleet_results, key):
    """ISSUE 4 / ROADMAP open item: with -deterministic_dots the
    fleet-sharded Krylov solve must equal the replicated layout EXACTLY
    (values and residual traces) at matched state-shard count — the
    lane-at-a-time projections remove the vmap-width dot association."""
    r = fleet_results[key]
    assert r["converged"] and r["n_results"] == 5
    assert r["dv"] == 0.0, r
    assert r["dpi"] == 0, r
    assert r["trace_res_eq"] and r["trace_inner_eq"], r
    assert r["outer_eq"] and r["inner_eq"], r


@pytest.mark.parametrize("layout", ["1d", "2d"])
@pytest.mark.parametrize("mode", ["mincost", "maxreward"])
def test_device_materialization_sharded_parity(fleet_results, layout, mode):
    """Device-pipeline from_functions placement must be bit-for-bit the
    host-callback placement on sharded meshes, padding included."""
    r = fleet_results[f"fn_place/{layout}/{mode}"]
    assert r["bitwise"], r
    assert r["n_to"] == 504 if layout == "1d" else r["n_to"] % 4 == 0


def test_function_backed_fleet_layout(fleet_results):
    """Function-backed MDPs solve under layout='fleet' (per-instance
    constructors sharded over the fleet axis) with results matching the
    replicated path bit-for-bit (vi), trimmed to each true n."""
    r = fleet_results["fn_fleet"]
    assert r["layout"] in ("fleet", "fleet2d"), r
    assert r["converged"], r
    assert r["dv"] == 0.0 and r["dpi"] == 0, r
    assert r["lens"] == [300, 280, 300], r


def test_function_backed_fleet_checkpoint_elastic(fleet_results):
    """A device-materialized fleet's checkpoint stores the true (B, n) —
    resuming on the replicated host-built path must work (not raise
    'refusing to resume') and converge to the uninterrupted solution."""
    r = fleet_results["fn_fleet_elastic"]
    assert "error" not in r, r
    assert r["interrupted"], "phase 1 unexpectedly converged"
    assert r["converged"], r
    assert r["dv"] < 1e-8, r


def test_pad_fleet_disabled_raises_actionable(fleet_results):
    msg = fleet_results["pad_error"]
    assert msg is not None, "pad_fleet=False did not raise"
    assert "B=5" in msg and "4-way" in msg and "pad_fleet" in msg, msg


def test_fleet_checkpoint_restores_onto_smaller_fleet_axis(fleet_results):
    """Interrupt on fleet-axis 4, resume on fleet-axis 2: same iterate
    path as an uninterrupted solve (mesh-agnostic fleet checkpoints)."""
    r = fleet_results["elastic"]
    assert r["interrupted"], "phase 1 unexpectedly converged"
    assert r["converged"]
    assert r["dv"] < 1e-12 and r["dpi"] == 0, r
    assert r["outer_eq"], "resume diverged from the uninterrupted path"


@pytest.mark.parametrize("layout", ["fleet", "fleet2d"])
def test_monitor_one_record_per_iteration_under_fleet(fleet_results, layout):
    """ISSUE 5 satellite: the monitor callback fires on every device but
    only the lead shard's record is kept — exactly one host record per
    outer iteration (k=0 included), ks contiguous, rows trimmed to the
    true B=5 (not the padded 8)."""
    r = fleet_results[f"monitor/{layout}"]
    assert r["converged"], r
    assert r["unique"] and r["ks_contiguous"], r
    assert r["n_records"] == r["k_max"] + 1, r
    assert r["k_max"] == r["outer_max"], r
    assert r["rows"] == 5, r


def test_span_criterion_under_fleet_layout(fleet_results):
    """-stop_criterion span compiles into the fleet-sharded loop: bit-equal
    values vs the replicated span run, strictly fewer outers than atol
    with the same returned policies."""
    r = fleet_results["span_fleet"]
    assert r["converged"], r
    assert r["dv"] == 0.0 and r["outer_eq"], r
    assert r["strictly_fewer"], r
    assert r["same_policy"], r


def test_span_masks_mesh_padding_nondivisible_n(fleet_results):
    """n=301 pads to 304 on 8 state shards; the padded rows' 0 residual
    must not enter the span min — sharded and replicated span runs stop at
    the identical outer count."""
    r = fleet_results["span_nondivisible"]
    assert r["converged"], r
    assert r["sh_outer"] == r["rep_outer"], r
    assert r["dpi"] == 0, r


def test_user_registered_ksp_under_fleet_layout(fleet_results):
    """Acceptance: a register_ksp solver selected via MADUPITE_OPTIONS
    -ksp_type runs under layout='fleet' and matches the replicated path."""
    r = fleet_results["user_ksp_fleet"]
    assert r["method"] == "ipi_myrich", r
    assert r["converged"], r
    assert r["dv"] < 1e-10 and r["dpi"] == 0, r


def test_elastic_restart_nondivisible_n():
    """ROADMAP open item: n=500 pads to 504 on 8 shards but to 500 on 4;
    mesh-agnostic checkpoints must store the unpadded n so the 8 -> 4
    restart works for every n, not just divisible ones."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.elastic", "--n", "500"],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        proc.stdout[-2000:] + "\n" + proc.stderr[-2000:]
    assert "elastic restart preserved the solve exactly" in proc.stdout


# --------------------------------------------------------------------------- #
# In-process guards (no multi-device mesh needed)                             #
# --------------------------------------------------------------------------- #

def test_fleet_layout_guards():
    from repro.core import IPIOptions, generators, solve, solve_many
    mdp = generators.garnet(n=40, m=3, k=2, gamma=0.9, seed=0)
    with pytest.raises(ValueError, match="solve_many"):
        solve(mdp, IPIOptions(), layout="fleet")
    with pytest.raises(ValueError, match="mesh"):
        solve_many([mdp, mdp], IPIOptions(), layout="fleet")


def test_fleet_padded_batch_validation():
    from repro.core.partition import fleet_padded_batch
    assert fleet_padded_batch(8, 4) == 8
    assert fleet_padded_batch(5, 4) == 8
    assert fleet_padded_batch(5, 4, pad=True) == 8
    with pytest.raises(ValueError, match="pad_fleet"):
        fleet_padded_batch(5, 4, pad=False)
    assert fleet_padded_batch(4, 4, pad=False) == 4


def test_pad_fleet_dim_dummy_instances_are_frozen():
    """Dummy pad instances must carry zero cost (optimal value 0, residual
    0 at the solver's zero start -> frozen immediately) and valid
    probability rows."""
    from repro.core import generators, stack_mdps
    from repro.core.mdp import gammas_of
    from repro.core.partition import pad_fleet_dim
    mdps = [generators.garnet(n=30, m=3, k=2, gamma=g, seed=s)
            for s, g in enumerate((0.9, 0.95, 0.99))]
    st = stack_mdps(mdps)
    padded = pad_fleet_dim(st, 4)
    assert padded.batch == 4
    assert gammas_of(padded) == (0.9, 0.95, 0.99, 0.99)
    pad_val = np.asarray(padded.val)[3]
    pad_cost = np.asarray(padded.cost)[3]
    np.testing.assert_allclose(pad_val.sum(-1), 1.0, atol=1e-6)
    assert (pad_cost == 0.0).all()
    # real instances untouched
    np.testing.assert_array_equal(np.asarray(padded.val)[:3],
                                  np.asarray(st.val))
    with pytest.raises(ValueError, match="unbatched|batched"):
        pad_fleet_dim(mdps[0], 4)


def test_mesh_axes_fleet_layouts():
    import jax
    from repro.core.partition import mesh_axes
    from repro.launch.mesh import mesh_kwargs
    mesh2 = jax.make_mesh((1, 1), ("fleet", "data"), **mesh_kwargs(2))
    ax = mesh_axes(mesh2, "fleet")
    assert ax.fleet == "fleet" and ax.state == ("data",) and ax.action is None
    mesh3 = jax.make_mesh((1, 1, 1), ("fleet", "data", "model"),
                          **mesh_kwargs(3))
    ax = mesh_axes(mesh3, "fleet2d")
    assert ax.fleet == "fleet" and ax.state == ("data",) \
        and ax.action == "model"
    with pytest.raises(ValueError, match="layout"):
        mesh_axes(mesh2, "nope")
