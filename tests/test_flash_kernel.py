"""Pallas flash-attention kernel vs the chunked-scan oracle (which itself is
validated against dense attention in test_models.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import chunked_attention


def _qkv(key, b, t, s, h, kv, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, t, h, d), dtype)
    k = jax.random.normal(k2, (b, s, kv, d), dtype)
    v = jax.random.normal(k3, (b, s, kv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,t,h,kv,d", [
    (1, 64, 4, 4, 32),      # MHA
    (2, 96, 4, 2, 64),      # GQA, non-block-multiple T
    (1, 128, 8, 1, 16),     # MQA
])
def test_flash_matches_oracle_causal(b, t, h, kv, d):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, t, t, h, kv, d)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    ref = chunked_attention(q, k, v, q_offset=0, chunk=32, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 32, 64, 4, 4, 32)
    out = flash_attention(q, k, v, causal=False, block_q=16, block_k=32,
                          interpret=True)
    ref = chunked_attention(q, k, v, q_offset=0, chunk=32, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16_inputs():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 64, 64, 4, 2, 32, jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    ref = chunked_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), q_offset=0, chunk=32,
                            causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=2e-2, rtol=2e-2)
