"""Communication-overlapped backups + asynchronous VI (ISSUE 7).

Two invariant families:

* **overlap parity** — ``-comm_overlap on`` splits every backup into an
  interior part (computed while the value window is in flight) and a
  frontier part (finished against the arrived window); the split must be
  *bitwise* invisible: identical values, policies and residual traces to
  ``-comm_overlap off`` for every method and layout, including halo
  layouts and non-divisible state counts (where the plan degrades to the
  synchronous path rather than mis-splitting).
* **async_vi certification** — ``-method async_vi`` runs ``-async_sweeps``
  stale local sweeps per value exchange; it must converge in fewer value
  exchanges than synchronous vi, return the same policy, and its
  midpoint-corrected value must actually lie within the reported span gap
  certificate of the true optimum.

The distributed cases run the real shard_map path on 8 forced host devices
in a subprocess (device count must be set before jax initializes).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import IPIOptions, generators, partition

# --------------------------------------------------------------------------- #
# Interior/frontier split classification (host-side, no mesh needed)          #
# --------------------------------------------------------------------------- #


def test_margins_stencil_chain():
    """chain_walk successors are {s-1, s, s+1}: exactly one frontier row at
    each shard edge."""
    mdp = generators.chain_walk(64, gamma=0.9)
    assert partition.overlap_margins(mdp, 8) == (1, 1)


def test_margins_respect_nonzero_weights_only():
    """Zero-weight ELL fill entries must not create frontier rows — only
    columns that actually contribute count."""
    mdp = generators.chain_walk(64, gamma=0.9)
    # point every padding-like slot at a remote column with weight 0
    val = np.asarray(mdp.val).copy()
    idx = np.asarray(mdp.idx).copy()
    idx[:, :, -1] = 0            # all rows "reference" state 0 ...
    val[:, :, -1] = 0.0          # ... with zero weight
    import dataclasses
    poked = dataclasses.replace(mdp, idx=idx, val=val)
    assert partition.overlap_margins(poked, 8) == (1, 1)


def test_frontier_reach_stencil_chain():
    """chain_walk rows reference {s-1, s, s+1}: frontier rows reach exactly
    one column past the shard boundary, so the planner can run the solve on
    a width-1 halo ring exchange instead of the full all-gather."""
    mdp = generators.chain_walk(64, gamma=0.9)
    assert partition.frontier_reach(mdp, 8) == 1


def test_frontier_reach_matches_maze_bandwidth():
    """maze2d's 5-point stencil couples rows +-width: the reach equals the
    grid width (up/down neighbours cross shard boundaries by one grid row)."""
    mdp = generators.maze2d(32, gamma=0.9)
    assert partition.frontier_reach(mdp, 8) == 32


def test_frontier_reach_ignores_zero_weight_fill():
    import dataclasses
    mdp = generators.chain_walk(64, gamma=0.9)
    val = np.asarray(mdp.val).copy()
    idx = np.asarray(mdp.idx).copy()
    fill = val == 0
    idx[fill] = 63          # remote column, but weight 0: must not count
    mdp = dataclasses.replace(mdp, idx=idx, val=val)
    assert partition.frontier_reach(mdp, 8) == 1


def test_frontier_reach_undefined_cases():
    mdp = generators.chain_walk(64, gamma=0.9)
    assert partition.frontier_reach(mdp, 1) is None      # single shard
    mdp63 = generators.chain_walk(63, gamma=0.9)
    assert partition.frontier_reach(mdp63, 8) is None    # ragged partition


def test_margins_dense_coupling_disables_plan():
    """garnet rows draw random global columns: no interior — no plan."""
    mdp = generators.garnet(n=64, m=3, k=4, gamma=0.9, seed=0)
    assert partition.overlap_margins(mdp, 8) is None


def test_margins_non_divisible_n_disables_plan():
    mdp = generators.chain_walk(63, gamma=0.9)
    assert partition.overlap_margins(mdp, 8) is None


def test_margins_single_shard_disables_plan():
    mdp = generators.chain_walk(64, gamma=0.9)
    assert partition.overlap_margins(mdp, 1) is None


def test_margins_classification_is_sound():
    """Every row outside the reported margins must have all of its
    nonzero-weight successors inside its own shard block."""
    mdp = generators.maze2d(32, gamma=0.95)          # n = 1024, bandwidth 32
    n_shards = 8
    f_lo, f_hi = partition.overlap_margins(mdp, n_shards)
    n = mdp.n_global
    n_local = n // n_shards
    idx = np.asarray(mdp.idx)
    nz = np.asarray(mdp.val) != 0
    for s in range(n):
        i_loc = s % n_local
        if f_lo <= i_loc < n_local - f_hi:           # classified interior
            start = s - i_loc
            cols = idx[s][nz[s]]
            assert cols.min() >= start
            assert cols.max() < start + n_local, (s, f_lo, f_hi)


def test_comm_overlap_option_validated():
    with pytest.raises(ValueError, match="comm_overlap"):
        IPIOptions(comm_overlap="sometimes")
    with pytest.raises(ValueError, match="async_sweeps"):
        IPIOptions(async_sweeps=0)


# --------------------------------------------------------------------------- #
# 8-fake-device parity (subprocess: real shard_map + collectives)             #
# --------------------------------------------------------------------------- #

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, json
from repro.core import generators, IPIOptions
from repro.core.driver import solve, solve_many
from repro.launch.mesh import make_fleet_mesh, mesh_kwargs

out = {}


def pair(tag, mdp, method, mesh, layout, **kw):
    rs = {}
    for ov in ("off", "on"):
        opts = IPIOptions(method=method, dtype="float64",
                          comm_overlap=ov, **kw)
        rs[ov] = solve(mdp, opts, mesh=mesh, layout=layout)
    a, b = rs["off"], rs["on"]
    out[tag] = dict(
        dv_bits=int((np.asarray(a.v).view(np.uint64)
                     != np.asarray(b.v).view(np.uint64)).sum()),
        dpi=int((a.policy != b.policy).sum()),
        trace_eq=bool(np.array_equal(a.trace_residual, b.trace_residual,
                                     equal_nan=True)),
        outer=int(a.outer_iterations), outer_on=int(b.outer_iterations))


mesh = jax.make_mesh((4, 2), ("data", "model"), **mesh_kwargs(2))
mesh1d = jax.make_mesh((8,), ("data",), **mesh_kwargs(1))
chain = generators.chain_walk(512, gamma=0.99)
maze = generators.maze2d(24, gamma=0.99)

# stencil workload, every method, 1d + 2d layouts — parity along the whole
# (unconverged) trajectory, which is stricter than at the fixed point
for method in ("vi", "mpi", "ipi_gmres"):
    pair(f"{method}/1d", chain, method, mesh1d, "1d",
         atol=1e-12, max_outer=40)
    pair(f"{method}/2d", chain, method, mesh, "2d",
         atol=1e-12, max_outer=40)

# halo layout: window is the +-halo exchange, margins come from the band
pair("vi/halo", maze, "vi", mesh1d, "1d", atol=1e-12, max_outer=40, halo=24)

# non-divisible n: plan must degrade to the synchronous path, not mis-split
pair("vi/raggedn", generators.chain_walk(509, gamma=0.99), "vi", mesh1d,
     "1d", atol=1e-12, max_outer=40)

# fleet layout (solve_many): margins on the batched shard
fleet_mdps = [generators.chain_walk(256, gamma=g) for g in (0.95, 0.97)]
frs = {}
for ov in ("off", "on"):
    frs[ov] = solve_many(
        fleet_mdps, IPIOptions(method="vi", dtype="float64", atol=1e-12,
                               max_outer=40, comm_overlap=ov),
        mesh=make_fleet_mesh(4), layout="fleet")
out["vi/fleet"] = dict(
    dv_bits=int(sum((np.asarray(a.v).view(np.uint64)
                     != np.asarray(b.v).view(np.uint64)).sum()
                    for a, b in zip(frs["off"], frs["on"]))),
    dpi=int(sum((a.policy != b.policy).sum()
                for a, b in zip(frs["off"], frs["on"]))),
    trace_eq=all(np.array_equal(a.trace_residual, b.trace_residual,
                                equal_nan=True)
                 for a, b in zip(frs["off"], frs["on"])),
    outer=int(frs["off"][0].outer_iterations),
    outer_on=int(frs["on"][0].outer_iterations))

# ---- async_vi: fewer exchanges, same policy, certificate actually holds ----
ref = solve(chain, IPIOptions(method="vi", atol=1e-10, dtype="float64",
                              max_outer=20000), mesh=mesh1d, layout="1d")
sync = solve(chain, IPIOptions(method="vi", atol=1e-6,
                               stop_criterion="span", dtype="float64",
                               max_outer=20000), mesh=mesh1d, layout="1d")
asy = solve(chain, IPIOptions(method="async_vi", async_sweeps=8, atol=1e-6,
                              stop_criterion="span", dtype="float64",
                              max_outer=20000), mesh=mesh1d, layout="1d")
out["async"] = dict(
    converged=bool(asy.converged and sync.converged),
    outer_sync=int(sync.outer_iterations), outer_async=int(asy.outer_iterations),
    dpi=int((asy.policy != sync.policy).sum()),
    gap=float(asy.gap_bound),
    err=float(np.abs(np.asarray(asy.v) - np.asarray(ref.v)).max()))

# async_sweeps=1 IS synchronous vi (bit-for-bit, including the trace)
a1 = solve(chain, IPIOptions(method="async_vi", async_sweeps=1, atol=1e-6,
                             stop_criterion="span", dtype="float64",
                             max_outer=20000), mesh=mesh1d, layout="1d")
out["async1"] = dict(
    dv_bits=int((np.asarray(a1.v).view(np.uint64)
                 != np.asarray(sync.v).view(np.uint64)).sum()),
    outer_eq=bool(a1.outer_iterations == sync.outer_iterations),
    trace_eq=bool(np.array_equal(a1.trace_residual, sync.trace_residual,
                                 equal_nan=True)))

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


_PAIR_KEYS = ["vi/1d", "vi/2d", "mpi/1d", "mpi/2d", "ipi_gmres/1d",
              "ipi_gmres/2d", "vi/halo", "vi/raggedn", "vi/fleet"]


@pytest.mark.parametrize("key", _PAIR_KEYS)
def test_overlap_is_bitwise_invisible(results, key):
    r = results[key]
    assert r["dv_bits"] == 0, r
    assert r["dpi"] == 0, r
    assert r["trace_eq"], r
    assert r["outer"] == r["outer_on"], r


def test_async_vi_fewer_exchanges_same_policy(results):
    r = results["async"]
    assert r["converged"]
    assert r["outer_async"] < r["outer_sync"], r
    assert r["dpi"] == 0, r


def test_async_vi_certificate_holds(results):
    """The midpoint-corrected value must really be within gap_bound of the
    optimum — the certificate is a guarantee, not a heuristic."""
    r = results["async"]
    assert r["gap"] > 0
    assert r["err"] <= r["gap"] * 1.01 + 1e-9, r


def test_async_sweeps_one_is_vi(results):
    r = results["async1"]
    assert r["dv_bits"] == 0 and r["outer_eq"] and r["trace_eq"], r
