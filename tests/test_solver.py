"""Correctness of the iPI solver family against exact oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IPIOptions, generators, solve
from repro.core.solvers import dense_policy_value

GAMMA = 0.95
ALL_METHODS = ["vi", "mpi", "ipi_richardson", "ipi_gmres", "ipi_bicgstab",
               "pi", "ipi_chebyshev", "ipi_anderson"]


def _value_iteration_oracle(mdp, tol=1e-10, iters=100000):
    """Plain numpy VI to machine precision, on the *identical* ELL
    arithmetic the solver uses (a dense f32 matrix would round duplicate
    successor entries differently)."""
    idx = np.asarray(mdp.idx)
    val = np.asarray(mdp.val, np.float64)
    g = np.asarray(mdp.cost, np.float64)
    v = np.zeros(idx.shape[0])
    for _ in range(iters):
        q = g + mdp.gamma * (val * v[idx]).sum(-1)
        v_new = q.min(1)
        if np.abs(v_new - v).max() < tol:
            return v_new, q.argmin(1)
        v = v_new
    raise AssertionError("oracle VI did not converge")


@pytest.fixture(scope="module")
def garnet_small():
    mdp = generators.garnet(n=120, m=6, k=4, gamma=GAMMA, seed=0)
    v_star, pi_star = _value_iteration_oracle(mdp)
    return mdp, v_star, pi_star


@pytest.mark.parametrize("method", ALL_METHODS)
def test_method_reaches_optimum(garnet_small, method):
    mdp, v_star, _ = garnet_small
    r = solve(mdp, IPIOptions(method=method, atol=1e-9, dtype="float64",
                              max_outer=20000))
    assert r.converged, r.summary()
    np.testing.assert_allclose(r.v, v_star, atol=1e-7)
    # the returned policy must be exactly optimal-greedy: its exact value
    # equals v*
    v_pi = dense_policy_value(mdp, jnp.asarray(r.policy))
    np.testing.assert_allclose(np.asarray(v_pi), v_star, atol=1e-6)


@pytest.mark.parametrize("gen,kw", [
    (generators.maze2d, dict(size=10, gamma=0.98)),
    (generators.sis, dict(pop=150, n_actions=4, gamma=0.97)),
    (generators.chain_walk, dict(n=200, gamma=0.99)),
])
def test_instance_families(gen, kw):
    mdp = gen(**kw)
    mdp.validate()
    v_star, _ = _value_iteration_oracle(mdp)
    r = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64"))
    assert r.converged
    np.testing.assert_allclose(r.v, v_star, atol=1e-6)


def test_gap_certificate(garnet_small):
    """||v - v*||_inf <= residual / (1 - gamma) must hold at any tolerance."""
    mdp, v_star, _ = garnet_small
    r = solve(mdp, IPIOptions(method="vi", atol=1e-3, dtype="float64"))
    assert np.abs(r.v - v_star).max() <= r.gap_bound * (1 + 1e-9) + 1e-12


def test_vi_residual_contracts(garnet_small):
    mdp, _, _ = garnet_small
    r = solve(mdp, IPIOptions(method="vi", atol=1e-8, dtype="float64"))
    tr = r.trace_residual
    # gamma-contraction of the Bellman residual (relative fp slack: the
    # ratio sits exactly at gamma, so ulp-level noise crosses it)
    assert (tr[1:] <= GAMMA * tr[:-1] * (1 + 1e-6) + 1e-12).all()


def test_krylov_beats_vi_on_hard_instance():
    """The paper's headline: on gamma->1 instances Krylov-iPI crushes VI."""
    mdp = generators.chain_walk(n=300, gamma=0.999)
    r_vi = solve(mdp, IPIOptions(method="vi", atol=1e-8, max_outer=30000,
                                 dtype="float64"))
    r_gm = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-8,
                                 max_outer=100, dtype="float64"))
    assert r_gm.converged
    np.testing.assert_allclose(r_gm.v, r_vi.v, atol=1e-4)
    assert r_gm.outer_iterations <= r_vi.outer_iterations / 100


def test_special_case_equivalences(garnet_small):
    """mPI with 1 sweep == VI (same iterates)."""
    mdp, _, _ = garnet_small
    r_vi = solve(mdp, IPIOptions(method="vi", atol=1e-6, dtype="float64"))
    r_m1 = solve(mdp, IPIOptions(method="mpi", mpi_sweeps=1, atol=1e-6,
                                 dtype="float64"))
    assert r_vi.outer_iterations == r_m1.outer_iterations
    np.testing.assert_allclose(r_vi.v, r_m1.v, atol=0)


def test_warm_start(garnet_small):
    mdp, v_star, _ = garnet_small
    r = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64"),
              v0=jnp.asarray(v_star))
    assert r.converged and r.outer_iterations <= 1
