"""Batched fleet engine (solve_many) vs B independent solve calls.

The contract (ISSUE 1): per-instance results must be what the per-instance
solves produce — policy bit-for-bit, values to atol, and per-instance
iteration counts / traces exact (this exercises the convergence-mask freeze:
instances converge at different outer k and must stop accumulating).
"""

import numpy as np
import pytest

from repro.core import (IPIOptions, generators, solve, solve_many,
                        stack_mdps)
from repro.core.mdp import batch_parts


def _fleet(seeds, gamma=0.95, n=120, m=6, k=4):
    return [generators.garnet(n=n, m=m, k=k, gamma=gamma, seed=s)
            for s in seeds]


def _assert_matches(singles, fleet, v_atol=1e-9):
    assert len(singles) == len(fleet)
    for b, (s, f) in enumerate(zip(singles, fleet)):
        assert f.converged, f"instance {b}: {f.summary()}"
        np.testing.assert_array_equal(f.policy, s.policy,
                                      err_msg=f"instance {b} policy")
        np.testing.assert_allclose(f.v, s.v, atol=v_atol,
                                   err_msg=f"instance {b} values")
        assert f.outer_iterations == s.outer_iterations, \
            f"instance {b}: outer {f.outer_iterations} != " \
            f"{s.outer_iterations} (freeze broken)"


@pytest.mark.parametrize("method", ["vi", "mpi", "ipi_gmres", "ipi_bicgstab"])
def test_solve_many_matches_independent(method):
    """B=4 heterogeneous garnets; per-instance parity incl. iteration
    counts, inner totals and traces."""
    mdps = _fleet(seeds=[0, 1, 2, 3])
    opts = IPIOptions(method=method, atol=1e-9, dtype="float64",
                      max_outer=20000)
    singles = [solve(m, opts) for m in mdps]
    fleet = solve_many(mdps, opts)
    _assert_matches(singles, fleet)
    # instances must NOT all converge at the same k, else the freeze path
    # was never exercised
    if method in ("ipi_gmres", "ipi_bicgstab"):
        assert len({r.outer_iterations for r in fleet}) > 1 or \
            len({r.inner_iterations for r in fleet}) > 1
    for s, f in zip(singles, fleet):
        assert f.inner_iterations == s.inner_iterations
        # Krylov dot-product reduction order may differ by ~1 ulp under vmap
        np.testing.assert_allclose(f.trace_residual, s.trace_residual,
                                   atol=1e-12, rtol=1e-4)
        np.testing.assert_array_equal(f.trace_inner, s.trace_inner)


def test_gamma_sweep_fleet():
    """Heterogeneous gammas run the traced-gamma path (exact algebra,
    fp-level rounding): values to tolerance, policies and counts exact."""
    gammas = [0.9, 0.95, 0.99]
    mdps = [generators.garnet(n=100, m=5, k=4, gamma=g, seed=1)
            for g in gammas]
    st = stack_mdps(mdps)
    assert st.shared_topology            # same seed -> same sparsity
    assert st.gamma == tuple(gammas)
    _, _, gamma_t = batch_parts(st)
    assert gamma_t is not None           # traced-gamma path engaged
    opts = IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64")
    singles = [solve(m, opts) for m in mdps]
    fleet = solve_many(mdps, opts)
    _assert_matches(singles, fleet, v_atol=1e-7)


def test_heterogeneous_state_counts_pad_and_trim():
    mdps = [generators.garnet(n=90, m=4, k=3, gamma=0.95, seed=0),
            generators.garnet(n=120, m=4, k=3, gamma=0.95, seed=1)]
    opts = IPIOptions(method="mpi", atol=1e-8, dtype="float64")
    fleet = solve_many(mdps, opts)
    assert [len(r.v) for r in fleet] == [90, 120]
    _assert_matches([solve(m, opts) for m in mdps], fleet)


def test_stacked_container_and_instance_roundtrip():
    mdps = _fleet(seeds=[3, 4], gamma=0.9)
    st = stack_mdps(mdps)
    assert st.batch == 2 and not st.shared_topology
    st.validate()
    for b in range(2):
        inst = st.instance(b)
        np.testing.assert_array_equal(np.asarray(inst.idx),
                                      np.asarray(mdps[b].idx))
        assert inst.gamma == mdps[b].gamma


def test_solve_many_warm_start_and_guards():
    mdps = _fleet(seeds=[0, 1])
    opts = IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64")
    singles = [solve(m, opts) for m in mdps]
    fleet = solve_many(mdps, opts, v0s=[s.v for s in singles])
    assert all(r.outer_iterations <= 1 for r in fleet)
    with pytest.raises(ValueError, match="solve_many"):
        solve(stack_mdps(mdps), opts)
    with pytest.raises(ValueError, match="solve"):
        solve_many(mdps[0], opts)


def test_options_validation_raises():
    with pytest.raises(ValueError, match="method"):
        IPIOptions(method="nope")
    with pytest.raises(ValueError, match="dtype"):
        IPIOptions(dtype="bfloat16")
    with pytest.raises(ValueError, match="forcing_eta"):
        IPIOptions(forcing_eta=1.5)
    with pytest.raises(ValueError, match="halo"):
        IPIOptions(halo=-1)
    with pytest.raises(ValueError, match="gather_dtype"):
        IPIOptions(gather_dtype="int32")
    with pytest.raises(ValueError, match="wider"):
        IPIOptions(dtype="float32", gather_dtype="float64")


def test_generate_many_seed_ensemble_and_sweep():
    ens = generators.generate_many("garnet", 3, n=50, m=3, k=2, seed=10)
    assert len(ens) == 3
    assert not np.array_equal(np.asarray(ens[0].cost),
                              np.asarray(ens[1].cost))
    sw = generators.generate_many("chain_walk", 3, n=40,
                                  sweep={"gamma": [0.9, 0.99, 0.999]})
    assert [m.gamma for m in sw] == [0.9, 0.99, 0.999]
    with pytest.raises(ValueError, match="sweep"):
        generators.generate_many("garnet", 3, n=50, m=3, k=2,
                                 sweep={"gamma": [0.9]})
