"""LM substrate correctness: per-arch smoke + decode/prefill consistency +
mamba2 scan-vs-recurrence equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticSource
from repro.models import build_model
from repro.train.optimizer import init_opt_state
from repro.train.steps import (make_decode_step, make_prefill_step,
                               make_train_step)


def _batch(cfg, b=4, t=32):
    src = SyntheticSource(
        cfg.vocab_size, t, b, n_patches=cfg.n_patches, d_model=cfg.d_model,
        encoder_len=cfg.encoder_len if cfg.family == "encdec" else 0)
    return src.next_batch(0)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_train_step(arch):
    """Reduced config: one train step on CPU, finite loss, shapes preserved."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(remat="full", grad_dtype="float32")
    batch = _batch(cfg)
    step = make_train_step(model, tcfg, n_microbatches=2)
    p2, opt2, metrics = jax.jit(step)(
        params, init_opt_state(params, tcfg), jnp.int32(0), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == b.shape and a.dtype == b.dtype
    # params actually moved
    moved = max(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert moved > 0


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_full_config_constructs(arch):
    """The FULL assigned config must at least build abstract params with the
    exact dimensions (exercised for real via the dry-run)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    analytic = cfg.param_count()
    assert abs(n - analytic) / analytic < 0.05, (n, analytic)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_decode_matches_full_forward(arch):
    """Greedy decode step t must see the same logits as a full forward over
    t+1 tokens (cache correctness across every family)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, t = 2, 16
    batch = _batch(cfg, b, t + 1)
    toks = batch["tokens"]
    extra = batch.get("patches")
    # vlm: the synthetic source already budgets n_patches out of the text
    # tokens; decode the last *text* token in that case
    t = toks.shape[1] - 1

    prefill = make_prefill_step(model)
    decode = make_decode_step(model)
    _, cache = jax.jit(prefill)(params, toks[:, :t], extra)

    # pad attention caches by 1 slot for the new token
    def pad_kv(path, x):
        names = [str(getattr(p, "key", "")) for p in path]
        if names[-1] in ("k", "v"):
            return jnp.pad(x, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        return x
    cache = jax.tree_util.tree_map_with_path(pad_kv, cache)

    _, logits_dec, _ = jax.jit(decode)(params, toks[:, t:t + 1], cache)

    # full forward over t+1 tokens
    if cfg.family == "encdec":
        hidden, _, _ = model.forward(params, toks[:, :t + 1], frames=extra,
                                     mode="train", remat="none")
    elif cfg.family == "vlm":
        hidden, _, _ = model.forward(params, toks[:, :t + 1], patches=extra,
                                     mode="train", remat="none")
    else:
        hidden, _, _ = model.forward(params, toks[:, :t + 1], mode="train",
                                     remat="none")
    logits_full = model.logits(params, hidden[:, -1:])
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32), atol=2e-3, rtol=2e-3)


def test_mamba2_scan_equals_recurrence():
    """Chunked SSD (training path) must equal the token-by-token recurrence
    (decode path) — the state-space-duality identity."""
    cfg = get_smoke_config("mamba2-130m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    b, t = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, t), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    hidden_scan, _, _ = model.forward(params, toks, mode="train",
                                      remat="none")
    cache = model.init_cache(b, t, dtype=jnp.float32)
    outs = []
    decode = make_decode_step(model)
    for i in range(t):
        _, logits, cache = decode(params, toks[:, i:i + 1], cache)
        outs.append(logits)
    logits_step = jnp.concatenate(outs, axis=1)
    logits_scan = model.logits(params, hidden_scan)
    np.testing.assert_allclose(np.asarray(logits_step, np.float32),
                               np.asarray(logits_scan, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_chunked_attention_matches_dense():
    """Flash-scan attention == plain softmax attention."""
    from repro.models.attention import chunked_attention
    rng = jax.random.PRNGKey(0)
    b, t, h, kv, hd = 2, 40, 4, 2, 16
    q = jax.random.normal(rng, (b, t, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, t, kv, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, t, kv, hd))
    out = chunked_attention(q, k, v, q_offset=0, chunk=8, causal=True)
    # dense reference
    qg = q.reshape(b, t, kv, h // kv, hd)
    sc = jnp.einsum("btkgh,bskh->bkgts", qg, k) * hd ** -0.5
    mask = jnp.tril(jnp.ones((t, t), bool))
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    ref = jnp.einsum("bkgts,bskh->btkgh", p, v).reshape(b, t, h, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_moe_single_expert_equals_dense_mlp():
    """n_experts=1, top_k=1, ample capacity -> MoE == plain MLP."""
    from repro.models.layers import apply_mlp
    from repro.models.moe import apply_moe, init_moe
    cfg = dataclasses.replace(
        get_smoke_config("olmoe-1b-7b"), n_experts=1, top_k=1,
        capacity_factor=2.0, moe_group_size=16)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y_moe, aux = apply_moe(p, x, cfg)
    dense_params = {"w_gate": p["w_gate"][0], "w_up": p["w_up"][0],
                    "w_down": p["w_down"][0]}
    y_mlp = apply_mlp(dense_params, x, "swiglu")
    np.testing.assert_allclose(np.asarray(y_moe), np.asarray(y_mlp),
                               atol=1e-5, rtol=1e-5)
    assert np.isfinite(float(aux["load_balance_loss"]))


def test_moe_capacity_drops_tokens():
    """With capacity factor << 1 some tokens must be dropped (combine rows
    sum to < 1) but the layer still runs and outputs finite values."""
    cfg = dataclasses.replace(get_smoke_config("olmoe-1b-7b"),
                              capacity_factor=0.1)
    from repro.models.moe import apply_moe, init_moe
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, _ = apply_moe(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
