"""Bit-exactness of the tiled streaming kernels (interpret mode) vs ref.py.

The tiled Pallas rewrite streams the value vector through VMEM-sized
windows and reduces actions tile-by-tile with a running (min, argmin)
carried in scratch.  Because every formulation pins the product and the
``gamma * pv`` rounding (:func:`repro.kernels.ref.pin_rounding`), the tiled
kernel is required to match the one-shot XLA reference *bit for bit* — not
within a tolerance — across non-divisible shapes, both float widths, and
argmin ties that straddle action-tile boundaries (where a naive per-tile
argmin would lose the global smallest-index tie-break).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bellman_ell, ops, ref, spmv_ell

jax.config.update("jax_enable_x64", True)


def _mk(n, m, k, dtype, seed=0, n_cols=None):
    n_cols = n_cols or n
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, n_cols, (n, m, k)).astype(np.int32))
    val = jnp.asarray(rng.random((n, m, k)).astype(dtype))
    cost = jnp.asarray(rng.random((n, m)).astype(dtype))
    v = jnp.asarray(rng.random(n_cols).astype(dtype))
    return idx, val, cost, v


def _assert_bitequal(got, want):
    gv, ga = got
    wv, wa = want
    np.testing.assert_array_equal(
        np.asarray(gv).view(np.uint8), np.asarray(wv).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(wa))


# --------------------------------------------------------------------------- #
# Interpret-mode parity sweep                                                 #
# --------------------------------------------------------------------------- #

# (n, m, k, tile_n, tile_m, tile_v): non-divisible row counts, several
# action tiles, several value windows, and windows that don't divide n.
SWEEP = [
    (64, 4, 3, 64, 4, 64),       # single tile everywhere (degenerate grid)
    (301, 5, 4, 64, 2, 128),     # ragged rows + ragged action tiles
    (130, 17, 2, 32, 8, 37),     # m spans 3 action tiles, odd value window
    (97, 3, 6, 16, 3, 16),       # many value windows, prime n
    (256, 2, 1, 256, 1, 100),    # K=1, one action per tile, ragged window
]


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("shape", SWEEP, ids=[str(s) for s in SWEEP])
def test_tiled_backup_bitmatches_ref(shape, dtype):
    n, m, k, tn, tm, tv = shape
    idx, val, cost, v = _mk(n, m, k, dtype)
    gamma = 0.997
    want = jax.jit(ref.ell_backup)(idx, val, cost, gamma, v)
    got = bellman_ell.ell_backup(idx, val, cost, gamma, v, interpret=True,
                                 tile_n=tn, tile_m=tm, tile_v=tv)
    _assert_bitequal(got, want)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_tiled_spmv_bitmatches_ref(dtype):
    for n, k, tn, tv in [(301, 4, 64, 128), (97, 6, 16, 16), (64, 1, 64, 37)]:
        rng = np.random.default_rng(3)
        idx = jnp.asarray(rng.integers(0, n, (n, k)).astype(np.int32))
        val = jnp.asarray(rng.random((n, k)).astype(dtype))
        x = jnp.asarray(rng.random(n).astype(dtype))
        want = jax.jit(ref.ell_matvec)(idx, val, x)
        got = spmv_ell.ell_matvec(idx, val, x, interpret=True,
                                  tile_n=tn, tile_v=tv)
        np.testing.assert_array_equal(
            np.asarray(got).view(np.uint8), np.asarray(want).view(np.uint8))


def test_blocked_backup_bitmatches_ref():
    """The cache-blocked scan formulation is bit-identical to the one-shot
    chain, including the non-divisible remainder chunk."""
    for n, bn in [(301, 64), (256, 256), (97, 100), (500, 125)]:
        idx, val, cost, v = _mk(n, 5, 4, np.float32, seed=n)
        want = jax.jit(ref.ell_backup)(idx, val, cost, 0.95, v)
        got = jax.jit(lambda i, w, c, g, u, bn=bn: ref.ell_backup_blocked(
            i, w, c, g, u, block_rows=bn))(idx, val, cost, 0.95, v)
        _assert_bitequal(got, want)


# --------------------------------------------------------------------------- #
# Argmin tie-breaks across tile boundaries                                    #
# --------------------------------------------------------------------------- #

def test_tiebreak_across_action_tiles():
    """Bitwise-equal Q columns in *different* action tiles must resolve to
    the smallest action id — the cross-tile running-min must use a strict
    comparison, or a later tile would steal the tie."""
    n, m, k = 40, 9, 3
    idx, val, cost, v = _mk(n, m, k, np.float32, seed=7)
    # actions 2 and 7 are clones (tiles 0 and 2 under tile_m=3) and strictly
    # the best: their q columns tie bitwise, argmin must say 2.
    val = val.at[:, 7].set(val[:, 2])
    idx = idx.at[:, 7].set(idx[:, 2])
    cost = cost.at[:, 2].set(-5.0)
    cost = cost.at[:, 7].set(-5.0)
    want = jax.jit(ref.ell_backup)(idx, val, cost, 0.9, v)
    got = bellman_ell.ell_backup(idx, val, cost, 0.9, v, interpret=True,
                                 tile_n=16, tile_m=3, tile_v=16)
    _assert_bitequal(got, want)
    assert (np.asarray(got[1]) == 2).all()


def test_tiebreak_within_and_across_tiles_all_equal():
    """All actions identical: argmin must be 0 everywhere regardless of the
    action-tile partition."""
    n, m, k = 33, 8, 2
    idx, val, cost, v = _mk(n, 1, k, np.float32, seed=11)
    idx = jnp.broadcast_to(idx, (n, m, k))
    val = jnp.broadcast_to(val, (n, m, k))
    cost = jnp.broadcast_to(cost, (n, m))
    for tm in (1, 2, 3, 8):
        got = bellman_ell.ell_backup(idx, val, cost, 0.99, v, interpret=True,
                                     tile_n=8, tile_m=tm, tile_v=11)
        assert (np.asarray(got[1]) == 0).all(), f"tile_m={tm}"


def test_successors_straddle_value_windows():
    """Successor columns placed exactly at window edges (tv-1, tv, 2tv-1,
    2tv) must each be owned by exactly one window — no double count, no
    drop."""
    n, m, k, tv = 16, 2, 4, 8
    cols = np.array([tv - 1, tv, 2 * tv - 1, 0], np.int32)
    idx = jnp.asarray(np.broadcast_to(cols, (n, m, k)).copy())
    rng = np.random.default_rng(5)
    val = jnp.asarray(rng.random((n, m, k), dtype=np.float32))
    cost = jnp.asarray(rng.random((n, m), dtype=np.float32))
    v = jnp.asarray(rng.random(n, dtype=np.float32))
    want = jax.jit(ref.ell_backup)(idx, val, cost, 0.9, v)
    got = bellman_ell.ell_backup(idx, val, cost, 0.9, v, interpret=True,
                                 tile_n=8, tile_m=1, tile_v=tv)
    _assert_bitequal(got, want)


# --------------------------------------------------------------------------- #
# Dispatch layer: impl parity, batching, traced gamma                         #
# --------------------------------------------------------------------------- #

def test_ops_impl_parity_bitwise():
    idx, val, cost, v = _mk(230, 6, 4, np.float32, seed=2)
    outs = {impl: ops.ell_backup(idx, val, cost, 0.93, v, impl=impl)
            for impl in ("xla", "blocked", "pallas_interpret", None)}
    base = outs["xla"]
    for impl, got in outs.items():
        _assert_bitequal(got, base)


def test_ops_batched_and_squeeze_paths():
    b, n, m, k = 3, 120, 4, 3
    rng = np.random.default_rng(9)
    idx = jnp.asarray(rng.integers(0, n, (b, n, m, k)).astype(np.int32))
    val = jnp.asarray(rng.random((b, n, m, k)).astype(np.float32))
    cost = jnp.asarray(rng.random((b, n, m)).astype(np.float32))
    v = jnp.asarray(rng.random((b, n)).astype(np.float32))
    for impl in ("blocked", "pallas_interpret"):
        tv, am = ops.ell_backup(idx, val, cost, 0.96, v, impl=impl)
        assert tv.shape == (b, n) and am.shape == (b, n)
        for i in range(b):
            want = jax.jit(ref.ell_backup)(idx[i], val[i], cost[i], 0.96,
                                           v[i])
            _assert_bitequal((tv[i], am[i]), want)
        # B_local == 1 (fleet-shard fast path): squeezed, not 1-lane vmapped,
        # and bit-equal to the batched lane
        tv1, am1 = ops.ell_backup(idx[:1], val[:1], cost[:1], 0.96, v[:1],
                                  impl=impl)
        assert tv1.shape == (1, n) and am1.shape == (1, n)
        _assert_bitequal((tv1[0], am1[0]), (tv[0], am[0]))


def test_gamma_is_traced_no_retrace():
    """gamma is a traced argument everywhere: sweeping it must not grow the
    jit cache (one compiled program serves every discount)."""
    idx, val, cost, v = _mk(64, 3, 2, np.float32, seed=4)
    ops.ell_backup(idx, val, cost, 0.9, v, impl="blocked")
    before = ops.ell_backup._cache_size()
    for g in (0.5, 0.95, 0.99, 0.999):
        ops.ell_backup(idx, val, cost, g, v, impl="blocked")
    assert ops.ell_backup._cache_size() == before


# --------------------------------------------------------------------------- #
# End-to-end: a full solve is impl-invariant                                  #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("method", ["vi", "ipi_gmres"])
def test_solve_1d_impl_invariant(method):
    """The same problem solved under every CPU impl must produce identical
    policies and bit-identical value vectors (the kernels are bit-equal, so
    the whole outer/inner iteration path is too)."""
    from repro.core import IPIOptions, generators
    from repro.core.driver import solve

    mdp = generators.garnet(n=150, m=5, k=4, gamma=0.95, seed=3)
    results = {}
    for impl in ("xla", "blocked", "pallas_interpret"):
        r = solve(mdp, IPIOptions(method=method, atol=1e-8, dtype="float64",
                                  impl=impl, max_outer=20000))
        assert r.converged
        results[impl] = r
    base = results["xla"]
    for impl, r in results.items():
        np.testing.assert_array_equal(r.policy, base.policy, err_msg=impl)
        np.testing.assert_array_equal(
            np.asarray(r.v).view(np.uint8),
            np.asarray(base.v).view(np.uint8), err_msg=impl)
        assert r.outer_iterations == base.outer_iterations, impl
