"""Matrix-free Bellman operators (ISSUE 9).

The non-negotiable invariant: solving through the matrix-free operator —
row tiles rebuilt from the ``from_functions`` constructors inside every
backup, never a stored table — is *bitwise* identical to solving the
materialized container: same values, same policies, same iteration
counts, for every method, mode, FN_REGISTRY family, kernel impl and
layout.  Plus the seams: materialization resolution and its actionable
errors, band metadata for halo layouts, admission-control byte budgets,
and cache eviction on ``Session.close``.

The multi-device legs (1d sharding, fleet batching, comm-overlap on a
banded family) run the real shard_map path on 8 forced host devices in a
subprocess (device count must be set before jax initializes).
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import MDP, Session
from repro.api.mdp import _BUILDER_CACHE
from repro.core import IPIOptions, generators, partition
from repro.core.driver import _validate_banded, solve as driver_solve
from repro.core.mdp import MatrixFreeMDP, stack_mdps
from repro.kernels import matrix_free, ops
from repro.serve.queue import AdmissionError, Request, RequestQueue

# small instances of every FN_REGISTRY family (each exercises a different
# structure: global random columns, 5-point stencil, birth-death band,
# 2-successor chain)
FAMS = {
    "garnet": dict(n=300, m=6, k=4, gamma=0.9, seed=0),
    "maze2d": dict(size=12, gamma=0.95),
    "sis": dict(pop=150, n_actions=4, gamma=0.95),
    "chain_walk": dict(n=200, gamma=0.95),
}


def _bits(x):
    x = np.asarray(x)
    return x.view(np.uint64 if x.dtype == np.float64 else np.uint32)


def _cores(name, mode="mincost"):
    fam = FAMS[name]
    mat = MDP.from_generator(name, deferred=True, mode=mode, **fam)
    mf = MDP.from_generator(name, deferred=True, mode=mode, **fam)
    return mat.build(), mf.build("matrix_free")


def _assert_same(a, b):
    assert (_bits(a.v) != _bits(b.v)).sum() == 0
    assert (a.policy != b.policy).sum() == 0
    assert a.outer_iterations == b.outer_iterations
    assert a.inner_iterations == b.inner_iterations
    assert np.array_equal(a.trace_residual, b.trace_residual,
                          equal_nan=True)


# --------------------------------------------------------------------------- #
# Bitwise parity: methods x families x modes (single device)                  #
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("name", sorted(FAMS))
def test_parity_every_family_ipi(name):
    """ipi_gmres runs the whole machinery — backup for the outer residual,
    policy_rows for the inner Krylov solve — on each family's structure."""
    core_mat, core_mf = _cores(name)
    opts = IPIOptions(method="ipi_gmres", atol=1e-8, max_outer=200)
    _assert_same(driver_solve(core_mat, opts), driver_solve(core_mf, opts))


@pytest.mark.parametrize("method", ["vi", "mpi", "async_vi"])
@pytest.mark.parametrize("mode", ["mincost", "maxreward"])
def test_parity_methods_and_modes(method, mode):
    """The backup-only methods, in both optimization senses — maxreward
    exercises the negate-inside-the-rebuilt-tile path of mf_backup."""
    core_mat, core_mf = _cores("maze2d", mode=mode)
    opts = IPIOptions(method=method, mode=mode, atol=1e-7, max_outer=3000)
    _assert_same(driver_solve(core_mat, opts), driver_solve(core_mf, opts))


def test_parity_interpret_chunk_kernel():
    """The un-jitted tile body (the kernel the matrix-free scan consumes)
    is bit-identical across impls, including the Pallas interpreter."""
    mdp = generators.chain_walk(128, gamma=0.95)
    import jax.numpy as jnp
    v = jnp.linspace(-1.0, 1.0, 128, dtype=jnp.float32)
    ref_vals, ref_acts = ops.ell_backup_chunk(
        mdp.idx, mdp.val, mdp.cost, 0.95, v, impl="xla")
    for impl in ("blocked", "pallas_interpret"):
        vals, acts = ops.ell_backup_chunk(
            mdp.idx, mdp.val, mdp.cost, 0.95, v, impl=impl)
        assert (_bits(vals) != _bits(ref_vals)).sum() == 0, impl
        assert np.array_equal(np.asarray(acts), np.asarray(ref_acts)), impl


def test_parity_mf_backup_interpret_impl():
    """mf_backup's impl override threads through to the rebuilt tiles."""
    spec = MDP.from_generator("chain_walk", deferred=True,
                              **FAMS["chain_walk"])._row_spec()
    import jax.numpy as jnp
    v = jnp.linspace(0.0, 1.0, spec.n, dtype=jnp.float32)
    acts = tuple(range(spec.m))
    ref_vals, ref_acts = matrix_free.mf_backup(
        spec, 0, spec.n, acts, 0.95, v, impl="xla")
    vals, acts_out = matrix_free.mf_backup(
        spec, 0, spec.n, acts, 0.95, v, impl="pallas_interpret")
    assert (_bits(vals) != _bits(ref_vals)).sum() == 0
    assert np.array_equal(np.asarray(acts_out), np.asarray(ref_acts))


def test_parity_chunked_rebuild():
    """Tiling the rebuild (block_rows) cannot change a single bit — the
    math is row-independent.  Run under jit with a traced row0 exactly
    like the solver does (eager whole-array calls constant-fold the
    constructors through a different evaluator and can differ by ULPs —
    that path never executes inside a solve)."""
    import jax
    import jax.numpy as jnp
    spec = MDP.from_generator("sis", deferred=True,
                              **FAMS["sis"])._row_spec()
    v = jnp.linspace(-2.0, 2.0, spec.n, dtype=jnp.float32)
    acts = tuple(range(spec.m))
    bk = jax.jit(
        lambda r0, v, bn: matrix_free.mf_backup(
            spec, r0, spec.n, acts, 0.9, v, block_rows=bn),
        static_argnums=2)
    whole = bk(jnp.int32(0), v, None)
    core = MDP.from_generator("sis", deferred=True, **FAMS["sis"]).build()
    mat = ops.ell_backup_chunk(core.idx, core.val, core.cost, 0.9, v)
    for bn in (37, 64):
        tiled = bk(jnp.int32(0), v, bn)
        assert (_bits(whole[0]) != _bits(tiled[0])).sum() == 0, bn
        assert np.array_equal(np.asarray(whole[1]),
                              np.asarray(tiled[1])), bn
        assert (_bits(mat[0]) != _bits(tiled[0])).sum() == 0, bn


# --------------------------------------------------------------------------- #
# Materialization resolution + actionable errors                              #
# --------------------------------------------------------------------------- #


def _np_mdp(n=64, **kw):
    def P(rs, a):
        nxt = np.clip(rs + 1, 0, n - 1)
        return (np.stack([nxt, rs], -1),
                np.broadcast_to(np.array([0.9, 0.1]), (len(rs), 2)))

    def g(rs, a):
        return np.where(rs == 0, 0.0, 1.0)

    return MDP.from_functions(P, g, n, 2, nnz=2, vectorized=True, **kw)


def test_host_callbacks_error_is_actionable():
    """numpy constructors cannot be re-traced inside a backup: asking for
    matrix_free must fail loudly, pointing at the fix."""
    mdp = _np_mdp()
    with pytest.raises(ValueError, match="jax.numpy"):
        mdp.materialization("matrix_free")
    with pytest.raises(ValueError, match="matrix-free"):
        mdp.build("matrix_free")


def test_auto_never_picks_matrix_free():
    mdp = MDP.from_generator("chain_walk", deferred=True,
                             **FAMS["chain_walk"])
    assert mdp.materialization() == "device"
    assert mdp.materialization("matrix_free") == "matrix_free"


def test_host_pin_wins_over_matrix_free():
    """device=False is an explicit host pin; the option defers to it the
    same way it does for 'device'."""
    fam = dict(generators.FN_REGISTRY["chain_walk"](**FAMS["chain_walk"]))
    mdp = MDP.from_functions(**fam, device=False)
    assert mdp.materialization("matrix_free") == "host"


def test_matrix_free_container_shape():
    _, core = _cores("chain_walk")
    assert isinstance(core, MatrixFreeMDP)
    assert core.tag.dtype == np.int8
    assert core.n_local == FAMS["chain_walk"]["n"]
    assert core.gamma == FAMS["chain_walk"]["gamma"]


def test_negative_band_rejected():
    fam = dict(generators.FN_REGISTRY["chain_walk"](**FAMS["chain_walk"]))
    fam["band"] = -1
    with pytest.raises(ValueError, match="band"):
        MDP.from_functions(**fam)


# --------------------------------------------------------------------------- #
# Band metadata: partition planning + halo validation                         #
# --------------------------------------------------------------------------- #


def test_band_metadata_drives_partition_planning():
    """With no table to measure, margins/reach come from the declared
    band — sis is birth-death (band=1), garnet declares none."""
    sis = MDP.from_generator("sis", deferred=True, pop=149,
                             n_actions=4).build("matrix_free")   # n=150
    assert sis.spec.band == 1
    assert partition.overlap_margins(sis, 5) == (1, 1)
    assert partition.frontier_reach(sis, 5) == 1
    _, gar = _cores("garnet")
    assert gar.spec.band is None
    assert partition.overlap_margins(gar, 5) is None


def test_halo_without_band_is_actionable():
    _, gar = _cores("garnet")
    with pytest.raises(ValueError, match="declared matrix"):
        _validate_banded(gar, 2, None, "1d")


# --------------------------------------------------------------------------- #
# Batching: stack_mdps on matrix-free containers                              #
# --------------------------------------------------------------------------- #


def test_stack_requires_shared_spec():
    _, a = _cores("chain_walk")
    _, b = _cores("chain_walk")
    stacked = stack_mdps([a, b])
    assert stacked.batch == 2
    assert stacked.tag.shape == (2, a.n_local)
    _, other = _cores("sis")
    with pytest.raises(ValueError):
        stack_mdps([a, other])


def test_gamma_sweep_parity_solve_many():
    """A fleet-style gamma sweep over one constructor pair: each lane
    bitwise-matches its materialized solo solve."""
    from repro.core.driver import solve_many
    fam = dict(generators.FN_REGISTRY["chain_walk"](n=160))
    gammas = (0.9, 0.95, 0.99)
    cores = []
    for g in gammas:
        fam_g = dict(fam, gamma=g)
        cores.append(MDP.from_functions(**fam_g).build("matrix_free"))
    opts = IPIOptions(method="vi", atol=1e-7, max_outer=3000)
    rs = solve_many(cores, opts)
    for g, r in zip(gammas, rs):
        fam_g = dict(fam, gamma=g)
        ref = driver_solve(MDP.from_functions(**fam_g).build(), opts)
        assert (_bits(r.v) != _bits(ref.v)).sum() == 0
        assert (r.policy != ref.policy).sum() == 0


# --------------------------------------------------------------------------- #
# Serve admission: the byte budget                                            #
# --------------------------------------------------------------------------- #


def _request(mdp, mat):
    return Request(mdp, ("sig",), {}, materialization=mat)


def test_admission_matrix_free_byte_budget():
    """-serve_max_states names the materialized-table byte budget: the
    same n that is rejected materialized is admitted matrix-free, and the
    matrix-free rejection only kicks in past the byte-equivalent count."""
    fam = dict(generators.FN_REGISTRY["garnet"](n=500, m=8, k=8))
    mdp = MDP.from_functions(**fam)
    q = RequestQueue(max_depth=8, max_states=100)
    with pytest.raises(AdmissionError, match="matrix_free") as ei:
        q.push(_request(mdp, None))          # materialized: 500 > 100
    assert ei.value.reason == "too_large"
    q.push(_request(mdp, "matrix_free"))     # same n, O(n) footprint: fits
    assert len(q) == 1
    # garnet m=8, nnz=8: table 544 B/state vs (krylov-conservative)
    # operator 85 B/state — the byte budget admits 6.4x the states
    cap = matrix_free.table_bytes(100, 8, 8) \
        // matrix_free.operator_bytes(1, 8)
    assert cap > 500
    big = MDP.from_functions(**dict(generators.FN_REGISTRY["garnet"](
        n=cap + 1, m=8, k=8)))
    with pytest.raises(AdmissionError, match="byte") as ei:
        q.push(_request(big, "matrix_free"))
    assert ei.value.reason == "too_large"


def test_server_resolves_materialization_per_request():
    """End-to-end: a server whose session pins matrix_free solves a
    function-backed MDP through the operator and matches the materialized
    answer bit for bit."""
    from repro.serve import Server
    fam = FAMS["chain_walk"]
    opts = {"-method": "vi", "-atol": 1e-7, "-serve_batch_window": 0.01,
            "-mdp_materialize": "matrix_free"}
    with Server(opts) as srv:
        req = srv.submit(MDP.from_generator("chain_walk", deferred=True,
                                            **fam))
        assert req.materialization == "matrix_free"
        assert req.sig[-2] == "matrix_free"
        r = req.result(timeout=600)
    ref = driver_solve(
        MDP.from_generator("chain_walk", deferred=True, **fam).build(),
        IPIOptions(method="vi", atol=1e-7))
    assert (_bits(r.v) != _bits(ref.v)).sum() == 0
    assert (r.policy != ref.policy).sum() == 0


# --------------------------------------------------------------------------- #
# Eviction: Session.close drops operator programs and containers             #
# --------------------------------------------------------------------------- #


def test_session_close_evicts_matrix_free_state():
    fam = FAMS["chain_walk"]
    s = Session({"-method": "vi", "-atol": 1e-7,
                 "-mdp_materialize": "matrix_free"})
    mdp = MDP.from_generator("chain_walk", deferred=True, **fam)
    r = s.solve(mdp)
    assert np.isfinite(r.residual)
    assert ("built", "matrix_free") in mdp._device_cache
    s.close()
    assert ("built", "matrix_free") not in mdp._device_cache


def test_evict_builders_purges_program_cache():
    mdp = MDP.from_generator("chain_walk", deferred=True,
                             **FAMS["chain_walk"])
    mdp.build("matrix_free")
    skey = dataclasses.replace(mdp._spec, gamma=0.0)
    assert any(k[0] == skey for k in _BUILDER_CACHE)
    mdp.evict()                       # plain evict keeps the warm builder
    assert any(k[0] == skey for k in _BUILDER_CACHE)
    mdp.evict(builders=True)
    assert not any(k[0] == skey for k in _BUILDER_CACHE)


# --------------------------------------------------------------------------- #
# Dryrun: matrix-free memory model + crossover                                #
# --------------------------------------------------------------------------- #


def test_dryrun_matrix_free_cell(monkeypatch):
    """The dryrun memory model: a matrix-free cell reports both footprints
    and the crossover, and its lowering (which traces the constructors)
    charges the recompute FLOPs."""
    import jax

    from repro.launch import dryrun
    from repro.launch.mesh import mesh_kwargs
    assert "mdp_mf_vi_1g" in dryrun.MDP_MF_CELLS    # the 1B-state cell
    monkeypatch.setitem(
        dryrun.MDP_MF_CELLS, "mdp_mf_test_small",
        ("garnet", dict(n=1 << 14, m=8, k=8), "1d", "vi", 0))
    mesh = jax.make_mesh((1, 1), ("data", "model"), **mesh_kwargs(2))
    rec = dryrun.run_mdp_cell("mdp_mf_test_small", mesh)
    assert rec["operator_bytes"] < rec["table_bytes"] / 10
    assert rec["memory_ratio"] > 10
    assert rec["states_per_16g_matrix_free"] > \
        10 * rec["states_per_16g_materialized"]
    assert rec["flops"] > 0


# --------------------------------------------------------------------------- #
# 8-fake-device parity (subprocess: real shard_map + collectives)             #
# --------------------------------------------------------------------------- #

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np, json
from repro.api import MDP
from repro.core import IPIOptions, generators
from repro.core.driver import solve, solve_many
from repro.launch.mesh import make_fleet_mesh, mesh_kwargs

out = {}


def bits(x):
    x = np.asarray(x)
    return x.view(np.uint64 if x.dtype == np.float64 else np.uint32)


def record(tag, a, b):
    out[tag] = dict(
        dv_bits=int((bits(a.v) != bits(b.v)).sum()),
        dpi=int((a.policy != b.policy).sum()),
        trace_eq=bool(np.array_equal(a.trace_residual, b.trace_residual,
                                     equal_nan=True)),
        outer=int(a.outer_iterations), outer_mf=int(b.outer_iterations))


mesh1d = jax.make_mesh((8,), ("data",), **mesh_kwargs(1))

# 1d sharded: materialized vs matrix-free along the whole (unconverged)
# trajectory — stricter than parity at the fixed point
fam = dict(generators.FN_REGISTRY["sis"](pop=333, n_actions=4, gamma=0.99))
for method in ("vi", "ipi_gmres"):
    opts = IPIOptions(method=method, atol=1e-12, max_outer=40)
    a = solve(MDP.from_functions(**fam).build(), opts,
              mesh=mesh1d, layout="1d")
    b = solve(MDP.from_functions(**fam).build("matrix_free"), opts,
              mesh=mesh1d, layout="1d")
    record(f"{method}/1d", a, b)

# halo layout on the declared band (sis: birth-death, band=1)
opts = IPIOptions(method="vi", atol=1e-12, max_outer=40, halo=1)
fam319 = dict(generators.FN_REGISTRY["sis"](pop=319, n_actions=4,
                                            gamma=0.99))
a = solve(MDP.from_functions(**fam319).build(), opts,
          mesh=mesh1d, layout="1d")
b = solve(MDP.from_functions(**fam319).build("matrix_free"), opts,
          mesh=mesh1d, layout="1d")
record("vi/halo", a, b)

# comm overlap must stay bitwise-invisible through the operator too
record("vi/overlap",
       solve(MDP.from_functions(**fam319).build("matrix_free"),
             IPIOptions(method="vi", atol=1e-12, max_outer=40,
                        comm_overlap="off"), mesh=mesh1d, layout="1d"),
       solve(MDP.from_functions(**fam319).build("matrix_free"),
             IPIOptions(method="vi", atol=1e-12, max_outer=40,
                        comm_overlap="on"), mesh=mesh1d, layout="1d"))

# fleet layout: a gamma sweep batched into one fleet program
fam_fn = generators.FN_REGISTRY["chain_walk"]
gammas = (0.9, 0.95, 0.99, 0.995)
opts = IPIOptions(method="vi", atol=1e-10, max_outer=4000)
mats = [MDP.from_functions(**dict(fam_fn(n=240), gamma=g)).build()
        for g in gammas]
mfs = [MDP.from_functions(**dict(fam_fn(n=240), gamma=g))
       .build("matrix_free") for g in gammas]
fleet = make_fleet_mesh(4)
ra = solve_many(mats, opts, mesh=fleet, layout="fleet")
rb = solve_many(mfs, opts, mesh=fleet, layout="fleet")
out["vi/fleet"] = dict(
    dv_bits=int(sum((bits(a.v) != bits(b.v)).sum()
                    for a, b in zip(ra, rb))),
    dpi=int(sum((a.policy != b.policy).sum() for a, b in zip(ra, rb))),
    trace_eq=all(np.array_equal(a.trace_residual, b.trace_residual,
                                equal_nan=True)
                 for a, b in zip(ra, rb)),
    outer=int(ra[0].outer_iterations), outer_mf=int(rb[0].outer_iterations))

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


_PAIR_KEYS = ["vi/1d", "ipi_gmres/1d", "vi/halo", "vi/overlap", "vi/fleet"]


@pytest.mark.parametrize("key", _PAIR_KEYS)
def test_sharded_matrix_free_is_bitwise_identical(results, key):
    r = results[key]
    assert r["dv_bits"] == 0, r
    assert r["dpi"] == 0, r
    assert r["trace_eq"], r
    assert r["outer"] == r["outer_mf"], r
