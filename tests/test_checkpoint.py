"""Fault tolerance: checkpoint/restart of the solver and torn-write safety."""

import os

import numpy as np
import pytest

from repro.core import IPIOptions, generators, solve
from repro.utils import checkpoint as ckpt


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": np.arange(5.0), "b": {"c": np.int32(3)}}
    ckpt.save(str(tmp_path), 7, tree, meta={"note": "x"})
    out = ckpt.restore(str(tmp_path), tree)
    assert out is not None
    restored, step, meta = out
    assert step == 7 and meta["note"] == "x"
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_torn_write_is_skipped(tmp_path):
    tree = {"a": np.arange(3.0)}
    ckpt.save(str(tmp_path), 1, tree)
    # a newer checkpoint whose file is corrupt (simulated crash mid-write)
    with open(tmp_path / "step_0000000002.npz", "wb") as f:
        f.write(b"garbage")
    restored, step, _ = ckpt.restore(str(tmp_path), tree)
    assert step == 1
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_structure_mismatch_raises_not_silent_reinit(tmp_path):
    """A readable checkpoint whose pytree grew/shrank (written by another
    solver version) must raise an actionable error — silently skipping it
    would reinitialize from k=0 and discard the run's progress."""
    ckpt.save(str(tmp_path), 1, {"a": np.arange(3.0)})
    like = {"a": np.arange(3.0), "b": np.zeros(2)}
    with pytest.raises(ValueError, match="different solver version"):
        ckpt.restore(str(tmp_path), like)


def test_solver_restart_resumes_identically(tmp_path):
    """Kill after a few outer iterations; restart must land on the exact
    same iterate path (deterministic restart = madupite's chunked solve)."""
    mdp = generators.garnet(n=300, m=8, k=5, gamma=0.99, seed=11)
    opts = IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64")

    r_full = solve(mdp, opts)

    d1 = str(tmp_path / "ck")
    # run only a few outers by lying about max_outer, then "crash"
    opts_short = IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64",
                            max_outer=2)
    r_partial = solve(mdp, opts_short, checkpoint_dir=d1, chunk=1)
    assert not r_partial.converged

    # restart with the full budget from the same checkpoint dir
    r_resumed = solve(mdp, opts, checkpoint_dir=d1, chunk=1)
    assert r_resumed.converged
    np.testing.assert_allclose(r_resumed.v, r_full.v, atol=1e-12)
    assert r_resumed.outer_iterations == r_full.outer_iterations


def test_checkpoint_every_chunk(tmp_path):
    mdp = generators.maze2d(8, gamma=0.95)
    d = str(tmp_path / "ck2")
    solve(mdp, IPIOptions(method="vi", atol=1e-6), checkpoint_dir=d, chunk=16)
    assert ckpt.latest_step(d) is not None
