"""Halo-exchange layout: exactness vs the all-gather baseline (the
beyond-paper optimization of EXPERIMENTS.md §Perf P1)."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import IPIOptions, generators, solve


@pytest.mark.parametrize("method", ["vi", "ipi_gmres", "ipi_bicgstab"])
def test_halo_single_device_exact(method):
    mdp = generators.maze2d(size=20, gamma=0.99)   # bandwidth = 20
    base = solve(mdp, IPIOptions(method=method, atol=1e-8, dtype="float64"))
    halo = solve(mdp, IPIOptions(method=method, atol=1e-8, dtype="float64",
                                 halo=24))
    np.testing.assert_array_equal(halo.v, base.v)
    assert halo.outer_iterations == base.outer_iterations
    assert halo.inner_iterations == base.inner_iterations


@settings(max_examples=8, deadline=None)
@given(size=st.integers(5, 25), gamma=st.floats(0.5, 0.995),
       slip=st.floats(0.0, 0.4))
def test_halo_property(size, gamma, slip):
    """For any maze instance, halo=bandwidth gives the identical solve."""
    mdp = generators.maze2d(size=size, gamma=gamma, slip=slip)
    base = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-7,
                                 dtype="float64"))
    halo = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-7,
                                 dtype="float64", halo=size))
    np.testing.assert_array_equal(halo.v, base.v)


def test_halo_rejects_wide_band():
    """Bandwidth violation must be caught, not silently mis-solved."""
    mdp = generators.garnet(100, 4, 3, seed=0)     # random columns: full band
    with pytest.raises(ValueError, match="bandwidth"):
        solve(mdp, IPIOptions(method="vi", atol=1e-6, halo=5))


def test_compressed_gather_converges():
    """Compressed inner gathers still converge when the target tolerance sits
    above the wire-noise floor (eps_wire * ||v||_inf) — the regime where the
    iPI forcing term absorbs the matvec quantization.  (bf16 at tight
    tolerances is REFUTED as an optimization — EXPERIMENTS.md §Perf P1.)"""
    mdp = generators.chain_walk(400, gamma=0.9)   # ||v*|| ~ 10
    base = solve(mdp, IPIOptions(method="ipi_richardson", atol=1e-4,
                                 dtype="float64"))
    # f32 wire: noise ~ 1e-6 * 10 << atol
    comp = solve(mdp, IPIOptions(method="ipi_richardson", atol=1e-4,
                                 dtype="float64", gather_dtype="float32"))
    assert comp.converged
    assert np.abs(comp.v - base.v).max() < 1e-3
