"""Sharding-rule inference: every full-config param must get a legal spec on
the production meshes (divisibility), and TP/EP/FSDP rules must fire."""

import math

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.train import sharding as shd


def _abstract_mesh(shape, names):
    if hasattr(jax.sharding, "AxisType"):
        return jax.sharding.AbstractMesh(
            shape, names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(names))
    # pre-0.5 signature: tuple of (name, size) pairs
    return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


MESHES = [((16, 16), ("data", "model")),
          ((2, 16, 16), ("pod", "data", "model"))]


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("mesh_shape,mesh_names", MESHES)
def test_specs_divide(arch, mesh_shape, mesh_names):
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    mesh = _abstract_mesh(mesh_shape, mesh_names)
    specs = shd.infer_param_specs(shapes, mesh)

    def check(path, s, spec):
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = math.prod(dict(zip(mesh_names, mesh_shape))[a]
                             for a in axes)
            assert s.shape[d] % size == 0, (path, s.shape, spec)
    jax.tree_util.tree_map_with_path(check, shapes, specs)


def test_tp_rules_fire():
    """Attention/MLP/vocab shards over 'model'; experts over 'model' (EP)."""
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("arctic-480b")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    specs = shd.infer_param_specs(shapes, mesh)
    assert "model" in jax.tree_util.tree_flatten(specs["embed"])[0] or \
        "model" in tuple(specs["embed"])
    moe_spec = specs["blocks"]["moe"]["w_up"]       # (L, E, d, f)
    assert moe_spec[1] == "model", moe_spec          # EP on the expert axis
    attn_spec = specs["blocks"]["attn"]["wq"]        # (L, d, h*hd)
    assert attn_spec[2] == "model", attn_spec


def test_fsdp_shards_large_params_only():
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("granite-34b")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    specs = shd.infer_param_specs(shapes, mesh)
    # norms replicated; big matrices carry 'data' somewhere
    norm_spec = specs["blocks"]["ln1"]
    assert all(a is None for a in norm_spec)
    wq = specs["blocks"]["attn"]["wq"]
    assert "data" in tuple(wq), wq


def test_batch_and_cache_specs():
    mesh = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert shd.batch_axes(mesh) == ("pod", "data")
    assert shd.data_spec(mesh, 2) == P(("pod", "data"), None)
    cfg = get_config("zamba2-1.2b")
    cs = shd.cache_spec(cfg, mesh, batch=1)
    # B=1: sequence-parallel decode — seq dim sharded instead of batch
    assert not cs["batch_sharded"]
    assert cs["attn"][2] == ("pod", "data")
