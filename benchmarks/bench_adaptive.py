"""Benchmark 10 — the adaptive driver's claim: ``-method auto`` lands
within 1.3x of the best fixed method on every instance family, including
the GMRES outliers where the fixed-method spread covers orders of
magnitude (ISSUE 10 tentpole).

For each instance family: every fixed leg (vi / mpi / ipi_gmres, plus the
preconditioned ``ipi_gmres -pc_type jacobi`` combo the rule table selects
in the ill-conditioned regime) and the ``auto`` leg, all timed **warm**
through one :class:`repro.api.Session` — the second solve reuses both the
compiled programs and (for auto) the session's per-family probe cache, so
the ratio reflects steady-state method quality, not probe or compile cost.

The pc-vs-plain pair on the hard chain doubles as the preconditioning
acceptance row (jacobi >= 2x plain GMRES on at least one outlier).

``MADUPITE_BENCH_SCALE`` (default 1.0) scales instance sizes so CI can
run a quick leg (e.g. ``MADUPITE_BENCH_SCALE=0.02``).

Run directly:  PYTHONPATH=src:. python -m benchmarks.bench_adaptive
or via:        PYTHONPATH=src:. python -m benchmarks.run --only adaptive
"""

from __future__ import annotations

import os
import time

from repro.api import Session
from repro.core import generators

SCALE = float(os.environ.get("MADUPITE_BENCH_SCALE", "1.0"))

# f32 Bellman residuals bottom out near eps * ||v|| ~ 1e-7 * 1/(1-gamma):
# 1e-3 sits safely above that floor for the gamma=0.9999 chain while still
# exercising the full fixed-method spread
ATOL = 1e-3
MAX_OUTER = 3000


def _n(n: int, lo: int = 64) -> int:
    return max(int(n * SCALE), lo)


INSTANCES = {
    "garnet_0.95": lambda: generators.garnet(_n(1_024), 8, 4, gamma=0.95,
                                             seed=0),
    "chain_0.999": lambda: generators.chain_walk(_n(2_000), gamma=0.999),
    "chain_0.9999": lambda: generators.chain_walk(_n(1_500), gamma=0.9999),
}

# (tag, solve overrides) — auto last so its warm pass can only reuse
# programs a fixed leg already compiled when the rule table agrees
LEGS = [
    ("vi", {"method": "vi"}),
    ("mpi", {"method": "mpi"}),
    ("ipi_gmres", {"method": "ipi_gmres"}),
    ("ipi_gmres+jacobi", {"method": "ipi_gmres", "pc_type": "jacobi"}),
    ("auto", {"method": "auto"}),
]


def run(csv_rows: list):
    scale_tag = "" if SCALE == 1.0 else f";scale={SCALE}"
    with Session({"-atol": ATOL, "-max_outer": MAX_OUTER,
                  "-max_inner": 512, "-verbose": False}) as sess:
        for iname, make in INSTANCES.items():
            mdp = make()
            walls: dict[str, float] = {}
            conv: dict[str, bool] = {}
            for tag, ov in LEGS:
                sess.solve(mdp, **ov)            # compile / probe pass
                t0 = time.time()
                r = sess.solve(mdp, **ov)        # timed warm pass
                walls[tag] = time.time() - t0
                conv[tag] = bool(r.converged)
                csv_rows.append((
                    f"adaptive/{iname}/{tag}", walls[tag] * 1e6,
                    f"converged={conv[tag]};outer={r.outer_iterations}"
                    f"{scale_tag}"))
                print(f"  {iname:14s} {tag:18s} wall={walls[tag]:7.2f}s "
                      f"conv={conv[tag]} outer={r.outer_iterations}",
                      flush=True)
            fixed = {t: w for t, w in walls.items()
                     if t != "auto" and conv[t]}
            if fixed and conv["auto"]:
                best_tag = min(fixed, key=fixed.get)
                ratio = walls["auto"] / fixed[best_tag]
                csv_rows.append((
                    f"adaptive/{iname}/auto_vs_best", ratio,
                    f"best={best_tag};auto_within_1.3x={ratio <= 1.3}"
                    f"{scale_tag}"))
                print(f"  {iname:14s} auto/best({best_tag}) = {ratio:.2f}x",
                      flush=True)
            if conv.get("ipi_gmres+jacobi"):
                # plain GMRES may not even converge within max_outer; its
                # wall is then a LOWER bound on the true cost, so the
                # reported speedup is conservative
                sp = walls["ipi_gmres"] / walls["ipi_gmres+jacobi"]
                csv_rows.append((
                    f"adaptive/{iname}/jacobi_vs_plain_gmres", sp,
                    f"plain_converged={conv['ipi_gmres']}{scale_tag}"))
                print(f"  {iname:14s} jacobi speedup over plain gmres = "
                      f"{sp:.2f}x (plain conv={conv['ipi_gmres']})",
                      flush=True)


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
