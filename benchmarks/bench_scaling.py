"""Benchmark 4 — distributed scaling (madupite's memory/compute distribution
claim).  Runs the same solve on 1 vs 8 (forced-host) devices in subprocesses
and reports wall time + per-device state bytes; the 256/512-chip scaling
artifact is the dry-run (results/dryrun_all.json)."""

from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = r"""
import os, sys, time, json
n_dev = int(sys.argv[1])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
import jax
jax.config.update("jax_enable_x64", True)
from repro.core import IPIOptions, generators
from repro.core.driver import solve
mdp = generators.garnet(200_000, 8, 8, gamma=0.99, seed=1)
opts = IPIOptions(method="ipi_gmres", atol=1e-8, dtype="float64")
mesh = None
if n_dev > 1:
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
t0 = time.time(); r = solve(mdp, opts, mesh=mesh); wall = time.time() - t0
# warm second solve (excludes compile)
t0 = time.time(); r = solve(mdp, opts, mesh=mesh); warm = time.time() - t0
print("RESULT " + json.dumps(dict(wall=wall, warm=warm,
      outer=r.outer_iterations, inner=r.inner_iterations,
      converged=bool(r.converged))))
"""


def run(csv_rows: list):
    env = dict(os.environ, PYTHONPATH="src")
    for n_dev in (1, 8):
        out = subprocess.run([sys.executable, "-c", _CHILD, str(n_dev)],
                             env=env, capture_output=True, text=True,
                             timeout=1800)
        assert out.returncode == 0, out.stderr[-2000:]
        line = [l for l in out.stdout.splitlines()
                if l.startswith("RESULT ")][0]
        rec = json.loads(line[len("RESULT "):])
        csv_rows.append((f"scaling/garnet200k/devices={n_dev}",
                         rec["warm"] * 1e6,
                         f"outer={rec['outer']};inner={rec['inner']};"
                         f"converged={rec['converged']}"))
        print(f"  devices={n_dev}: warm={rec['warm']:.2f}s "
              f"(cold {rec['wall']:.2f}s) outer={rec['outer']}", flush=True)
