"""Benchmark 4 — distributed scaling (madupite's memory/compute distribution
claim).  Runs the same solve on 1 vs 8 (forced-host) devices in subprocesses
and reports wall time + per-device state bytes; the 256/512-chip scaling
artifact is the dry-run (results/dryrun_all.json).

PR 7 rows: ``-comm_overlap on`` vs ``off`` iteration throughput on the
8-fake-device stencil workload (same XLA flags both sides, bitwise-equal
results asserted in-bench), and ``async_vi`` vs synchronous ``vi``
wall-clock at equal span tolerance.  ``MADUPITE_BENCH_SCALE`` (CI: 0.02)
scales the instance sizes."""

from __future__ import annotations

import json
import os
import subprocess
import sys

SCALE = float(os.environ.get("MADUPITE_BENCH_SCALE", "1.0"))

def _round8(x: float, lo: int = 64) -> int:
    return max(lo, int(x)) // 8 * 8


# full scale: 8M-state chain_walk stencil; CI (SCALE=0.02): ~167k states
N_OVERLAP = _round8(8_388_608 * SCALE, 4096)
OVERLAP_ITERS = 20
N_GARNET = _round8(200_000 * SCALE, 8_000)

_CHILD = r"""
import os, sys, time, json
n_dev = int(sys.argv[1])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
import jax
jax.config.update("jax_enable_x64", True)
from repro.core import IPIOptions, generators
from repro.core.driver import solve
n = int(sys.argv[2])
mdp = generators.garnet(n, 8, 8, gamma=0.99, seed=1)
opts = IPIOptions(method="ipi_gmres", atol=1e-8, dtype="float64")
mesh = None
if n_dev > 1:
    from repro.launch.mesh import mesh_kwargs
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"), **mesh_kwargs(2))
t0 = time.time(); r = solve(mdp, opts, mesh=mesh); wall = time.time() - t0
# warm second solve (excludes compile)
t0 = time.time(); r = solve(mdp, opts, mesh=mesh); warm = time.time() - t0
print("RESULT " + json.dumps(dict(wall=wall, warm=warm,
      outer=r.outer_iterations, inner=r.inner_iterations,
      converged=bool(r.converged))))
"""

# -comm_overlap on vs off in ONE child (same XLA flags, bitwise compare).
# Fixed-iteration throughput: atol=1e-30 never trips, max_outer bounds work.
_CHILD_OVERLAP = r"""
import os, sys, time, json
n, iters = int(sys.argv[1]), int(sys.argv[2])
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    "--xla_cpu_enable_concurrency_optimized_scheduler=true "
    "--xla_cpu_enable_fast_min_max=false")
import jax
import numpy as np
from repro.core import IPIOptions, generators
from repro.core.driver import solve
from repro.launch.mesh import mesh_kwargs
mdp = generators.chain_walk(n, gamma=0.9999)
mesh = jax.make_mesh((8,), ("data",), **mesh_kwargs(1))
out = {}
res, opt, best = {}, {}, {}
for ov in ("off", "on"):
    opt[ov] = IPIOptions(method="mpi", mpi_sweeps=10, atol=1e-30,
                         max_outer=iters, dtype="float32", comm_overlap=ov)
    res[ov] = solve(mdp, opt[ov], mesh=mesh)       # compile
    best[ov] = float("inf")
for _ in range(3):          # interleave warm reps so machine drift cancels
    for ov in ("off", "on"):
        t0 = time.time()
        res[ov] = solve(mdp, opt[ov], mesh=mesh)
        best[ov] = min(best[ov], time.time() - t0)
for ov in ("off", "on"):
    out[f"itps_{ov}"] = iters / best[ov]
out["bitwise_v"] = bool(np.array_equal(
    np.asarray(res["off"].v).view(np.uint32),
    np.asarray(res["on"].v).view(np.uint32)))
out["policy_eq"] = bool(np.array_equal(np.asarray(res["off"].policy),
                                       np.asarray(res["on"].policy)))
print("RESULT " + json.dumps(out))
"""

# async_vi (k stale sweeps per exchange) vs synchronous vi, equal span
# tolerance; same-policy + certificate checked in-child.  The maze uses
# slip=0.45 (slow mixing -> 2.5x fewer exchanges, the regime async VI
# targets) and a deterministic 1e-3 cost jitter: a square maze has many
# equal-length routes whose exactly-tied Q-values would otherwise let f64
# rounding pick different (equally optimal) argmins per trajectory.
_CHILD_ASYNC = r"""
import os, sys, time, json, dataclasses
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.core import IPIOptions, generators
from repro.core.driver import solve
from repro.launch.mesh import mesh_kwargs
if sys.argv[1] == "chain":
    mdp, sweeps = generators.chain_walk(512, gamma=0.99), 8
else:
    mdp, sweeps = generators.maze2d(24, gamma=0.99, slip=0.45), 3
    rng = np.random.default_rng(0)
    cost = np.asarray(mdp.cost) * (1 + 1e-3 * rng.random(mdp.cost.shape))
    cost[np.asarray(mdp.cost) == 0] = 0.0          # keep the goal absorbing
    mdp = dataclasses.replace(mdp, cost=cost)
mesh = jax.make_mesh((8,), ("data",), **mesh_kwargs(1))
out, res, opt, best = {}, {}, {}, {}
for method, kw in (("vi", {}), ("async_vi", dict(async_sweeps=sweeps))):
    opt[method] = IPIOptions(method=method, atol=1e-6, dtype="float64",
                             stop_criterion="span", max_outer=4000, **kw)
    res[method] = solve(mdp, opt[method], mesh=mesh)   # compile
    best[method] = float("inf")
for _ in range(5):          # interleave warm reps so machine drift cancels
    for method in opt:
        t0 = time.time()
        res[method] = solve(mdp, opt[method], mesh=mesh)
        best[method] = min(best[method], time.time() - t0)
for method in opt:
    out[f"wall_{method}"] = best[method]
    out[f"outer_{method}"] = int(res[method].outer_iterations)
    assert res[method].converged
out["policy_eq"] = bool(np.array_equal(np.asarray(res["vi"].policy),
                                       np.asarray(res["async_vi"].policy)))
out["gap"] = float(res["async_vi"].gap_bound)
print("RESULT " + json.dumps(out))
"""


def _child(script: str, *argv: object) -> dict:
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script,
                          *map(str, argv)],
                         env=env, capture_output=True, text=True,
                         timeout=1800)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def run(csv_rows: list):
    for n_dev in (1, 8):
        rec = _child(_CHILD, n_dev, N_GARNET)
        csv_rows.append((f"scaling/garnet{N_GARNET//1000}k/devices={n_dev}",
                         rec["warm"] * 1e6,
                         f"outer={rec['outer']};inner={rec['inner']};"
                         f"converged={rec['converged']}"))
        print(f"  devices={n_dev}: warm={rec['warm']:.2f}s "
              f"(cold {rec['wall']:.2f}s) outer={rec['outer']}", flush=True)

    # communication-overlapped backups (PR 7 tentpole a): mpi's policy
    # sweeps each carry a value exchange, so the planner's collective
    # shrink (full all-gather -> frontier-reach ring exchange) compounds
    rec = _child(_CHILD_OVERLAP, N_OVERLAP, OVERLAP_ITERS)
    assert rec["bitwise_v"] and rec["policy_eq"], rec
    ratio = rec["itps_on"] / rec["itps_off"]
    for ov in ("off", "on"):
        csv_rows.append(
            (f"scaling/overlap_chain{N_OVERLAP}_mpi/comm_overlap={ov}",
             OVERLAP_ITERS / rec[f"itps_{ov}"] * 1e6,
             f"itps={rec[f'itps_{ov}']:.3f};bitwise_v=True;"
             f"ratio_on_off={ratio:.3f}"))
    print(f"  overlap chain n={N_OVERLAP} (mpi/10 sweeps): "
          f"off={rec['itps_off']:.2f} it/s on={rec['itps_on']:.2f} it/s "
          f"({ratio:.2f}x, bitwise-identical)", flush=True)

    # async_vi stale sweeps vs synchronous vi (PR 7 tentpole b)
    for inst, tag in (("chain", "chain512"), ("maze", "maze24_slip45")):
        rec = _child(_CHILD_ASYNC, inst)
        assert rec["policy_eq"], rec
        speedup = rec["wall_vi"] / rec["wall_async_vi"]
        for m in ("vi", "async_vi"):
            csv_rows.append((f"scaling/async_{tag}/method={m}",
                             rec[f"wall_{m}"] * 1e6,
                             f"outer={rec[f'outer_{m}']};policy_eq=True;"
                             f"gap={rec['gap']:.3e};"
                             f"speedup_async={speedup:.3f}"))
        print(f"  async {tag}: vi={rec['wall_vi']:.2f}s "
              f"({rec['outer_vi']} outers) "
              f"async_vi={rec['wall_async_vi']:.2f}s "
              f"({rec['outer_async_vi']} exchanges) {speedup:.2f}x, "
              f"same policy, gap<={rec['gap']:.2e}", flush=True)
