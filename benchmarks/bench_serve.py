"""Serving throughput/latency: the batched server vs sequential solves
(ISSUE 8 tentpole claim: >= 2x per-request throughput on a warm ragged
workload, B >= 16 over two shape buckets, with bitwise-equal results).

Two measurements:

* ``warm_ragged`` — 48 requests, state counts 64/96 (two shape buckets
  under the pad-waste rule), all programs warm, submitted in one burst.
  Baseline: a sequential loop of ``Session.solve`` calls.  Server: the
  scheduler coalesces the burst into a handful of compiled dispatches
  (one per shape bucket per take).  Per-request results must be
  **bitwise-equal** to the sequential baseline (vi is elementwise —
  lanes cannot perturb each other).
* ``poisson`` — the same workload arriving on a seeded Poisson clock;
  p50/p95 request latency and throughput, batched server vs a
  no-batching server (``-serve_max_batch 1``, sequential dispatch
  discipline) vs a deadline-bounded server (``-serve_deadline_ms``
  closes the batching window early for latency-sensitive requests).
  The warm-up wave replays the identical arrival schedule so the timed
  wave runs warm slots.

Run directly:  PYTHONPATH=src:. python -m benchmarks.bench_serve
or via:        PYTHONPATH=src:. python -m benchmarks.run --only serve
"""

from __future__ import annotations

import random
import threading
import time

import numpy as np

from repro.api import MDP, Session
from repro.serve import Server
from repro.serve.stats import percentile

B = 48
NS = [64, 96]                      # two shape buckets (pad waste > 25%)
OPTS = {"-method": "vi", "-atol": 1e-8, "-dtype": "float64",
        "-verbose": False, "-serve_max_batch": 64}


def _fleet(seed0: int) -> list[MDP]:
    rng = random.Random(seed0)
    ns = [NS[i % 2] for i in range(B)]
    rng.shuffle(ns)
    return [MDP.from_generator("garnet", n=n, m=4, k=4, gamma=0.95,
                               seed=seed0 + i) for i, n in enumerate(ns)]


def _burst(server: Server, mdps):
    """Submit everything in one burst (fixed order, so the scheduler's
    take/bucket partition — and therefore the compiled slot shapes — is
    reproducible across waves), then wait for all results."""
    t0 = time.perf_counter()
    reqs = [server.submit(m) for m in mdps]
    results = [r.result(timeout=600) for r in reqs]
    return results, time.perf_counter() - t0


def _prewarm_slots(server: Server, cap: int = 32) -> None:
    """Compile every mid2 slot the timed waves can touch: for each shape
    bucket, one burst per slot size.  Arrival-timing jitter changes how a
    Poisson wave groups into takes — without this sweep a timed wave can
    hit a slot the seeded warm replay never compiled, and one cold compile
    swamps the latency quantiles."""
    slots = [1, 2, 3, 4, 6, 8, 12, 16, 24, 32]
    for n in NS:
        for s in (x for x in slots if x <= cap):
            reqs = [server.submit(MDP.from_generator(
                "garnet", n=n, m=4, k=4, gamma=0.95, seed=j))
                for j in range(s)]
            for r in reqs:
                r.result(timeout=600)


def _poisson_wave(server: Server, mdps, rate: float, seed: int):
    """Concurrent client threads on a seeded Poisson arrival clock."""
    rng = random.Random(seed)
    lats = [None] * len(mdps)

    def client(i):
        t0 = time.perf_counter()
        server.submit(mdps[i]).result(timeout=600)
        lats[i] = time.perf_counter() - t0

    t0 = time.perf_counter()
    threads = []
    for i in range(len(mdps)):
        t = threading.Thread(target=client, args=(i,))
        threads.append(t)
        t.start()
        if i + 1 < len(mdps):
            time.sleep(rng.expovariate(rate))
    for t in threads:
        t.join()
    return lats, time.perf_counter() - t0


def run(rows) -> None:
    mdps = _fleet(0)

    # -- warm ragged: sequential Session.solve baseline --------------------- #
    with Session(OPTS) as sess:
        for m in mdps:
            sess.solve(m)                  # compile both shapes
        t0 = time.perf_counter()
        base = [sess.solve(m) for m in mdps]
        seq_wall = time.perf_counter() - t0
    rows.append((f"serve/warm_ragged_seq_B{B}", seq_wall * 1e6, "baseline"))
    print(f"  warm ragged B={B}: sequential {seq_wall*1e3:.0f} ms "
          f"({seq_wall / B * 1e3:.2f} ms/req)", flush=True)

    # -- warm ragged: batched server ---------------------------------------- #
    with Server({**OPTS, "-serve_batch_window": 0.005}) as srv:
        _burst(srv, mdps)                                 # warm programs
        warm_dispatches = srv.stats()["dispatches"]
        results, srv_wall = _burst(srv, mdps)
        st = srv.stats()
    bitwise = all(
        np.array_equal(np.asarray(a.v), np.asarray(b.v)) and
        np.array_equal(np.asarray(a.policy), np.asarray(b.policy))
        for a, b in zip(results, base))
    pc = st["program_cache"]
    speedup = seq_wall / srv_wall
    dispatches = st["dispatches"] - warm_dispatches       # timed wave only
    rows.append((f"serve/warm_ragged_server_B{B}", srv_wall * 1e6,
                 f"speedup={speedup:.2f}x bitwise={bitwise} "
                 f"dispatches={dispatches} "
                 f"cache_hit_rate={pc['hit_rate']:.2f}"))
    print(f"  warm ragged B={B}: server {srv_wall*1e3:.0f} ms "
          f"-> {speedup:.2f}x  bitwise={bitwise} "
          f"dispatches={dispatches} "
          f"cache_hit_rate={pc['hit_rate']:.2f}", flush=True)

    # -- Poisson arrivals: batched vs no-batching vs deadline-bounded ------- #
    # the deadline leg keeps the 10 ms window but bounds every request's
    # queue wait at 2 ms (-serve_deadline_ms): tail latency should drop
    # toward the nobatch leg while keeping some coalescing
    rate = 400.0
    legs = [("batched", {"-serve_batch_window": 0.01}),
            ("nobatch", {"-serve_max_batch": 1,
                         "-serve_batch_window": 0.0}),
            ("deadline2ms", {"-serve_batch_window": 0.01,
                             "-serve_deadline_ms": 2.0})]
    for tag, extra in legs:
        with Server({**OPTS, **extra}) as srv:
            # warm every pow2 slot, then replay the identical seeded
            # arrival schedule once before timing it
            _prewarm_slots(srv, cap=1 if tag == "nobatch" else 32)
            _poisson_wave(srv, mdps, rate, seed=4)
            d0 = srv.stats()["dispatches"]
            lats, wall = _poisson_wave(srv, mdps, rate, seed=4)
            st = srv.stats()
        p50, p95 = percentile(lats, 50), percentile(lats, 95)
        thr = B / wall
        rows.append((f"serve/poisson{int(rate)}_{tag}_B{B}", p50 * 1e6,
                     f"p95_ms={p95*1e3:.1f} throughput={thr:.0f}req/s "
                     f"dispatches={st['dispatches'] - d0}"))
        print(f"  poisson rate={rate:.0f}/s {tag}: p50 {p50*1e3:.1f} ms  "
              f"p95 {p95*1e3:.1f} ms  {thr:.0f} req/s", flush=True)


if __name__ == "__main__":
    rows: list = []
    run(rows)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
