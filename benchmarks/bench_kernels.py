"""Benchmark 3 — kernel layer: fused streaming Bellman backup vs the unfused
per-action baseline.

The fused row is the dispatch layer's default path (``-kernel_impl auto`` —
the cache-blocked XLA implementation on CPU, with the scan chunk chosen by
the tile autotuner).  The unfused baseline is the madupite "standard
kernels" composition: one policy-restricted SpMV per action, stacked into
the (n, m) Q-table, then min/argmin — what you write without a fused
backup primitive.  Both sides are jit'd callables with identical
``(idx, val, cost, gamma, v)`` signatures and identical outputs, so the
comparison is like-for-like.

Shapes:
  * n=1e6, m=4, K=4 2-D grid stencil (N/S/E/W neighbors) — the paper's
    maze/diffusion-style problem family; banded successor structure.
  * n=1e5, m=16, K=8 uniform-random successors — unstructured (garnet-like).

Extra rows: the blocked-impl tile sweep (recording the autotuned choice),
the policy SpMV, and — at full scale — an XLA-flag-bundle A/B comparison
run in fresh subprocesses (flags must precede backend init).

The Pallas path is validated bit-for-bit in interpret mode (see
tests/test_kernels_tiled.py) and targeted at TPU; CPU wall time here only
covers the XLA impls.  ``MADUPITE_BENCH_SCALE`` (CI: ~0.02) scales the
state counts.
"""

from __future__ import annotations

import functools
import os
import subprocess
import sys
import time

import numpy as np

SCALE = float(os.environ.get("MADUPITE_BENCH_SCALE", "1.0"))

_REPS = 5


def _time(fn, *args, reps: int = _REPS) -> float:
    """us per call: min over ``reps`` timed calls after one warmup call."""
    import jax

    out = fn(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6


def _stencil_ell(side: int, m: int, k: int):
    """2-D grid with an N/S/E/W successor stencil shared across actions."""
    import jax.numpy as jnp

    n = side * side
    rng = np.random.default_rng(0)
    r = np.arange(n)
    x, y = r // side, r % side
    nb = np.stack([((x + 1) % side) * side + y, ((x - 1) % side) * side + y,
                   x * side + (y + 1) % side, x * side + (y - 1) % side], -1)
    nb = nb[:, :k] if k <= 4 else np.pad(nb, ((0, 0), (0, k - 4)), "edge")
    idx = np.broadcast_to(nb[:, None, :], (n, m, k)).astype(np.int32)
    val = rng.random((n, m, k), dtype=np.float32)
    val /= val.sum(-1, keepdims=True)
    cost = rng.random((n, m), dtype=np.float32)
    v = rng.random(n, dtype=np.float32)
    return (jnp.asarray(idx.copy()), jnp.asarray(val), jnp.asarray(cost),
            jnp.asarray(v))


def _random_ell(n: int, m: int, k: int):
    import jax.numpy as jnp

    rng = np.random.default_rng(1)
    idx = rng.integers(0, n, (n, m, k)).astype(np.int32)
    val = rng.random((n, m, k), dtype=np.float32)
    val /= val.sum(-1, keepdims=True)
    cost = rng.random((n, m), dtype=np.float32)
    v = rng.random(n, dtype=np.float32)
    return (jnp.asarray(idx), jnp.asarray(val), jnp.asarray(cost),
            jnp.asarray(v))


def _unfused(i, w, c, g, u):
    """Per-action SpMV composition (the standard-kernels baseline)."""
    import jax.numpy as jnp

    m = i.shape[1]
    cols = [jnp.sum(w[:, a, :] * jnp.take(u, i[:, a, :], axis=0), axis=-1)
            for a in range(m)]
    q = c + g * jnp.stack(cols, axis=1)
    return q.min(-1), q.argmin(-1).astype(jnp.int32)


def _bench_backup(rows, label, idx, val, cost, v):
    import jax

    from repro.kernels import ops

    n, m, k = idx.shape
    gamma = 0.99
    # tune eagerly first: the fused timing below traces ops.ell_backup
    # inside an outer jit, where the tuner can only consult its cache
    impl = ops._resolve(None)
    bn = (ops.backup_block_rows(n, m, k, v.shape[0], val.dtype)
          if impl == "blocked" else None)
    fused = jax.jit(lambda i, w, c, g, u: ops.ell_backup(i, w, c, g, u))
    unfused = jax.jit(_unfused)
    t_un = _time(unfused, idx, val, cost, gamma, v)
    t_fu = _time(fused, idx, val, cost, gamma, v)
    ratio = t_un / t_fu if t_fu else float("nan")
    rows.append((f"kernels/backup_unfused/n={n}", t_un,
                 f"per-action SpMV + stack + min/argmin m={m} K={k}"))
    rows.append((f"kernels/backup_fused/n={n}", t_fu,
                 f"impl=auto->{impl} block_rows={bn} "
                 f"{ratio:.2f}x vs unfused"))
    print(f"  {label}: unfused {t_un / 1e3:.1f} ms, fused {t_fu / 1e3:.1f} ms"
          f" ({ratio:.2f}x, impl={impl}, block_rows={bn})", flush=True)


def _bench_tile_sweep(rows, idx, val, cost, v):
    import jax

    from repro.kernels import ops, ref

    n, m, k = idx.shape
    gamma = 0.99
    cands = [c for c in ops.BLOCK_ROWS_CANDIDATES if c <= n] or [n]
    sweep = {}
    for bn in cands:
        fn = jax.jit(functools.partial(ref.ell_backup_blocked, block_rows=bn))
        sweep[bn] = _time(fn, idx, val, cost, gamma, v, reps=3)
    chosen = ops.backup_block_rows(n, m, k, v.shape[0], val.dtype)
    best = min(sweep, key=sweep.get)
    detail = " ".join(f"bn={bn}:{int(us)}us" for bn, us in sweep.items())
    rows.append((f"kernels/backup_tile_sweep/n={n}", sweep[best],
                 f"{detail} autotuned={chosen}"))
    print(f"  tile sweep: {detail}; autotuned choice bn={chosen}", flush=True)


def _bench_spmv(rows, idx, val, v):
    import jax

    from repro.kernels import ops, ref

    n, _, k = idx.shape
    i1, w1 = idx[:, 0, :], val[:, 0, :]
    fused = jax.jit(lambda i, w, x: ops.ell_matvec(i, w, x))
    plain = jax.jit(ref.ell_matvec)
    t_fu = _time(fused, i1, w1, v)
    t_pl = _time(plain, i1, w1, v)
    rows.append((f"kernels/spmv_blocked/n={n}", t_fu,
                 f"{t_pl / t_fu:.2f}x vs one-shot chain K={k}"))
    print(f"  spmv: blocked {t_fu / 1e3:.2f} ms vs chain {t_pl / 1e3:.2f} ms",
          flush=True)


_CHILD = r"""
import sys, time
sys.path.insert(0, {src!r})
import numpy as np
import jax, jax.numpy as jnp
from repro.kernels import ops, tuning
tuning.configure(enabled=False)
side = {side}; m, k = 4, 4
n = side * side
rng = np.random.default_rng(0)
r = np.arange(n); x, y = r // side, r % side
nb = np.stack([((x+1)%side)*side+y, ((x-1)%side)*side+y,
               x*side+(y+1)%side, x*side+(y-1)%side], -1)
idx = jnp.asarray(
    np.broadcast_to(nb[:, None, :], (n, m, k)).astype(np.int32).copy())
val = jnp.asarray(rng.random((n, m, k), dtype=np.float32))
cost = jnp.asarray(rng.random((n, m), dtype=np.float32))
v = jnp.asarray(rng.random(n, dtype=np.float32))
fn = jax.jit(lambda i, w, c, g, u: ops.ell_backup(i, w, c, g, u))
out = fn(idx, val, cost, 0.99, v)
jax.tree_util.tree_map(lambda a: a.block_until_ready(), out)
ts = []
for _ in range(5):
    t0 = time.perf_counter()
    out = fn(idx, val, cost, 0.99, v)
    jax.tree_util.tree_map(lambda a: a.block_until_ready(), out)
    ts.append(time.perf_counter() - t0)
print("US=%.1f" % (min(ts) * 1e6))
"""


def _bench_flag_bundles(rows, side: int) -> None:
    """A/B the XLA flag bundles in fresh subprocesses (flags must be set
    before the backend initializes, so in-process timing can't see them)."""
    from repro.utils import xla_flags

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    child = _CHILD.format(src=os.path.join(root, "src"), side=side)
    base_us = None
    for bundle in (None, "cpu-single", "cpu-host"):
        env = dict(os.environ)
        if bundle is not None:
            env["XLA_FLAGS"] = xla_flags.merged_flags(
                bundle, env.get("XLA_FLAGS", ""))
        try:
            out = subprocess.run(
                [sys.executable, "-c", child], env=env, timeout=600,
                capture_output=True, text=True, check=True).stdout
            us = float(next(l for l in out.splitlines()
                            if l.startswith("US=")).split("=")[1])
        except (subprocess.SubprocessError, StopIteration, ValueError) as e:
            print(f"  bundle {bundle}: failed ({e})", flush=True)
            continue
        name = bundle or "none"
        if base_us is None:
            base_us = us
        rows.append((f"kernels/backup_bundle_{name}", us,
                     f"XLA_FLAGS bundle {name} ({base_us / us:.2f}x vs none)"))
        print(f"  bundle {name}: {us / 1e3:.1f} ms", flush=True)


def run(rows) -> None:
    side = max(32, int(round(1000 * SCALE ** 0.5)))
    n_rand = max(1024, int(100_000 * SCALE))

    idx, val, cost, v = _stencil_ell(side, 4, 4)
    _bench_backup(rows, f"stencil n={side * side} m=4 K=4", idx, val, cost, v)
    _bench_tile_sweep(rows, idx, val, cost, v)
    _bench_spmv(rows, idx, val, v)

    ridx, rval, rcost, rv = _random_ell(n_rand, 16, 8)
    _bench_backup(rows, f"random n={n_rand} m=16 K=8", ridx, rval, rcost, rv)

    if SCALE >= 1.0:
        _bench_flag_bundles(rows, side)
