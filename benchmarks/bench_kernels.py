"""Benchmark 3 — kernel layer: fused Bellman backup / SpMV wall time vs the
unfused XLA reference (CPU timings; the Pallas path is validated in
interpret mode and targeted at TPU — see EXPERIMENTS.md for the roofline
projection instead of CPU wall time)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / reps * 1e6


def run(csv_rows: list):
    rng = np.random.default_rng(0)
    for (n, m, k) in [(100_000, 16, 8), (1_000_000, 8, 4)]:
        idx = jnp.asarray(rng.integers(0, n, (n, m, k)).astype(np.int32))
        val = jnp.asarray(rng.random((n, m, k)).astype(np.float32))
        cost = jnp.asarray(rng.random((n, m)).astype(np.float32))
        v = jnp.asarray(rng.random(n).astype(np.float32))

        fused = jax.jit(lambda i, w, c, u: ops.ell_backup(i, w, c, 0.99, u))
        us = _time(fused, idx, val, cost, v)
        csv_rows.append((f"kernels/backup_fused/n={n}", us,
                         f"flops={2*n*m*k:.2e}"))

        def unfused(i, w, c, u):
            q = c + 0.99 * (w * jnp.take(u, i, axis=0)).sum(-1)
            return q.min(-1), q.argmin(-1)
        us2 = _time(jax.jit(unfused), idx, val, cost, v)
        csv_rows.append((f"kernels/backup_unfused/n={n}", us2, ""))
        print(f"  backup n={n:9d}: fused={us:9.0f}us unfused={us2:9.0f}us",
              flush=True)
