"""Benchmark 1 — madupite's core claim: a selectable inner solver beats any
fixed method across instance families (Gargiani et al. 2023/2024, Tables of
iteration counts / wall time per method).

For each instance family and each method: outer iterations, cumulative inner
iterations, wall time to the same certified tolerance.
"""

from __future__ import annotations

import time

import jax

from repro.core import IPIOptions, generators
from repro.core.driver import solve

METHODS = ["vi", "mpi", "ipi_richardson", "ipi_gmres", "ipi_bicgstab"]

INSTANCES = {
    "garnet_50k": lambda: generators.garnet(50_000, 16, 8, gamma=0.99,
                                            seed=0),
    "maze2d_150": lambda: generators.maze2d(150, gamma=0.998),
    "sis_20k": lambda: generators.sis(20_000, 8, gamma=0.999),
    "chain_0.9999": lambda: generators.chain_walk(5_000, gamma=0.9999),
}


def run(csv_rows: list):
    jax.config.update("jax_enable_x64", True)
    for iname, make in INSTANCES.items():
        mdp = make()
        for method in METHODS:
            opts = IPIOptions(method=method, atol=1e-8, dtype="float64",
                              max_outer=100_000 if method == "vi" else 5000,
                              mpi_sweeps=100, max_inner=1000)
            t0 = time.time()
            r = solve(mdp, opts)
            wall = time.time() - t0
            csv_rows.append((
                f"solvers/{iname}/{method}",
                wall * 1e6,
                f"outer={r.outer_iterations};inner={r.inner_iterations};"
                f"res={r.residual:.2e};converged={r.converged}"))
            print(f"  {iname:16s} {method:16s} wall={wall:7.2f}s "
                  f"outer={r.outer_iterations:6d} "
                  f"inner={r.inner_iterations:8d} conv={r.converged}",
                  flush=True)
