"""Benchmark 1 — madupite's core claim: a selectable inner solver beats any
fixed method across instance families (Gargiani et al. 2023/2024, Tables of
iteration counts / wall time per method).

For each instance family and each registered method: outer iterations,
cumulative inner iterations, wall time to the same certified tolerance.
The method list is drawn from the live registry (ISSUE 5), so the new
``ipi_chebyshev`` / ``ipi_anderson`` inner solvers — and any user-registered
KSP — ride along automatically.

A second table benchmarks the *stopping criteria*: ``-stop_criterion span``
vs ``atol`` on the long-mixing chain_walk instance, asserting the span
seminorm certifies in strictly fewer outer iterations with the same
returned policy (the paper-level claim behind span stopping).

``MADUPITE_BENCH_SCALE`` (default 1.0) scales the instance sizes so CI can
run a quick leg (e.g. ``MADUPITE_BENCH_SCALE=0.02``) while the default
remains the full paper-scale table.
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import IPIOptions, generators
from repro.core.driver import solve
from repro.core.methods import get_method, method_names

SCALE = float(os.environ.get("MADUPITE_BENCH_SCALE", "1.0"))

# pi is exact policy iteration (dense solves, different cost model);
# virtual methods (auto) are drivers over these, not methods of their own
METHODS = [m for m in method_names(builtin_only=True)
           if m != "pi" and not get_method(m).virtual]


def _n(n: int, lo: int = 64) -> int:
    return max(int(n * SCALE), lo)


INSTANCES = {
    "garnet_50k": lambda: generators.garnet(_n(50_000), 16, 8, gamma=0.99,
                                            seed=0),
    "maze2d_150": lambda: generators.maze2d(max(int(150 * SCALE ** 0.5), 12),
                                            gamma=0.998),
    "sis_20k": lambda: generators.sis(_n(20_000), 8, gamma=0.999),
    "chain_0.9999": lambda: generators.chain_walk(_n(5_000), gamma=0.9999),
}


def run(csv_rows: list):
    jax.config.update("jax_enable_x64", True)
    for iname, make in INSTANCES.items():
        mdp = make()
        for method in METHODS:
            opts = IPIOptions(method=method, atol=1e-8, dtype="float64",
                              max_outer=100_000 if method == "vi" else 5000,
                              mpi_sweeps=100, max_inner=1000)
            t0 = time.time()
            r = solve(mdp, opts)
            wall = time.time() - t0
            scale_tag = "" if SCALE == 1.0 else f";scale={SCALE}"
            csv_rows.append((
                f"solvers/{iname}/{method}",
                wall * 1e6,
                f"outer={r.outer_iterations};inner={r.inner_iterations};"
                f"res={r.residual:.2e};converged={r.converged}{scale_tag}"))
            print(f"  {iname:16s} {method:16s} wall={wall:7.2f}s "
                  f"outer={r.outer_iterations:6d} "
                  f"inner={r.inner_iterations:8d} conv={r.converged}",
                  flush=True)

    # ---- stopping criteria: span vs atol on the long-mixing chain ----------
    mdp = generators.chain_walk(_n(5_000), gamma=0.9999)
    rows = {}
    for crit in ("atol", "span"):
        opts = IPIOptions(method="vi", atol=1e-8, dtype="float64",
                          max_outer=1_000_000, stop_criterion=crit)
        t0 = time.time()
        rows[crit] = (solve(mdp, opts), time.time() - t0)
    r_atol, w_atol = rows["atol"]
    r_span, w_span = rows["span"]
    assert r_span.converged and r_atol.converged
    assert r_span.outer_iterations < r_atol.outer_iterations, \
        (r_span.outer_iterations, r_atol.outer_iterations)
    assert np.array_equal(r_span.policy, r_atol.policy), \
        "span stopping returned a different policy than atol"
    scale_tag = "" if SCALE == 1.0 else f";scale={SCALE}"
    for crit, (r, w) in rows.items():
        csv_rows.append((
            f"solvers/chain_stop/{crit}", w * 1e6,
            f"outer={r.outer_iterations};res={r.residual:.2e}{scale_tag}"))
    speedup = r_atol.outer_iterations / max(r_span.outer_iterations, 1)
    csv_rows.append((
        "solvers/chain_stop/span_vs_atol_outers",
        float(r_span.outer_iterations),
        f"{speedup:.1f}x fewer outers, same policy{scale_tag}"))
    print(f"  chain stop-criterion: atol outer={r_atol.outer_iterations} "
          f"({w_atol:.2f}s) vs span outer={r_span.outer_iterations} "
          f"({w_span:.2f}s) = {speedup:.1f}x fewer, same policy",
          flush=True)
