"""Assemble EXPERIMENTS.md tables from results/*.json artifacts."""

import json
import os


def fmt(x, digits=2):
    if x is None:
        return "-"
    if isinstance(x, str):
        return x
    return f"{x:.{digits}e}"


def gb(x):
    return "-" if x in (None, -1) else f"{x / 2**30:.2f}"


DRYRUN_PATHS = ("results/dryrun_all.json", "results/dryrun_moe_refresh.json",
                "results/dryrun_moe2.json", "results/dryrun_small_refresh.json",
                "results/dryrun_small2.json",
                "results/dryrun_mdp_refresh.json")


def dryrun_table(paths=DRYRUN_PATHS):
    d = {}
    for p in paths:  # later files overwrite earlier cells (refreshes win)
        if os.path.exists(p):
            d.update(json.load(open(p)))
    lines = ["| cell | mesh | status | lower+compile s | temp GB/dev | "
             "args GB/dev | AG | AR | RS | A2A | CP |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for key, r in sorted(d.items()):
        parts = key.rsplit("/", 1)
        cell, mesh = parts[0], parts[1]
        if r["status"] != "ok":
            lines.append(f"| {cell} | {mesh} | FAIL | - | - | - |  |  |  |  |  |")
            continue
        c = r.get("collective_counts", {})
        lines.append(
            f"| {cell} | {mesh} | ok | "
            f"{r['lower_s'] + r['compile_s']:.0f} | "
            f"{gb(r.get('temp_size_in_bytes'))} | "
            f"{gb(r.get('argument_size_in_bytes'))} | "
            f"{c.get('all-gather', 0)} | {c.get('all-reduce', 0)} | "
            f"{c.get('reduce-scatter', 0)} | {c.get('all-to-all', 0)} | "
            f"{c.get('collective-permute', 0)} |")
    return "\n".join(lines)


def roofline_table(paths=("results/roofline.json",
                          "results/roofline_mdp2.json",
                          "results/roofline_whisper_opt.json",
                          "results/roofline_mamba_opt.json")):
    d = {}
    for p in paths:
        if not os.path.exists(p):
            continue
        tag = " (shipped-opt)" if p.endswith("_opt.json") else ""
        for k, v in json.load(open(p)).items():
            d[k + tag] = v
    lines = ["| cell | compute s | memory s | collective s | dominant | "
             "MODEL_FLOPs/dev | useful ratio | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for key, r in sorted(d.items()):
        if r.get("status") != "ok":
            lines.append(f"| {key} | FAIL {r.get('error', '')[:40]} | | | | | | |")
            continue
        lines.append(
            f"| {key} | {fmt(r['compute_s'])} | {fmt(r['memory_s'])} | "
            f"{fmt(r['collective_s'])} | **{r['dominant']}** | "
            f"{fmt(r['model_flops_per_device'])} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{r.get('roofline_fraction', 0):.2e} |")
    return "\n".join(lines)


def perf_table(path="results/perf_iters.jsonl"):
    if not os.path.exists(path):
        return "(no perf iterations recorded)"
    rows = [json.loads(ln) for ln in open(path) if ln.strip()]
    lines = ["| cell | variant | compute s | memory s | collective s | "
             "bound (max term) | dominant |",
             "|---|---|---|---|---|---|---|"]
    for r in rows:
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        lines.append(
            f"| {r['arch']}/{r['shape']} | {r['variant']} | "
            f"{fmt(r['compute_s'])} | {fmt(r['memory_s'])} | "
            f"{fmt(r['collective_s'])} | {fmt(bound)} | {r['dominant']} |")
    return "\n".join(lines)


if __name__ == "__main__":
    os.makedirs("results", exist_ok=True)
    with open("results/tables.md", "w") as f:
        f.write("## Dry-run\n\n" + dryrun_table() + "\n\n")
        f.write("## Roofline\n\n" + roofline_table() + "\n\n")
        f.write("## Perf iterations\n\n" + perf_table() + "\n")
    print("wrote results/tables.md")
