import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration driver: hypothesis -> change -> re-lower -> re-analyse.

Each --variant toggles one optimization lever; the tool lowers the cell with
the lever applied and reports the three roofline terms so before/after pairs
land in EXPERIMENTS.md §Perf.

Levers (comma-separated in --variant):
  embed_novocabfsdp   embed table: TP on vocab only (kills the gather
                      involuntary-remat replication)
  replicate_small     no TP/FSDP for models < 1B params (pure DP; tiny archs
                      are over-distributed at TP=16)
  remat_dots          save dot outputs instead of full remat
  micro<N>            per-device microbatch size N (e.g. micro4)
  ssmchunk<N>         mamba2 SSD chunk length
  moegroup<N>         MoE dispatch group size
  attnchunk<N>        flash-scan KV chunk
  seqshard_attn       shard the attention *sequence* dim over data for
                      prefill (context parallelism)

Usage:
  PYTHONPATH=src:. python benchmarks/perf_iter.py \
      --arch mamba2-130m --shape train_4k --variant replicate_small
"""

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp


def apply_variants(arch, variants):
    """Returns (cfg_override, tcfg_override, sharding_kwargs)."""
    from repro.configs import get_config, get_train_config
    cfg = get_config(arch)
    tcfg = get_train_config(arch)
    shard_kw = {}
    for v in variants:
        if v == "embed_novocabfsdp":
            shard_kw["embed_tp_only"] = True
        elif v == "replicate_small":
            shard_kw["replicate_below"] = 1_000_000_000
        elif v == "remat_dots":
            tcfg = dataclasses.replace(tcfg, remat="dots")
        elif v.startswith("micro"):
            tcfg = dataclasses.replace(tcfg, microbatch=int(v[5:]))
        elif v.startswith("ssmchunk"):
            cfg = dataclasses.replace(cfg, ssm_chunk=int(v[8:]))
        elif v.startswith("moegroup"):
            cfg = dataclasses.replace(cfg, moe_group_size=int(v[8:]))
        elif v.startswith("attnchunk"):
            cfg = dataclasses.replace(cfg, attn_chunk=int(v[9:]))
        elif v == "baseline" or not v:
            pass
        else:
            raise ValueError(f"unknown variant {v!r}")
    return cfg, tcfg, shard_kw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    import repro.configs as C
    import repro.train.sharding as shd
    from benchmarks import roofline as R
    from repro.launch.mesh import make_production_mesh

    variants = [v.strip() for v in args.variant.split(",")]
    cfg_o, tcfg_o, shard_kw = apply_variants(args.arch, variants)

    # patch the config registry + trainer config + sharding rules
    orig_cfg, orig_t = C.get_config, C.get_train_config
    C.get_config = lambda a: cfg_o if a == args.arch else orig_cfg(a)
    C.get_train_config = lambda a: tcfg_o if a == args.arch else orig_t(a)
    import repro.launch.specs as S
    S.get_config, S.get_train_config = C.get_config, C.get_train_config

    if shard_kw:
        orig_spec = shd.param_spec

        def patched(path, shape, **kw):
            import numpy as np
            size = int(np.prod(shape))
            if shard_kw.get("replicate_below", 0) and \
                    _model_small(cfg_o, shard_kw["replicate_below"]):
                from jax.sharding import PartitionSpec as P
                return P(*([None] * len(shape)))
            names = shd._path_names(path)
            if shard_kw.get("embed_tp_only") and names[-1] == "embed":
                kw = dict(kw, fsdp=False)
            return orig_spec(path, shape, **kw)
        shd.param_spec = patched

    def _model_small(cfg, thresh):
        return cfg.param_count() < thresh

    mesh = make_production_mesh(multi_pod=False)
    rec = R.lm_cell_terms(args.arch, args.shape, mesh)
    rec["variant"] = args.variant
    print(json.dumps(rec, indent=1))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        mode = "a" if os.path.exists(args.out) else "w"
        with open(args.out, mode) as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
