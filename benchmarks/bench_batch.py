"""Fleet-solve throughput: solve_many (one batched program) vs a sequential
Python loop of per-instance ``solve`` calls (ISSUE 1 tentpole claim:
>= 3x for B=8 garnet instances on CPU).

Two regimes, matching the two fleet workloads the batched engine serves:

* ``cold``  — gamma-conditioning sweep (the paper's gamma -> 1 study).
  ``gamma`` is a static compile-time constant of the kernels, so the
  sequential loop pays one full dispatch/compile/solve round-trip *per
  instance* while ``solve_many`` compiles ONE traced-gamma program for the
  whole fleet.  Timed from a cleared jit cache: the end-to-end cost of
  "a fleet arrives, solve it".
* ``warm``  — seed ensemble, jit caches hot (identical statics, so the
  sequential loop compiles only once).  What remains is per-call dispatch /
  host-sync / result overhead, which the single fleet program amortizes.

Run directly:  PYTHONPATH=src:. python -m benchmarks.bench_batch
or via:        PYTHONPATH=src:. python -m benchmarks.run --only batch
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import IPIOptions, generators
from repro.core.driver import solve, solve_many

B = 8


def _bench(fn, reps, *, cold=False):
    if not cold:
        fn()                      # warm-up (compile)
    ts = []
    for _ in range(reps):
        if cold:
            jax.clear_caches()
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6          # us


def _check(fleet, opts, *, strict_iters: bool) -> bool:
    """Fleet results must match the per-instance solves they replace.

    ``strict_iters`` (homogeneous-gamma fleets: bit-identical arithmetic)
    additionally requires exact per-instance outer counts; heterogeneous
    gammas run the traced-gamma path, where f32 rounding near gamma -> 1 can
    legitimately shift the Krylov iteration path — there the guarantee is
    the convergence certificate (both converge, same policy, values close).
    """
    r_seq = [solve(m, opts) for m in fleet]
    r_bat = solve_many(fleet, opts)
    return all(rb.converged and rs.converged and
               (rs.policy == rb.policy).all() and
               abs(rs.v - rb.v).max() < 1e-3 and
               (not strict_iters or
                rs.outer_iterations == rb.outer_iterations)
               for rs, rb in zip(r_seq, r_bat))


def run(rows) -> None:
    # -- cold: gamma sweep, per-instance compile vs one fleet program ------- #
    gammas = list(1.0 - np.geomspace(0.05, 0.002, B))
    sweep = generators.generate_many("garnet", B, n=512, m=8, k=4,
                                     sweep={"gamma": gammas})
    opts = IPIOptions(method="ipi_gmres", atol=1e-5, dtype="float32",
                      max_outer=500)
    agree = _check(sweep, opts, strict_iters=False)
    us_seq = _bench(lambda: [solve(m, opts) for m in sweep], 2, cold=True)
    us_bat = _bench(lambda: solve_many(sweep, opts), 2, cold=True)
    rows.append((f"batch/gamma_sweep_cold_seq_B{B}", us_seq, "baseline"))
    rows.append((f"batch/gamma_sweep_cold_many_B{B}", us_bat,
                 f"speedup={us_seq / us_bat:.2f}x agree={agree}"))
    print(f"  cold gamma sweep  B={B}: seq {us_seq/1e3:.0f} ms  "
          f"solve_many {us_bat/1e3:.0f} ms  -> {us_seq/us_bat:.2f}x "
          f"(agree={agree})", flush=True)

    # -- warm: seed ensemble, dispatch/host-sync amortization --------------- #
    ens = generators.generate_many("garnet", B, n=64, m=4, k=4,
                                   gamma=0.95, seed=0)
    opts = IPIOptions(method="vi", atol=1e-3, dtype="float32",
                      max_outer=2000)
    agree = _check(ens, opts, strict_iters=True)
    us_seq = _bench(lambda: [solve(m, opts) for m in ens], 5)
    us_bat = _bench(lambda: solve_many(ens, opts), 5)
    rows.append((f"batch/seed_ensemble_warm_seq_B{B}", us_seq, "baseline"))
    rows.append((f"batch/seed_ensemble_warm_many_B{B}", us_bat,
                 f"speedup={us_seq / us_bat:.2f}x agree={agree}"))
    print(f"  warm seed ensemble B={B}: seq {us_seq/1e3:.1f} ms  "
          f"solve_many {us_bat/1e3:.1f} ms  -> {us_seq/us_bat:.2f}x "
          f"(agree={agree})", flush=True)


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(r)
