import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline-term extraction (per arch x shape cell, single-pod mesh).

Methodology (see EXPERIMENTS.md §Roofline): XLA's cost analysis counts
while-loop bodies ONCE, so the full-depth scan-over-layers lowering
undercounts flops/bytes/collectives.  We therefore lower *reduced-depth,
unrolled* variants at full width (loop-free HLO -> exact counts), fit the
per-layer cost linearly in depth, and evaluate at the real depth:

    dense/moe/ssm/vlm : f(L) = c + a.L          (two lowers, L=1,2)
    hybrid (zamba2)   : f = c + a.L_mamba + s.N_shared   (three lowers)
    encdec (whisper)  : f = c + e.L_enc + d.L_dec        (three lowers)

Train cells are lowered with n_microbatches=1 and scaled by the real
microbatch count (grad accumulation repeats the identical body; the
optimizer-update overcount is <0.1% and noted).  MDP solver terms are lowered
loop-free directly (one Bellman backup / one policy matvec per record).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (collective bytes are per-device, so the term uses one link's bandwidth).
"""

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _counts(lowered):
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    from repro.launch.dryrun import collective_bytes
    coll = collective_bytes(compiled.as_text())
    return dict(flops=float(cost.get("flops", 0)),
                bytes=float(cost.get("bytes accessed", 0)),
                coll=float(sum(v for k, v in coll.items()
                               if k != "counts")),
                coll_by_kind={k: v for k, v in coll.items()
                              if k != "counts"})


def _lower_cell(arch, shape_name, mesh, cfg_override):
    """Lower one (possibly reduced-depth) unrolled cell; return counts."""
    import repro.launch.specs as S
    from repro.configs import get_train_config
    from repro.models import build_model
    from repro.train.steps import (make_decode_step, make_prefill_step,
                                   make_train_step)

    # Patch the registry config via monkey-patched get_config path:
    # easier: rebuild specs manually with the override config.
    from repro.configs.base import SHAPES
    import repro.configs as C
    import repro.train.sharding as shd
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = cfg_override
    shape = SHAPES[shape_name]
    tcfg = get_train_config(arch)
    model = build_model(cfg)
    pshapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    if tcfg.replicate_params:
        pspecs = jax.tree.map(lambda s: P(*([None] * len(s.shape))), pshapes)
    else:
        pspecs = shd.infer_param_specs(pshapes, mesh)
    sds = lambda s, sp: jax.ShapeDtypeStruct(
        s.shape, s.dtype, sharding=NamedSharding(mesh, sp))
    psds = jax.tree.map(sds, pshapes, pspecs)

    # borrow the shape-dependent builders by faking the registry entry
    orig_get = C.get_config
    C.get_config = lambda a: cfg if a == arch else orig_get(a)
    S_get = S.get_config
    S.get_config = C.get_config
    try:
        if shape.kind == "train":
            from repro.train.optimizer import init_opt_state
            oshapes = jax.eval_shape(lambda p: init_opt_state(p, tcfg),
                                     pshapes)
            if tcfg.replicate_params:
                ospecs = jax.tree.map(
                    lambda s: P(*([None] * len(s.shape))), oshapes)
            else:
                ospecs = shd.infer_param_specs(oshapes, mesh)
            osds = jax.tree.map(sds, oshapes, ospecs)
            batch = S.batch_specs(arch, shape, mesh)
            fn = make_train_step(model, tcfg, n_microbatches=1, unroll=True)
            out_sh = (jax.tree.map(lambda s: s.sharding, psds),
                      jax.tree.map(lambda s: s.sharding, osds), None)
            lowered = jax.jit(fn, out_shardings=out_sh).lower(
                psds, osds, jax.ShapeDtypeStruct((), jnp.int32), batch)
            scale = S.n_microbatches(arch, shape, mesh)
        elif shape.kind == "prefill":
            batch = S.batch_specs(arch, shape, mesh)
            fn = make_prefill_step(model, unroll=True)
            lowered = jax.jit(fn).lower(psds, batch["tokens"],
                                        batch.get("patches"))
            scale = 1
        else:
            cache = S.cache_specs(arch, shape, mesh)
            token = S.decode_token_specs(arch, shape, mesh)
            fn = make_decode_step(model, unroll=True)
            cache_sh = jax.tree.map(lambda s: s.sharding, cache)
            lowered = jax.jit(fn, out_shardings=(None, None, cache_sh)).lower(
                psds, token, cache)
            scale = 1
    finally:
        C.get_config = orig_get
        S.get_config = orig_get
    c = _counts(lowered)
    return {k: (v * scale if k != "coll_by_kind" else
                {kk: vv * scale for kk, vv in v.items()})
            for k, v in c.items()}


def lm_cell_terms(arch: str, shape_name: str, mesh) -> dict:
    """Fit reduced-depth counts to the full config; return roofline terms."""
    from repro.configs import get_config
    cfg = get_config(arch)
    rep = dataclasses.replace

    if cfg.family == "hybrid":
        f1 = _lower_cell(arch, shape_name, mesh,
                         rep(cfg, n_layers=1, shared_attn_every=0))
        f2 = _lower_cell(arch, shape_name, mesh,
                         rep(cfg, n_layers=2, shared_attn_every=0))
        f2s = _lower_cell(arch, shape_name, mesh,
                          rep(cfg, n_layers=2, shared_attn_every=2))
        n_sites = cfg.n_layers // cfg.shared_attn_every
        fit = lambda k: (f1[k] + (cfg.n_layers - 1) * (f2[k] - f1[k])
                         + n_sites * (f2s[k] - f2[k]))
    elif cfg.family == "encdec":
        f11 = _lower_cell(arch, shape_name, mesh,
                          rep(cfg, n_layers=1, encoder_layers=1))
        f21 = _lower_cell(arch, shape_name, mesh,
                          rep(cfg, n_layers=2, encoder_layers=1))
        f12 = _lower_cell(arch, shape_name, mesh,
                          rep(cfg, n_layers=1, encoder_layers=2))
        fit = lambda k: (f11[k]
                         + (cfg.n_layers - 1) * (f21[k] - f11[k])
                         + (cfg.encoder_layers - 1) * (f12[k] - f11[k]))
    else:
        f1 = _lower_cell(arch, shape_name, mesh, rep(cfg, n_layers=1))
        f2 = _lower_cell(arch, shape_name, mesh, rep(cfg, n_layers=2))
        fit = lambda k: f1[k] + (cfg.n_layers - 1) * (f2[k] - f1[k])

    flops, bts, coll = fit("flops"), fit("bytes"), fit("coll")
    return finish_terms(arch, shape_name, mesh, flops, bts, coll)


def finish_terms(arch, shape_name, mesh, flops, bts, coll) -> dict:
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    import math
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = math.prod(mesh.shape.values())
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2 * n_active * shape.global_batch  # one token
    t_comp = flops / PEAK_FLOPS
    t_mem = bts / HBM_BW
    t_coll = coll / ICI_BW
    dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
    return dict(
        arch=arch, shape=shape_name,
        flops_per_device=flops, bytes_per_device=bts,
        collective_bytes_per_device=coll,
        compute_s=t_comp, memory_s=t_mem, collective_s=t_coll,
        dominant=dom[1],
        model_flops_global=model_flops,
        model_flops_per_device=model_flops / chips,
        useful_flops_ratio=(model_flops / chips) / max(flops, 1),
        roofline_fraction=max(
            min((model_flops / chips) / PEAK_FLOPS, t_comp)
            / max(t_comp, t_mem, t_coll, 1e-30), 0.0),
    )


# --------------------------------------------------------------------------- #
# MDP solver roofline (loop-free lowers of the per-iteration bodies)          #
# --------------------------------------------------------------------------- #

def mdp_terms(name: str, mesh) -> dict:
    from functools import partial
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import bellman, partition
    from repro.core.mdp import EllMDP
    from repro.launch.dryrun import MDP_CELLS

    import math
    from repro.core.mdp import DenseMDP
    n, m, k, layout, method, halo = MDP_CELLS[name]
    axes = partition.mesh_axes(mesh, layout)
    if k == 0:  # dense (MXU) representation
        mdp_abs = DenseMDP(p=jax.ShapeDtypeStruct((n, m, n), jnp.float32),
                           cost=jax.ShapeDtypeStruct((n, m), jnp.float32),
                           gamma=0.9999, n_global=n, m_global=m)
    else:
        mdp_abs = EllMDP(idx=jax.ShapeDtypeStruct((n, m, k), jnp.int32),
                         val=jax.ShapeDtypeStruct((n, m, k), jnp.float32),
                         cost=jax.ShapeDtypeStruct((n, m), jnp.float32),
                         gamma=0.9999, n_global=n, m_global=m)
    specs = partition.mdp_pspecs(mdp_abs, axes)
    ns = lambda sp: NamedSharding(mesh, sp)
    mdp_sds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns(sp)),
        mdp_abs, specs)
    v_sds = jax.ShapeDtypeStruct((n,), jnp.float32, sharding=ns(P(axes.state)))

    def one_vi_iteration(mdp, v):
        v_g = bellman.gather_v(v, axes, halo=halo)
        tv, pi = bellman.backup(mdp, v_g, axes, halo=halo)
        res = axes.pmax_state(jnp.max(jnp.abs(tv - v)))
        return tv, pi, res

    fn = jax.jit(jax.shard_map(
        one_vi_iteration, mesh=mesh, in_specs=(specs, P(axes.state)),
        out_specs=(P(axes.state), P(axes.state), P()), check_vma=False))
    c = _counts(fn.lower(mdp_sds, v_sds))

    chips = math.prod(mesh.shape.values())
    # useful backup flops: 2nmK sparse, 2*n^2*m dense
    model_flops = 2.0 * n * m * (k if k else n)
    t_comp = c["flops"] / PEAK_FLOPS
    t_mem = c["bytes"] / HBM_BW
    t_coll = c["coll"] / ICI_BW
    dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
    return dict(arch=name, shape=f"backup[{layout}]",
                flops_per_device=c["flops"], bytes_per_device=c["bytes"],
                collective_bytes_per_device=c["coll"],
                compute_s=t_comp, memory_s=t_mem, collective_s=t_coll,
                dominant=dom[1], model_flops_global=model_flops,
                model_flops_per_device=model_flops / chips,
                useful_flops_ratio=(model_flops / chips) / max(c["flops"], 1),
                roofline_fraction=(model_flops / chips / PEAK_FLOPS)
                / max(t_comp, t_mem, t_coll, 1e-30))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=("lm", "mdp", "all"), default="all")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()

    from repro.configs import ARCHS, cells
    from repro.launch.dryrun import MDP_CELLS
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    jobs = []
    if args.arch:
        shapes = [args.shape] if args.shape else \
            [s.name for s in cells(args.arch)]
        jobs += [("lm", args.arch, s) for s in shapes]
    if args.suite in ("lm", "all") and not args.arch:
        jobs += [("lm", a, s.name) for a in ARCHS for s in cells(a)]
    if args.suite in ("mdp", "all") and not args.arch:
        jobs += [("mdp", c, "") for c in MDP_CELLS]

    results = {}
    for kind, a, s in jobs:
        key = f"{a}/{s}" if s else a
        t0 = time.time()
        try:
            rec = lm_cell_terms(a, s, mesh) if kind == "lm" \
                else mdp_terms(a, mesh)
            rec["status"] = "ok"
        except Exception as e:  # noqa: BLE001
            import traceback
            rec = {"status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-1500:]}
        rec["wall_s"] = round(time.time() - t0, 1)
        results[key] = rec
        if rec["status"] == "ok":
            print(f"[ok] {key:36s} dom={rec['dominant']:10s} "
                  f"comp={rec['compute_s']:.2e}s mem={rec['memory_s']:.2e}s "
                  f"coll={rec['collective_s']:.2e}s "
                  f"useful={rec['useful_flops_ratio']:.3f}", flush=True)
        else:
            print(f"[FAIL] {key}: {rec['error']}", flush=True)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
