"""API-layer cost: session dispatch overhead + from_functions construction.

The session layer (ISSUE 3) must be *free* on the hot path: once a solve
shape is warm, ``Session.solve`` adds only options resolution, placement
lookup and stats bookkeeping on top of ``driver.solve``.  This bench

* times warm ``driver.solve`` vs warm ``Session.solve`` on the same
  instance and asserts the session adds < 5% wall overhead;
* times ``MDP.from_functions`` materialization of a million-state MDP
  (vectorized callables -> device ELL blocks), the construction mode that
  never builds a host-global tensor.

Run directly:  PYTHONPATH=src:. python -m benchmarks.bench_api
or via:        PYTHONPATH=src:. python -m benchmarks.run --only api
"""

from __future__ import annotations

import time

import numpy as np

from repro.api import MDP, Session
from repro.core import IPIOptions, generators
from repro.core.driver import solve as driver_solve

MAX_OVERHEAD = 0.05


def _paired(fn_a, fn_b, reps=60):
    """Interleaved timings with the call order alternated every rep (us).

    A back-to-back comparison of two ~25ms walls differs by several percent
    from CPU frequency drift and cache position alone; alternating the
    order inside each pair cancels the position bias, and the median of
    per-pair differences is robust to the drift."""
    fn_a(), fn_b()                # warm-up (compile + any placement)
    ta, tb = [], []
    for i in range(reps):
        first, second = (fn_a, fn_b) if i % 2 == 0 else (fn_b, fn_a)
        t0 = time.perf_counter()
        first()
        t1 = time.perf_counter()
        second()
        t2 = time.perf_counter()
        da, db = (t1 - t0, t2 - t1) if i % 2 == 0 else (t2 - t1, t1 - t0)
        ta.append(da)
        tb.append(db)
    diff = float(np.median(np.subtract(tb, ta)))
    return float(np.median(ta)) * 1e6, float(np.median(ta)) * 1e6 \
        + diff * 1e6


def run(rows: list) -> None:
    import jax
    jax.config.update("jax_enable_x64", True)   # PETSc-style f64 baseline
    # ---- warm dispatch overhead: Session.solve vs driver.solve ------------
    mdp = generators.garnet(n=2000, m=8, k=6, gamma=0.95, seed=0)
    ipi = IPIOptions(method="ipi_gmres", atol=1e-8, dtype="float64")
    session = Session({"-method": "ipi_gmres", "-atol": 1e-8,
                       "-dtype": "float64", "-layout": "single"})
    t_driver, t_session = _paired(lambda: driver_solve(mdp, ipi),
                                  lambda: session.solve(mdp))
    session.close()
    overhead = t_session / t_driver - 1.0
    assert overhead < MAX_OVERHEAD, \
        f"session warm-path overhead {overhead:.1%} >= {MAX_OVERHEAD:.0%}"
    rows.append(("api/solve_driver_warm", t_driver, "baseline"))
    rows.append(("api/solve_session_warm", t_session,
                 f"overhead={overhead:+.2%}<{MAX_OVERHEAD:.0%}"))
    print(f"  warm dispatch: driver {t_driver/1e3:.2f}ms, session "
          f"{t_session/1e3:.2f}ms (overhead {overhead:+.2%})")

    # ---- from_functions million-state construction -------------------------
    n = 1_000_000

    def transitions(rs, a):
        left = np.clip(rs - 1, 0, n - 1)
        right = np.clip(rs + 1, 0, n - 1)
        fwd, bwd = (left, right) if a == 0 else (right, left)
        return (np.stack([fwd, bwd], -1),
                np.broadcast_to(np.array([0.7, 0.3]), (len(rs), 2)))

    def cost(rs, a):
        return np.where(rs == 0, 0.0, 1.0)

    t0 = time.perf_counter()
    m = MDP.from_functions(transitions, cost, n, 2, nnz=2, gamma=0.999,
                           vectorized=True)
    core = m.build()
    core.val.block_until_ready()
    t_build = (time.perf_counter() - t0) * 1e6
    states_per_s = n / (t_build / 1e6)
    rows.append(("api/from_functions_1m_states", t_build,
                 f"{states_per_s/1e6:.2f}M states/s"))
    print(f"  from_functions: {n:,} states x 2 actions materialized in "
          f"{t_build/1e6:.2f}s ({states_per_s/1e6:.2f}M states/s)")
    # one cheap residual eval proves the tables are usable as-built
    r = driver_solve(core, IPIOptions(method="vi", atol=1e30, max_outer=1))
    assert np.isfinite(r.residual)


if __name__ == "__main__":
    rows: list = []
    run(rows)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
