"""API-layer cost: session dispatch overhead + from_functions construction.

The session layer (ISSUE 3) must be *free* on the hot path: once a solve
shape is warm, ``Session.solve`` adds only options resolution, placement
lookup and stats bookkeeping on top of ``driver.solve``.  This bench

* times warm ``driver.solve`` vs warm ``Session.solve`` on the same
  instance and asserts the session adds < 5% wall overhead;
* times ``MDP.from_functions`` materialization of a million-state MDP
  through BOTH pipelines — the numpy host-callback path and the
  device-side generator pipeline (jit'd row constructors, ISSUE 4) — and
  asserts the device pipeline is >= 10x the host baseline once its block
  program is compiled (the construction-rate claim; the cold row reports
  trace+compile+run);
* times a 10M-state device-only construction (a scale the host callback
  path is too slow to be practical for, and whose single host-global
  tensor a real multi-host deployment could not hold anywhere);
* solves a matrix-free from_functions MDP at 10x the state count of a
  materialized reference to the same convergence certificate, with a
  resident footprint below the smaller reference's table (ISSUE 9 —
  the state-ceiling claim).

Run directly:  PYTHONPATH=src:. python -m benchmarks.bench_api
or via:        PYTHONPATH=src:. python -m benchmarks.run --only api
"""

from __future__ import annotations

import time

import numpy as np

from repro.api import MDP, Session
from repro.core import IPIOptions, generators
from repro.core.driver import solve as driver_solve

MAX_OVERHEAD = 0.05
MIN_DEVICE_SPEEDUP = 10.0


def _chain_np(n):
    """Host-callback (numpy) chain constructors — the numpy mirror of
    :func:`repro.core.generators.chain_walk_functions` (same tables), so
    the host/device comparison differs only in pipeline."""
    def transitions(rs, a):
        left = np.clip(rs - 1, 0, n - 1)
        right = np.clip(rs + 1, 0, n - 1)
        fwd, bwd = (left, right) if a == 0 else (right, left)
        return (np.stack([fwd, bwd], -1),
                np.broadcast_to(np.array([0.7, 0.3]), (len(rs), 2)))

    def cost(rs, a):
        return np.where(rs == 0, 0.0, 1.0)

    return transitions, cost


def _chain_dev(n):
    """The canonical jit-able chain constructors (device pipeline)."""
    spec = generators.chain_walk_functions(n)
    return spec["P_fn"], spec["g_fn"]


def _time_build(mdp) -> float:
    t0 = time.perf_counter()
    core = mdp.build()
    core.val.block_until_ready()
    return time.perf_counter() - t0


def _paired(fn_a, fn_b, reps=60):
    """Interleaved timings with the call order alternated every rep (us).

    A back-to-back comparison of two ~25ms walls differs by several percent
    from CPU frequency drift and cache position alone; alternating the
    order inside each pair cancels the position bias, and the median of
    per-pair differences is robust to the drift."""
    fn_a(), fn_b()                # warm-up (compile + any placement)
    ta, tb = [], []
    for i in range(reps):
        first, second = (fn_a, fn_b) if i % 2 == 0 else (fn_b, fn_a)
        t0 = time.perf_counter()
        first()
        t1 = time.perf_counter()
        second()
        t2 = time.perf_counter()
        da, db = (t1 - t0, t2 - t1) if i % 2 == 0 else (t2 - t1, t1 - t0)
        ta.append(da)
        tb.append(db)
    diff = float(np.median(np.subtract(tb, ta)))
    return float(np.median(ta)) * 1e6, float(np.median(ta)) * 1e6 \
        + diff * 1e6


def run(rows: list) -> None:
    import jax
    jax.config.update("jax_enable_x64", True)   # PETSc-style f64 baseline
    # ---- warm dispatch overhead: Session.solve vs driver.solve ------------
    # The session path now routes every solve through the live method
    # registry and the compiled stop-criterion machinery (ISSUE 5); with
    # the monitor DISABLED this must still be within MAX_OVERHEAD of the
    # bare driver warm path — the "observability is free when off"
    # guardrail (paired, order-alternating timing as in PR 3).
    mdp = generators.garnet(n=2000, m=8, k=6, gamma=0.95, seed=0)
    ipi = IPIOptions(method="ipi_gmres", atol=1e-8, dtype="float64")
    session = Session({"-method": "ipi_gmres", "-atol": 1e-8,
                       "-dtype": "float64", "-layout": "single"})
    t_driver, t_session = _paired(lambda: driver_solve(mdp, ipi),
                                  lambda: session.solve(mdp))
    overhead = t_session / t_driver - 1.0
    assert overhead < MAX_OVERHEAD, \
        f"monitor-off session warm-path overhead {overhead:.1%} >= " \
        f"{MAX_OVERHEAD:.0%}"
    rows.append(("api/solve_driver_warm", t_driver, "baseline"))
    rows.append(("api/solve_session_warm", t_session,
                 f"monitor-off overhead={overhead:+.2%}<{MAX_OVERHEAD:.0%}"))
    print(f"  warm dispatch: driver {t_driver/1e3:.2f}ms, session "
          f"{t_session/1e3:.2f}ms (monitor-off overhead {overhead:+.2%})")

    # ---- monitor-enabled cost (informational row, not asserted) -----------
    sink = lambda rec: None
    t_off, t_mon = _paired(lambda: session.solve(mdp),
                           lambda: session.solve(mdp, monitor=sink))
    session.close()
    mon_over = t_mon / t_off - 1.0
    rows.append(("api/solve_session_monitor_on", t_mon,
                 f"streaming records costs {mon_over:+.2%} vs monitor-off"))
    print(f"  monitor on: {t_mon/1e3:.2f}ms ({mon_over:+.2%} vs off — "
          f"callback streaming, separate compiled program)")

    # ---- from_functions million-state construction: host vs device ---------
    n = 1_000_000
    P_np, g_np = _chain_np(n)
    m_host = MDP.from_functions(P_np, g_np, n, 2, nnz=2, gamma=0.999,
                                vectorized=True)
    assert m_host.materialization() == "host"   # numpy callables: host path
    t_host = _time_build(m_host)
    rows.append(("api/from_functions_1m_host", t_host * 1e6,
                 f"{n/t_host/1e6:.2f}M states/s (numpy callbacks)"))
    print(f"  from_functions host: {n:,} states x 2 actions in "
          f"{t_host:.2f}s ({n/t_host/1e6:.2f}M states/s)")

    P_dev, g_dev = _chain_dev(n)
    m_dev = MDP.from_functions(P_dev, g_dev, n, 2, nnz=2, gamma=0.999,
                               vectorized=True)
    assert m_dev.materialization() == "device"  # jnp callables: auto-detect
    t_cold = _time_build(m_dev)                 # trace + compile + run
    t_warm = min(
        _time_build(_evicted(m_dev)) for _ in range(7))
    speedup = t_host / t_warm
    rows.append(("api/from_functions_1m_device_cold", t_cold * 1e6,
                 f"{n/t_cold/1e6:.2f}M states/s incl. compile"))
    rows.append(("api/from_functions_1m_device", t_warm * 1e6,
                 f"{n/t_warm/1e6:.2f}M states/s = {speedup:.1f}x host"))
    print(f"  from_functions device: cold {t_cold:.2f}s, warm "
          f"{t_warm*1e3:.0f}ms ({n/t_warm/1e6:.1f}M states/s, "
          f"{speedup:.1f}x host)")
    assert speedup >= MIN_DEVICE_SPEEDUP, \
        f"device pipeline {speedup:.1f}x < {MIN_DEVICE_SPEEDUP:.0f}x host"
    # bit-for-bit parity between the pipelines, and the tables are usable
    host_core = m_host.build()
    dev_core = m_dev.build()
    for f in ("idx", "val", "cost"):
        assert np.array_equal(np.asarray(getattr(dev_core, f)),
                              np.asarray(getattr(host_core, f))), f
    r = driver_solve(dev_core,
                     IPIOptions(method="vi", atol=1e30, max_outer=1))
    assert np.isfinite(r.residual)

    # ---- 10M states: device pipeline only ----------------------------------
    n10 = 10_000_000
    P10, g10 = _chain_dev(n10)
    m10 = MDP.from_functions(P10, g10, n10, 2, nnz=2, gamma=0.999,
                             vectorized=True)
    t10 = _time_build(m10)
    rows.append(("api/from_functions_10m_device", t10 * 1e6,
                 f"{n10/t10/1e6:.2f}M states/s incl. compile"))
    print(f"  from_functions device 10M: {t10:.2f}s "
          f"({n10/t10/1e6:.1f}M states/s incl. compile)")

    # ---- matrix-free solving: the state ceiling (ISSUE 9) ------------------
    # Materialized, the per-state cost is the ELL table — n*m*(8*nnz+4)
    # bytes — while the matrix-free operator stores one int8 tag plus the
    # VI iterate (17 B/state, constructors re-traced every backup).  The
    # claim: a from_functions MDP at >= 10x the materialized reference's
    # state count solves to the SAME certificate (converged under identical
    # stopping options) while its resident footprint stays BELOW the
    # smaller materialized table's.
    from repro.kernels import matrix_free as _mf

    n_ref, mult = 20_000, 10
    fam = dict(m=8, k=8, gamma=0.8, seed=0)        # 544 B/state materialized
    vi = IPIOptions(method="vi", atol=1e-6, max_outer=5000)

    core_ref = MDP.from_generator("garnet", deferred=True, n=n_ref,
                                  **fam).build()
    t0 = time.perf_counter()
    r_ref = driver_solve(core_ref, vi)
    t_ref = time.perf_counter() - t0
    tab_ref = _mf.table_bytes(n_ref, 8, 8)
    assert r_ref.converged, r_ref.summary()
    rows.append((f"api/matrix_free_ref_materialized_{n_ref}", t_ref * 1e6,
                 f"vi converged res={r_ref.residual:.1e} "
                 f"table={tab_ref/2**20:.0f}MiB "
                 f"{n_ref*r_ref.outer_iterations/t_ref/1e6:.1f}M states/s"))

    n_mf = mult * n_ref
    core_mf = MDP.from_generator("garnet", deferred=True, n=n_mf,
                                 **fam).build("matrix_free")
    t0 = time.perf_counter()
    r_mf = driver_solve(core_mf, vi)
    t_mf = time.perf_counter() - t0
    op_mf = _mf.operator_bytes(n_mf, 8, krylov=False)
    tab_mf = _mf.table_bytes(n_mf, 8, 8)
    # the ceiling-lift certificate: 10x the states, same convergence
    # verdict under the same options, resident bytes under the SMALLER
    # materialized table (i.e. >10x effective memory headroom)
    assert r_mf.converged, r_mf.summary()
    assert op_mf < tab_ref, (op_mf, tab_ref)
    rows.append((f"api/matrix_free_vi_{n_mf}", t_mf * 1e6,
                 f"{mult}x states of materialized ref, vi converged "
                 f"res={r_mf.residual:.1e} operator={op_mf/2**20:.0f}MiB "
                 f"vs table {tab_mf/2**20:.0f}MiB "
                 f"({tab_mf/op_mf:.0f}x less memory) "
                 f"{n_mf*r_mf.outer_iterations/t_mf/1e6:.1f}M states/s"))
    print(f"  matrix-free: {n_mf:,} states ({mult}x ref) converged in "
          f"{t_mf:.1f}s with {op_mf/2**20:.0f}MiB resident "
          f"(materialized table would be {tab_mf/2**20:.0f}MiB; "
          f"ref table {tab_ref/2**20:.0f}MiB)")


def _evicted(mdp):
    """Drop the cached container so build() re-materializes (the compiled
    block builder stays warm — that is the steady-state construction
    rate)."""
    mdp.evict()
    return mdp


if __name__ == "__main__":
    rows: list = []
    run(rows)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
