"""Benchmark 5 — LM substrate sanity: reduced-config train-step wall time
per architecture (smoke-scale; full-scale numbers are roofline projections
in results/roofline.json)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke_config, get_train_config
from repro.data.pipeline import SyntheticSource
from repro.models import build_model
from repro.train.optimizer import init_opt_state
from repro.train.steps import make_train_step


def run(csv_rows: list):
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        tcfg = get_train_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        src = SyntheticSource(
            cfg.vocab_size, 64, 8, n_patches=cfg.n_patches,
            d_model=cfg.d_model,
            encoder_len=cfg.encoder_len if cfg.family == "encdec" else 0)
        batch = src.next_batch(0)
        step = jax.jit(make_train_step(model, tcfg, n_microbatches=2))
        opt = init_opt_state(params, tcfg)
        p, o, m = step(params, opt, jnp.int32(0), batch)
        jax.block_until_ready(m)
        t0 = time.time()
        for i in range(3):
            p, o, m = step(p, o, jnp.int32(i + 1), batch)
        jax.block_until_ready(m)
        us = (time.time() - t0) / 3 * 1e6
        csv_rows.append((f"lm_substrate/{arch}/train_step_smoke", us,
                         f"loss={float(m['loss']):.3f}"))
        print(f"  {arch:16s} {us:9.0f}us/step loss={float(m['loss']):.3f}",
              flush=True)
