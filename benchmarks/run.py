"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows (and progress to stderr-ish
stdout).  Full suite:

    PYTHONPATH=src:. python -m benchmarks.run [--only solvers,kernels,...]

Tables:
  solvers       — method comparison across instance families (core claim)
  conditioning  — gamma -> 1 sweep (Krylov-iPI vs VI iteration growth)
  kernels       — fused Bellman backup vs unfused reference
  scaling       — 1 vs 8 device distributed solve
  lm_substrate  — per-arch smoke train-step timing
(roofline terms live in benchmarks/roofline.py -> results/roofline.json)
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: solvers,conditioning,kernels,scaling,"
                         "lm_substrate")
    args = ap.parse_args()

    from benchmarks import (bench_conditioning, bench_kernels,
                            bench_lm_substrate, bench_scaling, bench_solvers)
    suites = {
        "solvers": bench_solvers.run,
        "conditioning": bench_conditioning.run,
        "kernels": bench_kernels.run,
        "scaling": bench_scaling.run,
        "lm_substrate": bench_lm_substrate.run,
    }
    pick = args.only.split(",") if args.only else list(suites)
    rows = []
    for name in pick:
        print(f"== bench:{name} ==", flush=True)
        try:
            suites[name](rows)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"  [FAIL] {name}: {type(e).__name__}: {e}", flush=True)
            rows.append((f"{name}/SUITE_FAILED", -1, str(e)[:80]))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
