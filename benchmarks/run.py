"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows (and progress to stderr-ish
stdout), and persists the same rows machine-readably to
``benchmarks/results/BENCH_batch.json`` so the perf trajectory accumulates
across PRs.  Full suite:

    PYTHONPATH=src:. python -m benchmarks.run [--only solvers,kernels,...]

Tables:
  solvers       — method comparison across instance families (core claim)
  conditioning  — gamma -> 1 sweep (Krylov-iPI vs VI iteration growth)
  kernels       — fused Bellman backup vs unfused reference
  scaling       — 1 vs 8 device distributed solve
  batch         — fleet solve_many vs sequential loop (>= 3x claim)
  fleet         — fleet-sharded layout: per-device memory ~B/fleet_size of
                  the replicated layout + weak scaling (needs multi-device,
                  e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8)
  api           — session-layer dispatch overhead (<5% warm) +
                  from_functions million-state construction
  serve         — batched serving vs sequential solves (>= 2x claim) +
                  Poisson-arrival latency quantiles
  adaptive      — -method auto vs fixed methods (within 1.3x of best) +
                  preconditioned-vs-plain GMRES on the outliers
  lm_substrate  — per-arch smoke train-step timing
(roofline terms live in benchmarks/roofline.py -> results/roofline.json)
"""

import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: solvers,conditioning,kernels,scaling,"
                         "batch,fleet,api,serve,adaptive,lm_substrate")
    ap.add_argument("--json-out", default=None,
                    help="path for the machine-readable results "
                         "(default: benchmarks/results/BENCH_batch.json)")
    args = ap.parse_args()

    from benchmarks import (bench_adaptive, bench_api, bench_batch,
                            bench_conditioning, bench_fleet, bench_kernels,
                            bench_lm_substrate, bench_scaling, bench_serve,
                            bench_solvers)
    suites = {
        "solvers": bench_solvers.run,
        "conditioning": bench_conditioning.run,
        "kernels": bench_kernels.run,
        "scaling": bench_scaling.run,
        "batch": bench_batch.run,
        "fleet": bench_fleet.run,
        "api": bench_api.run,
        "serve": bench_serve.run,
        "adaptive": bench_adaptive.run,
        "lm_substrate": bench_lm_substrate.run,
    }
    pick = args.only.split(",") if args.only else list(suites)
    rows = []
    for name in pick:
        print(f"== bench:{name} ==", flush=True)
        try:
            suites[name](rows)
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"  [FAIL] {name}: {type(e).__name__}: {e}", flush=True)
            rows.append((f"{name}/SUITE_FAILED", -1, str(e)[:80]))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    out = os.path.abspath(args.json_out or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "results",
        "BENCH_batch.json"))
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # merge by row name: a partial (--only ...) run refreshes its own rows
    # without clobbering the others, so the file accumulates the trajectory
    merged = {}
    if os.path.exists(out):
        try:
            with open(out) as f:
                merged = {r["name"]: r for r in json.load(f)}
        except (json.JSONDecodeError, KeyError, TypeError):
            merged = {}
    for name, us, derived in rows:
        merged[name] = {"name": name, "us_per_call": us, "derived": derived}
    # a suite that ran clean this time retires its stale failure marker
    failed = {name for name, _, _ in rows if name.endswith("/SUITE_FAILED")}
    for suite in pick:
        marker = f"{suite}/SUITE_FAILED"
        if marker not in failed:
            merged.pop(marker, None)
    with open(out, "w") as f:
        json.dump(list(merged.values()), f, indent=2)
    print(f"\n[run] wrote {len(rows)} rows ({len(merged)} total) -> {out}")


if __name__ == "__main__":
    main()
