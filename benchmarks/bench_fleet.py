"""Fleet-sharded layout (ISSUE 2 tentpole claim): distribute the instance
dim of a ``solve_many`` fleet over the mesh's leading ``fleet`` axis.

Two measurements, matching the two halves of the claim:

* **memory** — per-device *fleet memory*: the bytes that grow with B on
  every device under the replicated layouts.  Two components:

  - the replicated per-instance solver bookkeeping (``res`` / ``k`` /
    ``inner_total`` / both trace arrays: ``B x (max_outer + 1)`` floats on
    EVERY device under ``layout="1d"``), measured from the actual
    ``addressable_shards`` of a live solve state;
  - the gathered value window the Bellman backup materializes per device —
    ``B_local x n_global`` (the all-gather runs per lane, so the replicated
    layout materializes the FULL ``B x n_global`` value matrix on every
    device; this is the term that caps B at single-device memory).

  Both shrink by ``B / fleet_size`` under ``layout="fleet"`` — the
  acceptance ratio reported in the ``derived`` column.  (The state/action
  tables are invariant: they are already sharded over all devices either
  way.)

* **weak scaling** — grow the fleet with the fleet axis (B = 2 x F for
  F = 1, 2, 4, 8) at fixed per-slice work and record wall-clock: under
  fleet sharding each slice solves its own 2 instances independently (zero
  cross-slice collectives in the body), so time should stay ~flat while B
  grows 8x.  The replicated layout at the largest B is timed alongside as
  the baseline it beats.

Parity is asserted on every timed configuration (``agree=`` in the derived
column): values bit-for-bit vs the replicated path for the elementwise
method family, exact policies / iteration paths for Krylov.

Run with a fake multi-device CPU mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src:. python -m benchmarks.run --only fleet
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import IPIOptions, generators, partition
from repro.core.driver import solve_many
from repro.core import driver as _driver
from repro.launch.mesh import make_fleet_mesh, make_host_mesh

B = 16
N = 512


def _fleet(b, n=N, gamma=0.95):
    return [generators.garnet(n=n, m=6, k=4, gamma=gamma, seed=s)
            for s in range(b)]


def _device0_bytes(tree) -> int:
    d0 = jax.devices()[0]
    return sum(sh.data.nbytes for leaf in jax.tree_util.tree_leaves(tree)
               for sh in getattr(leaf, "addressable_shards", [])
               if sh.device == d0)


def _fleet_state_bytes(mdps, opts, mesh, layout) -> tuple[int, int]:
    """(bookkeeping bytes on device 0, gather-window bytes per device) for
    a live solve state under ``layout``."""
    from repro.core.mdp import stack_mdps
    st = stack_mdps(mdps)
    dev_mdp, axes, _ = partition.shard_mdp(st, mesh, layout)
    _, init = _driver._make_runners(dev_mdp, opts, mesh, axes, dev_mdp.batch)
    state = init(None)
    book = _device0_bytes((state.res, state.k, state.inner_total,
                           state.trace_res, state.trace_inner))
    fleet_shards = partition._axis_size(mesh, axes.fleet)
    b_local = dev_mdp.batch // fleet_shards
    gather = b_local * dev_mdp.n_global * np.dtype(opts.dtype).itemsize
    return book, gather


def _agree(rs, base, *, exact: bool) -> bool:
    # exact: bit-for-bit (elementwise method family); otherwise policies /
    # iteration paths exact with ulp-level f32 value differences (batched
    # Krylov dots associate differently per device-local lane count)
    dv = max(float(np.abs(a.v - b.v).max()) for a, b in zip(rs, base))
    ok = all(r.converged for r in rs) and \
        all((a.policy == b.policy).all() for a, b in zip(rs, base)) and \
        all(a.outer_iterations == b.outer_iterations
            for a, b in zip(rs, base))
    return ok and (dv == 0.0 if exact else dv < 1e-4)


def _time(fn, reps=3) -> float:
    fn()                                  # compile / warm-up
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6                  # us


def run(rows) -> None:
    n_dev = len(jax.devices())
    fleet_max = 1
    while fleet_max * 2 <= n_dev:
        fleet_max *= 2
    if fleet_max < 2:
        print("  [skip] fleet bench needs >1 device; set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8", flush=True)
        rows.append(("fleet/SKIPPED_single_device", -1, f"n_dev={n_dev}"))
        return

    opts = IPIOptions(method="ipi_gmres", atol=1e-5, dtype="float32",
                      max_outer=500)

    # -- per-device fleet memory: replicated vs fleet-sharded ---------------- #
    mdps = _fleet(B)
    book_r, gath_r = _fleet_state_bytes(
        mdps, opts, make_host_mesh((n_dev, 1)), "1d")
    book_f, gath_f = _fleet_state_bytes(
        mdps, opts, make_fleet_mesh(fleet_max), "fleet")
    ratio = (book_r + gath_r) / (book_f + gath_f)
    rows.append((f"fleet/mem_per_device_replicated_B{B}",
                 0.0, f"bytes={book_r + gath_r}"))
    rows.append((f"fleet/mem_per_device_fleet{fleet_max}_B{B}",
                 0.0, f"bytes={book_f + gath_f} ratio={ratio:.2f}x"))
    print(f"  per-device fleet memory B={B}: replicated "
          f"{(book_r + gath_r)/1e3:.1f} kB (book {book_r/1e3:.1f} + gather "
          f"{gath_r/1e3:.1f}) vs fleet-{fleet_max} "
          f"{(book_f + gath_f)/1e3:.1f} kB -> {ratio:.2f}x "
          f"(ideal {fleet_max}x)", flush=True)

    # -- parity: fleet-sharded == replicated --------------------------------- #
    base = solve_many(mdps, opts)
    vi = IPIOptions(method="vi", atol=1e-4, dtype="float32",
                    max_outer=20000)
    base_vi = solve_many(mdps, vi)
    mesh = make_fleet_mesh(fleet_max)
    ok_vi = _agree(solve_many(mdps, vi, mesh=mesh, layout="fleet"),
                   base_vi, exact=True)
    ok_kry = _agree(solve_many(mdps, opts, mesh=mesh, layout="fleet"),
                    base, exact=False)
    rows.append((f"fleet/parity_B{B}_fleet{fleet_max}", 0.0,
                 f"vi_bit_for_bit={ok_vi} krylov={ok_kry}"))
    print(f"  parity vs replicated: vi bit-for-bit={ok_vi} "
          f"ipi_gmres (exact path, ulp values)={ok_kry}", flush=True)

    # -- weak scaling: B grows with the fleet axis --------------------------- #
    f, b_per = 1, 2
    while f <= fleet_max:
        b = b_per * f
        sub = _fleet(b)
        mesh_f = make_fleet_mesh(f)
        us = _time(lambda: solve_many(sub, opts, mesh=mesh_f,
                                      layout="fleet"))
        rows.append((f"fleet/weak_scaling_F{f}_B{b}", us,
                     f"per_instance_us={us / b:.0f}"))
        print(f"  weak scaling F={f} B={b}: {us/1e3:.0f} ms "
              f"({us/b/1e3:.1f} ms/instance)", flush=True)
        f *= 2
    b = b_per * fleet_max
    sub = _fleet(b)
    mesh_r = make_host_mesh((n_dev, 1))
    us_rep = _time(lambda: solve_many(sub, opts, mesh=mesh_r, layout="1d"))
    rows.append((f"fleet/weak_scaling_replicated_B{b}", us_rep,
                 "baseline (fleet dim replicated)"))
    print(f"  replicated layout at B={b}: {us_rep/1e3:.0f} ms", flush=True)


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(r)
