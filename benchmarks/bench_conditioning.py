"""Benchmark 2 — conditioning sweep: iterations to tolerance as gamma -> 1
(the figure-style claim motivating Krylov iPI: VI cost grows ~1/(1-gamma),
iGMRES-PI stays flat)."""

from __future__ import annotations

import time

import jax

from repro.core import IPIOptions, generators
from repro.core.driver import solve

GAMMAS = [0.9, 0.99, 0.999, 0.9999]


def run(csv_rows: list):
    jax.config.update("jax_enable_x64", True)
    for gamma in GAMMAS:
        mdp = generators.chain_walk(2_000, gamma=gamma)
        for method in ("vi", "ipi_gmres"):
            opts = IPIOptions(method=method, atol=1e-8, dtype="float64",
                              max_outer=2_000_000 if method == "vi" else 500,
                              max_inner=2000)
            t0 = time.time()
            r = solve(mdp, opts, chunk=4096)
            wall = time.time() - t0
            total = r.outer_iterations + r.inner_iterations
            csv_rows.append((
                f"conditioning/gamma={gamma}/{method}", wall * 1e6,
                f"total_iters={total};converged={r.converged}"))
            print(f"  gamma={gamma:7} {method:10s} total_iters={total:8d} "
                  f"wall={wall:6.2f}s", flush=True)
