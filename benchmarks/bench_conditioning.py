"""Benchmark 2 — conditioning sweep: iterations to tolerance as gamma -> 1
(the figure-style claim motivating Krylov iPI: VI cost grows ~1/(1-gamma),
iGMRES-PI stays flat), plus the preconditioned leg (``-pc_type jacobi``)
showing the Jacobi-scaled Krylov inner solves hold up in the hardest
regime.

``MADUPITE_BENCH_SCALE`` (default 1.0) scales the chain length so CI can
run a quick leg (e.g. ``MADUPITE_BENCH_SCALE=0.02``)."""

from __future__ import annotations

import os
import time

import jax

from repro.core import IPIOptions, generators
from repro.core.driver import solve

SCALE = float(os.environ.get("MADUPITE_BENCH_SCALE", "1.0"))

GAMMAS = [0.9, 0.99, 0.999, 0.9999]

# (tag, method, pc_type)
LEGS = [("vi", "vi", "none"),
        ("ipi_gmres", "ipi_gmres", "none"),
        ("ipi_gmres+jacobi", "ipi_gmres", "jacobi")]


def run(csv_rows: list):
    jax.config.update("jax_enable_x64", True)
    n = max(int(2_000 * SCALE), 64)
    scale_tag = "" if SCALE == 1.0 else f";scale={SCALE}"
    for gamma in GAMMAS:
        mdp = generators.chain_walk(n, gamma=gamma)
        for tag, method, pc in LEGS:
            opts = IPIOptions(method=method, atol=1e-8, dtype="float64",
                              max_outer=2_000_000 if method == "vi" else 500,
                              max_inner=2000, pc_type=pc)
            t0 = time.time()
            r = solve(mdp, opts, chunk=4096)
            wall = time.time() - t0
            total = r.outer_iterations + r.inner_iterations
            csv_rows.append((
                f"conditioning/gamma={gamma}/{tag}", wall * 1e6,
                f"total_iters={total};converged={r.converged}{scale_tag}"))
            print(f"  gamma={gamma:7} {tag:18s} total_iters={total:8d} "
                  f"wall={wall:6.2f}s", flush=True)
