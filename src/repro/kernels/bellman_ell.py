"""Pallas TPU kernel: fused ELL Bellman backup.

The solver's hot spot (one per outer iteration, and the entire inner loop of
VI).  Fuses gather -> weighted-sum -> +cost -> min/argmin over actions so the
(n, m) Q-table never round-trips to HBM — on the XLA path the Q-table is a
materialized intermediate, which at n=10^7, m=256 is a 10 GB HBM write+read
per backup.  TPU adaptation of madupite's CSR row kernels (see DESIGN.md A1):

  * the value vector ``v`` is staged *whole* into VMEM (BlockSpec with a
    constant index map) — after the state-axis all-gather it is the only
    operand reused across every row of the block, so one HBM->VMEM copy
    serves ``TILE_N * m * K`` gathers.  VMEM budget: n_cols * 4 bytes
    (<= ~3M states per shard; the ops.py wrapper falls back to XLA above).
  * idx/val/cost stream through VMEM in ``(TILE_N, m, K)`` tiles.
  * the gather is a VPU dynamic-gather over VMEM (``jnp.take``), which Mosaic
    vectorizes; there is no MXU work in the sparse path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 256


def _backup_kernel(idx_ref, val_ref, cost_ref, v_ref, out_v_ref, out_pi_ref,
                   *, gamma: float):
    v = v_ref[...]
    idx = idx_ref[...]
    val = val_ref[...]
    dt = jnp.result_type(jnp.float32, val.dtype, v.dtype)
    tn, m, k = idx.shape
    gathered = jnp.take(v, idx.reshape(tn, m * k), axis=0).reshape(tn, m, k)
    pv = jnp.sum(val.astype(dt) * gathered.astype(dt), axis=-1)
    q = cost_ref[...].astype(dt) + gamma * pv
    out_v_ref[...] = jnp.min(q, axis=-1)
    out_pi_ref[...] = jnp.argmin(q, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("gamma", "interpret", "tile_n"))
def ell_backup(idx, val, cost, gamma: float, v, *, interpret: bool = False,
               tile_n: int = DEFAULT_TILE_N):
    """Fused backup on an ELL block -> ``(min_a Q (n,), argmin_a Q (n,) i32)``."""
    n, m, k = idx.shape
    tile = min(tile_n, n)
    pad = (-n) % tile
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0), (0, 0)))
        val = jnp.pad(val, ((0, pad), (0, 0), (0, 0)))
        cost = jnp.pad(cost, ((0, pad), (0, 0)))
    n_pad = n + pad
    dt = jnp.result_type(jnp.float32, val.dtype, v.dtype)
    out_v, out_pi = pl.pallas_call(
        functools.partial(_backup_kernel, gamma=gamma),
        grid=(n_pad // tile,),
        in_specs=[
            pl.BlockSpec((tile, m, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, m, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
            pl.BlockSpec(v.shape, lambda i: (0,)),   # whole v resident in VMEM
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), dt),
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(idx, val, cost, v)
    return out_v[:n], out_pi[:n]


def ell_qvalues(idx, val, cost, gamma: float, v, *, interpret: bool = False,
                tile_n: int = DEFAULT_TILE_N):
    """Q-table variant (kept for parity with ref; the fused form is preferred)."""
    from repro.kernels import spmv_ell
    n, m, k = idx.shape
    pv = spmv_ell.ell_matvec(idx.reshape(n * m, k), val.reshape(n * m, k), v,
                             interpret=interpret, tile_n=tile_n)
    return cost.astype(pv.dtype) + gamma * pv.reshape(n, m)
