"""Pallas TPU kernel: tiled streaming fused ELL Bellman backup.

The solver's hot spot (one per outer iteration, and the entire inner loop of
VI).  Fuses gather -> weighted-sum -> +cost -> min/argmin over actions so the
(n, m) Q-table never round-trips to HBM — on the XLA path the Q-table is a
materialized intermediate, which at n=10^7, m=256 is a 10 GB HBM write+read
per backup.  TPU adaptation of madupite's CSR row kernels (see DESIGN.md A1).

Unlike the first-generation kernel (whole value vector resident in VMEM,
one grid dimension over row tiles), this version runs a 2-D grid

    grid = (row tiles, action tiles * value-window tiles)

and streams *both* the table and the value vector:

  * idx/val/cost arrive in ``(TILE_N, TILE_M, K)`` / ``(TILE_N, TILE_M)``
    blocks — one action tile at a time, so wide-action MDPs no longer pull
    ``m`` whole action columns per row tile.
  * ``v`` arrives in ``(TILE_V,)`` windows.  Each window contributes the
    entries of the gathered dot whose column ids fall inside the window; a
    VMEM scratch block holds the per-(row, action, k) partials, so the final
    K-sum reduces in exactly ref.py's order (bit-identical accumulation).
    VMEM budget is now O(TILE_V + TILE_N * TILE_M * K) instead of O(n_cols).
  * running (min, argmin) scratch carries the best action across action
    tiles with a strict ``<`` — first minimum wins, preserving the exact
    smallest-index tie-break across tile boundaries.

The second grid dimension is the flattened (action tile, value window) pair
with the value window fastest, so each action tile's partial-dot scratch is
completed (all value windows) before the running min consumes it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref

DEFAULT_TILE_N = 256
DEFAULT_TILE_M = 16
DEFAULT_TILE_V = 128 * 1024


def _backup_kernel(gamma_ref, idx_ref, val_ref, cost_ref, v_ref,
                   out_v_ref, out_pi_ref,
                   part_ref, best_ref, arg_ref,
                   *, a_tiles: int, v_tiles: int, tile_m: int, tile_v: int):
    c = pl.program_id(1)
    a = c // v_tiles           # action tile
    j = c % v_tiles            # value window
    idx = idx_ref[...]
    val = val_ref[...]
    tn, tm, k = idx.shape
    dt = part_ref.dtype

    @pl.when(j == 0)
    def _init_partials():
        part_ref[...] = jnp.zeros_like(part_ref)

    # Accumulate this value window's share of the gathered dot.  Every
    # (row, action, k) slot is owned by exactly one window (the one holding
    # its column id), so `where` never double-counts and the K-sum below
    # reduces in ref.py's exact order.
    lo = j * tile_v
    local = idx - lo
    in_window = (local >= 0) & (local < tile_v)
    vblk = v_ref[...]
    safe = jnp.clip(local, 0, tile_v - 1)
    gathered = jnp.take(vblk, safe.reshape(tn, tm * k), axis=0).reshape(
        tn, tm, k)
    contrib = val.astype(dt) * gathered.astype(dt)
    part_ref[...] = jnp.where(in_window, contrib, part_ref[...])

    @pl.when(j == v_tiles - 1)
    def _reduce_actions():
        gamma = gamma_ref[0, 0]
        pv = jnp.sum(part_ref[...], axis=-1)
        # pin_rounding matches ref.ell_qvalues' pinned double rounding of
        # cost + gamma*pv (see ref.py); plain jnp ops, so it lowers on every
        # Pallas backend.
        q = cost_ref[...].astype(dt) + ref.pin_rounding(gamma * pv)
        tile_best = jnp.min(q, axis=-1)
        tile_arg = jnp.argmin(q, axis=-1).astype(jnp.int32) + a * tile_m

        @pl.when(a == 0)
        def _():
            best_ref[...] = tile_best
            arg_ref[...] = tile_arg

        @pl.when(a > 0)
        def _():
            hit = tile_best < best_ref[...]
            best_ref[...] = jnp.where(hit, tile_best, best_ref[...])
            arg_ref[...] = jnp.where(hit, tile_arg, arg_ref[...])

        @pl.when(a == a_tiles - 1)
        def _():
            out_v_ref[...] = best_ref[...]
            out_pi_ref[...] = arg_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("interpret", "tile_n", "tile_m", "tile_v"))
def ell_backup(idx, val, cost, gamma, v, *, interpret: bool = False,
               tile_n: int = DEFAULT_TILE_N, tile_m: int = DEFAULT_TILE_M,
               tile_v: int = DEFAULT_TILE_V):
    """Fused backup on an ELL block -> ``(min_a Q (n,), argmin_a Q (n,) i32)``."""
    n, m, k = idx.shape
    n_cols = v.shape[0]
    tn = min(tile_n, n)
    tm = min(tile_m, m)
    tv = min(tile_v, n_cols)
    dt = jnp.result_type(jnp.float32, val.dtype, v.dtype)

    pad_n = (-n) % tn
    pad_m = (-m) % tm
    pad_v = (-n_cols) % tv
    if pad_n or pad_m:
        idx = jnp.pad(idx, ((0, pad_n), (0, pad_m), (0, 0)))
        val = jnp.pad(val, ((0, pad_n), (0, pad_m), (0, 0)))
        # Padded action columns get +inf cost so they can never win the min;
        # padded rows are sliced off below.
        cost = jnp.pad(cost, ((0, pad_n), (0, pad_m)),
                       constant_values=jnp.inf)
    if pad_v:
        v = jnp.pad(v, (0, pad_v))
    n_pad, m_pad, v_pad = n + pad_n, m + pad_m, n_cols + pad_v

    a_tiles = m_pad // tm
    v_tiles = v_pad // tv
    gamma_arr = jnp.full((1, 1), gamma, dt)
    out_v, out_pi = pl.pallas_call(
        functools.partial(_backup_kernel, a_tiles=a_tiles, v_tiles=v_tiles,
                          tile_m=tm, tile_v=tv),
        grid=(n_pad // tn, a_tiles * v_tiles),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, c: (0, 0)),
            pl.BlockSpec((tn, tm, k),
                         lambda i, c, vt=v_tiles: (i, c // vt, 0)),
            pl.BlockSpec((tn, tm, k),
                         lambda i, c, vt=v_tiles: (i, c // vt, 0)),
            pl.BlockSpec((tn, tm), lambda i, c, vt=v_tiles: (i, c // vt)),
            pl.BlockSpec((tv,), lambda i, c, vt=v_tiles: (c % vt,)),
        ],
        out_specs=[
            pl.BlockSpec((tn,), lambda i, c: (i,)),
            pl.BlockSpec((tn,), lambda i, c: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), dt),
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tn, tm, k), dt),
            pltpu.VMEM((tn,), dt),
            pltpu.VMEM((tn,), jnp.int32),
        ],
        interpret=interpret,
    )(gamma_arr, idx, val, cost, v)
    return out_v[:n], out_pi[:n]


def ell_qvalues(idx, val, cost, gamma, v, *, interpret: bool = False,
                tile_n: int = DEFAULT_TILE_N, tile_v: int = DEFAULT_TILE_V):
    """Q-table variant (kept for parity with ref; the fused form is preferred)."""
    from repro.kernels import spmv_ell
    n, m, k = idx.shape
    pv = spmv_ell.ell_matvec(idx.reshape(n * m, k), val.reshape(n * m, k), v,
                             interpret=interpret, tile_n=tile_n,
                             tile_v=tile_v)
    return cost.astype(pv.dtype) + gamma * pv.reshape(n, m)
