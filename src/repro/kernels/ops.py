"""Jit'd dispatch layer over the kernels.

Implementations:
  * ``"xla"``              — pure-jnp reference (ref.py); default on CPU.
  * ``"pallas"``           — Pallas TPU kernels (compiled; TPU target).
  * ``"pallas_interpret"`` — Pallas kernels run through the interpreter
                             (CPU-correctness validation; used by tests).

The distributed solver calls these entry points; switching ``impl`` swaps the
compute engine without touching solver logic.
"""

from __future__ import annotations

import functools

import jax

from . import ref

_DEFAULT_IMPL = "xla"
_VALID = ("xla", "pallas", "pallas_interpret")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in _VALID, impl
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _resolve(impl: str | None) -> str:
    impl = impl or _DEFAULT_IMPL
    assert impl in _VALID, impl
    return impl


@functools.partial(jax.jit, static_argnames=("gamma", "impl"))
def ell_backup(idx, val, cost, gamma: float, v, *, impl: str | None = None):
    """Fused Bellman backup on an ELL block -> (v_new (n,), argmin (n,) int32)."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.ell_backup(idx, val, cost, gamma, v)
    from . import bellman_ell
    return bellman_ell.ell_backup(idx, val, cost, gamma, v,
                                  interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("gamma", "impl"))
def ell_qvalues(idx, val, cost, gamma: float, v, *, impl: str | None = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.ell_qvalues(idx, val, cost, gamma, v)
    from . import bellman_ell
    return bellman_ell.ell_qvalues(idx, val, cost, gamma, v,
                                   interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("impl",))
def ell_matvec(idx, val, x, *, impl: str | None = None):
    """Policy-restricted SpMV y = P_pi @ x on (n, K) ELL rows."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.ell_matvec(idx, val, x)
    from . import spmv_ell
    return spmv_ell.ell_matvec(idx, val, x,
                               interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("gamma", "impl"))
def dense_backup(p, cost, gamma: float, v, *, impl: str | None = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.dense_backup(p, cost, gamma, v)
    from . import dense_backup as dense_backup_kernel
    return dense_backup_kernel.dense_backup(p, cost, gamma, v,
                                            interpret=(impl == "pallas_interpret"))
