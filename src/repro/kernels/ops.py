"""Jit'd dispatch layer over the kernels.

Implementations:
  * ``"auto"``             — pick per backend: ``pallas`` on TPU, ``blocked``
                             elsewhere; tile sizes come from the autotuner
                             (:mod:`repro.kernels.tuning`).  The default.
  * ``"xla"``              — pure-jnp reference (ref.py), one fused chain.
  * ``"blocked"``          — cache-blocked XLA: row-chunked scan whose chunk
                             working set stays in cache (ref.py blocked
                             variants; bit-identical to ``xla``).
  * ``"pallas"``           — Pallas TPU kernels (compiled; TPU target).
  * ``"pallas_interpret"`` — Pallas kernels run through the interpreter
                             (CPU-correctness validation; used by tests).

The distributed solver calls these entry points; switching ``impl`` swaps the
compute engine without touching solver logic.  Tile sizes (the scan chunk of
``blocked``, the Pallas grid tiles) are Python ints resolved at trace time:
explicit keyword > autotuner cache > default.

Batched fleets: every entry point also accepts a leading batch dim ``B`` on
its table arguments (``val``/``cost``/``p`` rank +1; ``idx`` batched or
shared across instances; ``v``/``x`` batched ``(B, n)`` or shared ``(n,)``)
and vmaps the per-instance kernel — so the same Pallas/XLA kernels serve
multi-instance solves without a batched reimplementation.  A size-1 batch
dim — the common device-local shape under the fleet-sharded layouts, where
each fleet shard owns ``B / fleet_size`` instances — is squeezed and run
through the unbatched kernel directly instead of a 1-lane vmap.  The
autotuner sees the device-local (post-squeeze / per-lane) shape, so fleet
layouts resolve the same tiles as a single-instance solve of the same size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref, tuning

_DEFAULT_IMPL = "auto"
_VALID = ("auto", "xla", "blocked", "pallas", "pallas_interpret")

# Scan-chunk candidates for the blocked implementation (rows per chunk).
BLOCK_ROWS_CANDIDATES = (31_250, 62_500, 125_000, 250_000, 500_000)

# Cap on synthetic tuning data (elements), so tuning a huge solve does not
# allocate a huge benchmark table; block_rows choices transfer downward.
_MAX_BENCH_ELEMS = 1 << 26


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in _VALID, impl
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _resolve(impl: str | None) -> str:
    impl = impl or _DEFAULT_IMPL
    assert impl in _VALID, impl
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "blocked"
    return impl


def _ax(arr, batched_ndim: int):
    """vmap in_axis for an optionally-batched operand."""
    return 0 if arr.ndim == batched_ndim else None


def _sq(arr, batched_ndim: int):
    """Squeeze a (size-1) leading batch dim off an optionally-batched
    operand — the B_local == 1 fast path of the fleet-sharded layouts."""
    return arr[0] if arr.ndim == batched_ndim else arr


# ---------------------------------------------------------------------------
# Trace-time tile resolution
# ---------------------------------------------------------------------------


def _backend() -> str:
    return jax.default_backend()


def _bench_shape(n: int, m: int, k: int) -> int:
    """Benchmark row count: the real n, capped so synthetic data stays small."""
    per_row = max(1, m * k)
    return max(1, min(n, _MAX_BENCH_ELEMS // per_row))


def _block_rows_default(n: int) -> int:
    return min(ref.DEFAULT_BLOCK_ROWS, max(1, n))


def _tuned_block_rows(kernel: str, n: int, m: int, k: int, n_cols: int,
                      dtype, bench_builder) -> int:
    """Resolve the blocked-impl scan chunk: autotuner cache, else timed
    search over BLOCK_ROWS_CANDIDATES, else the default."""
    n_bench = _bench_shape(n, m, k)
    cands = sorted({c for c in BLOCK_ROWS_CANDIDATES if c <= n_bench}
                   | {_block_rows_default(n_bench)})
    bench = None
    if tuning.enabled() and n * m * k >= tuning.MIN_TUNE_ELEMS:
        bench = bench_builder(n_bench, m, k, min(n_cols, n_bench), dtype)
    choice = tuning.tune(kernel, _backend(), n, m, k, np.dtype(dtype).name,
                         cands, _block_rows_default(n), bench)
    return int(min(choice, n)) if n else 1


def _make_backup_bench(n, m, k, n_cols, dtype):
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, n_cols, (n, m, k)).astype(np.int32))
    val = jnp.asarray(rng.random((n, m, k)).astype(dtype))
    cost = jnp.asarray(rng.random((n, m)).astype(dtype))
    v = jnp.asarray(rng.random(n_cols).astype(dtype))

    def bench(block_rows):
        fn = jax.jit(functools.partial(ref.ell_backup_blocked,
                                       block_rows=int(block_rows)))
        return tuning.measure(lambda: fn(idx, val, cost, 0.99, v))

    return bench


def _make_matvec_bench(n, k, _unused_m, n_cols, dtype):
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, n_cols, (n, k)).astype(np.int32))
    val = jnp.asarray(rng.random((n, k)).astype(dtype))
    x = jnp.asarray(rng.random(n_cols).astype(dtype))

    def bench(block_rows):
        fn = jax.jit(functools.partial(ref.ell_matvec_blocked,
                                       block_rows=int(block_rows)))
        return tuning.measure(lambda: fn(idx, val, x))

    return bench


def backup_block_rows(n: int, m: int, k: int, n_cols: int, dtype) -> int:
    """Trace-time scan-chunk choice for the blocked fused backup."""
    return _tuned_block_rows("ell_backup_blocked", n, m, k, n_cols, dtype,
                             _make_backup_bench)


def matvec_block_rows(n: int, k: int, n_cols: int, dtype) -> int:
    """Trace-time scan-chunk choice for the blocked policy SpMV."""
    return _tuned_block_rows(
        "ell_matvec_blocked", n, 1, k, n_cols, dtype,
        lambda nb, _m, kb, nc, dt: _make_matvec_bench(nb, kb, _m, nc, dt))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def _ell_backup(idx, val, cost, gamma, v, impl, block_rows):
    if impl == "xla":
        return ref.ell_backup(idx, val, cost, gamma, v)
    if impl == "blocked":
        n, m, k = idx.shape
        bn = block_rows or backup_block_rows(n, m, k, v.shape[0], val.dtype)
        return ref.ell_backup_blocked(idx, val, cost, gamma, v,
                                      block_rows=bn)
    from . import bellman_ell
    return bellman_ell.ell_backup(idx, val, cost, gamma, v,
                                  interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("impl", "block_rows"))
def ell_backup(idx, val, cost, gamma, v, *, impl: str | None = None,
               block_rows: int | None = None):
    """Fused Bellman backup on an ELL block -> (v_new (n,), argmin (n,) int32)."""
    impl = _resolve(impl)
    if val.ndim == 4:
        if val.shape[0] == 1:
            tv, am = _ell_backup(_sq(idx, 4), val[0], cost[0], gamma,
                                 _sq(v, 2), impl, block_rows)
            return tv[None], am[None]
        fn = lambda i, vl, c, vv: _ell_backup(i, vl, c, gamma, vv, impl,
                                              block_rows)
        return jax.vmap(fn, in_axes=(_ax(idx, 4), 0, 0, _ax(v, 2)))(
            idx, val, cost, v)
    return _ell_backup(idx, val, cost, gamma, v, impl, block_rows)


def ell_backup_chunk(idx, val, cost, gamma, v, *, impl: str | None = None):
    """Un-jitted fused backup on ONE row chunk — the matrix-free tile body.

    The matrix-free operator rebuilds row tiles inside an already-traced
    scan, so this entry point skips the jit wrapper and the chunk-level
    re-blocking of :func:`ell_backup` (the caller owns the tiling) while
    dispatching to the same per-implementation math:

    * ``"xla"``     — ``ref.ell_backup`` (jnp.min/argmin chain);
    * ``"blocked"`` — the exact per-chunk body of ``ref.ell_backup_blocked``
      (``rowmin_argmin`` over ``ell_qvalues`` — bit-identical to ``"xla"``);
    * ``"pallas"``/``"pallas_interpret"`` — the Pallas kernel on the chunk.

    Bit-identical to running the materialized kernel over the same rows:
    the math is row-independent, so any chunking yields the same bits.
    """
    impl = _resolve(impl)
    if impl == "xla":
        return ref.ell_backup(idx, val, cost, gamma, v)
    if impl == "blocked":
        return ref.rowmin_argmin(ref.ell_qvalues(idx, val, cost, gamma, v))
    from . import bellman_ell
    return bellman_ell.ell_backup(idx, val, cost, gamma, v,
                                  interpret=(impl == "pallas_interpret"))


def _ell_qvalues(idx, val, cost, gamma, v, impl, block_rows):
    if impl == "xla":
        return ref.ell_qvalues(idx, val, cost, gamma, v)
    if impl == "blocked":
        n, m, k = idx.shape
        bn = block_rows or backup_block_rows(n, m, k, v.shape[0], val.dtype)
        return ref.ell_qvalues_blocked(idx, val, cost, gamma, v,
                                       block_rows=bn)
    from . import bellman_ell
    return bellman_ell.ell_qvalues(idx, val, cost, gamma, v,
                                   interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("impl", "block_rows"))
def ell_qvalues(idx, val, cost, gamma, v, *, impl: str | None = None,
                block_rows: int | None = None):
    impl = _resolve(impl)
    if val.ndim == 4:
        if val.shape[0] == 1:
            return _ell_qvalues(_sq(idx, 4), val[0], cost[0], gamma,
                                _sq(v, 2), impl, block_rows)[None]
        fn = lambda i, vl, c, vv: _ell_qvalues(i, vl, c, gamma, vv, impl,
                                               block_rows)
        return jax.vmap(fn, in_axes=(_ax(idx, 4), 0, 0, _ax(v, 2)))(
            idx, val, cost, v)
    return _ell_qvalues(idx, val, cost, gamma, v, impl, block_rows)


def _ell_matvec(idx, val, x, impl, block_rows):
    if impl == "xla":
        return ref.ell_matvec(idx, val, x)
    if impl == "blocked":
        n, k = idx.shape
        bn = block_rows or matvec_block_rows(n, k, x.shape[0], val.dtype)
        return ref.ell_matvec_blocked(idx, val, x, block_rows=bn)
    from . import spmv_ell
    return spmv_ell.ell_matvec(idx, val, x,
                               interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("impl", "block_rows"))
def ell_matvec(idx, val, x, *, impl: str | None = None,
               block_rows: int | None = None):
    """Policy-restricted SpMV y = P_pi @ x on (n, K) ELL rows."""
    impl = _resolve(impl)
    if val.ndim == 3:
        if val.shape[0] == 1:
            return _ell_matvec(_sq(idx, 3), val[0], _sq(x, 2), impl,
                               block_rows)[None]
        fn = lambda i, vl, xx: _ell_matvec(i, vl, xx, impl, block_rows)
        return jax.vmap(fn, in_axes=(_ax(idx, 3), 0, _ax(x, 2)))(idx, val, x)
    return _ell_matvec(idx, val, x, impl, block_rows)


def _dense_backup(p, cost, gamma, v, impl):
    # The dense path has no blocked variant; cache-blocking a dense matmul is
    # XLA's own job, so "blocked" falls back to the reference chain.
    if impl in ("xla", "blocked"):
        return ref.dense_backup(p, cost, gamma, v)
    from . import dense_backup as dense_backup_kernel
    return dense_backup_kernel.dense_backup(p, cost, gamma, v,
                                            interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("impl",))
def dense_backup(p, cost, gamma, v, *, impl: str | None = None):
    impl = _resolve(impl)
    if p.ndim == 4:
        if p.shape[0] == 1:
            tv, am = _dense_backup(p[0], cost[0], gamma, _sq(v, 2), impl)
            return tv[None], am[None]
        fn = lambda pp, c, vv: _dense_backup(pp, c, gamma, vv, impl)
        return jax.vmap(fn, in_axes=(0, 0, _ax(v, 2)))(p, cost, v)
    return _dense_backup(p, cost, gamma, v, impl)
