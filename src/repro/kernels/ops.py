"""Jit'd dispatch layer over the kernels.

Implementations:
  * ``"xla"``              — pure-jnp reference (ref.py); default on CPU.
  * ``"pallas"``           — Pallas TPU kernels (compiled; TPU target).
  * ``"pallas_interpret"`` — Pallas kernels run through the interpreter
                             (CPU-correctness validation; used by tests).

The distributed solver calls these entry points; switching ``impl`` swaps the
compute engine without touching solver logic.

Batched fleets: every entry point also accepts a leading batch dim ``B`` on
its table arguments (``val``/``cost``/``p`` rank +1; ``idx`` batched or
shared across instances; ``v``/``x`` batched ``(B, n)`` or shared ``(n,)``)
and vmaps the per-instance kernel — so the same Pallas/XLA kernels serve
multi-instance solves without a batched reimplementation.  A size-1 batch
dim — the common device-local shape under the fleet-sharded layouts, where
each fleet shard owns ``B / fleet_size`` instances — is squeezed and run
through the unbatched kernel directly instead of a 1-lane vmap.
"""

from __future__ import annotations

import functools

import jax

from . import ref

_DEFAULT_IMPL = "xla"
_VALID = ("xla", "pallas", "pallas_interpret")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in _VALID, impl
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _resolve(impl: str | None) -> str:
    impl = impl or _DEFAULT_IMPL
    assert impl in _VALID, impl
    return impl


def _ax(arr, batched_ndim: int):
    """vmap in_axis for an optionally-batched operand."""
    return 0 if arr.ndim == batched_ndim else None


def _sq(arr, batched_ndim: int):
    """Squeeze a (size-1) leading batch dim off an optionally-batched
    operand — the B_local == 1 fast path of the fleet-sharded layouts."""
    return arr[0] if arr.ndim == batched_ndim else arr


def _ell_backup(idx, val, cost, gamma, v, impl):
    if impl == "xla":
        return ref.ell_backup(idx, val, cost, gamma, v)
    from . import bellman_ell
    return bellman_ell.ell_backup(idx, val, cost, gamma, v,
                                  interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("gamma", "impl"))
def ell_backup(idx, val, cost, gamma: float, v, *, impl: str | None = None):
    """Fused Bellman backup on an ELL block -> (v_new (n,), argmin (n,) int32)."""
    impl = _resolve(impl)
    if val.ndim == 4:
        if val.shape[0] == 1:
            tv, am = _ell_backup(_sq(idx, 4), val[0], cost[0], gamma,
                                 _sq(v, 2), impl)
            return tv[None], am[None]
        fn = lambda i, vl, c, vv: _ell_backup(i, vl, c, gamma, vv, impl)
        return jax.vmap(fn, in_axes=(_ax(idx, 4), 0, 0, _ax(v, 2)))(
            idx, val, cost, v)
    return _ell_backup(idx, val, cost, gamma, v, impl)


def _ell_qvalues(idx, val, cost, gamma, v, impl):
    if impl == "xla":
        return ref.ell_qvalues(idx, val, cost, gamma, v)
    from . import bellman_ell
    return bellman_ell.ell_qvalues(idx, val, cost, gamma, v,
                                   interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("gamma", "impl"))
def ell_qvalues(idx, val, cost, gamma: float, v, *, impl: str | None = None):
    impl = _resolve(impl)
    if val.ndim == 4:
        if val.shape[0] == 1:
            return _ell_qvalues(_sq(idx, 4), val[0], cost[0], gamma,
                                _sq(v, 2), impl)[None]
        fn = lambda i, vl, c, vv: _ell_qvalues(i, vl, c, gamma, vv, impl)
        return jax.vmap(fn, in_axes=(_ax(idx, 4), 0, 0, _ax(v, 2)))(
            idx, val, cost, v)
    return _ell_qvalues(idx, val, cost, gamma, v, impl)


def _ell_matvec(idx, val, x, impl):
    if impl == "xla":
        return ref.ell_matvec(idx, val, x)
    from . import spmv_ell
    return spmv_ell.ell_matvec(idx, val, x,
                               interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("impl",))
def ell_matvec(idx, val, x, *, impl: str | None = None):
    """Policy-restricted SpMV y = P_pi @ x on (n, K) ELL rows."""
    impl = _resolve(impl)
    if val.ndim == 3:
        if val.shape[0] == 1:
            return _ell_matvec(_sq(idx, 3), val[0], _sq(x, 2), impl)[None]
        fn = lambda i, vl, xx: _ell_matvec(i, vl, xx, impl)
        return jax.vmap(fn, in_axes=(_ax(idx, 3), 0, _ax(x, 2)))(idx, val, x)
    return _ell_matvec(idx, val, x, impl)


def _dense_backup(p, cost, gamma, v, impl):
    if impl == "xla":
        return ref.dense_backup(p, cost, gamma, v)
    from . import dense_backup as dense_backup_kernel
    return dense_backup_kernel.dense_backup(p, cost, gamma, v,
                                            interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("gamma", "impl"))
def dense_backup(p, cost, gamma: float, v, *, impl: str | None = None):
    impl = _resolve(impl)
    if p.ndim == 4:
        if p.shape[0] == 1:
            tv, am = _dense_backup(p[0], cost[0], gamma, _sq(v, 2), impl)
            return tv[None], am[None]
        fn = lambda pp, c, vv: _dense_backup(pp, c, gamma, vv, impl)
        return jax.vmap(fn, in_axes=(0, 0, _ax(v, 2)))(p, cost, v)
    return _dense_backup(p, cost, gamma, v, impl)
