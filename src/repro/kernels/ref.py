"""Pure-jnp oracles for every kernel in this package.

These are the semantic ground truth: the Pallas kernels must match them
bit-for-bit up to float tolerance (tests/test_kernels.py sweeps shapes and
dtypes against these).  They are also the XLA fallback implementation used on
non-TPU backends.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _acc_dtype(*arrays):
    """Accumulation dtype: at least f32, f64 if any operand is f64 (the
    PETSc-faithful double-precision path)."""
    return jnp.result_type(jnp.float32, *(a.dtype for a in arrays))


def pin_rounding(x: jax.Array) -> jax.Array:
    """Identity that the compiler cannot see through.

    XLA:CPU may contract a multiply into a following add (FMA) in some
    fusions but not others, so eager / jit / scan-blocked / Pallas-interpret
    renderings of the same math can disagree at the last ulp.  Routing the
    product through a runtime-dependent select pins every implementation to
    the same double rounding.  (``optimization_barrier`` does not block the
    contraction, it happens during LLVM lowering inside a fusion.)
    """
    return jnp.where(x == x, x, 0.0 * x)


def ell_gather_dot(idx: jax.Array, val: jax.Array, v: jax.Array) -> jax.Array:
    """sum_k val[..., k] * v[idx[..., k]]  — the ELL row-gather dot.

    idx: (..., K) int32 global column ids; val: (..., K); v: (n_cols,).
    Returns (...,) accumulated in >= f32 (f64 when v is f64).
    """
    dt = _acc_dtype(val, v)
    gathered = jnp.take(v, idx, axis=0)
    prod = pin_rounding(val.astype(dt) * gathered.astype(dt))
    return jnp.sum(prod, axis=-1)


def ell_qvalues(idx: jax.Array, val: jax.Array, cost: jax.Array, gamma: float,
                v: jax.Array) -> jax.Array:
    """Q(s, a) = g(s, a) + gamma * sum_{s'} P(s, a, s') v(s')  on an ELL block."""
    pv = ell_gather_dot(idx, val, v)
    return cost.astype(pv.dtype) + pin_rounding(gamma * pv)


def ell_backup(idx: jax.Array, val: jax.Array, cost: jax.Array, gamma: float,
               v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused Bellman backup: (min_a Q, argmin_a Q) with smallest-index tie-break."""
    q = ell_qvalues(idx, val, cost, gamma, v)
    return jnp.min(q, axis=-1), jnp.argmin(q, axis=-1).astype(jnp.int32)


def ell_matvec(idx: jax.Array, val: jax.Array, x: jax.Array) -> jax.Array:
    """y(s) = sum_{s'} P_pi(s, s') x(s') on policy-restricted ELL rows (n, K)."""
    return ell_gather_dot(idx, val, x)


# ---------------------------------------------------------------------------
# Cache-blocked variants.
#
# Same math as the oracles above, restructured so XLA emits a row-chunked loop
# whose per-chunk working set (idx/val/cost chunk + the gathered q block) fits
# in cache instead of streaming the whole (n, m, K) table through one fused
# expression.  Bit-identical to the plain oracles: each chunk runs the exact
# per-row computation of `ell_qvalues`, and the column-wise running min below
# reduces in the same order as `jnp.min`/`jnp.argmin` (strict `<` keeps the
# first minimum, i.e. the smallest action index).
# ---------------------------------------------------------------------------

# Rows per chunk.  At the paper's typical widths (m*K between 16 and 128
# entries/row) this keeps a chunk's table slice plus its q block well inside
# the last-level cache on common parts.
DEFAULT_BLOCK_ROWS = 125_000

# Above this action count the unrolled running min stops paying for its trace
# size; fall back to the reduction ops (same result, see module tests).
_COLMIN_UNROLL_LIMIT = 64


def rowmin_argmin(q: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(min, argmin) over the trailing axis via a column-wise running min.

    Unrolled vertical selects vectorise better than the horizontal reduce on
    CPU and are bit-identical to jnp.min/jnp.argmin with first-min
    (smallest-index) tie-breaking.
    """
    m = q.shape[-1]
    if m > _COLMIN_UNROLL_LIMIT:
        return jnp.min(q, axis=-1), jnp.argmin(q, axis=-1).astype(jnp.int32)
    best = q[..., 0]
    arg = jnp.zeros(q.shape[:-1], jnp.int32)
    for a in range(1, m):
        qa = q[..., a]
        hit = qa < best
        best = jnp.where(hit, qa, best)
        arg = jnp.where(hit, jnp.int32(a), arg)
    return best, arg


def _blocked_rows(fn, chunked_args, tail_args, n, block_rows):
    """Run `fn(*chunk)` over row chunks of size block_rows with a tail chunk.

    chunked_args are split along axis 0; tail_args are closed over whole
    (e.g. the value vector v).  Results are concatenated along axis 0.
    """
    bn = max(1, min(int(block_rows), n))
    nb = n // bn
    head = nb * bn
    if nb <= 1 and head == n:
        return fn(*chunked_args, *tail_args)

    def chunk(carry, args):
        return carry, fn(*args, *tail_args)

    split = tuple(a[:head].reshape((nb, bn) + a.shape[1:]) for a in chunked_args)
    _, out = jax.lax.scan(chunk, 0, split)
    out = jax.tree_util.tree_map(
        lambda x: x.reshape((head,) + x.shape[2:]), out)
    if head < n:
        rem = fn(*(a[head:] for a in chunked_args), *tail_args)
        out = jax.tree_util.tree_map(
            lambda x, r: jnp.concatenate([x, r], axis=0), out, rem)
    return out


def ell_backup_blocked(idx: jax.Array, val: jax.Array, cost: jax.Array,
                       gamma: float, v: jax.Array,
                       block_rows: int = DEFAULT_BLOCK_ROWS,
                       ) -> tuple[jax.Array, jax.Array]:
    """Cache-blocked fused Bellman backup; bit-identical to `ell_backup`."""
    n = idx.shape[0]

    def body(ib, wb, cb):
        return rowmin_argmin(ell_qvalues(ib, wb, cb, gamma, v))

    return _blocked_rows(body, (idx, val, cost), (), n, block_rows)


def ell_qvalues_blocked(idx: jax.Array, val: jax.Array, cost: jax.Array,
                        gamma: float, v: jax.Array,
                        block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
    """Cache-blocked Q table; bit-identical to `ell_qvalues`."""
    n = idx.shape[0]

    def body(ib, wb, cb):
        return ell_qvalues(ib, wb, cb, gamma, v)

    return _blocked_rows(body, (idx, val, cost), (), n, block_rows)


def ell_matvec_blocked(idx: jax.Array, val: jax.Array, x: jax.Array,
                       block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
    """Cache-blocked policy-restricted SpMV; bit-identical to `ell_matvec`."""
    n = idx.shape[0]
    return _blocked_rows(ell_gather_dot, (idx, val), (x,), n, block_rows)


def dense_qvalues(p: jax.Array, cost: jax.Array, gamma: float,
                  v: jax.Array) -> jax.Array:
    """Dense-P Q table: cost + gamma * P @ v, >= f32 accumulation (MXU path)."""
    dt = _acc_dtype(p, v)
    pv = jax.lax.dot_general(
        p.astype(dt), v.astype(dt),
        dimension_numbers=(((2,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST)
    return cost.astype(dt) + gamma * pv


def dense_backup(p: jax.Array, cost: jax.Array, gamma: float,
                 v: jax.Array) -> tuple[jax.Array, jax.Array]:
    q = dense_qvalues(p, cost, gamma, v)
    return jnp.min(q, axis=-1), jnp.argmin(q, axis=-1).astype(jnp.int32)
