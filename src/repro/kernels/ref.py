"""Pure-jnp oracles for every kernel in this package.

These are the semantic ground truth: the Pallas kernels must match them
bit-for-bit up to float tolerance (tests/test_kernels.py sweeps shapes and
dtypes against these).  They are also the XLA fallback implementation used on
non-TPU backends.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _acc_dtype(*arrays):
    """Accumulation dtype: at least f32, f64 if any operand is f64 (the
    PETSc-faithful double-precision path)."""
    return jnp.result_type(jnp.float32, *(a.dtype for a in arrays))


def ell_gather_dot(idx: jax.Array, val: jax.Array, v: jax.Array) -> jax.Array:
    """sum_k val[..., k] * v[idx[..., k]]  — the ELL row-gather dot.

    idx: (..., K) int32 global column ids; val: (..., K); v: (n_cols,).
    Returns (...,) accumulated in >= f32 (f64 when v is f64).
    """
    dt = _acc_dtype(val, v)
    gathered = jnp.take(v, idx, axis=0)
    return jnp.sum(val.astype(dt) * gathered.astype(dt), axis=-1)


def ell_qvalues(idx: jax.Array, val: jax.Array, cost: jax.Array, gamma: float,
                v: jax.Array) -> jax.Array:
    """Q(s, a) = g(s, a) + gamma * sum_{s'} P(s, a, s') v(s')  on an ELL block."""
    pv = ell_gather_dot(idx, val, v)
    return cost.astype(pv.dtype) + gamma * pv


def ell_backup(idx: jax.Array, val: jax.Array, cost: jax.Array, gamma: float,
               v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused Bellman backup: (min_a Q, argmin_a Q) with smallest-index tie-break."""
    q = ell_qvalues(idx, val, cost, gamma, v)
    return jnp.min(q, axis=-1), jnp.argmin(q, axis=-1).astype(jnp.int32)


def ell_matvec(idx: jax.Array, val: jax.Array, x: jax.Array) -> jax.Array:
    """y(s) = sum_{s'} P_pi(s, s') x(s') on policy-restricted ELL rows (n, K)."""
    return ell_gather_dot(idx, val, x)


def dense_qvalues(p: jax.Array, cost: jax.Array, gamma: float,
                  v: jax.Array) -> jax.Array:
    """Dense-P Q table: cost + gamma * P @ v, >= f32 accumulation (MXU path)."""
    dt = _acc_dtype(p, v)
    pv = jax.lax.dot_general(
        p.astype(dt), v.astype(dt),
        dimension_numbers=(((2,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST)
    return cost.astype(dt) + gamma * pv


def dense_backup(p: jax.Array, cost: jax.Array, gamma: float,
                 v: jax.Array) -> tuple[jax.Array, jax.Array]:
    q = dense_qvalues(p, cost, gamma, v)
    return jnp.min(q, axis=-1), jnp.argmin(q, axis=-1).astype(jnp.int32)
