"""Pallas TPU kernel: ELL SpMV (policy-restricted transition matvec).

The inner-solver hot spot: every Richardson sweep / Krylov iteration applies
``A_pi x = x - gamma * P_pi x`` and ``P_pi x`` is this kernel.  Same VMEM
strategy as :mod:`repro.kernels.bellman_ell` — ``x`` staged whole into VMEM,
(row, K) tiles streamed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 512


def _spmv_kernel(idx_ref, val_ref, x_ref, out_ref):
    x = x_ref[...]
    idx = idx_ref[...]
    val = val_ref[...]
    dt = jnp.result_type(jnp.float32, val.dtype, x.dtype)
    tn, k = idx.shape
    gathered = jnp.take(x, idx.reshape(tn * k), axis=0).reshape(tn, k)
    out_ref[...] = jnp.sum(val.astype(dt) * gathered.astype(dt), axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_n"))
def ell_matvec(idx, val, x, *, interpret: bool = False,
               tile_n: int = DEFAULT_TILE_N):
    """``y[i] = sum_k val[i, k] * x[idx[i, k]]`` for (n, K) ELL rows."""
    n, k = idx.shape
    tile = min(tile_n, n)
    pad = (-n) % tile
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        val = jnp.pad(val, ((0, pad), (0, 0)))
    n_pad = n + pad
    dt = jnp.result_type(jnp.float32, val.dtype, x.dtype)
    out = pl.pallas_call(
        _spmv_kernel,
        grid=(n_pad // tile,),
        in_specs=[
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0,)),   # whole x resident in VMEM
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), dt),
        interpret=interpret,
    )(idx, val, x)
    return out[:n]
