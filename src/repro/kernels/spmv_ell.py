"""Pallas TPU kernel: tiled streaming ELL SpMV (policy-restricted matvec).

The inner-solver hot spot: every Richardson sweep / Krylov iteration applies
``A_pi x = x - gamma * P_pi x`` and ``P_pi x`` is this kernel.  Same tiling
strategy as :mod:`repro.kernels.bellman_ell` — a 2-D grid over (row tiles,
value windows) streams both the (n, K) table and ``x`` through VMEM instead
of staging ``x`` whole.  A VMEM scratch block holds per-(row, k) partials so
the final K-sum reduces in ref.py's exact order (bit-identical accumulation);
each (row, k) slot is owned by exactly one value window.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_N = 512
DEFAULT_TILE_V = 128 * 1024


def _spmv_kernel(idx_ref, val_ref, x_ref, out_ref, part_ref,
                 *, v_tiles: int, tile_v: int):
    j = pl.program_id(1)
    idx = idx_ref[...]
    val = val_ref[...]
    tn, k = idx.shape
    dt = part_ref.dtype

    @pl.when(j == 0)
    def _init_partials():
        part_ref[...] = jnp.zeros_like(part_ref)

    lo = j * tile_v
    local = idx - lo
    in_window = (local >= 0) & (local < tile_v)
    xblk = x_ref[...]
    safe = jnp.clip(local, 0, tile_v - 1)
    gathered = jnp.take(xblk, safe.reshape(tn * k), axis=0).reshape(tn, k)
    contrib = val.astype(dt) * gathered.astype(dt)
    part_ref[...] = jnp.where(in_window, contrib, part_ref[...])

    @pl.when(j == v_tiles - 1)
    def _reduce():
        out_ref[...] = jnp.sum(part_ref[...], axis=-1)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "tile_n", "tile_v"))
def ell_matvec(idx, val, x, *, interpret: bool = False,
               tile_n: int = DEFAULT_TILE_N, tile_v: int = DEFAULT_TILE_V):
    """``y[i] = sum_k val[i, k] * x[idx[i, k]]`` for (n, K) ELL rows."""
    n, k = idx.shape
    n_cols = x.shape[0]
    tn = min(tile_n, n)
    tv = min(tile_v, n_cols)
    pad_n = (-n) % tn
    pad_v = (-n_cols) % tv
    if pad_n:
        idx = jnp.pad(idx, ((0, pad_n), (0, 0)))
        val = jnp.pad(val, ((0, pad_n), (0, 0)))
    if pad_v:
        x = jnp.pad(x, (0, pad_v))
    n_pad, v_pad = n + pad_n, n_cols + pad_v
    v_tiles = v_pad // tv
    dt = jnp.result_type(jnp.float32, val.dtype, x.dtype)
    out = pl.pallas_call(
        functools.partial(_spmv_kernel, v_tiles=v_tiles, tile_v=tv),
        grid=(n_pad // tn, v_tiles),
        in_specs=[
            pl.BlockSpec((tn, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tv,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), dt),
        scratch_shapes=[pltpu.VMEM((tn, k), dt)],
        interpret=interpret,
    )(idx, val, x)
    return out[:n]
