"""Pallas TPU kernel: dense-P Bellman backup (MXU path).

For dense/benchmark MDPs the backup is ``Q = g + gamma * P @ v`` followed by a
min over actions — a (n*m, n_cols) matvec.  The kernel tiles the contraction
dimension so P streams HBM->VMEM exactly once per backup while the running
``(TILE_N, m)`` accumulator stays in a VMEM scratch buffer, and fuses the
cost-add + min/argmin into the final contraction step (the Q-table never
exists in HBM).  MXU alignment: pick TILE_C a multiple of 128; the
``(TILE_N * m, TILE_C) @ (TILE_C,)`` product maps onto the MXU as a skinny
matmul (memory-bound by design — see EXPERIMENTS.md roofline: arithmetic
intensity of a backup is ~0.25 flop/byte, so the win is bandwidth, i.e. the
single pass over P plus no Q-table traffic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_N = 128
DEFAULT_TILE_C = 512


def _dense_kernel(gamma_ref, p_ref, cost_ref, v_ref, out_v_ref, out_pi_ref,
                  acc_ref, *, c_steps: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tn, m, tc = p_ref.shape
    p2 = p_ref[...].reshape(tn * m, tc).astype(jnp.float32)
    x = v_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        p2, x, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(tn, m)

    @pl.when(c == c_steps - 1)
    def _finish():
        q = cost_ref[...].astype(jnp.float32) + gamma_ref[0, 0] * acc_ref[...]
        out_v_ref[...] = q.min(axis=-1)
        out_pi_ref[...] = jnp.argmin(q, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "tile_n", "tile_c"))
def dense_backup(p, cost, gamma, v, *, interpret: bool = False,
                 tile_n: int = DEFAULT_TILE_N, tile_c: int = DEFAULT_TILE_C):
    """Fused dense backup -> ``(min_a Q (n,), argmin_a Q (n,) i32)``."""
    n, m, n_cols = p.shape
    tn = min(tile_n, n)
    tc = min(tile_c, n_cols)
    pad_n = (-n) % tn
    pad_c = (-n_cols) % tc
    if pad_n or pad_c:
        p = jnp.pad(p, ((0, pad_n), (0, 0), (0, pad_c)))
        cost = jnp.pad(cost, ((0, pad_n), (0, 0)))
    if pad_c:
        v = jnp.pad(v, (0, pad_c))
    np_, ncp = n + pad_n, n_cols + pad_c
    c_steps = ncp // tc
    gamma_arr = jnp.full((1, 1), gamma, jnp.float32)
    out_v, out_pi = pl.pallas_call(
        functools.partial(_dense_kernel, c_steps=c_steps),
        grid=(np_ // tn, c_steps),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, c: (0, 0)),
            pl.BlockSpec((tn, m, tc), lambda i, c: (i, 0, c)),
            pl.BlockSpec((tn, m), lambda i, c: (i, 0)),
            pl.BlockSpec((tc,), lambda i, c: (c,)),
        ],
        out_specs=[
            pl.BlockSpec((tn,), lambda i, c: (i,)),
            pl.BlockSpec((tn,), lambda i, c: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((tn, m), jnp.float32)],
        interpret=interpret,
    )(gamma_arr, p, cost, v)
    return out_v[:n], out_pi[:n]
