"""Tile autotuner for the streaming kernels, with a persisted JSON cache.

The dispatch layer (:mod:`repro.kernels.ops`) resolves tile sizes at trace
time — tile sizes are Python ints baked into the jaxpr, so the lookup runs
as ordinary Python during tracing.  On a cache miss the tuner times each
candidate on synthetic data of the same shape/dtype (eager, outside the
trace being built) and persists the winner, keyed by

    (kernel, backend, n-bucket, m, K, dtype)

where the n-bucket is the next power of two — close shapes share an entry so
a solver sweeping problem sizes does not retune per size.  The JSON cache
lives at ``~/.cache/madupite/autotune.json`` by default; override with the
``-kernel_tune_cache`` option or :func:`configure`.  ``-kernel_tune off``
disables measurement (defaults are used and nothing is written).

A corrupt or unreadable cache file is treated as empty (warned once) and is
overwritten on the next successful tune.
"""

from __future__ import annotations

import json
import os
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

DEFAULT_CACHE_PATH = os.path.join(
    os.path.expanduser("~"), ".cache", "madupite", "autotune.json")

_CACHE_VERSION = 1

# Below this element count (n * m * K) tuning costs more than it can ever
# save; callers get the default candidate and nothing is cached.
MIN_TUNE_ELEMS = 1 << 21

_TIMING_REPS = 3


@dataclass
class _State:
    enabled: bool = True
    cache_path: str = DEFAULT_CACHE_PATH
    entries: dict[str, dict[str, Any]] = field(default_factory=dict)
    loaded_from: str | None = None
    warned_corrupt: bool = False


_state = _State()


def configure(*, enabled: bool | None = None,
              cache_path: str | None = None) -> None:
    """Set tuner behaviour (called by Session from the options DB)."""
    if enabled is not None:
        _state.enabled = bool(enabled)
    if cache_path is not None and cache_path != _state.cache_path:
        _state.cache_path = cache_path
        _state.entries = {}
        _state.loaded_from = None
        _state.warned_corrupt = False


def reset(*, cache_path: str | None = None) -> None:
    """Forget all in-memory state (tests)."""
    global _state
    _state = _State()
    if cache_path is not None:
        _state.cache_path = cache_path


def enabled() -> bool:
    return _state.enabled


def cache_path() -> str:
    return _state.cache_path


def n_bucket(n: int) -> int:
    """Next power of two >= n: close sizes share a tuning entry."""
    return 1 << max(0, int(n - 1).bit_length())


def cache_key(kernel: str, backend: str, n: int, m: int, k: int,
              dtype: Any) -> str:
    return f"{kernel}|{backend}|n{n_bucket(n)}|m{m}|k{k}|{dtype}"


def _load() -> None:
    if _state.loaded_from == _state.cache_path:
        return
    _state.loaded_from = _state.cache_path
    path = _state.cache_path
    if not os.path.exists(path):
        return
    try:
        with open(path) as f:
            blob = json.load(f)
        entries = blob["entries"]
        if not isinstance(entries, dict):
            raise ValueError("entries is not a dict")
    except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
        if not _state.warned_corrupt:
            warnings.warn(
                f"madupite autotune cache {path!r} is unreadable ({e}); "
                "starting from an empty cache", stacklevel=3)
            _state.warned_corrupt = True
        return
    # merge under whatever was recorded in-memory this process
    for key, entry in entries.items():
        _state.entries.setdefault(key, entry)


def _persist() -> None:
    path = _state.cache_path
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": _CACHE_VERSION, "entries": _state.entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError as e:
        warnings.warn(f"could not persist autotune cache to {path!r}: {e}",
                      stacklevel=3)


def lookup(key: str) -> dict[str, Any] | None:
    _load()
    return _state.entries.get(key)


def record(key: str, entry: dict[str, Any]) -> None:
    _load()
    _state.entries[key] = entry
    _persist()


def tune(kernel: str, backend: str, n: int, m: int, k: int, dtype: Any,
         candidates: Sequence[Any], default: Any,
         bench: Callable[[Any], float] | None,
         ) -> Any:
    """Resolve the tile choice for one kernel shape.

    Returns the cached winner if present; otherwise, when tuning is enabled,
    the shape is big enough and a ``bench`` callable is given, times each
    candidate (``bench(candidate) -> seconds``), records the winner and
    returns it.  In every other case returns ``default``.
    """
    key = cache_key(kernel, backend, n, m, k, dtype)
    hit = lookup(key)
    if hit is not None:
        return hit["choice"]
    if (not _state.enabled or bench is None
            or n * m * k < MIN_TUNE_ELEMS or len(candidates) <= 1):
        return default
    import jax

    if not jax.core.trace_state_clean():
        # The dispatch layer is being traced inside an enclosing jit:
        # running the candidates here would stage them into that trace
        # instead of timing them.  Fall back to the default and leave the
        # cache untouched, so a later eager call can still tune the shape.
        return default
    timings: dict[str, float] = {}
    best, best_t = default, float("inf")
    for cand in candidates:
        try:
            t = min(bench(cand) for _ in range(_TIMING_REPS))
        except Exception as e:  # noqa: BLE001 - a failing candidate is skipped
            warnings.warn(f"autotune candidate {cand!r} failed: {e}",
                          stacklevel=2)
            continue
        timings[str(cand)] = t
        if t < best_t:
            best, best_t = cand, t
    if timings:
        record(key, {"choice": best, "timings_s": timings})
    return best


def measure(fn: Callable[[], Any]) -> float:
    """One timed run of ``fn`` (seconds), blocking on all outputs."""
    import jax

    out = fn()
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    out = fn()
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return time.perf_counter() - t0
