"""Pallas TPU kernels for the solver's compute hot spots.

Layout per kernel: ``<name>.py`` (pl.pallas_call + BlockSpec tiling),
``ops.py`` (jit'd dispatch wrappers with XLA fallback), ``ref.py``
(pure-jnp oracles; the ground truth for tests/test_kernels.py).
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
