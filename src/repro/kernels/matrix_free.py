"""Matrix-free Bellman operator: recompute-over-store row evaluation.

The materialized path stores every MDP as an O(n*m*nnz) ELL table and
streams it through the fused backup kernels.  This module is the second
implementation of the same Bellman-operator contract: the jit-able
``from_functions`` row constructors (``P_fn(rows, a) -> (ids, probs)``,
``g_fn(rows, a) -> cost``) are **re-traced inside the backup and the
policy-row extraction**, tile by tile, so the only persistent per-shard
state is O(n) — the value/policy vectors plus a 1-byte placement tag.

Parity contract (the non-negotiable invariant)
----------------------------------------------
Every function here is bit-identical to the materialized path:

* :func:`build_rows_block` is the *same* traced builder the device
  materialization pipeline runs (``repro.api.mdp`` delegates here), so a
  rebuilt chunk equals the stored table's slice bit-for-bit;
* the per-chunk backup body runs the exact per-row math of the
  materialized kernels (``ops.ell_backup_chunk``), and that math is
  row-independent, so *any* row chunking produces identical bits —
  :func:`repro.kernels.ref._blocked_rows` chunking included;
* :func:`mf_policy_rows` replays :func:`repro.core.bellman.policy_rows`'s
  ``take_along_axis`` + ownership-mask arithmetic on rebuilt chunks, so
  the inner (Krylov) solvers consume bit-identical ``PolicyRows`` and need
  no changes at all.

Tiling mirrors ``ref.ell_backup_blocked``: a ``lax.scan`` over fixed row
chunks whose transient working set — the rebuilt ``(bn, m, nnz)`` block —
is bounded and cache-sized, which is also exactly the structure a Pallas
grid over row tiles wants (each scan body is one future grid step).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import ops, ref

__all__ = ["RowSpec", "build_rows_block", "mf_backup", "mf_policy_rows",
           "table_bytes", "operator_bytes"]

_BIG = 1e30


@dataclasses.dataclass(frozen=True)
class RowSpec:
    """Static description of a function-backed MDP's rows — the metadata a
    matrix-free container carries instead of arrays.

    Hashable (callables compare by identity), gamma-free on purpose: a
    gamma sweep over one constructor pair shares a single spec, hence a
    single compiled program (the generator registry memoizes its closure
    helpers so constructor identity is stable across calls).

    ``band`` is the declared matrix bandwidth — ``|successor - row| <=
    band`` for every nonzero-weight successor — or ``None`` when the rows
    reach globally.  The partition planner derives the frontier margins
    and the halo width from it, since there are no arrays to measure.
    """

    p_fn: Callable
    g_fn: Callable
    n: int
    m: int
    nnz: int
    vectorized: bool
    band: int | None = None


def build_rows_block(spec, rows, acts: tuple, mode: str):
    """One traced ELL block: ``rows`` (traced global ids) x ``acts``
    (static global action ids, padding included).

    ``spec`` is duck-typed (:class:`RowSpec` or the api layer's deferred
    ``_FunctionSpec``): it needs ``p_fn``/``g_fn``/``n``/``m``/``nnz``/
    ``vectorized``.

    Mirrors the host ``MDP._block`` semantics bit-for-bit: padded states
    (``rows >= n``) are zero-cost absorbing self-loops; padded action
    columns (``a >= m``) carry the never-greedy ``±BIG`` cost of the solve
    ``mode`` and point at state 0.  Constructors see the raw row ids —
    including shard-padding ids ``>= n``, whose outputs are masked — so
    they must tolerate any int32 input (clip/where, not assert).

    Returns ``(idx, val, cost, bad)`` where ``bad`` is a per-row ``(R, 2)``
    count of validation failures over the *real* entries — successor ids
    outside ``[0, n)`` and probability rows not summing to ~1 — folded into
    the same compiled program so the host raise costs one scalar readback.
    (Matrix-free consumers drop ``bad``; dead-code elimination removes it.)
    """
    big = _BIG if mode == "mincost" else -_BIG
    K, R = spec.nnz, rows.shape[0]
    pad_row = rows >= spec.n
    bad_ids = jnp.zeros((R,), jnp.int32)
    bad_sum = jnp.zeros((R,), jnp.int32)
    self_idx = jnp.zeros((R, K), jnp.int32).at[:, 0].set(
        rows.astype(jnp.int32))
    self_val = jnp.zeros((R, K), jnp.float32).at[:, 0].set(1.0)

    def conform(what, a, arr, shape, dtype):
        arr = jnp.asarray(arr)
        if arr.shape != shape:
            raise ValueError(
                f"device {what}(rows, a={a}) must return shape {shape} "
                f"(nnz={K} slots per row — zero-pad unused slots), got "
                f"{arr.shape}")
        return arr.astype(dtype)

    cols_i, cols_v, cols_c = [], [], []
    for a in acts:
        if a >= spec.m:
            # never-greedy padded action: cost ±BIG, self-transition to 0
            cols_i.append(jnp.zeros((R, K), jnp.int32))
            cols_v.append(self_val)
            cols_c.append(jnp.full((R,), big, jnp.float32))
            continue
        if spec.vectorized:
            ids, probs = spec.p_fn(rows, int(a))
            ids = conform("P_fn", a, ids, (R, K), jnp.int32)
            probs = conform("P_fn", a, probs, (R, K), jnp.float32)
            g = jnp.broadcast_to(
                jnp.asarray(spec.g_fn(rows, int(a)), jnp.float32), (R,))
        else:
            def one(r, a=a):
                i, p = spec.p_fn(r, int(a))
                return (conform("P_fn", a, i, (K,), jnp.int32),
                        conform("P_fn", a, p, (K,), jnp.float32),
                        jnp.asarray(spec.g_fn(r, int(a)),
                                    jnp.float32).reshape(()))
            ids, probs, g = jax.vmap(one)(rows)
        real = ~pad_row
        bad_ids = bad_ids + jnp.where(
            real, ((ids < 0) | (ids >= spec.n)).sum(-1, dtype=jnp.int32), 0)
        bad_sum = bad_sum + jnp.where(
            real & (jnp.abs(probs.astype(jnp.float32).sum(-1) - 1.0) > 1e-4),
            1, 0)
        cols_i.append(jnp.where(pad_row[:, None], self_idx, ids))
        cols_v.append(jnp.where(pad_row[:, None], self_val, probs))
        cols_c.append(jnp.where(pad_row, jnp.float32(0.0), g))
    return (jnp.stack(cols_i, axis=1), jnp.stack(cols_v, axis=1),
            jnp.stack(cols_c, axis=1), jnp.stack([bad_ids, bad_sum], axis=1))


def _chunk_rows(spec, n_rows: int, acts: tuple, v, block_rows) -> int:
    """Rows per rebuild tile: explicit, else the blocked-backup autotuner
    choice (the transient table chunk has the same shape/traffic profile
    as a materialized blocked chunk, so the tuned size transfers)."""
    if block_rows:
        return int(block_rows)
    return ops.backup_block_rows(n_rows, len(acts), spec.nnz,
                                 v.shape[-1], v.dtype)


def mf_backup(spec, row0, n_rows: int, acts: tuple, gamma, v, *,
              mode: str = "mincost", idx_map=None, impl: str | None = None,
              block_rows: int | None = None):
    """Matrix-free fused Bellman backup over ``n_rows`` rows starting at
    (traced) global row ``row0``: rebuild each row tile from the
    constructors, run the materialized chunk kernel on it, discard it.

    ``idx_map`` (optional) maps the rebuilt *global* successor ids into
    the coordinate system of ``v`` (halo windows, interior-local reads);
    identity when ``None``.  ``mode="maxreward"`` negates internally —
    like the materialized path, the returned ``(vmin, amin)`` live in the
    *negated* min-space so the caller's ``_finish_argmin(..., neg=True)``
    completes them identically.

    Peak transient memory is one ``(block_rows, len(acts), nnz)`` table
    chunk; the persistent footprint is O(n).
    """
    neg = mode == "maxreward"
    if neg:
        v = -v
    rows = row0 + jnp.arange(n_rows, dtype=jnp.int32)

    def body(r):
        idx, val, cost, _bad = build_rows_block(spec, r, acts, mode)
        if neg:
            cost = -cost
        if idx_map is not None:
            idx = idx_map(idx)
        return ops.ell_backup_chunk(idx, val, cost, gamma, v, impl=impl)

    bn = _chunk_rows(spec, n_rows, acts, v, block_rows)
    return ref._blocked_rows(body, (rows,), (), n_rows, bn)


def mf_policy_rows(spec, row0, n_rows: int, acts: tuple, a_sel, own, *,
                   mode: str = "mincost", block_rows: int | None = None):
    """Matrix-free ``P_pi``/``g_pi`` extraction: rebuild each row tile and
    replay :func:`repro.core.bellman.policy_rows`'s exact
    ``take_along_axis`` + ownership-mask arithmetic on it.

    Returns ``(idx_pi (n, K) int32, val_pi (n, K) f32, g_pi (n,) f32)`` —
    bit-identical to selecting from the materialized table, so the inner
    solvers run unchanged on the result.  The output is O(n*nnz) (the same
    transient the materialized path's selection produces); only the
    O(n*m*nnz) full table is never held.

    ``mode`` only affects padded action columns (``a >= m``), which a
    greedy ``a_sel`` never selects on the state-sharded layouts matrix-free
    supports — passed through for exactness anyway.
    """
    rows = row0 + jnp.arange(n_rows, dtype=jnp.int32)

    def body(r, a_sel_c, own_c):
        idx, val, cost, _bad = build_rows_block(spec, r, acts, mode)
        take = lambda x: jnp.take_along_axis(
            x, a_sel_c[:, None, None], axis=1)[:, 0]
        idx_pi = take(idx)
        val_pi = take(val) * own_c[:, None].astype(val.dtype)
        g_pi = jnp.take_along_axis(cost, a_sel_c[:, None], axis=1)[:, 0]
        g_pi = g_pi * own_c.astype(g_pi.dtype)
        return idx_pi, val_pi, g_pi

    bn = block_rows or min(ref.DEFAULT_BLOCK_ROWS, max(1, n_rows))
    return ref._blocked_rows(body, (rows, a_sel, own), (), n_rows, bn)


# --------------------------------------------------------------------------- #
# Memory model (serve admission, dryrun cost model, benches, README)          #
# --------------------------------------------------------------------------- #

# O(n) iteration state per state (f32): v, tv, window/staging, residual work
ITER_BYTES = 16


def table_bytes(n: int, m: int, nnz: int) -> int:
    """Materialized ELL container bytes: idx (i32) + val (f32) per slot,
    cost (f32) per (state, action) row."""
    return n * m * (8 * nnz + 4)


def operator_bytes(n: int, nnz: int, *, krylov: bool = True) -> int:
    """Peak per-solve device bytes of the matrix-free path: the 1-byte
    placement tag + O(n) value vectors, plus — for the policy-iteration
    methods (``krylov=True``) — the transient policy-restricted rows
    ``n * (8*nnz + 4)`` the inner solvers consume.  Pure VI never
    materializes policy rows; pass ``krylov=False`` for its footprint."""
    per = 1 + ITER_BYTES
    if krylov:
        per += 8 * nnz + 4
    return n * per
