"""Pallas TPU kernel: causal flash attention (GQA) — substrate hot spot.

The LM substrate's training/prefill attention is the pure-JAX online-softmax
scan in ``repro.models.attention``; on TPU the scan body becomes this fused
kernel so scores/probs never leave VMEM.  Grid: (batch*kv_head*q_group,
q_blocks); the kv loop runs inside the kernel body with a ``fori_loop`` over
kv blocks up to the causal frontier, carrying (m, l, o) accumulators in VMEM
scratch.

Layout notes (MXU/VPU):
  * block shapes (BLOCK_Q, d_head) x (BLOCK_K, d_head) put the contraction
    on the lane dim; d_head in {64, 80, 128} for the assigned archs — all
    <= 128, one MXU pass per (q, k) tile.
  * accumulators are f32; inputs may be bf16.
  * the causal mask is applied per-tile from broadcasted iotas, so fully
    masked tiles are skipped by bounding the fori_loop at the frontier
    (ceil((q_hi)/BLOCK_K) iterations) — the flash-2 scheduling.

Oracle: ``repro.models.attention.chunked_attention`` (itself validated
against dense softmax attention in tests/test_models.py); this kernel is
validated against it over shape/dtype sweeps in tests/test_flash_kernel.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_k: int,
                  scale: float, causal: bool):
    # q_ref: (BLOCK_Q, d); k_ref/v_ref: (seq_k, d); o_ref: (BLOCK_Q, d)
    block_q, d = q_ref.shape
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)

    n_kv = seq_k // block_k
    if causal:
        # frontier: last kv block that any query in this q block can see
        hi = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k, n_kv)
    else:
        hi = n_kv

    def body(ki, carry):
        m_prev, l_prev, o_prev = carry
        k_blk = jax.lax.dynamic_slice_in_dim(
            k_ref[...], ki * block_k, block_k, axis=0).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice_in_dim(
            v_ref[...], ki * block_k, block_k, axis=0).astype(jnp.float32)
        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1)
        o_new = o_prev * alpha[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, o_new

    init = (jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.zeros((block_q,), jnp.float32),
            jnp.zeros((block_q, d), jnp.float32))
    _, l, o = jax.lax.fori_loop(0, hi, body, init)
    o_ref[...] = (o / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, T, H, d); k, v: (B, S, KV, d); returns (B, T, H, d).

    GQA: H % KV == 0; query head h attends to kv head h // (H // KV).
    T and S are padded to block multiples internally (causal masking keeps
    padded keys inert for self-attention; for causal=False callers must
    pass unpadded S or mask externally).
    """
    b, t, h, d = q.shape
    s = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    scale = d ** -0.5
    bq = min(block_q, t)
    bk = min(block_k, s)
    pad_q = (-t) % bq
    pad_k = (-s) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    tp, sp = t + pad_q, s + pad_k

    # (B, T, H, d) -> (B*H, T, d) with h -> (kv_head, group)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, tp, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, sp, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, sp, d)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_k=bk, seq_k=sp, scale=scale,
                          causal=causal),
        grid=(b * h, tp // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, sp, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, sp, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, tp, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, h, tp, d).transpose(0, 2, 1, 3)
    return out[:, :t]
