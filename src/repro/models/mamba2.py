"""Mamba2 (SSD — state-space duality) block: chunked train scan + O(1) decode.

The SSD algorithm (Dao & Gu 2024): split the sequence into chunks of length
``L``; within a chunk the recurrence is computed as a (masked, decay-weighted)
attention-like matmul (MXU-friendly); across chunks a small recurrent state
``(H, d_head, N)`` is carried by a ``lax.scan``.  Decode is the pure
recurrence: ``S <- a * S + dt * B x``, ``y = C . S + D x``.

Shapes: d_inner = expand * d_model; H = d_inner / head_p heads of size
``head_p``; B/C are shared across heads (ngroups=1) with state size N.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init, rms_norm


def init_mamba2(key, cfg, dtype):
    d = cfg.d_model
    d_inner = cfg.expand * d
    n, h = cfg.ssm_state, cfg.ssm_heads
    conv_dim = d_inner + 2 * n
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
        "in_proj": _init(ks[0], (d, 2 * d_inner + 2 * n + h), dtype=dtype),
        "conv_w": _init(ks[1], (cfg.d_conv, conv_dim),
                        scale=cfg.d_conv ** -0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(
                ks[2], (h,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1))))
            ).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": _init(ks[3], (d_inner, d), dtype=dtype),
    }


def _split_proj(cfg, proj):
    d_inner = cfg.expand * cfg.d_model
    n, h = cfg.ssm_state, cfg.ssm_heads
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over time. xbc: (B,T,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None]
              for i in range(k))
    return jax.nn.silu(out + b[None, None])


def ssd_scan(x, dt, a_log, b_mat, c_mat, chunk: int):
    """Chunked SSD. x: (B,T,H,P); dt: (B,T,H); b_mat/c_mat: (B,T,N).

    Returns y: (B,T,H,P) and the final state (B,H,P,N).
    """
    bsz, t, h, p = x.shape
    n = b_mat.shape[-1]
    l = min(chunk, t)
    pad = (-t) % l
    if pad:
        # state-neutral padding: dt=0 => decay exp(0)=1 and zero input
        # contribution, so the carried state is untouched by pad tokens.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    t_pad = t + pad
    nc = t_pad // l
    f32 = jnp.float32
    xc = x.astype(f32).reshape(bsz, nc, l, h, p)
    dtc = dt.astype(f32).reshape(bsz, nc, l, h)
    bc = b_mat.astype(f32).reshape(bsz, nc, l, n)
    cc = c_mat.astype(f32).reshape(bsz, nc, l, n)

    log_a = -jnp.exp(a_log.astype(f32))[None, None, None] * dtc   # (B,nc,L,H) <= 0
    cum = jnp.cumsum(log_a, axis=2)                               # within-chunk
    dtx = xc * dtc[..., None]                                     # fold dt into x

    # intra-chunk: y_i += C_i.B_j * exp(cum_i - cum_j) * dtx_j  (j <= i)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)                # (B,nc,L,L)
    ii = jnp.arange(l)
    causal = (ii[:, None] >= ii[None, :])
    decay = jnp.exp(cum[:, :, :, None] - cum[:, :, None, :])      # (B,nc,L,L,H)
    m = jnp.where(causal[None, None, :, :, None], decay, 0.0) \
        * scores[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, dtx)

    # chunk-local end states: S_c = sum_j exp(cum_end - cum_j) * B_j (x) dtx_j
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)                  # (B,nc,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", bc, decay_end, dtx)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # (B,nc,H)

    def step(s_prev, xs):
        st, cd = xs                                               # (B,H,P,N), (B,H)
        s_new = s_prev * cd[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, p, n), f32)
    s_final, s_prevs = jax.lax.scan(
        step, s0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    s_prevs = s_prevs.swapaxes(0, 1)                              # (B,nc,H,P,N)

    # inter-chunk: y_i += (C_i * exp(cum_i)) . S_prev
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         cc, jnp.exp(cum), s_prevs)
    y = (y_intra + y_inter).reshape(bsz, t_pad, h, p)[:, :t]
    return y, s_final


def apply_mamba2(params, x, cfg, *, cache=None):
    """cache=None: full-sequence SSD (train/prefill); returns (y, cache_out).
    cache=(conv_state (B,K-1,C), ssm_state (B,H,P,N)): single-token decode."""
    bsz, t, d = x.shape
    d_inner = cfg.expand * d
    n, h = cfg.ssm_state, cfg.ssm_heads
    p = d_inner // h
    proj = x @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None])

    if cache is None:
        xbc_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        xs, b_mat, c_mat = jnp.split(xbc_conv, [d_inner, d_inner + n], -1)
        xh = xs.reshape(bsz, t, h, p)
        y, s_final = ssd_scan(xh, dt, params["a_log"], b_mat, c_mat,
                              cfg.ssm_chunk)
        conv_state = jnp.pad(xbc, ((0, 0), (cfg.d_conv - 1, 0), (0, 0))) \
            [:, -(cfg.d_conv - 1):, :]
        cache_out = (conv_state.astype(x.dtype), s_final)
    else:
        conv_state, s_prev = cache
        window = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
        conv = sum(window[:, i:i + 1, :] * params["conv_w"][i][None, None]
                   for i in range(cfg.d_conv))
        xbc_conv = jax.nn.silu(conv + params["conv_b"][None, None])
        xs, b_mat, c_mat = jnp.split(xbc_conv, [d_inner, d_inner + n], -1)
        xh = xs.reshape(bsz, 1, h, p).astype(jnp.float32)
        a = jnp.exp(-jnp.exp(params["a_log"]) * dt[:, 0])         # (B,H)
        dbx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                         b_mat[:, 0].astype(jnp.float32), xh[:, 0])
        s_new = s_prev * a[..., None, None] + dbx
        y = jnp.einsum("bn,bhpn->bhp",
                       c_mat[:, 0].astype(jnp.float32), s_new)[:, None]
        cache_out = (window[:, 1:, :], s_new)

    y = y + params["d_skip"][None, None, :, None] * \
        xs.reshape(bsz, t, h, p).astype(jnp.float32)
    y = y.reshape(bsz, t, d_inner).astype(x.dtype)
    y = rms_norm(params["norm_w"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["out_proj"], cache_out
