"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

One model class, four block kinds:

  * ``attn``  — pre-norm GQA attention + dense MLP (stablelm, minitron,
                granite, nemotron, llava backbone)
  * ``moe``   — GQA attention + MoE FFN (+ parallel dense residual, arctic)
  * ``mamba`` — Mamba2 SSD block (mamba2-130m; zamba2 backbone)
  * hybrid    — mamba stack with a single *shared* attention+MLP block
                applied every ``shared_attn_every`` layers (zamba2)

Layer stacks are scan-stacked (leading L axis) so the lowered HLO is O(1) in
depth; per-layer remat (``jax.checkpoint``) bounds activation memory to one
layer plus the carried residual stream.

Caches (decode):  attn -> (k, v) rings (B, S, KV, hd) + scalar length;
mamba -> (conv window, SSD state).  All cache tensors carry a leading L axis
and are scanned alongside the stacked params.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models.layers import _init, apply_mlp, init_mlp, rms_norm


def zero_aux():
    return {"load_balance_loss": jnp.float32(0.0),
            "router_z_loss": jnp.float32(0.0)}


def _init_attn_block(key, cfg, dtype, *, moe: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype),
         "ln2": jnp.ones((cfg.d_model,), dtype),
         "attn": attn_lib.init_attention(k1, cfg, dtype)}
    if moe:
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
        if cfg.dense_residual:
            p["mlp"] = init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    return p


def _init_mamba_block(key, cfg, dtype):
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "mamba": mamba_lib.init_mamba2(key, cfg, dtype)}


def _attn_block(p, x, cache, *, cfg, positions, moe: bool):
    h, cache_out = attn_lib.apply_attention(
        p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, cache=cache)
    x = x + h
    y = rms_norm(p["ln2"], x, cfg.norm_eps)
    aux = zero_aux()
    if moe:
        ym, aux = moe_lib.apply_moe(p["moe"], y, cfg)
        if cfg.dense_residual:
            ym = ym + apply_mlp(p["mlp"], y, cfg.mlp_type)
    else:
        ym = apply_mlp(p["mlp"], y, cfg.mlp_type)
    return x + ym, cache_out, aux


def _mamba_block(p, x, cache, *, cfg, positions):
    del positions
    h, cache_out = mamba_lib.apply_mamba2(
        p["mamba"], rms_norm(p["ln1"], x, cfg.norm_eps), cfg, cache=cache)
    return x + h, cache_out, zero_aux()


class DecoderLM:
    """init/apply wrapper around the block stacks."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.kind = {"dense": "attn", "vlm": "attn", "moe": "moe",
                     "ssm": "mamba", "hybrid": "mamba"}[cfg.family]

    # ------------------------------------------------------------------ init
    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, 8)
        params = {
            "embed": _init(keys[0], (cfg.vocab_size, cfg.d_model),
                           scale=1.0, dtype=dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = _init(
                keys[1], (cfg.d_model, cfg.vocab_size), dtype=dtype)
        if cfg.family == "vlm":
            params["patch_proj"] = _init(
                keys[2], (cfg.d_model, cfg.d_model), dtype=dtype)
        lkeys = jax.random.split(keys[3], cfg.n_layers)
        if self.kind in ("attn", "moe"):
            init_l = functools.partial(_init_attn_block, cfg=cfg, dtype=dtype,
                                       moe=(self.kind == "moe"))
        else:
            init_l = functools.partial(_init_mamba_block, cfg=cfg, dtype=dtype)
        params["blocks"] = jax.vmap(init_l)(lkeys)
        if cfg.family == "hybrid":
            # zamba2: ONE shared attention+MLP block reused at every call site
            params["shared"] = _init_attn_block(keys[4], cfg, dtype, moe=False)
        return params

    # -------------------------------------------------------------- caches
    def n_shared_sites(self) -> int:
        cfg = self.cfg
        if cfg.family != "hybrid" or not cfg.shared_attn_every:
            return 0
        return cfg.n_layers // cfg.shared_attn_every

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        """Empty decode caches (filled by prefill or supplied by the bench)."""
        cfg = self.cfg
        l = cfg.n_layers
        if self.kind in ("attn", "moe"):
            kv = dict(
                k=jnp.zeros((l, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                            dtype),
                v=jnp.zeros((l, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                            dtype))
            return {"blocks": kv, "len": jnp.int32(0)}
        cache = {"blocks": dict(
            conv=jnp.zeros((l, batch, cfg.d_conv - 1,
                            cfg.d_inner + 2 * cfg.ssm_state), dtype),
            ssm=jnp.zeros((l, batch, cfg.ssm_heads, cfg.head_p,
                           cfg.ssm_state), jnp.float32)),
            "len": jnp.int32(0)}
        ns = self.n_shared_sites()
        if ns:
            cache["shared"] = dict(
                k=jnp.zeros((ns, batch, max_len, cfg.n_kv_heads,
                             cfg.head_dim), dtype),
                v=jnp.zeros((ns, batch, max_len, cfg.n_kv_heads,
                             cfg.head_dim), dtype))
        return cache

    # -------------------------------------------------------------- forward
    def _embed(self, params, tokens, patches):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.family == "vlm" and patches is not None:
            pe = patches.astype(x.dtype) @ params["patch_proj"]
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def _block_fn(self, mode: str):
        cfg = self.cfg
        moe = self.kind == "moe"
        if self.kind in ("attn", "moe"):
            base = functools.partial(_attn_block, cfg=cfg, moe=moe)
        else:
            base = functools.partial(_mamba_block, cfg=cfg)
        return base

    def _scan_stack(self, params_stack, x, *, positions, mode, cache,
                    remat: str = "full", unroll: bool = False):
        """Run the scan-stacked block params over x. Returns (x, cache, aux)."""
        fn = self._block_fn(mode)
        if remat != "none" and mode == "train":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if remat == "dots" else None)
            fn = jax.checkpoint(fn, policy=policy, static_argnums=())

        if unroll:
            # Python-loop execution (roofline analysis path: XLA cost_analysis
            # counts while-loop bodies once, so the reduced-depth roofline
            # lowers use this to get loop-free HLO; see benchmarks/roofline.py)
            l = jax.tree.leaves(params_stack)[0].shape[0]
            aux = zero_aux()
            caches = []
            length = None if cache is None else cache["len"]
            for i in range(l):
                p_l = jax.tree.map(lambda a: a[i], params_stack)
                if mode == "decode":
                    c_l = jax.tree.map(lambda a: a[i], cache["blocks"])
                    if self.kind in ("attn", "moe"):
                        x, c, a = fn(p_l, x, (c_l["k"], c_l["v"], length),
                                     positions=positions)
                        caches.append(dict(k=c[0], v=c[1]))
                    else:
                        x, c, a = fn(p_l, x, (c_l["conv"], c_l["ssm"]),
                                     positions=positions)
                        caches.append(dict(conv=c[0], ssm=c[1]))
                else:
                    x, c, a = fn(p_l, x, None, positions=positions)
                    if mode == "prefill":
                        caches.append(dict(k=c[0], v=c[1])
                                      if self.kind in ("attn", "moe")
                                      else dict(conv=c[0], ssm=c[1]))
                aux = jax.tree.map(jnp.add, aux, a)
            cache_out = None
            if caches:
                cache_out = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
            return x, cache_out, aux

        if mode == "train":
            def body(carry, p_l):
                h, aux = carry
                h, _, a = fn(p_l, h, None, positions=positions)
                return (h, jax.tree.map(jnp.add, aux, a)), None
            (x, aux), _ = jax.lax.scan(body, (x, zero_aux()), params_stack)
            return x, None, aux

        if mode == "prefill":
            attn_like = self.kind in ("attn", "moe")

            def body(carry, p_l):
                h, aux = carry
                h, c, a = fn(p_l, h, None, positions=positions)
                c = dict(k=c[0], v=c[1]) if attn_like else \
                    dict(conv=c[0], ssm=c[1])
                return (h, jax.tree.map(jnp.add, aux, a)), c
            (x, aux), cache_out = jax.lax.scan(
                body, (x, zero_aux()), params_stack)
            return x, cache_out, aux

        # decode: thread per-layer cache slices through the scan
        length = cache["len"]

        def body(carry, xs):
            h, aux = carry
            p_l, c_l = xs
            if self.kind in ("attn", "moe"):
                c_in = (c_l["k"], c_l["v"], length)
                h, (k, v, _), a = fn(p_l, h, c_in, positions=positions)
                c_out = dict(k=k, v=v)
            else:
                h, c_out_t, a = fn(p_l, h, (c_l["conv"], c_l["ssm"]),
                                   positions=positions)
                c_out = dict(conv=c_out_t[0], ssm=c_out_t[1])
            return (h, jax.tree.map(jnp.add, aux, a)), c_out

        (x, aux), blocks_out = jax.lax.scan(
            body, (x, zero_aux()), (params_stack, cache["blocks"]))
        return x, blocks_out, aux

    def forward(self, params, tokens, *, patches=None, mode: str = "train",
                cache=None, remat: str = "full", unroll: bool = False):
        """Returns ``(hidden, cache_out, aux)``.

        train/prefill: ``tokens (B, T)``; decode: ``tokens (B, 1)`` + cache.
        """
        cfg = self.cfg
        x = self._embed(params, tokens, patches)
        b, t, _ = x.shape
        if mode == "decode":
            positions = jnp.full((b, 1), cache["len"], jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(t), (b, t))

        if cfg.family != "hybrid" or not cfg.shared_attn_every:
            x, blocks_cache, aux = self._scan_stack(
                params["blocks"], x, positions=positions, mode=mode,
                cache=cache, remat=remat, unroll=unroll)
            cache_out = self._pack_cache(blocks_cache, None, cache, t, mode)
            return rms_norm(params["final_norm"], x, cfg.norm_eps), \
                cache_out, aux

        # ---- zamba2 hybrid: segments of mamba blocks + shared attn block --- #
        every, l = cfg.shared_attn_every, cfg.n_layers
        sites = self.n_shared_sites()
        aux = zero_aux()
        shared_fn = functools.partial(_attn_block, cfg=cfg, moe=False)
        if mode == "train" and remat != "none":
            shared_fn = jax.checkpoint(shared_fn)
        seg_bounds = [(i * every, min((i + 1) * every, l)) for i in
                      range((l + every - 1) // every)]
        blocks_caches, shared_caches = [], []
        for si, (lo, hi) in enumerate(seg_bounds):
            seg_params = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
            seg_cache = None
            if mode == "decode":
                seg_cache = {"blocks": jax.tree.map(
                    lambda a: a[lo:hi], cache["blocks"]),
                    "len": cache["len"]}
            x, bc, a = self._scan_stack(seg_params, x, positions=positions,
                                        mode=mode, cache=seg_cache,
                                        remat=remat, unroll=unroll)
            aux = jax.tree.map(jnp.add, aux, a)
            if bc is not None:
                blocks_caches.append(bc)
            if si < sites:  # shared block after each full segment
                if mode == "decode":
                    sc = (cache["shared"]["k"][si], cache["shared"]["v"][si],
                          cache["len"])
                    x, (k, v, _), a2 = shared_fn(params["shared"], x, sc,
                                                 positions=positions)
                    shared_caches.append(dict(k=k, v=v))
                else:
                    x, sc_out, a2 = shared_fn(params["shared"], x, None,
                                              positions=positions)
                    if mode == "prefill":
                        shared_caches.append(dict(k=sc_out[0], v=sc_out[1]))
                aux = jax.tree.map(jnp.add, aux, a2)
        blocks_cache = None
        if blocks_caches:
            blocks_cache = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *blocks_caches)
        shared_cache = None
        if shared_caches:
            shared_cache = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *shared_caches)
        cache_out = self._pack_cache(blocks_cache, shared_cache, cache, t,
                                     mode)
        return rms_norm(params["final_norm"], x, cfg.norm_eps), cache_out, aux

    def _pack_cache(self, blocks_cache, shared_cache, cache_in, t, mode):
        if mode == "train" or blocks_cache is None:
            return None
        if mode == "prefill":
            out = {"blocks": blocks_cache, "len": jnp.int32(t)}
        else:
            out = {"blocks": blocks_cache, "len": cache_in["len"] + 1}
        if shared_cache is not None:
            out["shared"] = shared_cache
        return out

    def logits(self, params, hidden):
        w = params["embed"].T if self.cfg.tie_embeddings else params["unembed"]
        return hidden @ w
