"""Shared primitives: norms, projections, rotary embeddings, MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _init(key, shape, scale=None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


def rms_norm(w, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------- #
# Rotary position embeddings
# ---------------------------------------------------------------------------- #

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, n_heads, d_head); positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d // 2, dtype=jnp.float32) / (d // 2))
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., T, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------- #
# MLPs (dense FFN variants)
# ---------------------------------------------------------------------------- #

def init_mlp(key, d_model: int, d_ff: int, mlp_type: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {"w_gate": _init(k1, (d_model, d_ff), dtype=dtype),
                "w_up": _init(k2, (d_model, d_ff), dtype=dtype),
                "w_down": _init(k3, (d_ff, d_model), dtype=dtype)}
    # relu2 (nemotron squared-ReLU) and gelu (whisper) share the 2-matrix shape
    return {"w_up": _init(k1, (d_model, d_ff), dtype=dtype),
            "w_down": _init(k2, (d_ff, d_model), dtype=dtype)}


def apply_mlp(params, x, mlp_type: str):
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
    elif mlp_type == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    else:
        raise ValueError(mlp_type)
    return h @ params["w_down"]
