"""LM substrate: the assigned architectures as composable JAX modules.

Pure-functional modules: each exposes ``init(key, cfg) -> params`` and an
apply function; parameters are plain pytrees (dicts), layer stacks are
scan-stacked along a leading L axis for O(1)-size HLO.
"""

from repro.models.lm import DecoderLM
from repro.models.whisper import WhisperModel


def build_model(cfg):
    if cfg.family == "encdec":
        return WhisperModel(cfg)
    return DecoderLM(cfg)
