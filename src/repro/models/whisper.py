"""Whisper-style encoder–decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, enc_len, d) directly into the encoder.
Encoder: non-causal self-attention + GELU MLP.  Decoder: causal self-attn
(cached at decode) + cross-attn over the encoder output (enc K/V precomputed
once and carried in the cache) + GELU MLP.  Norms are LayerNorm (scale+bias)
as in Whisper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.layers import _init, apply_mlp, init_mlp, layer_norm
from repro.models.lm import zero_aux


def _init_ln(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def _init_enc_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": _init_ln(cfg.d_model, dtype),
            "attn": attn_lib.init_attention(k1, cfg, dtype),
            "ln2": _init_ln(cfg.d_model, dtype),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, "gelu", dtype)}


def _init_dec_block(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": _init_ln(cfg.d_model, dtype),
            "self_attn": attn_lib.init_attention(k1, cfg, dtype),
            "ln_x": _init_ln(cfg.d_model, dtype),
            "cross_attn": attn_lib.init_attention(k2, cfg, dtype),
            "ln2": _init_ln(cfg.d_model, dtype),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, "gelu", dtype)}


def _enc_block(p, x, cfg, positions):
    h, _ = attn_lib.apply_attention(
        p["attn"], layer_norm(p["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, causal=False)
    x = x + h
    return x + apply_mlp(p["mlp"], layer_norm(p["ln2"], x, cfg.norm_eps),
                         "gelu")


def _cross_attend(p, x, enc_k, enc_v, cfg):
    """Full (non-chunked) cross-attention over the (short) encoder output."""
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(*x.shape[:2], h, hd)
    sc = attn_lib._gqa_scores(q.astype(jnp.float32),
                              enc_k.astype(jnp.float32))
    pr = jax.nn.softmax(sc, axis=-1)
    y = attn_lib._gqa_out(pr, enc_v.astype(jnp.float32)).astype(x.dtype)
    return y.reshape(*x.shape[:2], h * hd) @ p["wo"]


def _dec_block(p, x, cache, *, cfg, positions, enc_kv):
    h, cache_out = attn_lib.apply_attention(
        p["self_attn"], layer_norm(p["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, cache=cache)
    x = x + h
    x = x + _cross_attend(p["cross_attn"],
                          layer_norm(p["ln_x"], x, cfg.norm_eps),
                          enc_kv[0], enc_kv[1], cfg)
    x = x + apply_mlp(p["mlp"], layer_norm(p["ln2"], x, cfg.norm_eps), "gelu")
    return x, cache_out, zero_aux()


class WhisperModel:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 6)
        enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": _init(ks[2], (cfg.vocab_size, cfg.d_model), scale=1.0,
                           dtype=dtype),
            "unembed": _init(ks[3], (cfg.d_model, cfg.vocab_size),
                             dtype=dtype),
            "enc_norm": _init_ln(cfg.d_model, dtype),
            "final_norm": _init_ln(cfg.d_model, dtype),
            "encoder": jax.vmap(
                functools.partial(_init_enc_block, cfg=cfg, dtype=dtype)
            )(enc_keys),
            "decoder": jax.vmap(
                functools.partial(_init_dec_block, cfg=cfg, dtype=dtype)
            )(dec_keys),
        }

    def encode(self, params, frames, remat: str = "full",
               unroll: bool = False):
        """frames: (B, enc_len, d) precomputed conv-frontend output (stub)."""
        cfg = self.cfg
        b, s, _ = frames.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        fn = functools.partial(_enc_block, cfg=cfg, positions=positions)
        if remat != "none":
            fn = jax.checkpoint(fn)
        x = frames.astype(jnp.dtype(cfg.dtype))
        if unroll:
            for i in range(cfg.encoder_layers):
                x = fn(jax.tree.map(lambda a: a[i], params["encoder"]), x)
        else:
            x, _ = jax.lax.scan(lambda h, p_l: (fn(p_l, h), None),
                                x, params["encoder"])
        return layer_norm(params["enc_norm"], x, cfg.norm_eps)

    def enc_kv(self, params, enc_out):
        """Per-decoder-layer cross K/V (L, B, S, KV, hd), computed once."""
        cfg = self.cfg
        kv, hd = cfg.n_kv_heads, cfg.head_dim

        def one(p_l):
            k = (enc_out @ p_l["cross_attn"]["wk"]).reshape(
                *enc_out.shape[:2], kv, hd)
            v = (enc_out @ p_l["cross_attn"]["wv"]).reshape(
                *enc_out.shape[:2], kv, hd)
            return k, v
        return jax.lax.map(one, params["decoder"])

    def forward(self, params, tokens, *, frames=None, enc_out=None,
                mode: str = "train", cache=None, remat: str = "full",
                unroll: bool = False):
        """Returns (hidden, cache_out, aux).  decode: cache carries enc K/V."""
        cfg = self.cfg
        if enc_out is None and frames is not None:
            enc_out = self.encode(params, frames, remat, unroll)
        x = jnp.take(params["embed"], tokens, axis=0)
        b, t, _ = x.shape
        if mode == "decode":
            positions = jnp.full((b, 1), cache["len"], jnp.int32)
            ek, ev = cache["enc_k"], cache["enc_v"]
        else:
            positions = jnp.broadcast_to(jnp.arange(t), (b, t))
            ek, ev = self.enc_kv(params, enc_out)

        def blk(p_l, h, c_l, ek_l, ev_l):
            return _dec_block(p_l, h, c_l, cfg=cfg, positions=positions,
                              enc_kv=(ek_l, ev_l))
        fn = blk
        if mode == "train" and remat != "none":
            fn = jax.checkpoint(blk)

        if mode in ("train", "prefill"):
            def body(carry, xs):
                h = carry
                p_l, ek_l, ev_l = xs
                h, c, _ = fn(p_l, h, None, ek_l, ev_l)
                return h, (dict(k=c[0], v=c[1]) if mode == "prefill" else None)
            if unroll:
                caches = []
                for i in range(cfg.n_layers):
                    sl = jax.tree.map(lambda a: a[i],
                                      (params["decoder"], ek, ev))
                    x, c, _ = fn(sl[0], x, None, sl[1], sl[2])
                    if mode == "prefill":
                        caches.append(dict(k=c[0], v=c[1]))
                caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches) \
                    if caches else None
            else:
                x, caches = jax.lax.scan(body, x, (params["decoder"], ek, ev))
            cache_out = None
            if mode == "prefill":
                cache_out = {"blocks": caches, "enc_k": ek, "enc_v": ev,
                             "len": jnp.int32(t)}
        else:
            length = cache["len"]

            def body(carry, xs):
                h = carry
                p_l, c_l, ek_l, ev_l = xs
                h, (k, v, _), _ = fn(p_l, h, (c_l["k"], c_l["v"], length),
                                     ek_l, ev_l)
                return h, dict(k=k, v=v)
            if unroll:
                blocks = []
                for i in range(cfg.n_layers):
                    p_l, c_l, ek_l, ev_l = jax.tree.map(
                        lambda a: a[i],
                        (params["decoder"], cache["blocks"], ek, ev))
                    x, (k, v, _), _ = fn(p_l, x, (c_l["k"], c_l["v"], length),
                                         ek_l, ev_l)
                    blocks.append(dict(k=k, v=v))
                blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
            else:
                x, blocks = jax.lax.scan(
                    body, x, (params["decoder"], cache["blocks"], ek, ev))
            cache_out = {"blocks": blocks, "enc_k": ek, "enc_v": ev,
                         "len": length + 1}
        hidden = layer_norm(params["final_norm"], x, cfg.norm_eps)
        return hidden, cache_out, zero_aux()

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        l, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        return {"blocks": dict(
            k=jnp.zeros((l, batch, max_len, kv, hd), dtype),
            v=jnp.zeros((l, batch, max_len, kv, hd), dtype)),
            "enc_k": jnp.zeros((l, batch, cfg.encoder_len, kv, hd), dtype),
            "enc_v": jnp.zeros((l, batch, cfg.encoder_len, kv, hd), dtype),
            "len": jnp.int32(0)}

    def logits(self, params, hidden):
        return hidden @ params["unembed"]
