"""GQA/MQA attention with RoPE: train (chunked-flash), prefill, decode.

Memory strategy (TPU): training/prefill attention is *online-softmax over KV
chunks* (flash-style, pure JAX ``lax.scan``) so the (T, T) score matrix never
materializes — peak is (T_q, chunk).  The Pallas flash kernel would replace
the scan body on real hardware; the scan form is what we lower for the
dry-run and it bounds memory identically.  Decode reads a (B, KV, S, d) cache
(sequence-shardable for the long-context shapes — softmax reductions over a
sharded S are handled by SPMD with psum/pmax collectives).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init, rope

NEG_INF = -1e30


def init_attention(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"wq": _init(k1, (d, h * hd), dtype=dtype),
            "wk": _init(k2, (d, kv * hd), dtype=dtype),
            "wv": _init(k3, (d, kv * hd), dtype=dtype),
            "wo": _init(k4, (h * hd, d), scale=(h * hd) ** -0.5, dtype=dtype)}


def _split_heads(x, n_heads, d_head):
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, d_head)


def _gqa_scores(q, k):
    """q: (B,T,H,hd), k: (B,S,KV,hd) -> (B, KV, H/KV, T, S)."""
    b, t, h, hd = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, t, kvh, h // kvh, hd)
    return jnp.einsum("btkgh,bskh->bkgts", qg, k) * (hd ** -0.5)


def _gqa_out(p, v):
    """p: (B,KV,G,T,S), v: (B,S,KV,hd) -> (B,T,H,hd)."""
    b, kvh, g, t, s = p.shape
    o = jnp.einsum("bkgts,bskh->btkgh", p, v)
    return o.reshape(b, t, kvh * g, v.shape[-1])


def chunked_attention(q, k, v, *, q_offset, chunk: int, causal: bool = True,
                      kv_len: int | None = None):
    """Online-softmax attention over KV chunks.

    q: (B,T,H,hd) at absolute positions [q_offset, q_offset+T);
    k, v: (B,S,KV,hd).  S must be a multiple of ``chunk`` (caller pads;
    ``kv_len`` masks padded key positions >= kv_len).
    """
    b, t, h, hd = q.shape
    s = k.shape[1]
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    kvh = k.shape[2]
    kc = k.reshape(b, n_chunks, chunk, kvh, hd).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd).swapaxes(0, 1)
    q32 = q.astype(jnp.float32)
    qpos = q_offset + jnp.arange(t)

    def step(carry, xs):
        m_prev, l_prev, o_prev = carry
        ci, kch, vch = xs
        sc = _gqa_scores(q32, kch.astype(jnp.float32))   # (B,KV,G,T,C)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((t, chunk), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]       # (T, C)
        if kv_len is not None:
            mask &= (kpos < kv_len)[None, :]
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m_prev, sc.max(-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(-1)
        o_new = o_prev * alpha[..., None] + jnp.einsum(
            "bkgtc,bckh->bkgth", p, vch.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    g = h // kvh
    init = (jnp.full((b, kvh, g, t), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, t), jnp.float32),
            jnp.zeros((b, kvh, g, t, hd), jnp.float32))
    (m, l, o), _ = jax.lax.scan(step, init, (jnp.arange(n_chunks), kc, vc))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    # (B,KV,G,T,hd) -> (B,T,H,hd)
    return o.swapaxes(2, 3).swapaxes(1, 2).reshape(b, t, h, hd).astype(q.dtype)


def apply_attention(params, x, cfg, *, positions, cache=None,
                    kv_x=None, causal=True):
    """Unified attention apply.

    * train/prefill: ``cache=None`` -> returns (y, (k, v)) over x itself
      (or over ``kv_x`` for cross-attention, non-causal).
    * decode: ``cache=(k_cache, v_cache, length)`` -> x is (B,1,d); returns
      (y, updated cache tuple).
    """
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(x @ params["wq"], h, hd)
    if kv_x is None:  # cross-attention uses unrotated q/k (whisper-style)
        q = rope(q, positions, cfg.rope_theta)

    if cache is None:
        src = x if kv_x is None else kv_x
        k = _split_heads(src @ params["wk"], kv, hd)
        v = _split_heads(src @ params["wv"], kv, hd)
        if kv_x is None:  # self-attention: rotate keys
            k = rope(k, positions, cfg.rope_theta)
        t_kv = k.shape[1]
        chunk = min(cfg.attn_chunk, t_kv)
        pad = (-t_kv) % chunk
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y = chunked_attention(q, k, v, q_offset=0, chunk=chunk,
                              causal=causal and kv_x is None,
                              kv_len=t_kv if pad else None)
        out = y.reshape(*y.shape[:2], h * hd) @ params["wo"]
        return out, (k[:, :t_kv], v[:, :t_kv])

    # ---- decode: one new token against the cache -------------------------- #
    k_cache, v_cache, length = cache
    k_new = _split_heads(x @ params["wk"], kv, hd)
    k_new = rope(k_new, positions, cfg.rope_theta)
    v_new = _split_heads(x @ params["wv"], kv, hd)
    # caches are (B, S, KV, hd); write at `length` (index dtypes must match —
    # keep everything at length.dtype so x64 mode doesn't mix int32/int64)
    zero = jnp.zeros((), length.dtype)
    start = (zero, jnp.asarray(length, length.dtype), zero, zero)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), start)
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), start)
    sc = _gqa_scores(q.astype(jnp.float32), k_cache.astype(jnp.float32))
    s = k_cache.shape[1]
    valid = jnp.arange(s) <= length           # positions 0..length inclusive
    sc = jnp.where(valid[None, None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    y = _gqa_out(p, v_cache.astype(jnp.float32)).astype(x.dtype)
    out = y.reshape(*y.shape[:2], h * hd) @ params["wo"]
    return out, (k_cache, v_cache, length + 1)
