"""Mixture-of-Experts layer (GShard/Switch-style capacity dispatch).

Expert-parallel design: expert weights carry a leading E axis sharded over
the ``model`` mesh axis; tokens are grouped (G groups of S tokens, G sharded
over ``data``), and the dispatch/combine einsums generate the all-to-all
collectives under SPMD.  Dispatch tensors are built slot-by-slot (a Python
loop over the top-k slots) so the peak intermediate is (G, S, E, C), never
(G, S, k, E, C) — at arctic scale (E=128, top-2) that is the difference
between ~170 MB and ~1.4 GB per microbatch.

Aux outputs: load-balance loss (Switch) and router z-loss, returned to the
trainer and added with configurable weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init


def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"router": _init(k1, (d, e), scale=d ** -0.5, dtype=jnp.float32),
         "w_up": _init(k2, (e, d, f), scale=d ** -0.5, dtype=dtype),
         "w_down": _init(k3, (e, f, d), scale=f ** -0.5, dtype=dtype)}
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = _init(k4, (e, d, f), scale=d ** -0.5, dtype=dtype)
    return p


def _capacity(s: int, top_k: int, n_experts: int, factor: float) -> int:
    if s * top_k <= 256:
        # decode / tiny-group regime: dropless (capacity = group size bounds
        # any expert's intake), else single-token decode drops slots
        return s
    return max(1, int(s * top_k * factor / n_experts))


def apply_moe(params, x, cfg):
    """x: (B, T, d) -> (y, aux) with aux = {load_balance_loss, router_z_loss}."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    s = min(cfg.moe_group_size, b * t)
    tokens = x.reshape(-1, d)
    n_tok = tokens.shape[0]
    pad = (-n_tok) % s
    if pad:  # zero-pad to a full group; padded rows are sliced off below
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    g = tokens.shape[0] // s
    xg = tokens.reshape(g, s, d)
    c = _capacity(s, k, e, cfg.capacity_factor)

    logits = xg.astype(jnp.float32) @ params["router"]          # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)                     # (G,S,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux losses (computed on slot-0 statistics, Switch-style)
    me = probs.mean(axis=(0, 1))                                 # (E,)
    ce = jax.nn.one_hot(experts[..., 0], e,
                        dtype=jnp.float32).mean(axis=(0, 1))
    load_balance = (e * jnp.sum(me * ce)).astype(jnp.float32)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) \
        .astype(jnp.float32)

    # slot-by-slot dispatch/combine construction
    dispatch = jnp.zeros((g, s, e, c), jnp.float32)
    combine = jnp.zeros((g, s, e, c), jnp.float32)
    counts = jnp.zeros((g, e), jnp.float32)
    for slot in range(k):
        m = jax.nn.one_hot(experts[..., slot], e,
                           dtype=jnp.float32)                    # (G,S,E)
        pos = counts[:, None, :] + jnp.cumsum(m, axis=1) - m     # 0-based
        keep = (pos < c) * m
        sl = jax.nn.one_hot(pos.astype(jnp.int32), c,
                            dtype=jnp.float32) * keep[..., None]
        dispatch = dispatch + sl
        combine = combine + sl * gates[..., slot, None, None]
        counts = counts + m.sum(axis=1)

    comp_dt = x.dtype
    xe = jnp.einsum("gsec,gsd->egcd", dispatch.astype(comp_dt), xg)
    if cfg.mlp_type == "swiglu":
        h = (jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, params["w_gate"]))
             * jnp.einsum("egcd,edf->egcf", xe, params["w_up"]))
    else:
        h = jax.nn.gelu(jnp.einsum("egcd,edf->egcf", xe, params["w_up"]))
    ye = jnp.einsum("egcf,efd->egcd", h, params["w_down"])
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(comp_dt), ye)
    y = y.reshape(-1, d)[:n_tok]
    aux = {"load_balance_loss": load_balance, "router_z_loss": z_loss}
    return y.reshape(b, t, d), aux
