"""Adaptive solver driver: ``-method auto`` made first-class.

madupite leaves method selection to the user; every benchmark table has a
different winner, and the gap between the best and worst method on one
instance spans orders of magnitude (the GMRES outliers).  This package
closes the loop:

* :mod:`repro.adaptive.probe` — a handful of cheap compiled VI iterations
  distill an instance into a :class:`~repro.adaptive.probe.ProblemProfile`
  (observed contraction, span-vs-norm ratio, probe residuals);
* :mod:`repro.adaptive.rules` — an explainable ordered rule table maps the
  profile to a (method, stop criterion, preconditioner) choice, plus the
  stagnation escalation chain;
* :mod:`repro.adaptive.supervisor` — between-chunks stagnation/divergence
  detection (the generalized Chebyshev ``divtol`` bail-out);
* :mod:`repro.adaptive.driver` — :func:`solve_adaptive`, which runs
  probe -> select -> supervised solve and hot-swaps mid-solve by resuming
  the current :class:`~repro.core.ipi.SolveState` under the next method.

The user surface is ``-method auto`` (plus ``-probe_iters``,
``-adapt_on_stagnation``, ``-pc_type``) through
:class:`repro.api.Session` — this package is the engine behind it.
"""

from repro.adaptive.driver import AdaptiveReport, solve_adaptive
from repro.adaptive.probe import ProblemProfile, estimate_contraction, probe
from repro.adaptive.rules import MethodChoice, escalate, explain, \
    select_method
from repro.adaptive.supervisor import StagnationSupervisor

__all__ = [
    "AdaptiveReport", "MethodChoice", "ProblemProfile",
    "StagnationSupervisor", "escalate", "estimate_contraction", "explain",
    "probe", "select_method", "solve_adaptive",
]
