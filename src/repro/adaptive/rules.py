"""Policy engine: an explainable rule table from profile to method choice.

The selector is deliberately NOT a learned model: it is an ordered list of
``(name, predicate, choose)`` rules over the :class:`ProblemProfile`, each
carrying a human-readable reason, so ``explain(profile)`` can print exactly
why a method was (or was not) picked — the PETSc ``-ksp_view`` ethos applied
to method selection.

The table encodes what the benchmark suite shows (``bench_solvers`` /
``bench_conditioning``):

* fast-contracting instances (dense-random garnets, modest gamma) are VI's
  home turf — inner solves cannot beat a plain backup sweep;
* moderately slow instances favor ``mpi`` (a fixed block of Richardson
  sweeps per outer amortizes the backup's argmin);
* long-mixing instances whose residual is nearly a constant vector
  (``span_ratio`` tiny) certify via the span criterion many times earlier
  than any sup-norm method;
* genuinely ill-conditioned instances (chains / SIS at gamma -> 1, the
  GMRES outliers) need a Krylov inner solver, and a Jacobi / block-Jacobi
  preconditioner to tame the restart stalls.

:func:`escalate` is the mid-solve hot-swap chain: when the supervisor
declares stagnation or divergence, the solve resumes under the next method
in a fixed robustness ordering, terminating at VI — the unconditional
contraction that cannot stagnate.
"""

from __future__ import annotations

import dataclasses

from repro.adaptive.probe import ProblemProfile

__all__ = ["MethodChoice", "RULES", "select_method", "explain", "escalate"]


@dataclasses.dataclass(frozen=True)
class MethodChoice:
    """A concrete (method, stop criterion, preconditioner) selection."""

    method: str
    stop_criterion: str = "atol"
    pc_type: str = "none"
    reason: str = ""

    def summary(self) -> str:
        pc = f" pc={self.pc_type}" if self.pc_type != "none" else ""
        return (f"{self.method} (stop={self.stop_criterion}{pc}) "
                f"— {self.reason}")


# Observed-contraction thresholds.  c <= FAST: VI reaches atol in a few
# dozen backups — inner solves cannot pay for themselves.  The cutoff is
# measured, not guessed: on the garnet family VI wins at observed c=0.76
# (1.8ms vs mpi 2.6ms) but loses from c=0.85 up (4.1ms vs 2.2ms, and 2.9x
# at c=0.89) — the crossover sits between, so FAST = 0.8.
# FAST < c <= MODERATE: fixed Richardson blocks (mpi) amortize the argmin.
# Above MODERATE the sup-norm horizon 1/(1-c) exceeds ~300 iterations and
# Krylov (or span certification) is required.
FAST_CONTRACTION = 0.8
MODERATE_CONTRACTION = 0.997
# span/res below this means the residual is a near-constant vector: the
# midpoint-corrected span certificate converges at the mixing rate, far
# faster than the sup-norm decay.
SPAN_FLAT = 0.05
# Below this state count even ill-conditioned instances go to mpi: a
# Richardson sweep propagates information one transition per application,
# so on small instances the fixed sweep blocks cross the state space many
# times over and beat Krylov wall-clock (bench_adaptive: mpi 0.32s vs
# gmres+jacobi 2.7s on chain n=750 at gamma=0.9999 — reversed at n=5000,
# where mpi stalls at the f32 residual floor and only gmres+jacobi
# converges).  The stagnation supervisor remains the safety net when the
# small-n bet goes wrong.
KRYLOV_MIN_N = 2048


def _krylov(profile: ProblemProfile, deterministic_dots: bool, reason: str) \
        -> MethodChoice:
    # GMRES + Jacobi is the measured hard-regime winner (chain n=5k at
    # gamma=0.9999: 65 outers / 4.3s vs >=3000 outers / 119s plain GMRES and
    # 1149 outers / 72s bicgstab+bjacobi): the elementwise scaling is nearly
    # free yet breaks the GMRES(restart) stall on advection-like chains.
    # bjacobi is stronger per-iteration at small n but its block applies
    # aggravate restart stagnation at scale, so it stays opt-in (-pc_type).
    # Jacobi is also order-free, so the same choice is legal under
    # -deterministic_dots.
    del deterministic_dots
    return MethodChoice("ipi_gmres", "atol", "jacobi", reason)


RULES = (
    ("probe-converged",
     lambda p: p.converged,
     lambda p, det: MethodChoice(
         "vi", "atol", "none",
         "probe already reached atol — one VI sweep re-certifies")),
    ("fast-contraction",
     lambda p: p.contraction <= FAST_CONTRACTION,
     lambda p, det: MethodChoice(
         "vi", "atol", "none",
         f"observed contraction {p.contraction:.4f} <= "
         f"{FAST_CONTRACTION}: plain backups win, inner solves can't pay")),
    ("moderate-contraction",
     lambda p: p.contraction <= MODERATE_CONTRACTION,
     lambda p, det: MethodChoice(
         "mpi", "atol", "none",
         f"observed contraction {p.contraction:.4f} <= "
         f"{MODERATE_CONTRACTION}: fixed Richardson blocks amortize the "
         f"backup argmin")),
    ("long-mixing-flat-span",
     lambda p: p.span_ratio <= SPAN_FLAT,
     lambda p, det: MethodChoice(
         "vi", "span", "none",
         f"span/res {p.span_ratio:.3e} <= {SPAN_FLAT}: residual is a "
         f"near-constant vector — span certifies at the mixing rate")),
    ("ill-conditioned-small",
     lambda p: p.n < KRYLOV_MIN_N,
     lambda p, det: MethodChoice(
         "mpi", "atol", "none",
         f"slow contraction {p.contraction:.4f} but only {p.n} states "
         f"(< {KRYLOV_MIN_N}): Richardson sweep blocks cross the state "
         f"space many times over — cheaper than Krylov at this size")),
    ("ill-conditioned",
     lambda p: True,
     lambda p, det: _krylov(
         p, det,
         f"observed contraction {p.contraction:.4f} with span/res "
         f"{p.span_ratio:.2f}: sup-norm horizon ~"
         f"{int(1.0 / max(1.0 - p.contraction, 1e-6))} iterations — "
         f"preconditioned Krylov inner solves required")),
)


def select_method(profile: ProblemProfile, *,
                  deterministic_dots: bool = False) -> MethodChoice:
    """First matching rule wins (the last rule always matches)."""
    for name, pred, choose in RULES:
        if pred(profile):
            choice = choose(profile, deterministic_dots)
            return dataclasses.replace(
                choice, reason=f"[{name}] {choice.reason}")
    raise AssertionError("unreachable: the fallback rule always matches")


def explain(profile: ProblemProfile, *,
            deterministic_dots: bool = False) -> str:
    """Every rule's verdict for this profile, first match marked — the
    ``-verbose`` / report rendering of the selection."""
    lines = [profile.summary()]
    matched = False
    for name, pred, choose in RULES:
        hit = pred(profile)
        mark = "->" if hit and not matched else ("  " if not hit else " +")
        if hit and not matched:
            matched = True
            lines.append(f"{mark} {name}: "
                         f"{choose(profile, deterministic_dots).summary()}")
        else:
            lines.append(f"{mark} {name}: "
                         f"{'matches (shadowed)' if hit else 'no match'}")
    return "\n".join(lines)


# Hot-swap escalation: a stagnating or diverging method hands its CURRENT
# SolveState to the next entry.  Ordered by escalation strength: cheap
# Richardson blocks first (also where out-of-chain methods like a
# diverging chebyshev land), then the Krylov combos — GMRES+Jacobi is the
# measured strongest stall-breaker (see _krylov), bicgstab the
# independent second opinion — and VI terminal (every ipi_* step is
# safeguarded to never lose to a VI sweep, and a gamma-contraction cannot
# stagnate, so the chain always ends at something that converges).
_CHAIN = ("mpi", "ipi_gmres", "ipi_bicgstab", "vi")
_CHAIN_DET = ("mpi", "ipi_gmres", "vi")


def escalate(method: str, *, deterministic_dots: bool = False) \
        -> MethodChoice | None:
    """The next method in the stagnation escalation chain after ``method``
    (``None`` when ``method`` is terminal).  Methods outside the chain
    (chebyshev, anderson, user-registered) escalate to the chain head."""
    chain = _CHAIN_DET if deterministic_dots else _CHAIN
    try:
        i = chain.index(method)
    except ValueError:
        i = -1
    if i + 1 >= len(chain):
        return None
    nxt = chain[i + 1] if i >= 0 else chain[0]
    pc = "none"
    if nxt in ("ipi_bicgstab", "ipi_gmres"):
        # jacobi (elementwise) is cheap, deterministic-dots safe, and never
        # hurts a diagonally-dominant system (I - gamma P_pi always is)
        pc = "jacobi"
    return MethodChoice(
        nxt, "atol", pc,
        f"escalated from stagnating/diverging {method!r}")
