"""Probe phase: cheap compiled iterations that profile an MDP instance.

``-method auto`` must not guess from static metadata alone — two MDPs with
the same ``(n, m, gamma)`` can have wildly different effective contraction
(a dense-random garnet mixes in a handful of sweeps; a 5000-state chain at
the same gamma takes tens of thousands).  The probe runs a handful of VI
iterations under the never-stopping ``"probe"`` stop criterion (fixed-length
residual trace, span recorded) and distills the trace into a
:class:`ProblemProfile`:

* **contraction** — geometric mean of consecutive residual ratios over the
  tail of the probe trace: the *observed* per-iteration decay rate, which is
  the effective discount of the instance (<= gamma; equality for
  worst-case-mixing chains).
* **span_ratio** — ``sp(T v - v) / ||T v - v||_inf`` at the probe end: a
  near-zero ratio means the residual is almost a constant vector — the
  long-mixing regime where span stopping certifies far earlier than atol.
* **converged** — the probe alone already met ``opts.atol`` (tiny / easy
  instances: any method finishes instantly; pick the cheapest).

The probe value vector is returned so the main solve warm-starts from it —
the probe iterations are never thrown away.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import driver as _driver
from repro.core.ipi import IPIOptions

_TINY = 1e-30


@dataclasses.dataclass(frozen=True)
class ProblemProfile:
    """What the probe learned about one MDP instance."""

    n: int                   # global state count
    gamma: float             # declared discount
    iters: int               # probe outer iterations actually run
    res0: float              # residual at k = 0
    res: float               # residual at probe end
    contraction: float       # observed per-iteration residual decay rate
    span_ratio: float        # sp(T v - v) / ||T v - v||_inf at probe end
    converged: bool          # probe already satisfied opts.atol

    def summary(self) -> str:
        return (f"n={self.n} gamma={self.gamma} probe_iters={self.iters} "
                f"contraction={self.contraction:.6f} "
                f"span_ratio={self.span_ratio:.3e} res={self.res:.3e}"
                + (" CONVERGED" if self.converged else ""))


def estimate_contraction(trace: np.ndarray) -> float:
    """Geometric mean of consecutive residual ratios over the tail half of
    the trace (the head is polluted by the v0 transient).  Returns 0.0 for
    traces too short (or too converged) to measure."""
    tr = np.asarray(trace, dtype=float)
    tr = tr[np.isfinite(tr)]
    if tr.size < 2:
        return 0.0
    with np.errstate(divide="ignore", invalid="ignore"):
        ratios = tr[1:] / np.maximum(tr[:-1], _TINY)
    ratios = ratios[np.isfinite(ratios) & (ratios > 0)]
    if ratios.size == 0:
        return 0.0
    tail = ratios[ratios.size // 2:]
    return float(np.exp(np.mean(np.log(np.maximum(tail, _TINY)))))


def probe(mdp, opts: IPIOptions, *, probe_iters: int = 8, mesh=None,
          layout: str = "1d", v0=None):
    """Run the probe and return ``(profile, v_probe)``.

    ``v_probe`` is the value iterate at probe end (true-``n`` length) — pass
    it as the main solve's ``v0`` so the probe work is reused.  The probe
    always runs plain VI (no inner solves, no preconditioner): its cost is
    ``probe_iters`` Bellman backups, the cheapest compiled iterations
    available, and its program is shared with any later VI solve.
    """
    k = max(int(probe_iters), 2)
    popts = dataclasses.replace(
        opts, method="vi", stop_criterion="probe",
        max_outer=min(k, opts.max_outer), pc_type="none", monitor=False)
    r = _driver.solve(mdp, popts, mesh=mesh, layout=layout, v0=v0,
                      chunk=popts.max_outer)
    res = float(r.residual)
    res0 = float(r.trace_residual[0]) if len(r.trace_residual) else res
    span = float(r.span)
    span_ratio = span / max(res, _TINY) if np.isfinite(span) else 1.0
    profile = ProblemProfile(
        n=int(mdp.n_global), gamma=float(mdp.gamma),
        iters=int(r.outer_iterations), res0=res0, res=res,
        contraction=estimate_contraction(r.trace_residual),
        span_ratio=span_ratio,
        converged=bool(np.isfinite(res) and res <= opts.atol))
    return profile, r.v
