"""Adaptive solve driver: probe -> select -> supervised solve -> hot-swap.

``solve_adaptive`` is what ``-method auto`` resolves to (and what
``-adapt_on_stagnation`` wraps around a fixed method): it owns the
checkpoint directory the hot-swap path resumes through, so a swap continues
from the CURRENT :class:`~repro.core.ipi.SolveState` — iterate, iteration
count, residual traces — rather than restarting from scratch.  Checkpoints
are method-agnostic by design (``_restore_or_init`` validates only the
problem identity), which is exactly what makes cross-method resume work.

The flow:

1. **probe** (virtual methods only): a few compiled VI iterations distill a
   :class:`~repro.adaptive.probe.ProblemProfile`; the probe iterate
   warm-starts the main solve.
2. **select**: the rule table picks (method, stop criterion, preconditioner)
   — or the caller's fixed method is kept, supervised.
3. **supervised solve**: :func:`repro.core.driver.solve` runs with a
   :class:`~repro.adaptive.supervisor.StagnationSupervisor` installed
   (unless the current method is terminal in the escalation chain).
4. **hot-swap**: on stagnation or divergence the solve is interrupted, the
   checkpoint is re-armed (sticky ``diverged`` flag cleared, ``res0`` reset
   so ``-divtol`` measures from the resume point; a NaN-poisoned state is
   discarded instead — resuming NaNs is worse than restarting), and the
   loop re-enters under the escalated method.  At most ``max_swaps``
   escalations; the chain terminates at VI, which cannot stagnate.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile

import numpy as np

from repro.adaptive import rules as _rules
from repro.adaptive.probe import probe as _probe, ProblemProfile
from repro.adaptive.rules import MethodChoice
from repro.adaptive.supervisor import StagnationSupervisor
from repro.core import driver as _driver
from repro.core import ipi as _ipi
from repro.core import methods as _methods
from repro.core.driver import SolveResult
from repro.utils import checkpoint as _ckpt

__all__ = ["AdaptiveReport", "solve_adaptive"]


@dataclasses.dataclass
class AdaptiveReport:
    """What the adaptive layer decided and why (lands in session stats)."""

    profile: ProblemProfile | None      # None when -method was concrete
    choice: MethodChoice | None         # initial selection (virtual only)
    methods: list                       # concrete methods actually run
    swaps: list                         # one dict per hot-swap event
    probe_iters: int = 0

    def as_dict(self) -> dict:
        return dict(
            profile=dataclasses.asdict(self.profile)
            if self.profile is not None else None,
            choice=dataclasses.asdict(self.choice)
            if self.choice is not None else None,
            methods=list(self.methods), swaps=list(self.swaps),
            probe_iters=int(self.probe_iters))


def _rearm_checkpoint(ckpt_dir: str) -> bool:
    """Prepare the newest checkpoint for a cross-method resume: clear the
    sticky ``diverged`` flag and reset ``res0`` to the current residual so
    the divergence guard re-arms relative to the resume point.  A
    NaN-poisoned state is discarded (checkpoint files removed) so the next
    method restarts clean.  Returns True when a resumable state remains."""
    step = _ckpt.latest_step(ckpt_dir)
    if step is None:
        return False
    like = _ipi.SolveState(
        v=0, tv=0, pi=0, res=0, k=0, inner_total=0, trace_res=0,
        trace_inner=0, res0=0, span=0, done=0, diverged=0, n_true=0, win=0)
    restored = _ckpt.restore(ckpt_dir, like)
    if restored is None:
        return False
    tree, step, meta = restored
    res = np.asarray(tree.res)
    v = np.asarray(tree.v)
    if np.isnan(res).any() or np.isnan(v).any():
        for f in os.listdir(ckpt_dir):
            if f.startswith("step_"):
                os.unlink(os.path.join(ckpt_dir, f))
        return False
    tree = dataclasses.replace(
        tree,
        diverged=np.zeros_like(np.asarray(tree.diverged), dtype=bool),
        res0=np.maximum(np.asarray(tree.res0, dtype=np.float32),
                        res.astype(np.float32)))
    _ckpt.save(ckpt_dir, step, tree, meta=meta)
    return True


def solve_adaptive(mdp, opts: _ipi.IPIOptions, *, mesh=None,
                   layout: str = "1d", v0=None, probe_iters: int = 8,
                   choice: MethodChoice | None = None,
                   supervise: bool = True, max_swaps: int = 3,
                   checkpoint_dir: str | None = None, chunk: int = 64,
                   verbose: bool = False, monitor=None):
    """Adaptively solve one (core) MDP; returns ``(result, report)``.

    ``opts.method`` may be virtual (``"auto"`` — probed and resolved here)
    or concrete (kept, but supervised for stagnation when ``supervise``).
    ``choice`` short-circuits the probe with a previously-selected
    :class:`MethodChoice` (the session's per-family cache).
    ``checkpoint_dir`` doubles as the hot-swap resume channel; when unset a
    private temporary directory is used and removed afterwards.
    """
    report = AdaptiveReport(profile=None, choice=None, methods=[], swaps=[])
    spec = _methods.get_method(opts.method)
    cur = opts
    if spec.virtual:
        if choice is None:
            report.profile, v_probe = _probe(
                mdp, opts, probe_iters=probe_iters, mesh=mesh,
                layout=layout, v0=v0)
            report.probe_iters = report.profile.iters
            choice = _rules.select_method(
                report.profile,
                deterministic_dots=opts.deterministic_dots)
            v0 = v_probe
            if verbose:
                print("[adaptive] " + _rules.explain(
                    report.profile,
                    deterministic_dots=opts.deterministic_dots))
        report.choice = choice
        cur = dataclasses.replace(
            opts, method=choice.method,
            stop_criterion=choice.stop_criterion,
            pc_type=choice.pc_type if opts.pc_type == "none"
            else opts.pc_type)
        if verbose:
            print(f"[adaptive] selected {choice.summary()}")

    own_ckpt = checkpoint_dir is None
    ckpt_dir = checkpoint_dir
    gamma = float(mdp.gamma)
    try:
        swaps = 0
        while True:
            nxt = _rules.escalate(
                cur.method, deterministic_dots=cur.deterministic_dots)
            sup = None
            if supervise and nxt is not None and swaps < max_swaps:
                sup = StagnationSupervisor(gamma, atol=cur.atol)
            if sup is not None and ckpt_dir is None:
                # the private checkpoint stream only exists to carry
                # SolveState across a hot-swap; with checkpoint_mode
                # "interrupt" it is written exactly once — at the trigger —
                # so supervised solves pay no per-chunk save.  A caller
                # checkpoint_dir keeps the per-chunk fault-tolerance
                # contract.
                ckpt_dir = tempfile.mkdtemp(prefix="madupite_adapt_")
            report.methods.append(cur.method)
            result = _driver.solve(
                mdp, cur, mesh=mesh, layout=layout, v0=v0,
                checkpoint_dir=ckpt_dir, chunk=chunk,
                checkpoint_mode="interrupt" if own_ckpt else "chunk",
                verbose=verbose, monitor=monitor, supervisor=sup)
            v0 = None                     # later rounds resume via ckpt
            interrupted = bool(result.diverged) or \
                (sup is not None and sup.triggered)
            if result.converged or not interrupted or nxt is None \
                    or swaps >= max_swaps \
                    or result.outer_iterations >= cur.max_outer:
                break
            reason = ("diverged" if result.diverged
                      else (sup.reason if sup is not None else "supervisor"))
            resumable = _rearm_checkpoint(ckpt_dir)
            report.swaps.append(dict(
                k=int(result.outer_iterations),
                residual=float(result.residual),
                from_method=cur.method, to_method=nxt.method,
                pc_type=nxt.pc_type, reason=reason,
                resumed=bool(resumable)))
            if verbose:
                print(f"[adaptive] hot-swap at k="
                      f"{result.outer_iterations}: {cur.method} -> "
                      f"{nxt.method} (pc={nxt.pc_type}) — {reason}"
                      + ("" if resumable else " [state discarded: NaN]"))
            cur = dataclasses.replace(
                cur, method=nxt.method, pc_type=nxt.pc_type)
            swaps += 1
    finally:
        if own_ckpt and ckpt_dir is not None:
            shutil.rmtree(ckpt_dir, ignore_errors=True)
    return result, report
