"""Mid-solve supervisor: detect stagnation / divergence between chunks.

The driver calls the supervisor once per completed run chunk with the
control tuple it already fetched (``{"k", "res", "k_prev", "res_prev",
"diverged"}``) — zero extra device syncs.  The supervisor computes the
observed per-iteration residual decay rate over the chunk and compares it
to the instance's discount: a healthy Krylov/MPI solve decays *much* faster
than gamma per outer iteration, while a safeguard-crawling one (Chebyshev
on a mis-bracketed spectrum, GMRES stalling at a restart) degenerates to
exactly the VI rate — paying full inner-solve cost for plain-backup
progress.  That is the hot-swap trigger: the solve is interrupted (its
state is already checkpointed) and resumed under the next method in the
escalation chain (:func:`repro.adaptive.rules.escalate`).

This generalizes the Chebyshev ``divtol`` bail-out template: divergence
(residual past ``-divtol`` x initial, or NaN) interrupts the compiled loop
on its own via the sticky ``SolveState.diverged`` flag; stagnation — the
subtler failure — is caught here.
"""

from __future__ import annotations

import numpy as np

__all__ = ["StagnationSupervisor"]

_TINY = 1e-30


class StagnationSupervisor:
    """Between-chunks callable for ``driver.solve(supervisor=...)``.

    Triggers (returns True, interrupting the solve) when the observed
    per-iteration residual decay rate over the last chunk is no better than
    ``gamma ** margin`` — i.e. the method is making at best VI-rate
    progress while paying its full inner-solve cost.  ``margin`` > 1 sets
    the threshold slightly *below* gamma so a crawl at exactly the VI rate
    is caught (default 1.1: for gamma=0.999 the threshold is ~0.9989).

    ``patience`` is how many CONSECUTIVE crawling chunks it takes to
    declare stagnation (healthy chunks reset the streak).  f32 sup-norm
    residuals are quantized, so a converging solve routinely shows single
    chunks with decay rate exactly 1.0 — the residual sits on one f32
    value for a chunk, then drops (measured on the gamma=0.9999 chain:
    isolated flat chunks amid a healthy 0.995/iter decay).  A genuine
    stall (GMRES pinned at a restart, a mis-bracketed Chebyshev) crawls
    for *every* subsequent chunk, so patience > 1 costs only
    ``(patience - 1) * chunk`` extra iterations before the hot-swap.

    Solves already within ``4 * atol`` of the target never trigger —
    rounding-plateau noise near convergence is not stagnation.
    """

    def __init__(self, gamma: float, *, atol: float = 0.0,
                 margin: float = 1.1, patience: int = 2):
        self.threshold = float(min(max(gamma, 0.0), 1.0 - 1e-9)) ** margin
        self.atol = float(atol)
        self.patience = max(int(patience), 1)
        self.triggered = False
        self.reason = ""
        self.rate = None          # last observed per-iteration decay rate
        self._streak = 0          # consecutive crawling chunks so far

    def __call__(self, info: dict) -> bool:
        if info.get("diverged"):
            self.triggered = True
            self.reason = "diverged (residual past -divtol x initial)"
            return True
        dk = int(info["k"]) - int(info["k_prev"])
        res, res_prev = float(info["res"]), float(info["res_prev"])
        if dk <= 0 or not np.isfinite(res) or not np.isfinite(res_prev):
            return False
        if res <= max(self.atol * 4.0, 0.0):
            return False          # converging plateau, not stagnation
        self.rate = (res / max(res_prev, _TINY)) ** (1.0 / dk)
        if self.rate >= self.threshold:
            self._streak += 1
            if self._streak >= self.patience:
                self.triggered = True
                self.reason = (f"stagnation: residual decay "
                               f"{self.rate:.6f}/iter >= threshold "
                               f"{self.threshold:.6f} over {self._streak} "
                               f"consecutive chunks")
                return True
        else:
            self._streak = 0
        return False
