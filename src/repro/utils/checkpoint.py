"""Generic fault-tolerant checkpointing for pytrees.

Design for scale (see DESIGN.md §3): checkpoints are *mesh-agnostic* — leaves
are saved as full (unsharded) arrays plus a JSON-serializable manifest, so a
restarted job may re-shard onto a different mesh (elastic restart after node
loss).  Mesh-agnostic also means mesh-padding-agnostic: callers persist the
*unpadded* truth (the solver driver trims its state to the true state count
``n`` and fleet size ``B`` before saving, and zero-pads after restoring),
because padded shapes depend on the mesh that wrote them — n=500 pads to 504
on 8 state shards but to 500 on 4, and a B=5 fleet pads to 8 on a 4-way
fleet axis.  Writes are atomic (tmp + rename); the newest complete step
wins; a corrupt/partial newest step is skipped (crash-during-write
tolerance).  At real 1000-node scale the same layout would be written as
per-host tiles + manifest; the single-process container writes one file.

``restore(like=...)`` only uses ``like`` for its tree *structure* (leaf
count / treedef) — leaf shapes come from the file, so ``jax.eval_shape``
output works as ``like`` and restored leaves may be smaller than the
running job's padded shapes.
"""

from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np

_MANIFEST = "manifest.json"


def save(ckpt_dir: str, step: int, tree, meta: dict | None = None) -> str:
    """Atomically persist ``tree`` (any pytree of arrays/scalars) at ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(x)) for i, x in
              enumerate(leaves)}
    payload = dict(step=int(step), treedef=str(treedef),
                   n_leaves=len(leaves), meta=meta or {})
    final = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, __manifest__=json.dumps(payload), **arrays)
    os.replace(tmp, final)  # atomic on POSIX
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(int(f[len("step_"):-len(".npz")])
                   for f in os.listdir(ckpt_dir)
                   if f.startswith("step_") and f.endswith(".npz"))
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like, step: int | None = None):
    """Restore into the structure of ``like``. Returns ``(tree, step, meta)``
    or ``None`` if no (valid) checkpoint exists.  Walks backwards past
    corrupt files (torn writes)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(int(f[len("step_"):-len(".npz")])
                   for f in os.listdir(ckpt_dir)
                   if f.startswith("step_") and f.endswith(".npz"))
    if step is not None:
        steps = [s for s in steps if s == step]
    for s in reversed(steps):
        path = os.path.join(ckpt_dir, f"step_{s:010d}.npz")
        try:
            with np.load(path, allow_pickle=False) as z:
                payload = json.loads(str(z["__manifest__"]))
                leaves_like, treedef = jax.tree_util.tree_flatten(like)
                if payload["n_leaves"] != len(leaves_like):
                    # A VALID checkpoint whose pytree structure differs from
                    # the running code (e.g. a release that grew the solver
                    # state): silently skipping would reinitialize from
                    # scratch and throw away the run's progress — surface it.
                    raise _StructureMismatch(
                        f"checkpoint {path!r} holds {payload['n_leaves']} "
                        f"leaves but this run's state has "
                        f"{len(leaves_like)}: it was written by a different "
                        f"solver version or problem; resume with the "
                        f"writing version, or point checkpoint_dir at a "
                        f"fresh directory to restart from scratch")
                leaves = [z[f"leaf_{i}"] for i in range(len(leaves_like))]
            tree = jax.tree_util.tree_unflatten(treedef, leaves)
            return tree, s, payload["meta"]
        except _StructureMismatch as e:
            raise ValueError(str(e)) from None
        except Exception:  # torn write -> try older
            continue
    return None


class _StructureMismatch(Exception):
    """Internal: a readable checkpoint with the wrong leaf count (must not
    be swallowed by the torn-write walk)."""
