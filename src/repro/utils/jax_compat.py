"""Version shims for jax API drift (this repo supports >= 0.4.37).

Keep every hasattr-branch on the jax surface here so the solver/launch
layers stay version-agnostic (mesh construction shims live in
:func:`repro.launch.mesh.mesh_kwargs`).
"""

from __future__ import annotations

import jax


def axis_size(name: str) -> int:
    """Size of a named mesh axis, callable inside ``shard_map``.

    ``jax.lax.axis_size`` only exists from jax 0.5; on 0.4.x a
    ``psum(1, name)`` over the axis constant-folds to a concrete Python
    ``int`` during tracing, which is all the callers need (static
    ppermute pair lists, window extents).
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def shard_map(fn, mesh, in_specs, out_specs):
    """``jax.shard_map`` with fallback to the pre-0.5 experimental API.

    Replication checking is disabled in both branches: the solver's
    collectives are hand-placed and several outputs (residuals, counters)
    are replicated by construction.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
