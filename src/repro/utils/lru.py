"""A small LRU cache with hit/miss/eviction counters.

The session layer and the serving subsystem both keep bounded caches of
expensive warm state (device-materialized fleet containers, compiled
program slots).  Before this module each cache was an ad-hoc dict with an
arbitrary drop order and no observability; :class:`LRUCache` gives them
one shared mechanism — least-recently-*used* eviction plus the counters
surfaced in :attr:`repro.api.Session.stats` and ``Server.stats()``.

Not thread-safe on its own: callers that share a cache across threads
(the serving scheduler) hold their own lock around access.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable, Iterator

__all__ = ["LRUCache"]

_MISSING = object()


class LRUCache:
    """Bounded mapping with least-recently-used eviction.

    ``get`` and ``put`` refresh recency and bump the ``hits`` / ``misses``
    counters; inserting past ``capacity`` evicts the least recently used
    entry (``evictions`` counts them).  ``pop`` / ``clear`` are bookkeeping
    removals and touch no counter.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"LRUCache capacity must be >= 1, got "
                             f"{capacity}")
        self.capacity = int(capacity)
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # ---- counted access ----------------------------------------------------
    def get(self, key: Hashable, default: Any = None) -> Any:
        """Counted lookup: a present key moves to most-recently-used."""
        value = self._data.get(key, _MISSING)
        if value is _MISSING:
            self.misses += 1
            return default
        self._data.move_to_end(key)
        self.hits += 1
        return value

    def put(self, key: Hashable, value: Any) -> tuple | None:
        """Insert/update ``key`` as most-recently-used.  Returns the evicted
        ``(key, value)`` pair when this push went past capacity."""
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        if len(self._data) > self.capacity:
            evicted = self._data.popitem(last=False)
            self.evictions += 1
            return evicted
        return None

    # ---- uncounted bookkeeping --------------------------------------------
    def peek(self, key: Hashable, default: Any = None) -> Any:
        """Uncounted, recency-preserving lookup."""
        return self._data.get(key, default)

    def pop(self, key: Hashable, default: Any = None) -> Any:
        return self._data.pop(key, default)

    def clear(self) -> None:
        self._data.clear()

    def keys(self):
        return list(self._data.keys())

    def items(self):
        return list(self._data.items())

    def values(self):
        return list(self._data.values())

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def __iter__(self) -> Iterator:
        return iter(list(self._data))

    # ---- observability -----------------------------------------------------
    def stats(self) -> dict:
        """Counters snapshot (what the session / server stats expose)."""
        total = self.hits + self.misses
        return {
            "size": len(self._data),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": (self.hits / total) if total else 0.0,
        }
