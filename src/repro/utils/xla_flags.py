"""Named XLA flag bundles, applied per topology at session start.

madupite ships PETSc options tables tuned per machine; the JAX analogue is
the ``XLA_FLAGS`` environment variable.  This module names a few vetted
per-topology combinations so a run can say ``-xla_flag_bundle cpu-single``
instead of exporting raw flags, and so A/B benchmarks
(``benchmarks/run.py --only kernels``) can sweep them reproducibly.

Flags must reach XLA before the backend initializes.  ``apply_bundle``
merges the bundle into ``os.environ["XLA_FLAGS"]`` (existing flags are kept;
bundle flags are appended, and XLA's last-one-wins parsing makes the bundle
take precedence on conflicts).  If the JAX backend is already up, the merge
still happens — useful for subprocess benchmarking — but a warning explains
that the current process will not see the change.
"""

from __future__ import annotations

import os
import warnings

# Each bundle: flag name -> value.  Rendered as --name=value.
BUNDLES: dict[str, dict[str, str]] = {
    # Single-core CPU solver runs (the common laptop / CI topology): stop
    # Eigen from spawning a thread pool that only adds scheduling noise at
    # nproc=1, and keep min/max IEEE-strict so argmin tie-breaks stay exact.
    "cpu-single": {
        "xla_cpu_multi_thread_eigen": "false",
        "xla_cpu_enable_fast_min_max": "false",
    },
    # Multi-core CPU hosts: default threading, strict min/max only.
    "cpu-host": {
        "xla_cpu_enable_fast_min_max": "false",
    },
    # Communication-overlapped backups on CPU (-comm_overlap): the
    # concurrency-optimized thunk scheduler lets XLA:CPU run the value-window
    # collective concurrently with the interior-row backup that does not
    # depend on it.  On TPU the same overlap needs the async-collective
    # family instead — use "tpu-collectives" there (the
    # xla_enable_async_all_gather / xla_enable_async_collective_permute
    # flags only exist in TPU-capable XLA builds and are fatal on CPU-only
    # ones, so they must not appear here).
    "cpu-overlap": {
        "xla_cpu_enable_concurrency_optimized_scheduler": "true",
        "xla_cpu_enable_fast_min_max": "false",
    },
    # TPU pods: overlap collective latency with compute — matters for the
    # state-axis all-gather before every backup and psum_state reductions.
    "tpu-collectives": {
        "xla_tpu_enable_latency_hiding_scheduler": "true",
        "xla_enable_async_all_gather": "true",
        "xla_enable_async_collective_permute": "true",
    },
    # TPU single-host: latency hiding only.
    "tpu-host": {
        "xla_tpu_enable_latency_hiding_scheduler": "true",
    },
}


def bundle_names() -> tuple[str, ...]:
    return tuple(sorted(BUNDLES))


def bundle(name: str) -> dict[str, str]:
    try:
        return dict(BUNDLES[name])
    except KeyError:
        raise KeyError(
            f"unknown XLA flag bundle {name!r}; "
            f"available: {', '.join(bundle_names())}") from None


def render(name: str) -> str:
    """The bundle as an XLA_FLAGS fragment: ``--flag=value ...``."""
    return " ".join(f"--{k}={v}" for k, v in bundle(name).items())


def backend_initialized() -> bool:
    """True if a JAX backend already exists (flags no longer take effect)."""
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:  # noqa: BLE001 - private API; absent means unknown
        return False


def merged_flags(name: str, existing: str | None = None) -> str:
    """Existing XLA_FLAGS with the bundle appended (bundle wins conflicts)."""
    fragment = render(name)
    existing = (existing if existing is not None
                else os.environ.get("XLA_FLAGS", ""))
    keep = [tok for tok in existing.split() if tok]
    # drop stale settings of the same flags so repeated applies stay idempotent
    names = {f"--{k}=" for k in bundle(name)}
    keep = [tok for tok in keep
            if not any(tok.startswith(p) for p in names)]
    return " ".join(keep + fragment.split())


def apply_bundle(name: str, *, env: dict | None = None) -> str:
    """Merge the bundle into ``env['XLA_FLAGS']`` and return the new value."""
    env = os.environ if env is None else env
    merged = merged_flags(name, env.get("XLA_FLAGS"))
    if env is os.environ and backend_initialized():
        warnings.warn(
            f"XLA flag bundle {name!r} applied after the JAX backend "
            "initialized; the current process keeps its old flags "
            "(subprocesses inherit the new ones)", stacklevel=2)
    env["XLA_FLAGS"] = merged
    return merged
