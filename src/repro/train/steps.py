"""Train / prefill / decode step builders.

``make_train_step`` returns a pure function
``(params, opt_state, step, batch) -> (params, opt_state, metrics)`` with:

  * gradient accumulation over fixed-shape microbatches (``lax.scan``) —
    bounds activation memory AND removes data-dependent shapes (no
    recompiles -> no compile-stragglers at scale);
  * per-layer remat (policy from TrainConfig) inside the model;
  * f32 (or bf16, TrainConfig.grad_dtype) gradient accumulator;
  * MoE aux losses folded in with configurable weights.

``make_prefill_step`` / ``make_decode_step`` build the serving entry points
(prefill returns the KV cache + last-position logits; decode consumes one
token against a full cache — the shapes the decode_32k / long_500k cells
lower).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.train.losses import softmax_xent


def _loss_fn(model, tcfg, params, tokens, labels, patches=None,
             unroll=False):
    if model.cfg.family == "encdec":
        hidden, _, aux = model.forward(params, tokens, frames=patches,
                                       mode="train", remat=tcfg.remat,
                                       unroll=unroll)
    else:
        hidden, _, aux = model.forward(params, tokens, patches=patches,
                                       mode="train", remat=tcfg.remat,
                                       unroll=unroll)
    w = params["embed"].T if model.cfg.tie_embeddings else params["unembed"]
    loss, _ = softmax_xent(hidden, w, labels)
    total = loss + tcfg.moe_aux * aux["load_balance_loss"] \
        + tcfg.zloss * aux["router_z_loss"]
    return total, {"loss": loss, **aux}


def make_train_step(model, tcfg, *, n_microbatches: int = 1,
                    unroll: bool = False):
    """batch: {tokens (B,T), labels (B,T) [, patches|frames (B,S,d)]}."""
    cfg = model.cfg
    acc_dt = jnp.dtype(tcfg.grad_dtype) if tcfg.grad_dtype else jnp.float32

    def train_step(params, opt_state, step, batch):
        grad_fn = jax.grad(
            functools.partial(_loss_fn, model, tcfg, unroll=unroll),
            has_aux=True)

        def micro(acc, mb):
            g, aux = grad_fn(params, mb["tokens"], mb["labels"],
                             mb.get("patches"))
            acc = jax.tree.map(lambda a, x: a + x.astype(a.dtype), acc, g)
            return acc, aux

        if n_microbatches > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape(n_microbatches,
                                    x.shape[0] // n_microbatches,
                                    *x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            grads, auxs = jax.lax.scan(micro, zeros, mbs)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            metrics = jax.tree.map(lambda a: a.mean(), auxs)
        else:
            grads, metrics = grad_fn(params, batch["tokens"],
                                     batch["labels"], batch.get("patches"))

        from repro.train.optimizer import apply_updates
        params, opt_state, gnorm = apply_updates(params, grads, opt_state,
                                                 step, tcfg)
        metrics = {**metrics, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model, *, unroll: bool = False):
    cfg = model.cfg

    def prefill_step(params, tokens, extra=None):
        kwargs = {}
        if cfg.family == "vlm":
            kwargs["patches"] = extra
        if cfg.family == "encdec":
            hidden, cache, _ = model.forward(params, tokens, frames=extra,
                                             mode="prefill", remat="none",
                                             unroll=unroll)
        else:
            hidden, cache, _ = model.forward(params, tokens, mode="prefill",
                                             remat="none", unroll=unroll,
                                             **kwargs)
        logits = model.logits(params, hidden[:, -1:])
        return logits, cache

    return prefill_step


def make_decode_step(model, *, unroll: bool = False):
    def decode_step(params, token, cache):
        hidden, cache, _ = model.forward(params, token, mode="decode",
                                         cache=cache, remat="none",
                                         unroll=unroll)
        logits = model.logits(params, hidden)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache

    return decode_step
