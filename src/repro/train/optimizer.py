"""Optimizers: Adam(W) and Adafactor, both with f32 master weights.

Memory profile per parameter (bytes), the number that decides which archs
fit a 16 GB v5e chip (DESIGN.md §5 / EXPERIMENTS.md):

  adam:      2 (bf16 param) + 4 (master) + 4 (m) + 4 (v)  = 14
  adafactor: 2 (bf16 param) + 4 (master) + ~0 (factored)  = ~6

Optimizer state inherits the parameter sharding spec (ZeRO-3 by
construction).  ``grad_dtype`` in TrainConfig compresses the grad-accum
buffer (bf16 accumulation halves accumulator HBM at <1e-3 relative error on
summed gradients — recorded as a distributed-optimization trick, default on
only for the accumulation buffer, never for the update math).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_factorable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128


def init_opt_state(params, tcfg):
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    if tcfg.optimizer == "adam":
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"master": master,
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}
    if tcfg.optimizer == "adafactor":
        def vrow(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) \
                if _is_factorable(p.shape) else jnp.zeros(p.shape, jnp.float32)

        def vcol(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
                if _is_factorable(p.shape) else jnp.zeros((), jnp.float32)
        return {"master": master,
                "vr": jax.tree.map(vrow, params),
                "vc": jax.tree.map(vcol, params)}
    raise ValueError(tcfg.optimizer)


def _schedule(step, tcfg):
    warmup = 100.0
    return tcfg.learning_rate * jnp.minimum(1.0, (step + 1) / warmup)


def apply_updates(params, grads, opt_state, step, tcfg):
    """Returns (params, opt_state).  All update math in f32."""
    lr = _schedule(step, tcfg)
    b1, b2, eps = 0.9, 0.95, 1e-8
    wd = tcfg.weight_decay

    # global-norm clip
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.vdot(g, g)
                         for g in jax.tree.leaves(g32)).real)
    scale = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    g32 = jax.tree.map(lambda g: g * scale, g32)

    if tcfg.optimizer == "adam":
        t = step + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         opt_state["m"], g32)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         opt_state["v"], g32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if p.ndim >= 2:
                u = u + wd * p
            return p - lr * u
        master = jax.tree.map(upd, opt_state["master"], m, v)
        new_state = {"master": master, "m": m, "v": v}
    else:  # adafactor (beta1=0, factored second moment)
        d = 1 - (1.0 / (step + 2)) ** 0.8  # decay-to-one schedule

        def upd(p, g, vr, vc):
            if _is_factorable(p.shape):
                vr = d * vr + (1 - d) * (g * g).mean(-1)
                vc = d * vc + (1 - d) * (g * g).mean(-2)
                r = vr / jnp.maximum(vr.mean(-1, keepdims=True), 1e-30)
                u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                         + eps)
            else:
                vr = d * vr + (1 - d) * g * g
                u = g / (jnp.sqrt(vr) + eps)
            # update clipping (Shazeer & Stern RMS-1)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                u = u + wd * p
            return p - lr * u, vr, vc
        out = jax.tree.map(upd, opt_state["master"], g32,
                           opt_state["vr"], opt_state["vc"])
        master = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        vr = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        vc = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"master": master, "vr": vr, "vc": vc}

    dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda mp, dt: mp.astype(dt),
                              new_state["master"], dtypes)
    return new_params, new_state, gnorm
