"""Sharding-rule inference for the LM substrate.

One uniform rule set (DESIGN.md §5):

  * TP over the ``model`` axis — attention head projections, FFN hidden dim,
    MoE expert axis (EP), vocab dim of embed/unembed.
  * FSDP over the ``data`` axis — every parameter above a size threshold
    shards its largest still-unsharded dim over ``data``; optimizer states
    inherit the param spec (ZeRO-3 equivalent).  Under scan-over-layers the
    per-layer all-gathers happen inside the loop, so peak memory is one
    de-sharded layer.
  * the leading L axis of scan-stacked block params is never sharded.
  * the ``pod`` axis (multi-pod mesh) is pure DP: batch shards over
    ``(pod, data)``; params are replicated across pods (cross-pod grad
    all-reduce only).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

FSDP_THRESHOLD = 1 << 20  # params smaller than 1M entries stay unsharded

# param-name -> which logical dim gets the TP ('model') axis, counted from
# the *end* of the shape (robust to the leading L stacking axis).
# value = negative dim index.
_TP_RULES = {
    "wq": -1, "wk": -1, "wv": -1, "w_gate": -1, "w_up": -1,
    "in_proj": -1, "unembed": -1, "patch_proj": -1,
    "wo": -2, "w_down": -2, "out_proj": -2,
    "embed": -2,   # (V, d): shard vocab
}
# MoE expert tensors: shard the expert axis (EP).  These names only occur
# under a "moe" sub-tree; detected by path.
_EP_NAMES = {"w_gate", "w_up", "w_down"}


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
    return out


def param_spec(path, shape, *, model_axis="model", data_axis="data",
               model_size=1, data_size=1, fsdp: bool = True) -> P:
    names = _path_names(path)
    leaf = names[-1]
    stacked = any(n in ("blocks", "encoder", "decoder") for n in names)
    nd = len(shape)
    spec: list = [None] * nd

    is_expert = "moe" in names and leaf in _EP_NAMES
    if is_expert:
        e_dim = 1 if stacked else 0
        if shape[e_dim] % model_size == 0:
            spec[e_dim] = model_axis
    elif leaf in _TP_RULES:
        d = nd + _TP_RULES[leaf]
        if 0 <= d < nd and shape[d] % model_size == 0:
            spec[d] = model_axis

    if fsdp and int(np.prod(shape)) >= FSDP_THRESHOLD:
        # largest unsharded, divisible dim; never the L stacking axis (dim 0
        # when stacked)
        cand = [(shape[d], d) for d in range(nd)
                if spec[d] is None and not (stacked and d == 0)
                and shape[d] % data_size == 0]
        if cand:
            _, d = max(cand)
            spec[d] = data_axis
    return P(*spec)


def infer_param_specs(params_or_shapes, mesh, *, fsdp: bool = True):
    """Pytree of PartitionSpec matching ``params_or_shapes``."""
    model_size = mesh.shape.get("model", 1)
    data_size = mesh.shape.get("data", 1)

    def one(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        return param_spec(path, shape, model_size=model_size,
                          data_size=data_size, fsdp=fsdp)
    return jax.tree_util.tree_map_with_path(one, params_or_shapes)


def batch_axes(mesh):
    """Axis names over which the global batch is sharded (DP incl. pod)."""
    names = [n for n in ("pod", "data") if n in mesh.axis_names]
    return tuple(names)


def data_spec(mesh, ndim: int) -> P:
    """Spec for (B, ...) host data: batch over (pod, data)."""
    return P(batch_axes(mesh), *([None] * (ndim - 1)))


def cache_spec(cfg, mesh, batch: int):
    """Decode-cache spec: batch over DP axes if it divides, otherwise the
    *sequence* dim shards over data (the long_500k B=1 sequence-parallel
    case); KV heads over model when divisible."""
    dp = batch_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    model_size = mesh.shape.get("model", 1)
    batch_ok = dp and batch % dp_size == 0
    kv_ok = cfg.n_kv_heads % model_size == 0
    b_ax = dp if batch_ok else None
    s_ax = None if batch_ok else (dp if dp else None)
    h_ax = "model" if kv_ok and "model" in mesh.axis_names else None
    # attention caches: (L, B, S, KV, hd)
    attn = P(None, b_ax, s_ax, h_ax, None)
    # mamba caches
    conv = P(None, b_ax, None, "model") \
        if (cfg.d_inner + 2 * cfg.ssm_state) % max(model_size, 1) == 0 \
        else P(None, b_ax, None, None)
    ssm = P(None, b_ax, None, None, None)
    return dict(attn=attn, conv=conv, ssm=ssm, batch_sharded=batch_ok)


def place(tree, mesh, specs):
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)
