"""Vocab-sharded softmax cross-entropy.

The unembed matrix is TP-sharded on the vocab dim, so logits come out
(B, T, V/model) per shard; the max / logsumexp / label-pick reductions over
the sharded V dim lower to all-reduces under SPMD — the full (B, T, V)
tensor never exists unsharded on any device.  (At nemotron/minitron scale,
V=256k, that is the difference between 4.2 GB and 262 MB per microbatch —
the memory-roofline fix recorded in EXPERIMENTS.md §Perf.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(hidden, unembed, labels, *, constrain=None):
    """hidden: (B, T, d); unembed: (d, V); labels: (B, T) int32.

    Returns (mean_loss f32, n_tokens).  ``constrain`` optionally applies a
    sharding constraint to the logits (keeps XLA from un-sharding V).
    """
    logits = (hidden @ unembed).astype(jnp.float32)   # (B, T, V_shard) f32
    if constrain is not None:
        logits = constrain(logits)
    m = jax.lax.stop_gradient(logits.max(-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
    label_logit = jnp.sum(
        jnp.where(vocab_ids == labels[..., None], logits, 0.0), axis=-1)
    loss = lse - label_logit
    return loss.mean(), loss.size
