"""The warm compiled-program cache, keyed by shape bucket.

The scheduler dispatches every bucket as one compiled batched program
whose identity is fully determined by its *shape slot*: the padded state
count, the fleet-slot size (request count padded per
``-serve_slot_policy``), and the solver-option signature.  JAX owns the
compiled executables themselves (the driver's bounded run-chunk cache and
the ``solve_chunk`` jit cache); this cache is the serving layer's
accounting of **which slots are warm** — a dispatch whose slot is resident
reuses a compiled program, a miss pays a compile.

Built on the same LRU mechanism as the session's device-fleet container
cache (:class:`repro.utils.lru.LRUCache`); hits / misses / evictions
surface in ``Server.stats()["program_cache"]``.  An evicted slot is
*cold* again from the server's perspective: its next dispatch is counted
(and budgeted) as a compile.
"""

from __future__ import annotations

import threading

from repro.utils.lru import LRUCache

__all__ = ["ProgramCache", "program_key"]


def program_key(sig: tuple, n_pad: int, slot: int) -> tuple:
    """The shape-bucket identity of one dispatch: compatibility signature
    (options + mode + container family + m + nnz) x padded state count x
    fleet-slot size."""
    return (sig, int(n_pad), int(slot))


class ProgramCache:
    """Thread-safe LRU of warm program slots with per-slot dispatch counts."""

    def __init__(self, capacity: int):
        self._lru = LRUCache(capacity)
        self._lock = threading.Lock()

    def touch(self, key: tuple) -> bool:
        """Record a dispatch against ``key``; True on a warm hit, False
        when the slot was cold (compile expected)."""
        with self._lock:
            entry = self._lru.get(key)
            if entry is None:
                self._lru.put(key, {"dispatches": 1})
                return False
            entry["dispatches"] += 1
            return True

    def stats(self) -> dict:
        with self._lock:
            out = self._lru.stats()
            out["slots"] = [
                {"n_pad": k[1], "fleet_slot": k[2],
                 "dispatches": v["dispatches"]}
                for k, v in self._lru.items()]
            return out
