"""The in-process MDP solve server: ``submit / result / stream / stats /
drain`` over an owning :class:`repro.api.Session`.

    from repro.api import MDP
    from repro.serve import Server

    with Server({"-method": "vi", "-atol": 1e-8,
                 "-serve_batch_window": 0.02}) as srv:
        reqs = [srv.submit(MDP.from_generator("garnet", n=n, m=8, seed=i))
                for i, n in enumerate([500, 700, 500, 680])]
        values = [r.result().v for r in reqs]
        print(srv.stats()["program_cache"])

Many client threads submit concurrently; one scheduler thread batches
compatible arrivals into compiled fleet programs (see
:mod:`repro.serve.scheduler`).  Admission control rejects — with
actionable errors — rather than queueing unboundedly.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Iterator, Mapping

from repro.api.mdp import MDP
from repro.api.options import Options
from repro.api.session import Session
from repro.core.mdp import DenseMDP, EllMDP
from repro.serve.cache import ProgramCache
from repro.serve.queue import AdmissionError, Request, RequestQueue
from repro.serve.scheduler import Scheduler
from repro.serve.stats import Telemetry

__all__ = ["Server"]


def _mdp_family(mdp: MDP) -> tuple:
    """The container part of the compatibility signature: what
    :func:`repro.core.mdp.stack_mdps` can stack into one program.  ELL
    instances batch across state counts (padded); dense ones only at
    equal ``n`` (so ``n`` joins the dense signature)."""
    if mdp.deferred:
        return ("ell", mdp._spec.m, mdp._spec.nnz)
    core = mdp._core
    if isinstance(core, EllMDP):
        return ("ell", core.m_global, core.nnz_per_row)
    return ("dense", core.m_global, core.n_global)


class Server:
    """A persistent batched solve service over one :class:`Session`.

    ``options`` seeds a server-owned session (closed with the server);
    alternatively pass an existing ``session`` whose options — including
    the ``-serve_*`` keys — configure the server (the caller keeps
    ownership and closes it).  The scheduler thread starts immediately.
    """

    def __init__(self, options: Options | Mapping[str, Any] | None = None,
                 *, session: Session | None = None):
        if session is not None and options is not None:
            raise ValueError("pass options OR an existing session, not "
                             "both (a provided session's options already "
                             "configure the server)")
        self._own_session = session is None
        self._session = session if session is not None else Session(options)
        opts = self._session.options
        self._queue = RequestQueue(opts.get("-serve_max_queue"),
                                   opts.get("-serve_max_states"))
        self._cache = ProgramCache(opts.get("-serve_program_cache"))
        self._telemetry = Telemetry()
        self._scheduler = Scheduler(
            self._session, self._queue, self._cache, self._telemetry,
            window=opts.get("-serve_batch_window"),
            max_batch=opts.get("-serve_max_batch"),
            slot_policy=opts.get("-serve_slot_policy"),
            bucketing=opts.get("-fleet_bucketing"))
        self._requests: weakref.WeakValueDictionary = \
            weakref.WeakValueDictionary()
        self._closed = False
        self._scheduler.start()

    # ---- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def session(self) -> Session:
        return self._session

    def drain(self, timeout: float | None = None) -> bool:
        """Graceful wind-down: reject new submits, finish every queued and
        in-flight bucket.  True when the server went quiescent within
        ``timeout`` (None = wait indefinitely)."""
        return self._scheduler.drain(timeout)

    def close(self, timeout: float | None = None) -> None:
        """Drain, stop the scheduler thread, release the owned session.
        Requests still queued after a ``timeout``-bounded drain fail with
        ``AdmissionError('closed')``."""
        if self._closed:
            return
        self._closed = True
        self._scheduler.drain(timeout)
        self._scheduler.stop()
        leftovers = self._queue.drain_all()
        if leftovers:
            self._telemetry.on_fail(len(leftovers))
            for r in leftovers:
                r._fail(AdmissionError(
                    "closed", f"server closed before request {r.id} was "
                              f"dispatched"))
        if self._own_session:
            self._session.close()

    # ---- the client surface ------------------------------------------------
    def submit(self, mdp, *, monitor: bool = False,
               **overrides) -> Request:
        """Enqueue one solve; returns the :class:`Request` handle.

        ``overrides`` are per-request option overrides (validated against
        the options registry; keys with or without the leading dash) —
        requests batch together only when their overrides, mode and
        container family match.  ``monitor=True`` opens the per-request
        convergence-record stream read by :meth:`stream`.

        Raises :class:`AdmissionError` (``reason`` of ``queue_full`` /
        ``too_large`` / ``draining`` / ``closed``) instead of queueing
        unboundedly.
        """
        if self._closed:
            self._reject("closed", "server is closed; create a new one")
        if self._scheduler.draining:
            self._reject("draining", "server is draining: in-flight work "
                                     "finishes, new work is rejected")
        req = self._make_request(mdp, monitor, overrides)
        try:
            self._queue.push(req)
        except AdmissionError as e:
            self._telemetry.on_reject(e.reason)
            raise
        self._telemetry.on_submit()
        self._requests[req.id] = req
        return req

    def result(self, request: Request | int,
               timeout: float | None = None):
        """Block for one request's :class:`repro.core.driver.SolveResult`
        (accepts the handle or its ``id``)."""
        return self._as_request(request).result(timeout)

    def stream(self, request: Request | int) -> Iterator[dict]:
        """Yield the request's per-iteration convergence records —
        ``{"request", "k", "res", "inner", "elapsed"}`` — as its bucket
        solves; ends when the request completes.  The stream spans the
        whole bucket's run: a lane that converges early plateaus at its
        final residual while bucket-mates finish.  The request must have
        been submitted with ``monitor=True``."""
        return self._as_request(request).records()

    def stats(self) -> dict:
        """Server telemetry: submit/reject/dispatch counters, batch sizes,
        latency quantiles, program-cache hit/miss/eviction counters, and
        the owning session's cache counters."""
        out = self._telemetry.snapshot()
        out["queue_depth"] = len(self._queue)
        out["in_flight"] = self._scheduler.in_flight_count()
        out["draining"] = self._scheduler.draining
        out["program_cache"] = self._cache.stats()
        out["session_caches"] = self._session.cache_stats
        return out

    # ---- internals ---------------------------------------------------------
    def _reject(self, reason: str, message: str) -> None:
        self._telemetry.on_reject(reason)
        raise AdmissionError(reason, message)

    def _wrap(self, mdp) -> MDP:
        if isinstance(mdp, MDP):
            pass
        elif isinstance(mdp, (EllMDP, DenseMDP)):
            mdp = MDP(mdp, mode=self._session.options.get("-mode"))
        else:
            raise TypeError(f"submit wants a repro.api.MDP (or a core "
                            f"EllMDP/DenseMDP), got {type(mdp).__name__}")
        core = mdp._core
        if core is not None and core.batch is not None:
            raise ValueError("submit takes one MDP per request (got a "
                             "batched container); the server does the "
                             "batching")
        return mdp

    def _make_request(self, mdp, monitor: bool, overrides: dict) -> Request:
        mdp = self._wrap(mdp)
        # normalize + validate the overrides now (actionable rejection at
        # submit, not a scheduler-thread failure mid-bucket)
        ov = Options(overrides).as_dict(explicit_only=True) \
            if overrides else {}
        # the dispatch deadline is serve-side QoS, not a solver option:
        # pop it BEFORE the signature is built so requests with different
        # deadlines still share a batch (the tightest one wins the linger)
        deadline_ms = ov.pop("-serve_deadline_ms",
                             self._session.options.get("-serve_deadline_ms"))
        mat = None
        if mdp.deferred:
            # resolve the pipeline at submit (per-request override, else
            # the session option): admission charges matrix-free requests
            # their O(n) footprint, and matrix-free batches only with
            # matrix-free over the identical constructor pair
            mat = mdp.materialization(
                ov.get("-mdp_materialize",
                       self._session.options.get("-mdp_materialize")))
        if mat == "matrix_free":
            # gamma-free spec: a gamma sweep batches into one fleet, while
            # different constructors/shapes (stack_mdps requires one shared
            # RowSpec) never share a bucket
            fam = ("matrix_free",
                   dataclasses.replace(mdp._spec, gamma=0.0))
        else:
            fam = _mdp_family(mdp)
        sig = (tuple(sorted(ov.items())), mdp.mode) + fam
        return Request(mdp, sig, ov, monitor=monitor, materialization=mat,
                       deadline_ms=deadline_ms)

    def _as_request(self, request: Request | int) -> Request:
        if isinstance(request, Request):
            return request
        req = self._requests.get(request)
        if req is None:
            raise KeyError(f"unknown (or garbage-collected) request id "
                           f"{request!r}; keep the Request handle submit "
                           f"returned")
        return req
