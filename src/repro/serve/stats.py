"""Thread-safe server telemetry: counters, batch shapes, latency quantiles.

One :class:`Telemetry` instance per server.  Client threads bump the
submit/reject counters, the scheduler thread the dispatch/completion ones;
``snapshot()`` renders the consistent dict ``Server.stats()`` returns.
"""

from __future__ import annotations

import threading
from collections import Counter, deque

__all__ = ["Telemetry", "percentile"]

# completed-request latencies kept for the quantile estimates (a rolling
# window so a long-lived server's stats call stays O(window))
_LATENCY_WINDOW = 4096


def percentile(xs, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a non-empty sequence."""
    xs = sorted(xs)
    if not xs:
        return float("nan")
    rank = max(0, min(len(xs) - 1, round(q / 100.0 * (len(xs) - 1))))
    return float(xs[rank])


class Telemetry:
    def __init__(self):
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected: Counter = Counter()
        self.dispatches = 0            # compiled-program launches (buckets)
        self.dispatched_requests = 0   # real requests across all dispatches
        self.padded_lanes = 0          # slot-padding duplicates solved
        self._batch_sizes: deque = deque(maxlen=_LATENCY_WINDOW)
        self._latencies: deque = deque(maxlen=_LATENCY_WINDOW)

    # ---- recording ---------------------------------------------------------
    def on_submit(self) -> None:
        with self._lock:
            self.submitted += 1

    def on_reject(self, reason: str) -> None:
        with self._lock:
            self.rejected[reason] += 1

    def on_dispatch(self, n_requests: int, n_padded: int) -> None:
        with self._lock:
            self.dispatches += 1
            self.dispatched_requests += n_requests
            self.padded_lanes += n_padded
            self._batch_sizes.append(n_requests)

    def on_complete(self, latency: float) -> None:
        with self._lock:
            self.completed += 1
            self._latencies.append(latency)

    def on_fail(self, n: int = 1) -> None:
        with self._lock:
            self.failed += n

    # ---- reporting ---------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            lat = list(self._latencies)
            sizes = list(self._batch_sizes)
            out = {
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "rejected": dict(self.rejected),
                "dispatches": self.dispatches,
                "dispatched_requests": self.dispatched_requests,
                "padded_lanes": self.padded_lanes,
            }
        out["batch"] = {
            "count": len(sizes),
            "mean_size": (sum(sizes) / len(sizes)) if sizes else 0.0,
            "max_size": max(sizes) if sizes else 0,
        }
        out["latency_s"] = {
            "count": len(lat),
            "mean": (sum(lat) / len(lat)) if lat else float("nan"),
            "p50": percentile(lat, 50) if lat else float("nan"),
            "p95": percentile(lat, 95) if lat else float("nan"),
        }
        return out
