"""Request objects and the admission-controlled request queue.

A :class:`Request` is the server-side handle for one submitted solve: its
compatibility signature (what may batch with what), the completion event
clients block on, and — when the client asked for monitoring — the stream
queue per-iteration convergence records are demultiplexed into.

The :class:`RequestQueue` is the single pending-work structure shared by
client threads (``push``) and the scheduler thread (``take_group``).
Admission control happens at ``push``: a full queue or an over-limit state
count raises :class:`AdmissionError` with an actionable message and a
machine-readable ``reason`` (``queue_full`` / ``too_large`` / ``draining``
/ ``closed``) so clients can back off, shrink, or fail over.
"""

from __future__ import annotations

import itertools
import queue as _queue
import threading
import time
from collections import deque
from typing import Any, Iterator

from repro.kernels import matrix_free

__all__ = ["AdmissionError", "Request", "RequestQueue"]

# end-of-stream sentinel pushed into a request's record queue at completion
_DONE = object()


class AdmissionError(RuntimeError):
    """A submit the server refused to accept.  ``reason`` is one of
    ``queue_full`` / ``too_large`` / ``draining`` / ``closed``."""

    def __init__(self, reason: str, message: str):
        super().__init__(message)
        self.reason = reason


_REQUEST_IDS = itertools.count()


class Request:
    """One submitted solve: a future-like handle plus its batching identity.

    ``sig`` is the compatibility signature — two requests may share a
    dispatched bucket only when their signatures match (same solver-option
    overrides, mode, container family, action count and nnz/row).
    """

    def __init__(self, mdp, sig: tuple, overrides: dict, *,
                 monitor: bool = False, materialization: str | None = None,
                 deadline_ms: float | None = None):
        self.id = next(_REQUEST_IDS)
        self.mdp = mdp
        self.sig = sig
        self.overrides = overrides
        # the resolved pipeline ("device"/"host"/"matrix_free"; None for
        # array-backed MDPs) — admission charges the *actual* footprint
        self.materialization = materialization
        self.monitor = bool(monitor)
        self.submitted = time.monotonic()
        # absolute dispatch deadline (-serve_deadline_ms): the scheduler
        # closes the batching window early rather than let this request's
        # queue wait exceed the bound.  None = the full window applies.
        self.deadline: float | None = \
            self.submitted + deadline_ms / 1e3 \
            if deadline_ms is not None else None
        self.dispatched: float | None = None
        self.completed: float | None = None
        self._event = threading.Event()
        self._result: Any = None
        self._error: BaseException | None = None
        self._records: _queue.Queue | None = \
            _queue.Queue() if monitor else None

    # ---- completion (scheduler side) ---------------------------------------
    def _complete(self, result) -> None:
        self._result = result
        self.completed = time.monotonic()
        self._event.set()
        self._end_stream()

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self.completed = time.monotonic()
        self._event.set()
        self._end_stream()

    def _push_record(self, record: dict) -> None:
        if self._records is not None:
            self._records.put(record)

    def _end_stream(self) -> None:
        if self._records is not None:
            self._records.put(_DONE)

    # ---- client side -------------------------------------------------------
    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency(self) -> float | None:
        """Submit-to-completion seconds (None while pending)."""
        if self.completed is None:
            return None
        return self.completed - self.submitted

    def result(self, timeout: float | None = None):
        """Block for the :class:`repro.core.driver.SolveResult` (re-raises
        a dispatch failure)."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.id} still pending after {timeout}s "
                f"(queued or its bucket is solving)")
        if self._error is not None:
            raise self._error
        return self._result

    def records(self) -> Iterator[dict]:
        """Yield monitor records as they stream in; ends at completion."""
        if self._records is None:
            raise ValueError(
                f"request {self.id} was submitted without monitor=True; "
                f"no stream to read")
        while True:
            rec = self._records.get()
            if rec is _DONE:
                return
            yield rec


class RequestQueue:
    """Admission-controlled FIFO shared by clients and the scheduler.

    ``cv`` is the queue's condition variable; the scheduler also uses it
    as the server-wide quiescence signal (drain waits on it until the
    queue is empty and nothing is in flight).
    """

    def __init__(self, max_depth: int, max_states: int | None):
        self.cv = threading.Condition()
        self.max_depth = int(max_depth)
        self.max_states = max_states
        self._items: deque[Request] = deque()

    def __len__(self) -> int:
        with self.cv:
            return len(self._items)

    def push(self, req: Request) -> None:
        """Admit one request or raise :class:`AdmissionError`.

        ``-serve_max_states`` names a *materialized-table byte budget*
        (the ELL table of ``max_states`` states at the request's shape):
        materialized requests are limited by state count exactly as
        before, while matrix-free requests — whose per-solve footprint is
        O(n), not O(n*m*nnz) — are admitted up to the same bytes, i.e.
        one to two orders of magnitude more states for typical shapes.
        """
        n = req.mdp.n
        if self.max_states is not None:
            if req.materialization == "matrix_free":
                spec = req.mdp._spec
                per = matrix_free.operator_bytes(1, spec.nnz)
                est = matrix_free.operator_bytes(n, spec.nnz)
                budget = matrix_free.table_bytes(
                    self.max_states, spec.m, spec.nnz)
                if est > budget:
                    raise AdmissionError(
                        "too_large",
                        f"request rejected: matrix-free solve needs "
                        f"~{est} bytes ({n} states x {per} B/state), over "
                        f"the -serve_max_states={self.max_states} byte "
                        f"budget ({budget} B — the materialized table of "
                        f"{self.max_states} states at m={spec.m}, "
                        f"nnz={spec.nnz}); this family admits up to "
                        f"{budget // per} matrix-free states — split the "
                        f"problem or raise the limit")
            elif n > self.max_states:
                raise AdmissionError(
                    "too_large",
                    f"request rejected: {n} states exceeds the per-request "
                    f"limit -serve_max_states={self.max_states}; split the "
                    f"problem, raise the limit, or — for a function-backed "
                    f"MDP — submit with -mdp_materialize matrix_free, "
                    f"whose O(n) footprint admits far more states under "
                    f"the same byte budget")
        with self.cv:
            if len(self._items) >= self.max_depth:
                raise AdmissionError(
                    "queue_full",
                    f"request rejected: queue depth {len(self._items)} is "
                    f"at -serve_max_queue={self.max_depth}; retry with "
                    f"backoff or raise the limit")
            self._items.append(req)
            self.cv.notify_all()

    # scheduler side — callers hold ``self.cv``
    def peek_oldest(self) -> Request | None:
        return self._items[0] if self._items else None

    def count_sig(self, sig: tuple) -> int:
        return sum(1 for r in self._items if r.sig == sig)

    def min_deadline(self, sig: tuple) -> float | None:
        """Tightest dispatch deadline over queued requests that would join
        a ``sig`` group (None when none carries one) — the linger early-out
        bound for deadline-aware batching."""
        ds = [r.deadline for r in self._items
              if r.sig == sig and r.deadline is not None]
        return min(ds) if ds else None

    def take_group(self, max_batch: int) -> list[Request]:
        """Pop the oldest request plus every queued request sharing its
        signature (arrival order, up to ``max_batch``).  Incompatible
        requests stay queued for the next cycle."""
        if not self._items:
            return []
        sig = self._items[0].sig
        group: list[Request] = []
        keep: deque[Request] = deque()
        for r in self._items:
            if r.sig == sig and len(group) < max_batch:
                group.append(r)
            else:
                keep.append(r)
        self._items = keep
        return group

    def drain_all(self) -> list[Request]:
        """Remove every queued request (abandoning close)."""
        with self.cv:
            out = list(self._items)
            self._items.clear()
            return out
