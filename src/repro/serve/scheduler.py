"""The batching scheduler: one background thread turning queued requests
into compiled fleet dispatches.

Loop shape (saxml-style continuous batching over shape buckets):

1. **Linger** — when the queue is non-empty, wait until the oldest request
   has aged ``-serve_batch_window`` seconds (or a full batch of compatible
   requests is queued, or the server is draining) so concurrent arrivals
   coalesce.
2. **Group** — pop the oldest request plus every queued request sharing
   its compatibility signature (solver-option overrides + mode +
   container family + action count + nnz/row), up to ``-serve_max_batch``.
3. **Bucket** — split the group by state count with the same pad-waste
   rule ``Session.solve_fleet`` uses (:func:`repro.api.fleet.
   bucket_indices`), then pad each bucket's request count up to its fleet
   slot (``-serve_slot_policy``) with duplicate lanes so program shapes
   repeat across traffic levels.
4. **Dispatch** — one ``solve_fleet`` program per bucket through the
   owning :class:`repro.api.Session` (which places it on the session mesh
   — fleet-sharded over >1 device), demultiplexing per-request results
   and per-iteration monitor records back to the submitting clients in
   input order.

Everything JAX-facing runs on this one thread; clients only touch their
request handles (events + record queues), so no JAX state is shared
across threads.
"""

from __future__ import annotations

import threading
import time

from repro.api.fleet import bucket_indices
from repro.serve.cache import ProgramCache, program_key
from repro.serve.queue import Request, RequestQueue
from repro.serve.stats import Telemetry

__all__ = ["Scheduler", "slot_size"]

# granularity of the linger poll: arrivals notify the condition variable,
# so this only bounds how late a max-batch early-dispatch can trigger
_POLL_S = 0.005


def slot_size(n_requests: int, policy: str, cap: int) -> int:
    """Fleet-slot size for a bucket of ``n_requests`` requests.

    ``mid2`` (default) rounds up on the power-of-two-with-midpoints grid
    ``1, 2, 3, 4, 6, 8, 12, 16, 24, ...`` — two program shapes per octave,
    duplicate-lane waste capped at 1/3 of the slot (plain pow2 wastes up
    to 1/2).  ``pow2`` is the classic grid; ``exact`` compiles one program
    per distinct request count (best for steady repeated workloads).
    Capped at ``-serve_max_batch``."""
    if policy == "exact":
        return n_requests
    s = 1
    while s < n_requests:
        mid = s + s // 2
        if policy == "mid2" and mid >= n_requests:
            s = mid
            break
        s *= 2
    return min(s, max(cap, n_requests))


class Scheduler:
    """Owns the scheduler thread; the server delegates drain/stop to it."""

    def __init__(self, session, queue: RequestQueue, cache: ProgramCache,
                 telemetry: Telemetry, *, window: float, max_batch: int,
                 slot_policy: str, bucketing: str):
        self._session = session
        self._queue = queue
        self._cache = cache
        self._telemetry = telemetry
        self._window = float(window)
        self._max_batch = int(max_batch)
        self._slot_policy = slot_policy
        self._bucketing = bucketing
        self._stop = False
        self._draining = False
        self._in_flight = 0                  # guarded by queue.cv
        self._thread = threading.Thread(
            target=self._run, name="madupite-serve-scheduler", daemon=True)

    # ---- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        self._thread.start()

    @property
    def draining(self) -> bool:
        return self._draining

    def in_flight_count(self) -> int:
        with self._queue.cv:
            return self._in_flight

    def drain(self, timeout: float | None = None) -> bool:
        """Reject new work (server-side), finish queued + in-flight
        buckets.  True when the server went quiescent within ``timeout``."""
        self._draining = True
        with self._queue.cv:
            self._queue.cv.notify_all()
            return self._queue.cv.wait_for(
                lambda: not self._queue.peek_oldest()
                and self._in_flight == 0,
                timeout)

    def stop(self, timeout: float | None = None) -> None:
        """Stop the thread (no new dispatches; an in-flight bucket
        finishes).  Call :meth:`drain` first for a graceful shutdown."""
        self._draining = True
        self._stop = True
        with self._queue.cv:
            self._queue.cv.notify_all()
        if self._thread.is_alive():
            self._thread.join(timeout)

    # ---- the scheduler loop ------------------------------------------------
    def _run(self) -> None:
        q = self._queue
        while True:
            with q.cv:
                while q.peek_oldest() is None and not self._stop:
                    q.cv.wait(0.1)
                if self._stop:
                    return
                oldest = q.peek_oldest()
                sig, deadline = oldest.sig, oldest.submitted + self._window
            self._linger(sig, deadline)
            if self._stop:
                return                     # leftovers fail at close()
            with q.cv:
                group = q.take_group(self._max_batch)
                self._in_flight += len(group)
            if not group:
                continue
            try:
                self._dispatch_group(group)
            finally:
                with q.cv:
                    self._in_flight -= len(group)
                    q.cv.notify_all()

    def _linger(self, sig: tuple, deadline: float) -> None:
        """The batching window: hold dispatch until the window closes, a
        full compatible batch is queued, the group's tightest per-request
        deadline (``-serve_deadline_ms``) arrives, or the server is
        draining.  The deadline is re-read every poll: a later arrival
        with a tighter bound shortens the wait for the whole group."""
        q = self._queue
        while not (self._stop or self._draining):
            with q.cv:
                if q.count_sig(sig) >= self._max_batch:
                    return
                dl = q.min_deadline(sig)
                eff = deadline if dl is None else min(deadline, dl)
                remaining = eff - time.monotonic()
                if remaining <= 0:
                    return
                q.cv.wait(min(remaining, _POLL_S))

    # ---- dispatch ----------------------------------------------------------
    def _dispatch_group(self, group: list[Request]) -> None:
        try:
            buckets = bucket_indices([r.mdp.n for r in group],
                                     policy=self._bucketing)
        except Exception as e:  # noqa: BLE001 — fail the group, not the loop
            self._fail(group, e)
            return
        for idx in buckets:
            batch = [group[i] for i in idx]
            try:
                self._dispatch_bucket(batch)
            except Exception as e:  # noqa: BLE001
                self._fail(batch, e)

    def _dispatch_bucket(self, batch: list[Request]) -> None:
        now = time.monotonic()
        for r in batch:
            r.dispatched = now
        n_pad = max(r.mdp.n for r in batch)
        slot = slot_size(len(batch), self._slot_policy, self._max_batch)
        n_dup = slot - len(batch)
        # duplicate lanes keep the program shape at the slot size; their
        # results are dropped (they re-solve batch[0]'s MDP)
        mdps = [r.mdp for r in batch] + [batch[0].mdp] * n_dup
        self._cache.touch(program_key(batch[0].sig, n_pad, slot))
        self._telemetry.on_dispatch(len(batch), n_dup)
        overrides = {k.lstrip("-"): v for k, v in batch[0].overrides.items()}
        # grouping/bucketing already happened here; the session must treat
        # the dispatched slot as ONE compiled program
        overrides["fleet_bucketing"] = "off"
        results = self._session.solve_fleet(
            mdps, monitor=self._demux(batch), **overrides)
        for req, res in zip(batch, results):
            req._complete(res)
            self._telemetry.on_complete(req.latency)

    def _demux(self, batch: list[Request]):
        """Per-bucket monitor callback forwarding each lane's row of the
        fleet record to its request's stream, tagged with the request id.
        None when nobody in the bucket asked for monitoring."""
        lanes = [(i, r) for i, r in enumerate(batch) if r.monitor]
        if not lanes:
            return None

        def forward(rec: dict) -> None:
            res, inner = rec["res"], rec["inner"]
            if not isinstance(res, list):
                res, inner = [res], [inner]
            for lane, req in lanes:
                if lane < len(res):
                    req._push_record({
                        "request": req.id, "k": rec["k"],
                        "res": res[lane], "inner": inner[lane],
                        "elapsed": rec["elapsed"]})

        return forward

    def _fail(self, requests: list[Request], exc: Exception) -> None:
        self._telemetry.on_fail(len(requests))
        for r in requests:
            r._fail(exc)
