"""Solve-as-a-service: a batched MDP serving subsystem over ``Session``.

A :class:`Server` is a persistent in-process service accepting solve
requests from many concurrent clients.  Requests pass admission control
(queue depth, per-request state-count limits), coalesce in a background
scheduler that dynamically batches compatible arrivals — same solver
options, same container family, state counts grouped by the fleet
pad-waste rule — inside a ``-serve_batch_window`` linger, and dispatch as
one compiled ``solve_many`` program per shape bucket through the owning
:class:`repro.api.Session`.  Per-request results and per-iteration
``-monitor`` records are demultiplexed back to the submitting clients in
input order; a warm compiled-program cache keyed by shape bucket reports
hit/miss/eviction counters in ``Server.stats()``.

    from repro.serve import Server
    with Server({"-method": "vi", "-serve_batch_window": 0.02}) as srv:
        req = srv.submit(mdp, monitor=True)
        for rec in srv.stream(req):
            print(rec)
        result = req.result()

The CLI entry point is ``python -m repro.launch.serve``.
"""

from repro.serve.cache import ProgramCache, program_key
from repro.serve.queue import AdmissionError, Request, RequestQueue
from repro.serve.scheduler import Scheduler, slot_size
from repro.serve.server import Server
from repro.serve.stats import Telemetry, percentile

__all__ = [
    "AdmissionError",
    "ProgramCache",
    "Request",
    "RequestQueue",
    "Scheduler",
    "Server",
    "Telemetry",
    "percentile",
    "program_key",
    "slot_size",
]
