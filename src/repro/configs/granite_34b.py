"""granite-34b [dense]: 88L llama-arch code model, MQA (kv=1). [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab_size=49152, d_head=128, mlp_type="swiglu")

TRAIN = TrainConfig(optimizer="adam", microbatch=1)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab_size=97, d_head=16, mlp_type="swiglu", attn_chunk=16,
    dtype="float32")
