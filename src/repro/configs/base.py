"""Config dataclasses + the architecture/shape registry."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0             # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"    # swiglu | relu2 | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False     # arctic: dense MLP in parallel with MoE
    moe_group_size: int = 512
    capacity_factor: float = 1.25
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    expand: int = 2
    d_conv: int = 4
    head_p: int = 64                 # mamba2 head dim
    ssm_chunk: int = 128
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0       # apply shared attn+mlp block every k layers
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_len: int = 1500
    # --- vlm (llava) ---
    n_patches: int = 0               # anyres patch embeddings prepended
    # --- common ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    attn_chunk: int = 1024           # flash-scan KV chunk
    dtype: str = "bfloat16"          # param/activation dtype
    tie_embeddings: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def ssm_heads(self) -> int:
        return (self.expand * self.d_model) // self.head_p

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D roofline term)."""
        d, hd = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.mlp_type == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        moe = 0
        if self.n_experts:
            per = mlp
            moe = self.n_experts * per + d * self.n_experts
            mlp = per if self.dense_residual else 0
        if self.family == "ssm" or self.family == "hybrid":
            n, h = self.ssm_state, self.ssm_heads
            din = self.d_inner
            mamba = (d * (2 * din + 2 * n + h) + self.d_conv * (din + 2 * n)
                     + din * d + din + 3 * h)
            if self.family == "ssm":
                return emb + self.n_layers * mamba
            shared = attn + 3 * d * 8192  # zamba2 shared block (counted once)
            return emb + self.n_layers * mamba + shared
        layer = attn + mlp + moe
        if self.family == "encdec":
            enc_layer = attn + mlp
            dec_layer = 2 * attn + mlp
            return emb + self.encoder_layers * enc_layer + \
                self.n_layers * dec_layer
        return emb + self.n_layers * layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        per_expert = (3 if self.mlp_type == "swiglu" else 2) * \
            self.d_model * self.d_ff
        inactive = self.n_layers * (self.n_experts - self.top_k) * per_expert
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                   # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Trainer knobs (per arch x shape, overridable from the launcher)."""
    optimizer: str = "adam"       # adam | adafactor
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatch: int = 0           # per-device microbatch; 0 -> auto
    remat: str = "full"           # full | dots | none
    zloss: float = 1e-3
    moe_aux: float = 1e-2
    grad_dtype: str = "bfloat16"  # gradient all-reduce compression dtype
    replicate_params: bool = False  # small models: pure DP beats TP=16
                                  # (EXPERIMENTS.md §Perf P2: 3x on whisper)
