"""minitron-8b [dense]: pruned nemotron, 256k vocab. [arXiv:2407.14679]"""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
    vocab_size=256000, d_head=128, mlp_type="relu2")

TRAIN = TrainConfig(optimizer="adam", microbatch=1)

SMOKE = ModelConfig(
    name="minitron-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=199, d_head=16, mlp_type="relu2", attn_chunk=16,
    dtype="float32")
