"""arctic-480b [moe]: 128-expert top-2 MoE with parallel dense residual MLP
(Arctic's dense+MoE hybrid). Adafactor: 480B of Adam state does not fit
16 GB/chip even fully sharded (see DESIGN.md §5). [hf:Snowflake/arctic-base]"""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab_size=32000, n_experts=128, top_k=2, dense_residual=True,
    moe_group_size=512, mlp_type="swiglu")

TRAIN = TrainConfig(optimizer="adafactor", microbatch=1)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=97, n_experts=8, top_k=2, dense_residual=True,
    moe_group_size=32, mlp_type="swiglu", attn_chunk=16, dtype="float32")
