"""mamba2-130m [ssm]: pure SSD (state-space duality), attention-free.
All four shapes incl. long_500k run. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12, d_ff=0,
    vocab_size=50280, ssm_state=128, expand=2, head_p=64)

TRAIN = TrainConfig(optimizer="adam", microbatch=4, replicate_params=True)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=97, ssm_state=16, expand=2, head_p=16, ssm_chunk=8,
    dtype="float32")
