"""llava-next-34b [vlm]: 34B decoder backbone; anyres vision frontend is a
STUB (precomputed patch embeddings prepended). [hf:llava-hf/llava-v1.6]"""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, n_patches=2880, mlp_type="swiglu")

TRAIN = TrainConfig(optimizer="adam", microbatch=1)

SMOKE = ModelConfig(
    name="llava-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=97, n_patches=4, mlp_type="swiglu", attn_chunk=16,
    dtype="float32")
