"""zamba2-1.2b [hybrid]: Mamba2 backbone + ONE shared attn+MLP block
applied every 6 layers (params reused across call sites). [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, ssm_state=64, expand=2, head_p=64,
    shared_attn_every=6, mlp_type="swiglu")

TRAIN = TrainConfig(optimizer="adam", microbatch=2)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=97, ssm_state=16, expand=2, head_p=16,
    shared_attn_every=2, mlp_type="swiglu", ssm_chunk=8, attn_chunk=16,
    dtype="float32")
