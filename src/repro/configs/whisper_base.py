"""whisper-base [audio]: 6L enc + 6L dec; conv frontend STUB (precomputed
frame embeddings (B, 1500, 512)). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, encoder_layers=6, encoder_len=1500,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=51865, mlp_type="gelu")

TRAIN = TrainConfig(optimizer="adam", microbatch=8, replicate_params=True)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, encoder_layers=2, encoder_len=8,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=97, mlp_type="gelu", attn_chunk=16, dtype="float32")
