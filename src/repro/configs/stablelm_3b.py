"""stablelm-3b [dense]: GQA kv=32 (MHA), d_head=80. [hf:stabilityai]"""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab_size=50304, mlp_type="swiglu")

TRAIN = TrainConfig(optimizer="adam", microbatch=2)

SMOKE = ModelConfig(
    name="stablelm-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=97, mlp_type="swiglu", attn_chunk=16, dtype="float32")
