"""nemotron-4-15b [dense]: GQA kv=8, squared-ReLU MLP, 256k vocab.
[arXiv:2402.16819]"""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=24576,
    vocab_size=256000, d_head=128, mlp_type="relu2")

TRAIN = TrainConfig(optimizer="adam", microbatch=1)

SMOKE = ModelConfig(
    name="nemotron-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=199, d_head=16, mlp_type="relu2", attn_chunk=16,
    dtype="float32")
