"""olmoe-1b-7b [moe]: 64 experts, top-8. [arXiv:2409.02060]"""
from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab_size=50304, n_experts=64, top_k=8, moe_group_size=512,
    mlp_type="swiglu")

TRAIN = TrainConfig(optimizer="adam", microbatch=2)

SMOKE = ModelConfig(
    name="olmoe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
    vocab_size=97, n_experts=8, top_k=4, moe_group_size=32,
    mlp_type="swiglu", attn_chunk=16, dtype="float32")
