"""Architecture registry: ``--arch <id>`` -> config module.

Each module defines CONFIG (exact assigned dims), TRAIN (trainer knobs
tuned to fit 16 GB/chip on the production mesh) and SMOKE (reduced
same-family config for CPU tests).
"""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig, SHAPES

ARCHS = {
    "zamba2-1.2b": "zamba2_1_2b",
    "llava-next-34b": "llava_next_34b",
    "arctic-480b": "arctic_480b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mamba2-130m": "mamba2_130m",
    "whisper-base": "whisper_base",
    "stablelm-3b": "stablelm_3b",
    "minitron-8b": "minitron_8b",
    "granite-34b": "granite_34b",
    "nemotron-4-15b": "nemotron_4_15b",
}

# archs whose attention is sub-quadratic-capable (SSM/hybrid) -> long_500k runs
LONG_CONTEXT_OK = {"zamba2-1.2b", "mamba2-130m"}


def get_module(arch: str):
    assert arch in ARCHS, f"unknown arch {arch!r}; choose from {list(ARCHS)}"
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return get_module(arch).CONFIG


def get_train_config(arch: str) -> TrainConfig:
    return getattr(get_module(arch), "TRAIN", TrainConfig())


def get_smoke_config(arch: str) -> ModelConfig:
    return get_module(arch).SMOKE


def cells(arch: str):
    """The assigned (shape) cells for this arch, with documented skips."""
    out = []
    for name, shape in SHAPES.items():
        if name == "long_500k" and arch not in LONG_CONTEXT_OK:
            continue  # full-attention arch: skip documented in DESIGN.md §4
        out.append(shape)
    return out


def all_cells():
    return [(a, s) for a in ARCHS for s in cells(a)]
