"""Deterministic, restartable data pipeline.

Two sources share one interface (``next_batch(step) -> batch dict``):

  * ``SyntheticSource`` — tokens drawn with a counter-based RNG keyed on
    ``(seed, step)``: any worker can produce any step's batch without state
    (the property that makes checkpoint-restart and elastic re-sharding
    trivial — the "cursor" is just the step number).
  * ``MemmapSource`` — a flat binary token file read as overlapping windows;
    the cursor is derived from ``step`` the same way.

Modality frontends (vlm/audio) are stubs per the assignment: patch/frame
embeddings are synthesized at the model dim.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticSource:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_patches: int = 0         # vlm: prepended patch embeddings
    d_model: int = 0
    encoder_len: int = 0       # audio: frame embeddings

    def next_batch(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        t_text = self.seq_len - self.n_patches
        tokens = jax.random.randint(
            key, (self.global_batch, t_text + 1), 0, self.vocab_size,
            dtype=jnp.int32)
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if self.n_patches:
            kp = jax.random.fold_in(key, 1)
            batch["patches"] = jax.random.normal(
                kp, (self.global_batch, self.n_patches, self.d_model),
                jnp.bfloat16)
            # labels cover the full (patch + text) sequence
            pad = jnp.zeros((self.global_batch, self.n_patches), jnp.int32)
            batch["labels"] = jnp.concatenate([pad, batch["labels"]], axis=1)
        if self.encoder_len:
            kf = jax.random.fold_in(key, 2)
            batch["patches"] = jax.random.normal(
                kf, (self.global_batch, self.encoder_len, self.d_model),
                jnp.bfloat16)
        return batch


@dataclasses.dataclass(frozen=True)
class MemmapSource:
    path: str
    seq_len: int
    global_batch: int
    dtype: str = "uint16"

    def next_batch(self, step: int) -> dict:
        data = np.memmap(self.path, dtype=self.dtype, mode="r")
        window = self.seq_len + 1
        n_windows = (len(data) - 1) // window
        idx = (step * self.global_batch
               + np.arange(self.global_batch)) % max(n_windows, 1)
        toks = np.stack([np.asarray(data[i * window:(i + 1) * window])
                         for i in idx]).astype(np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}
