"""ShapeDtypeStruct input builders for every (arch x shape x mesh) cell.

``input_specs`` returns sharding-annotated ShapeDtypeStructs for all inputs
of the lowered step — weak-type-correct, shardable, zero allocation.  The
same builders feed the dry-run, the roofline extraction, and (materialized)
the real launchers.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_train_config
from repro.configs.base import SHAPES, ShapeConfig
from repro.models import build_model
from repro.train import sharding as shd
from repro.train.optimizer import init_opt_state


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in shd.batch_axes(mesh)]))


def n_microbatches(arch: str, shape: ShapeConfig, mesh) -> int:
    tcfg = get_train_config(arch)
    per_dev = shape.global_batch // dp_size(mesh)
    micro = max(tcfg.microbatch, 1)
    return max(per_dev // micro, 1)


def _replicated_specs(shapes):
    return jax.tree.map(lambda s: P(*([None] * len(s.shape))), shapes)


def param_specs(arch: str, mesh, *, fsdp: bool = True):
    """(abstract param shapes, PartitionSpec tree, sharded SDS tree)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    if get_train_config(arch).replicate_params:
        specs = _replicated_specs(shapes)
    else:
        specs = shd.infer_param_specs(shapes, mesh, fsdp=fsdp)
    sds = jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs)
    return model, shapes, specs, sds


def opt_specs(arch: str, mesh, param_shapes, *, fsdp: bool = True):
    tcfg = get_train_config(arch)
    shapes = jax.eval_shape(lambda p: init_opt_state(p, tcfg), param_shapes)
    if tcfg.replicate_params:
        specs = _replicated_specs(shapes)
    else:
        specs = shd.infer_param_specs(shapes, mesh, fsdp=fsdp)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs)


def batch_specs(arch: str, shape: ShapeConfig, mesh):
    """Training/prefill batch SDS: tokens/labels (+ patches/frames)."""
    cfg = get_config(arch)
    b, t = shape.global_batch, shape.seq_len
    dspec = shd.data_spec(mesh, 2)
    out = {}
    if cfg.family == "vlm":
        t_text = t - cfg.n_patches
        out["tokens"] = _sds((b, t_text), jnp.int32, mesh, dspec)
        out["labels"] = _sds((b, t), jnp.int32, mesh, dspec)
        out["patches"] = _sds((b, cfg.n_patches, cfg.d_model), jnp.bfloat16,
                              mesh, shd.data_spec(mesh, 3))
    elif cfg.family == "encdec":
        out["tokens"] = _sds((b, t), jnp.int32, mesh, dspec)
        out["labels"] = _sds((b, t), jnp.int32, mesh, dspec)
        out["patches"] = _sds((b, cfg.encoder_len, cfg.d_model), jnp.bfloat16,
                              mesh, shd.data_spec(mesh, 3))
    else:
        out["tokens"] = _sds((b, t), jnp.int32, mesh, dspec)
        out["labels"] = _sds((b, t), jnp.int32, mesh, dspec)
    return out


def cache_specs(arch: str, shape: ShapeConfig, mesh):
    """Decode-cache SDS tree matching model.init_cache structure."""
    cfg = get_config(arch)
    model = build_model(cfg)
    b, s = shape.global_batch, shape.seq_len
    cshapes = jax.eval_shape(
        lambda: model.init_cache(b, s, dtype=jnp.bfloat16))
    cs = shd.cache_spec(cfg, mesh, b)

    def one(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        if "len" in names:
            return _sds(leaf.shape, leaf.dtype, mesh, P())
        if any(n in ("k", "v", "enc_k", "enc_v") for n in names):
            return _sds(leaf.shape, leaf.dtype, mesh, cs["attn"])
        if "conv" in names:
            return _sds(leaf.shape, leaf.dtype, mesh, cs["conv"])
        if "ssm" in names:
            return _sds(leaf.shape, leaf.dtype, mesh, cs["ssm"])
        return _sds(leaf.shape, leaf.dtype, mesh, P())
    return jax.tree_util.tree_map_with_path(one, cshapes)


def decode_token_specs(arch: str, shape: ShapeConfig, mesh):
    cfg = get_config(arch)
    b = shape.global_batch
    dp = dp_size(mesh)
    spec = shd.data_spec(mesh, 2) if b % dp == 0 and b >= dp else P(None, None)
    return _sds((b, 1), jnp.int32, mesh, spec)


def input_specs(arch: str, shape_name: str, mesh) -> dict:
    """All SDS inputs for the cell's step function, by kind."""
    shape = SHAPES[shape_name]
    model, pshapes, pspecs, psds = param_specs(arch, mesh)
    out = dict(model=model, params=psds, param_specs=pspecs, shape=shape)
    if shape.kind == "train":
        out["opt"] = opt_specs(arch, mesh, pshapes)
        out["batch"] = batch_specs(arch, shape, mesh)
        out["step"] = jax.ShapeDtypeStruct((), jnp.int32)
        out["n_micro"] = n_microbatches(arch, shape, mesh)
    elif shape.kind == "prefill":
        out["batch"] = batch_specs(arch, shape, mesh)
    else:  # decode
        out["cache"] = cache_specs(arch, shape, mesh)
        out["token"] = decode_token_specs(arch, shape, mesh)
    return out
