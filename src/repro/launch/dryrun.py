import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the step function is lowered with sharding-annotated ShapeDtypeStructs (no
allocation), compiled for the 256-chip single-pod mesh and the 512-chip
2-pod mesh, and the compiled artifact's memory_analysis / cost_analysis /
collective schedule are recorded for EXPERIMENTS.md (§Dry-run, §Roofline).

Usage:
  python -m repro.launch.dryrun --suite lm --mesh pod --out results.json
  python -m repro.launch.dryrun --arch granite-34b --shape train_4k --mesh multipod
  python -m repro.launch.dryrun --suite mdp
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, keyed by op kind (result-shape
    bytes of each collective op in the partitioned module)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_txt, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_txt)
        counts[kind] += 1
    out["counts"] = counts
    return out


def analyze(compiled, lower_s, compile_s) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # <= 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    rec = dict(
        flops=float(cost.get("flops", -1)),
        bytes_accessed=float(cost.get("bytes accessed", -1)),
        collectives={k: v for k, v in coll.items() if k != "counts"},
        collective_counts=coll["counts"],
        lower_s=round(lower_s, 2), compile_s=round(compile_s, 2),
    )
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        rec[attr] = getattr(mem, attr, None)
    return rec


# ------------------------------------------------------------------------- #
# LM cells                                                                   #
# ------------------------------------------------------------------------- #

def run_lm_cell(arch: str, shape_name: str, mesh) -> dict:
    from repro.configs import get_train_config
    from repro.launch import specs as S
    from repro.train.steps import (make_decode_step, make_prefill_step,
                                   make_train_step)

    si = S.input_specs(arch, shape_name, mesh)
    model, shape = si["model"], si["shape"]
    tcfg = get_train_config(arch)
    t0 = time.time()
    if shape.kind == "train":
        fn = make_train_step(model, tcfg, n_microbatches=si["n_micro"])
        out_shardings = (jax.tree.map(lambda s: s.sharding, si["params"]),
                         jax.tree.map(lambda s: s.sharding, si["opt"]),
                         None)
        lowered = jax.jit(fn, out_shardings=out_shardings).lower(
            si["params"], si["opt"], si["step"], si["batch"])
    elif shape.kind == "prefill":
        fn = make_prefill_step(model)
        lowered = jax.jit(fn).lower(si["params"], si["batch"]["tokens"],
                                    si["batch"].get("patches"))
    else:
        fn = make_decode_step(model)
        cache_sh = jax.tree.map(lambda s: s.sharding, si["cache"])
        lowered = jax.jit(fn, out_shardings=(None, None, cache_sh)).lower(
            si["params"], si["token"], si["cache"])
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return analyze(compiled, t1 - t0, t2 - t1)


# ------------------------------------------------------------------------- #
# MDP (paper) cells                                                          #
# ------------------------------------------------------------------------- #

MDP_CELLS = {
    # name: (n, m, K, layout, method, halo).  A "+pc" suffix on the method
    # ("ipi_gmres+jacobi") compiles the same program with that preconditioner
    # enabled, so the setup + apply FLOPs are charged by cost_analysis; the
    # "auto" method compiles the probe program (a short VI burst) AND the
    # main solve and reports their summed cost — what an adaptive solve pays.
    "mdp_vi_16m": (1 << 24, 16, 16, "1d", "vi", 0),
    "mdp_gmres_16m": (1 << 24, 16, 16, "1d", "ipi_gmres", 0),
    "mdp_gmres_16m_jacobi": (1 << 24, 16, 16, "1d", "ipi_gmres+jacobi", 0),
    "mdp_gmres_16m_bjacobi": (1 << 24, 16, 16, "1d", "ipi_gmres+bjacobi", 0),
    "mdp_auto_16m": (1 << 24, 16, 16, "1d", "auto", 0),
    "mdp_gmres_2d_1m_256a": (1 << 20, 256, 16, "2d", "ipi_gmres", 0),
    "mdp_bicgstab_64m": (1 << 26, 8, 8, "1d", "ipi_bicgstab", 0),
    # beyond-paper layouts (§Perf): banded halo exchange replaces the
    # all-gather of v (maze2d-structured instance, bandwidth = 4096)
    "mdp_vi_16m_halo": (1 << 24, 16, 16, "1d", "vi", 4096),
    "mdp_gmres_16m_halo": (1 << 24, 16, 16, "1d", "ipi_gmres", 4096),
    # dense transition tensor (K=0 marker): backups become MXU matmuls —
    # the compute-bound corner of the solver (small-n, action-rich MDPs)
    "mdp_dense_32k": (1 << 15, 64, 0, "1d", "vi", 0),
}

# Matrix-free cells: the abstract container is an O(n) placement tag plus a
# REAL FN_REGISTRY row spec, so lowering re-traces the constructors inside
# every backup — the compiled cost_analysis therefore charges the per-sweep
# recompute FLOPs automatically, and memory_analysis shows the O(n)
# argument footprint (no table anywhere).
MDP_MF_CELLS = {
    # name: (fn-registry family, family kwargs, layout, method, halo)
    "mdp_mf_vi_64m": ("garnet", dict(n=1 << 26, m=8, k=8), "1d", "vi", 0),
    "mdp_mf_gmres_64m": ("garnet", dict(n=1 << 26, m=8, k=8), "1d",
                         "ipi_gmres", 0),
    # the state-ceiling cell: 2^30 states would need a 100+ GB/device ELL
    # table; the operator solves it in ~GBs of value vectors per device
    "mdp_mf_vi_1g": ("garnet", dict(n=1 << 30, m=8, k=8), "1d", "vi", 0),
    # banded family (sis: band=1) under the halo ring exchange
    "mdp_mf_vi_16m_halo": ("sis", dict(pop=(1 << 24) - 1, n_actions=4),
                           "1d", "vi", 1),
}


def run_mdp_cell(name: str, mesh) -> dict:
    from functools import partial

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import ipi, partition
    from repro.core.mdp import DenseMDP, EllMDP, MatrixFreeMDP

    spec = None
    if name in MDP_MF_CELLS:
        fam, fam_kw, layout, method, halo = MDP_MF_CELLS[name]
        from repro.api.mdp import MDP as _ApiMDP
        spec = _ApiMDP.from_generator(fam, deferred=True,
                                      **fam_kw)._row_spec()
        n, m, k = spec.n, spec.m, spec.nnz
    else:
        n, m, k, layout, method, halo = MDP_CELLS[name]
    axes = partition.mesh_axes(mesh, layout)
    import math
    n_shards = math.prod(mesh.shape[a] for a in (
        axes.state if isinstance(axes.state, tuple) else (axes.state,)))
    m_shards = 1 if axes.action is None else mesh.shape[axes.action]
    if spec is not None:  # matrix-free operator: O(n) tag, no table
        mdp_abs = MatrixFreeMDP(
            tag=jax.ShapeDtypeStruct((n,), jnp.int8),
            gamma=0.9999, n_global=n, m_global=m, spec=spec)
    elif k == 0:  # dense transition tensor
        mdp_abs = DenseMDP(
            p=jax.ShapeDtypeStruct((n, m, n), jnp.float32),
            cost=jax.ShapeDtypeStruct((n, m), jnp.float32),
            gamma=0.9999, n_global=n, m_global=m)
    else:
        mdp_abs = EllMDP(
            idx=jax.ShapeDtypeStruct((n, m, k), jnp.int32),
            val=jax.ShapeDtypeStruct((n, m, k), jnp.float32),
            cost=jax.ShapeDtypeStruct((n, m), jnp.float32),
            gamma=0.9999, n_global=n, m_global=m)
    specs = partition.mdp_pspecs(mdp_abs, axes)
    mdp_sds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        mdp_abs, specs)
    nl = n // n_shards

    def compile_program(opts):
        state_specs = ipi.SolveState(
            v=P(axes.state), tv=P(axes.state), pi=P(axes.state),
            res=P(), k=P(), inner_total=P(), trace_res=P(), trace_inner=P(),
            res0=P(), span=P(), done=P(), diverged=P(), n_true=P(),
            win=P(axes.state) if halo else P())
        sspec_tree = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                  state_specs)
        state_sds = ipi.SolveState(
            v=jax.ShapeDtypeStruct((n,), jnp.float32, sharding=sspec_tree.v),
            tv=jax.ShapeDtypeStruct((n,), jnp.float32,
                                    sharding=sspec_tree.tv),
            pi=jax.ShapeDtypeStruct((n,), jnp.int32, sharding=sspec_tree.pi),
            res=jax.ShapeDtypeStruct((), jnp.float32,
                                     sharding=sspec_tree.res),
            k=jax.ShapeDtypeStruct((), jnp.int32, sharding=sspec_tree.k),
            inner_total=jax.ShapeDtypeStruct((), jnp.int32,
                                             sharding=sspec_tree.inner_total),
            trace_res=jax.ShapeDtypeStruct((opts.max_outer + 1,), jnp.float32,
                                           sharding=sspec_tree.trace_res),
            trace_inner=jax.ShapeDtypeStruct((opts.max_outer,), jnp.int32,
                                             sharding=sspec_tree.trace_inner),
            res0=jax.ShapeDtypeStruct((), jnp.float32,
                                      sharding=sspec_tree.res0),
            span=jax.ShapeDtypeStruct((), jnp.float32,
                                      sharding=sspec_tree.span),
            done=jax.ShapeDtypeStruct((), jnp.bool_,
                                      sharding=sspec_tree.done),
            diverged=jax.ShapeDtypeStruct((), jnp.bool_,
                                          sharding=sspec_tree.diverged),
            n_true=jax.ShapeDtypeStruct((), jnp.int32,
                                        sharding=sspec_tree.n_true),
            # sync methods carry an empty stale window (async_vi state only)
            win=jax.ShapeDtypeStruct((0,), jnp.float32,
                                     sharding=sspec_tree.win))
        from repro.utils.jax_compat import shard_map as _shard_map
        fn = jax.jit(
            _shard_map(
                partial(ipi.solve_chunk, opts=opts, axes=axes),
                mesh=mesh,
                in_specs=(partition.mdp_pspecs(mdp_abs, axes),
                          state_specs, P(), P()),
                out_specs=state_specs))
        t0 = time.time()
        lowered = fn.lower(mdp_sds, state_sds,
                           jax.ShapeDtypeStruct((), jnp.int32),
                           jax.ShapeDtypeStruct((), jnp.int32))
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        return analyze(compiled, t1 - t0, t2 - t1)

    method_full = method
    method, _, pc_type = method.partition("+")
    if method == "auto":
        # an adaptive solve lowers (and pays for) TWO programs: the probe —
        # a short fixed-length VI burst under the never-stop "probe"
        # criterion — and the main solve the policy engine picks; charge
        # both so EXPERIMENTS.md reflects the true compile + step cost
        probe = compile_program(ipi.IPIOptions(
            method="vi", stop_criterion="probe", max_outer=8,
            halo=halo))
        rec = compile_program(ipi.IPIOptions(
            method="ipi_gmres", max_outer=100, max_inner=32,
            restart=16, halo=halo))
        for k_ in ("flops", "bytes_accessed", "lower_s", "compile_s"):
            rec[k_] = round(rec[k_] + probe[k_], 2)
        rec["collectives"] = {k_: v + probe["collectives"].get(k_, 0)
                              for k_, v in rec["collectives"].items()}
        rec["probe_flops"] = probe["flops"]
    else:
        rec = compile_program(ipi.IPIOptions(
            method=method, max_outer=100, max_inner=32, restart=16,
            halo=halo, pc_type=pc_type or "none"))
    rec["layout"] = layout
    rec["method"] = method_full
    rec["nmk"] = (n, m, k)
    # per-device value-window bytes received per backup: the banded layout
    # moves only the +-halo boundary entries, not the full vector — report
    # the actual window so EXPERIMENTS.md rooflines do not charge halo cells
    # for an all-gather they never issue
    itemsize = jnp.dtype(jnp.float32).itemsize
    rec["window_bytes"] = (2 * halo * itemsize if halo
                           else (n - nl) * itemsize)
    if spec is not None:
        # memory crossover: both footprints are linear in n, so the trade
        # is a constant ratio — report it plus the per-host state ceilings
        # each way (the recompute FLOPs the operator pays per sweep are
        # already in rec["flops"]: lowering traced the constructors)
        from repro.kernels import matrix_free as _mf
        krylov = method not in ("vi", "async_vi")
        tb = _mf.table_bytes(n, m, k)
        ob = _mf.operator_bytes(n, k, krylov=krylov)
        host = 16 << 30   # a 16 GB device/host as the reference budget
        rec["table_bytes"] = tb
        rec["operator_bytes"] = ob
        rec["memory_ratio"] = round(tb / ob, 2)
        rec["states_per_16g_materialized"] = host // (tb // n)
        rec["states_per_16g_matrix_free"] = host // (ob // n)
        print(f"[mf] {name}: table {tb / 1e9:.2f} GB vs operator "
              f"{ob / 1e9:.3f} GB ({tb / ob:.0f}x); a 16 GB device holds "
              f"{host // (tb // n):,} materialized vs "
              f"{host // (ob // n):,} matrix-free states", flush=True)
    return rec


# ------------------------------------------------------------------------- #
# CLI                                                                        #
# ------------------------------------------------------------------------- #

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=("lm", "mdp", "all"), default=None)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="both")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import ARCHS, cells
    from repro.launch.mesh import make_production_mesh

    meshes = {"pod": False, "multipod": True}
    mesh_names = [args.mesh] if args.mesh != "both" else ["pod", "multipod"]

    jobs = []
    if args.arch:
        shapes = [args.shape] if args.shape else \
            [s.name for s in cells(args.arch)]
        jobs += [("lm", args.arch, s) for s in shapes]
    if args.suite in ("lm", "all"):
        jobs += [("lm", a, s.name) for a in ARCHS for s in cells(a)]
    if args.suite in ("mdp", "all"):
        jobs += [("mdp", name, "")
                 for name in list(MDP_CELLS) + list(MDP_MF_CELLS)]

    results = {}
    for mesh_name in mesh_names:
        mesh = make_production_mesh(multi_pod=meshes[mesh_name])
        for kind, a, s in jobs:
            key = f"{a}/{s}/{mesh_name}" if s else f"{a}/{mesh_name}"
            t0 = time.time()
            try:
                rec = run_lm_cell(a, s, mesh) if kind == "lm" \
                    else run_mdp_cell(a, mesh)
                rec["status"] = "ok"
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = {"status": "FAIL", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            rec["wall_s"] = round(time.time() - t0, 2)
            results[key] = rec
            flops = rec.get("flops", 0)
            print(f"[{rec['status']}] {key}  wall={rec['wall_s']}s "
                  f"flops={flops:.3e} "
                  f"coll={sum(rec.get('collectives', {}).values()):.3e}B",
                  flush=True)
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_fail = sum(1 for r in results.values() if r["status"] != "ok")
    print(f"done: {len(results) - n_fail}/{len(results)} ok", flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
