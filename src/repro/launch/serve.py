"""MDP serving CLI — drive a :class:`repro.serve.Server` with a workload.

Stands up the in-process batched solve server and replays a request
stream into it from concurrent client threads, with Poisson arrivals:

    # generator-driven: 32 garnet requests, ragged state counts, ~50 req/s
    PYTHONPATH=src python -m repro.launch.serve --requests 32 \
        --instance garnet --n-choices 256,384 --m 8 --rate 50

    # file-driven: one JSON object per line
    PYTHONPATH=src python -m repro.launch.serve --workload reqs.jsonl

    # fleet-sharded buckets over 8 fake devices
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --requests 16 --rate 100

A workload-file line is ``{"instance": "garnet", "n": 256, "m": 8,
"seed": 3, "gamma": 0.95, "overrides": {"-atol": 1e-6},
"monitor": false}`` — generator kwargs at the top level, per-request
solver-option overrides under ``"overrides"``.

Server knobs are options-database keys (``-serve_batch_window``,
``-serve_max_queue``, ``-serve_max_states``, ``-serve_max_batch``,
``-serve_program_cache``, ``-serve_slot_policy``) reachable through
``--option key=value`` or ``MADUPITE_OPTIONS``; ``--window`` is sugar for
the batching window.  Exits non-zero when any request fails or is
rejected.
"""

from __future__ import annotations

import argparse
import json
import random
import threading
import time

from repro.api import MDP, Options
from repro.serve import AdmissionError, Server
from repro.serve.stats import percentile


def _parse_workload_file(path: str) -> list[dict]:
    specs = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            try:
                spec = json.loads(line)
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{lineno}: bad JSON: {e}")
            if "instance" not in spec:
                raise SystemExit(f"{path}:{lineno}: missing 'instance'")
            specs.append(spec)
    return specs


def _generate_workload(args) -> list[dict]:
    """Ragged synthetic workload: state counts drawn from --n-choices."""
    rng = random.Random(args.seed)
    choices = [int(x) for x in args.n_choices.split(",")]
    specs = []
    for i in range(args.requests):
        n = rng.choice(choices)
        spec = {"instance": args.instance, "gamma": args.gamma}
        if args.instance == "garnet":
            spec.update(n=n, m=args.m, k=args.k, seed=args.seed + i)
        elif args.instance == "maze2d":
            spec.update(size=max(2, round(n ** 0.5)), seed=args.seed + i)
        elif args.instance == "sis":
            spec.update(pop=n, n_actions=args.m, seed=args.seed + i)
        else:  # chain_walk
            spec.update(n=n)
        specs.append(spec)
    return specs


def _build_mdp(spec: dict) -> MDP:
    kw = {k: v for k, v in spec.items()
          if k not in ("instance", "overrides", "monitor")}
    return MDP.from_generator(spec["instance"], **kw)


def build_options(args) -> Options:
    opts = Options.from_sources()                    # env ingested here
    if args.window is not None:
        opts.set("-serve_batch_window", args.window, source="cli")
    if args.monitor:
        opts.set("-monitor", True, source="cli")
    opts.ingest_cli(args.option)
    if not opts.is_set("-dtype"):
        opts.set("-dtype", "float64", source="default")
    if not opts.is_set("-max_outer"):
        opts.set("-max_outer", 2000, source="default")
    return opts


def _submit_clients(server: Server, specs: list[dict], rate: float,
                    seed: int, monitor: bool):
    """One client thread per request, started on a Poisson arrival clock
    (exponential inter-arrival gaps at ``rate`` req/s)."""
    rng = random.Random(seed)
    outcomes: list[dict | None] = [None] * len(specs)

    def client(i: int, spec: dict) -> None:
        mon = bool(spec.get("monitor", monitor))
        overrides = spec.get("overrides", {})
        t0 = time.monotonic()
        try:
            req = server.submit(_build_mdp(spec), monitor=mon, **overrides)
            n_records = 0
            if mon:
                for _ in server.stream(req):
                    n_records += 1
            res = req.result()
            outcomes[i] = {"ok": True, "converged": bool(res.converged),
                           "outer": int(res.outer_iterations),
                           "latency": time.monotonic() - t0,
                           "records": n_records}
        except AdmissionError as e:
            outcomes[i] = {"ok": False, "rejected": e.reason,
                           "error": str(e)}
        except Exception as e:  # noqa: BLE001 — report, don't hang the run
            outcomes[i] = {"ok": False, "error": f"{type(e).__name__}: {e}"}

    threads = []
    for i, spec in enumerate(specs):
        t = threading.Thread(target=client, args=(i, spec), daemon=True)
        threads.append(t)
        t.start()
        if rate > 0 and i + 1 < len(specs):
            time.sleep(rng.expovariate(rate))
    for t in threads:
        t.join()
    return outcomes


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--workload", default=None,
                    help="JSONL request file (one spec per line); "
                         "otherwise a synthetic workload is generated")
    ap.add_argument("--requests", type=int, default=16,
                    help="generated workload size")
    ap.add_argument("--instance", default="garnet",
                    choices=["garnet", "maze2d", "sis", "chain_walk"])
    ap.add_argument("--n-choices", default="256,384",
                    help="comma-separated state counts the generated "
                         "workload samples from (ragged shape buckets)")
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--gamma", type=float, default=0.95)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate in requests/second "
                         "(0 = submit all at once)")
    ap.add_argument("--window", type=float, default=None,
                    help="option -serve_batch_window (batching linger, s)")
    ap.add_argument("--monitor", action="store_true",
                    help="stream per-iteration records for every request")
    ap.add_argument("--option", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="set any options-database key (repeatable; the "
                         "leading dash is optional), e.g. "
                         "--option serve_max_batch=16")
    args = ap.parse_args(argv)

    specs = (_parse_workload_file(args.workload) if args.workload
             else _generate_workload(args))
    if not specs:
        raise SystemExit("empty workload")
    opts = build_options(args)

    with Server(opts) as server:
        mesh, layout = server.session.placement()
        if mesh is not None:
            print(f"[serve] mesh {dict(mesh.shape)} layout={layout}")
        print(f"[serve] {len(specs)} requests, Poisson rate="
              f"{args.rate}/s, window="
              f"{opts.get('-serve_batch_window')}s")
        t0 = time.monotonic()
        outcomes = _submit_clients(server, specs, args.rate, args.seed,
                                   args.monitor)
        wall = time.monotonic() - t0
        server.drain()
        st = server.stats()

    ok = [o for o in outcomes if o and o.get("ok")]
    bad = [o for o in outcomes if not (o and o.get("ok"))]
    lats = sorted(o["latency"] for o in ok)
    print(f"[serve] completed={len(ok)}/{len(specs)} wall={wall:.2f}s "
          f"throughput={len(ok) / wall:.1f} req/s")
    if lats:
        print(f"[serve] latency p50={percentile(lats, 50) * 1e3:.1f}ms "
              f"p95={percentile(lats, 95) * 1e3:.1f}ms")
    pc = st["program_cache"]
    print(f"[serve] dispatches={st['dispatches']} "
          f"mean_batch={st['batch']['mean_size']:.1f} "
          f"padded_lanes={st['padded_lanes']}")
    print(f"[serve] program_cache hit_rate={pc['hit_rate']:.2f} "
          f"(hits={pc['hits']} misses={pc['misses']} "
          f"evictions={pc['evictions']})")
    for o in bad:
        print(f"[serve] FAILED: {o}")
    return 0 if not bad else 1


if __name__ == "__main__":
    raise SystemExit(main())
