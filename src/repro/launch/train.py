"""End-to-end LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Runs the full production loop on whatever devices exist: config -> mesh ->
sharded init -> checkpointed, microbatched, remat'd train steps -> metrics.
``--smoke`` selects the reduced config (CPU-friendly); the full configs are
exercised via the dry-run.  Restart-safe: re-launching with the same
--ckpt-dir resumes from the newest complete checkpoint (kill it mid-run to
test — the data cursor is the step counter, so no batch is skipped or
repeated).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config, get_train_config
from repro.data.pipeline import SyntheticSource
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train import sharding as shd
from repro.train.optimizer import init_opt_state
from repro.train.steps import make_train_step
from repro.utils import checkpoint as ckpt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = get_train_config(args.arch)
    model = build_model(cfg)
    mesh = make_host_mesh()
    print(f"[train] arch={cfg.name} devices={len(jax.devices())} "
          f"mesh={dict(mesh.shape)}")

    params = model.init(jax.random.PRNGKey(0))
    pspecs = shd.infer_param_specs(params, mesh)
    params = shd.place(params, mesh, pspecs)
    opt_state = init_opt_state(params, tcfg)
    start_step = 0

    if args.ckpt_dir:
        restored = ckpt.restore(args.ckpt_dir, (params, opt_state))
        if restored is not None:
            (params, opt_state), start_step, _ = restored
            print(f"[train] resumed from step {start_step}")

    src = SyntheticSource(
        cfg.vocab_size, args.seq, args.batch,
        n_patches=cfg.n_patches, d_model=cfg.d_model,
        encoder_len=cfg.encoder_len if cfg.family == "encdec" else 0)
    step_fn = jax.jit(make_train_step(model, tcfg,
                                      n_microbatches=args.microbatches))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = src.next_batch(step)
        batch = shd.place(batch, mesh,
                          jax.tree.map(lambda x: shd.data_spec(mesh, x.ndim),
                                       batch))
        params, opt_state, metrics = step_fn(params, opt_state,
                                             jnp.int32(step), batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = (time.time() - t0) / max(len(losses), 1)
            print(f"[train] step={step} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms/step",
                  flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, (params, opt_state),
                      meta=dict(arch=cfg.name))
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, (params, opt_state),
                  meta=dict(arch=cfg.name))
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"[train] done. loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
