"""MDP solve driver — the madupite CLI equivalent.

    PYTHONPATH=src python -m repro.launch.solve --instance maze2d --size 64 \
        --method ipi_gmres --atol 1e-8 --ckpt-dir /tmp/mdp_run

Generates (or loads) an instance, solves it with the selected iPI method —
distributed over all available devices when >1 — and reports the
convergence certificate.

Fleet mode: ``--batch N`` solves N instances in ONE compiled batched program
(:func:`repro.core.driver.solve_many`).  By default the fleet is a seed
ensemble (``seed .. seed+N-1``); ``--sweep-gamma LO HI`` makes it a
gamma-conditioning sweep instead (N log-spaced discount factors, the
paper's gamma -> 1 study in one invocation):

    PYTHONPATH=src python -m repro.launch.solve --instance garnet \
        --n 5000 --batch 8 --method ipi_gmres
    PYTHONPATH=src python -m repro.launch.solve --instance chain_walk \
        --n 2000 --batch 6 --sweep-gamma 0.9 0.9999

Fleet-sharded layouts: ``--layout fleet`` (or ``fleet2d``) shards the fleet's
instance dim over the mesh's leading ``fleet`` axis (``--fleet N`` picks the
axis size; default: all devices) so per-device fleet memory is B/N of the
replicated layouts:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.solve --instance garnet \
        --n 2000 --batch 16 --layout fleet --fleet 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import IPIOptions, generators, solve, solve_many
from repro.core.io import load_mdp
from repro.launch.mesh import make_fleet_mesh, make_host_mesh


def _gen_kwargs(args) -> dict:
    if args.instance == "garnet":
        return dict(n=args.n, m=args.m, k=args.k, gamma=args.gamma,
                    seed=args.seed)
    if args.instance == "maze2d":
        return dict(size=args.size, gamma=args.gamma, seed=args.seed)
    if args.instance == "sis":
        return dict(pop=args.n, n_actions=args.m, gamma=args.gamma,
                    seed=args.seed)
    if args.instance == "chain_walk":
        return dict(n=args.n, gamma=args.gamma)
    raise ValueError(args.instance)


def build_instance(args):
    if args.load:
        return load_mdp(args.load)
    return generators.REGISTRY[args.instance](**_gen_kwargs(args))


def build_fleet(args) -> list:
    """``--batch N`` fleet: seed ensemble, or a gamma sweep with
    ``--sweep-gamma``."""
    kw = _gen_kwargs(args)
    sweep = None
    if args.sweep_gamma is not None:
        lo, hi = args.sweep_gamma
        # log-spaced in (1 - gamma): resolves the conditioning ~ 1/(1-gamma)
        sweep = {"gamma": list(1.0 - np.geomspace(1 - lo, 1 - hi,
                                                  args.batch))}
    return generators.generate_many(args.instance, args.batch, sweep=sweep,
                                    **kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--instance", default="garnet",
                    choices=["garnet", "maze2d", "sis", "chain_walk"])
    ap.add_argument("--load", default=None, help="load an MDP saved by io.py")
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--gamma", type=float, default=0.99)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default="ipi_gmres")
    ap.add_argument("--atol", type=float, default=1e-8)
    ap.add_argument("--max-outer", type=int, default=2000)
    ap.add_argument("--layout", default="1d",
                    choices=["1d", "2d", "fleet", "fleet2d"])
    ap.add_argument("--fleet", type=int, default=None,
                    help="fleet-axis size for --layout fleet/fleet2d "
                         "(must divide the device count; default: all "
                         "devices)")
    ap.add_argument("--dtype", default="float64")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--single-device", action="store_true")
    ap.add_argument("--batch", type=int, default=1,
                    help="solve a fleet of N instances in one batched "
                         "program (seed ensemble unless --sweep-gamma)")
    ap.add_argument("--sweep-gamma", type=float, nargs=2, default=None,
                    metavar=("LO", "HI"),
                    help="with --batch: gamma sweep over [LO, HI] instead "
                         "of a seed ensemble")
    args = ap.parse_args(argv)

    if args.sweep_gamma is not None and args.batch <= 1:
        raise SystemExit("--sweep-gamma needs --batch N (the sweep IS the "
                         "fleet); e.g. --batch 8 --sweep-gamma 0.9 0.9999")
    fleet_layout = args.layout in ("fleet", "fleet2d")
    if fleet_layout and args.batch <= 1:
        raise SystemExit(f"--layout {args.layout} shards the fleet dim; it "
                         "needs a fleet (--batch N)")
    if args.dtype == "float64":
        jax.config.update("jax_enable_x64", True)

    opts = IPIOptions(method=args.method, atol=args.atol,
                      max_outer=args.max_outer, dtype=args.dtype)
    mesh = None
    if not args.single_device and len(jax.devices()) > 1:
        n_dev = len(jax.devices())
        if fleet_layout:
            fleet = args.fleet if args.fleet is not None else n_dev
            mesh = make_fleet_mesh(fleet, layout=args.layout)
        else:
            shape = (n_dev // 2, 2) if args.layout == "2d" and n_dev >= 2 \
                else (n_dev, 1)
            mesh = make_host_mesh(shape)
        print(f"[solve] distributed over mesh {dict(mesh.shape)} "
              f"layout={args.layout}")
    elif fleet_layout:
        raise SystemExit(f"--layout {args.layout} needs >1 device (set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N"
                         " to fake a mesh on CPU)")

    if args.batch > 1:
        if args.load:
            raise SystemExit("--batch does not combine with --load")
        fleet = build_fleet(args)
        print(f"[solve] fleet B={args.batch} instance={args.instance} "
              f"n={fleet[0].n_global} m={fleet[0].m_global} "
              f"gammas={[round(float(m.gamma), 6) for m in fleet]}")
        t0 = time.time()
        results = solve_many(fleet, opts, mesh=mesh, layout=args.layout,
                             checkpoint_dir=args.ckpt_dir, verbose=True)
        wall = time.time() - t0
        for b, r in enumerate(results):
            print(f"[solve] [{b}] {r.summary()}")
        print(f"[solve] fleet wall={wall:.2f}s "
              f"({wall / args.batch:.2f}s/instance amortized)")
        return 0 if all(r.converged for r in results) else 1

    mdp = build_instance(args)
    print(f"[solve] instance={args.instance} n={mdp.n_global} "
          f"m={mdp.m_global} nnz/row={mdp.nnz_per_row} gamma={mdp.gamma}")
    t0 = time.time()
    r = solve(mdp, opts, mesh=mesh, layout=args.layout,
              checkpoint_dir=args.ckpt_dir, verbose=True)
    print(f"[solve] {r.summary()}  wall={time.time()-t0:.2f}s")
    print(f"[solve] ||v - v*||_inf <= {r.gap_bound:.3e} (certificate)")
    return 0 if r.converged else 1


if __name__ == "__main__":
    raise SystemExit(main())
