"""MDP solve CLI — a thin shell over the options database + session layer.

Every solver/placement/output setting is an options-database key
(:mod:`repro.api.options`); the named flags below are convenience aliases
for the common ones, and ``--option key=value`` (repeatable) reaches the
full registry.  ``MADUPITE_OPTIONS`` in the environment is ingested first
(precedence: explicit flag / ``--option`` > environment > defaults):

    PYTHONPATH=src python -m repro.launch.solve --instance maze2d --size 64 \
        --method ipi_gmres --atol 1e-8 --ckpt-dir /tmp/mdp_run

    MADUPITE_OPTIONS="-method vi -atol 1e-6" \
    PYTHONPATH=src python -m repro.launch.solve --instance garnet --n 5000

    PYTHONPATH=src python -m repro.launch.solve --instance sis --n 2000 \
        --option mode=maxreward --option file_stats=run.json

Fleet mode: ``--batch N`` solves N instances in batched compiled programs
(``Session.solve_fleet``; a seed ensemble, or a gamma-conditioning sweep
with ``--sweep-gamma LO HI``).  The session auto-picks the mesh layout —
``fleet``-sharded over >1 device — overridable with ``--option layout=...``
/ ``--option fleet=F``:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.solve --instance garnet \
        --n 2000 --batch 16 --option layout=fleet --option fleet=8
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import MDP, Options, Session
from repro.core import generators


def _gen_kwargs(args) -> dict:
    if args.instance == "garnet":
        return dict(n=args.n, m=args.m, k=args.k, gamma=args.gamma,
                    seed=args.seed)
    if args.instance == "maze2d":
        return dict(size=args.size, gamma=args.gamma, seed=args.seed)
    if args.instance == "sis":
        return dict(pop=args.n, n_actions=args.m, gamma=args.gamma,
                    seed=args.seed)
    if args.instance == "chain_walk":
        return dict(n=args.n, gamma=args.gamma)
    raise ValueError(args.instance)


def build_instance(args) -> MDP:
    if args.load:
        return MDP.from_file(args.load)
    return MDP.from_generator(args.instance, **_gen_kwargs(args))


def build_fleet(args) -> list:
    """``--batch N`` fleet: seed ensemble, or a gamma sweep with
    ``--sweep-gamma``."""
    kw = _gen_kwargs(args)
    sweep = None
    if args.sweep_gamma is not None:
        lo, hi = args.sweep_gamma
        # log-spaced in (1 - gamma): resolves the conditioning ~ 1/(1-gamma)
        sweep = {"gamma": list(1.0 - np.geomspace(1 - lo, 1 - hi,
                                                  args.batch))}
    return generators.generate_many(args.instance, args.batch, sweep=sweep,
                                    **kw)


def build_options(args) -> Options:
    """Flags -> options database (env < flags/--option; flags the user did
    not pass fall back to CLI-flavored soft defaults, which still lose to
    ``MADUPITE_OPTIONS``)."""
    opts = Options.from_sources()                    # env ingested here
    flag_map = {"method": "-method", "ksp_type": "-ksp_type",
                "atol": "-atol", "stop_criterion": "-stop_criterion",
                "max_outer": "-max_outer", "dtype": "-dtype",
                "layout": "-layout", "fleet": "-fleet",
                "ckpt_dir": "-checkpoint_dir", "mode": "-mode"}
    for flag, key in flag_map.items():
        val = getattr(args, flag)
        if val is not None:
            opts.set(key, val, source="cli")
    if args.single_device:
        opts.set("-layout", "single", source="cli")
    if args.monitor:
        opts.set("-monitor", True, source="cli")
    opts.ingest_cli(args.option)
    # the CLI has always defaulted to PETSc-style f64 and a deep outer cap;
    # keep that, but let the environment override
    if not opts.is_set("-dtype"):
        opts.set("-dtype", "float64", source="default")
    if not opts.is_set("-max_outer"):
        opts.set("-max_outer", 2000, source="default")
    if not opts.is_set("-verbose"):
        opts.set("-verbose", True, source="default")
    return opts


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--instance", default="garnet",
                    choices=["garnet", "maze2d", "sis", "chain_walk"])
    ap.add_argument("--load", default=None, help="load an MDP saved by io.py")
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--gamma", type=float, default=0.99)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default=None,
                    help="option -method (any live-registry name)")
    ap.add_argument("--ksp-type", default=None,
                    help="option -ksp_type (inner solver sugar; any "
                         "live-registry name incl. user-registered)")
    ap.add_argument("--mode", default=None,
                    choices=["mincost", "maxreward"], help="option -mode")
    ap.add_argument("--atol", type=float, default=None, help="option -atol")
    ap.add_argument("--stop-criterion", default=None,
                    help="option -stop_criterion (atol|rtol|span|registered)")
    ap.add_argument("--monitor", action="store_true",
                    help="option -monitor (per-outer-iteration records)")
    ap.add_argument("--max-outer", type=int, default=None,
                    help="option -max_outer")
    ap.add_argument("--layout", default=None,
                    choices=["auto", "single", "1d", "2d", "fleet",
                             "fleet2d"], help="option -layout")
    ap.add_argument("--fleet", type=int, default=None,
                    help="option -fleet (fleet-axis size)")
    ap.add_argument("--dtype", default=None, help="option -dtype")
    ap.add_argument("--ckpt-dir", default=None,
                    help="option -checkpoint_dir")
    ap.add_argument("--single-device", action="store_true",
                    help="option -layout=single")
    ap.add_argument("--option", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="set any options-database key (repeatable; the "
                         "leading dash is optional), e.g. "
                         "--option mode=maxreward")
    ap.add_argument("--batch", type=int, default=1,
                    help="solve a fleet of N instances in batched "
                         "programs (seed ensemble unless --sweep-gamma)")
    ap.add_argument("--sweep-gamma", type=float, nargs=2, default=None,
                    metavar=("LO", "HI"),
                    help="with --batch: gamma sweep over [LO, HI] instead "
                         "of a seed ensemble")
    args = ap.parse_args(argv)

    if args.sweep_gamma is not None and args.batch <= 1:
        raise SystemExit("--sweep-gamma needs --batch N (the sweep IS the "
                         "fleet); e.g. --batch 8 --sweep-gamma 0.9 0.9999")
    opts = build_options(args)
    if opts.get("-layout") in ("fleet", "fleet2d") and args.batch <= 1:
        raise SystemExit(f"-layout {opts.get('-layout')} shards the fleet "
                         "dim; it needs a fleet (--batch N)")

    with Session(opts) as session:
        mesh, layout = session.placement(
            fleet_size=args.batch if args.batch > 1 else None)
        if mesh is not None:
            print(f"[solve] distributed over mesh {dict(mesh.shape)} "
                  f"layout={layout}")

        if args.batch > 1:
            if args.load:
                raise SystemExit("--batch does not combine with --load")
            fleet = build_fleet(args)
            print(f"[solve] fleet B={args.batch} instance={args.instance} "
                  f"n={fleet[0].n_global} m={fleet[0].m_global} "
                  f"gammas={[round(float(m.gamma), 6) for m in fleet]}")
            t0 = time.time()
            results = session.solve_fleet(fleet)
            wall = time.time() - t0
            for b, r in enumerate(results):
                print(f"[solve] [{b}] {r.summary()}")
            print(f"[solve] fleet wall={wall:.2f}s "
                  f"({wall / args.batch:.2f}s/instance amortized)")
            return 0 if all(r.converged for r in results) else 1

        mdp = build_instance(args)
        print(f"[solve] instance={args.instance} n={mdp.n} m={mdp.m} "
              f"gamma={mdp.gamma} mode={mdp.mode}")
        t0 = time.time()
        r = session.solve(mdp)
        print(f"[solve] {r.summary()}  wall={time.time()-t0:.2f}s")
        adaptive = session.stats[-1].get("adaptive")
        if adaptive is not None:
            # what -method auto / -adapt_on_stagnation actually ran
            choice = adaptive.get("choice")
            if choice is not None:
                print(f"[solve] auto-selected {choice['method']} "
                      f"(stop={choice['stop_criterion']} "
                      f"pc={choice['pc_type']}): {choice['reason']}")
            for s in adaptive["swaps"]:
                print(f"[solve] hot-swap at k={s['k']}: "
                      f"{s['from_method']} -> {s['to_method']} "
                      f"(pc={s['pc_type']}) — {s['reason']}")
            if adaptive["methods"]:
                print(f"[solve] methods run: "
                      f"{' -> '.join(adaptive['methods'])}")
        print(f"[solve] ||v - v*||_inf <= {r.gap_bound:.3e} (certificate)")
        return 0 if r.converged else 1


if __name__ == "__main__":
    raise SystemExit(main())
