"""MDP solve driver — the madupite CLI equivalent.

    PYTHONPATH=src python -m repro.launch.solve --instance maze2d --size 64 \
        --method ipi_gmres --atol 1e-8 --ckpt-dir /tmp/mdp_run

Generates (or loads) an instance, solves it with the selected iPI method —
distributed over all available devices when >1 — and reports the
convergence certificate.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.core import IPIOptions, generators, solve
from repro.core.io import load_mdp
from repro.launch.mesh import make_host_mesh


def build_instance(args):
    if args.load:
        return load_mdp(args.load)
    if args.instance == "garnet":
        return generators.garnet(args.n, args.m, args.k, gamma=args.gamma,
                                 seed=args.seed)
    if args.instance == "maze2d":
        return generators.maze2d(args.size, gamma=args.gamma, seed=args.seed)
    if args.instance == "sis":
        return generators.sis(args.n, args.m, gamma=args.gamma,
                              seed=args.seed)
    if args.instance == "chain_walk":
        return generators.chain_walk(args.n, gamma=args.gamma)
    raise ValueError(args.instance)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--instance", default="garnet",
                    choices=["garnet", "maze2d", "sis", "chain_walk"])
    ap.add_argument("--load", default=None, help="load an MDP saved by io.py")
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--gamma", type=float, default=0.99)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default="ipi_gmres")
    ap.add_argument("--atol", type=float, default=1e-8)
    ap.add_argument("--max-outer", type=int, default=2000)
    ap.add_argument("--layout", default="1d", choices=["1d", "2d"])
    ap.add_argument("--dtype", default="float64")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--single-device", action="store_true")
    args = ap.parse_args(argv)

    if args.dtype == "float64":
        jax.config.update("jax_enable_x64", True)

    mdp = build_instance(args)
    print(f"[solve] instance={args.instance} n={mdp.n_global} "
          f"m={mdp.m_global} nnz/row={mdp.nnz_per_row} gamma={mdp.gamma}")
    opts = IPIOptions(method=args.method, atol=args.atol,
                      max_outer=args.max_outer, dtype=args.dtype)
    mesh = None
    if not args.single_device and len(jax.devices()) > 1:
        n_dev = len(jax.devices())
        shape = (n_dev // 2, 2) if args.layout == "2d" and n_dev >= 2 \
            else (n_dev, 1)
        mesh = make_host_mesh(shape)
        print(f"[solve] distributed over mesh {dict(mesh.shape)} "
              f"layout={args.layout}")
    t0 = time.time()
    r = solve(mdp, opts, mesh=mesh, layout=args.layout,
              checkpoint_dir=args.ckpt_dir, verbose=True)
    print(f"[solve] {r.summary()}  wall={time.time()-t0:.2f}s")
    print(f"[solve] ||v - v*||_inf <= {r.gap_bound:.3e} (certificate)")
    return 0 if r.converged else 1


if __name__ == "__main__":
    raise SystemExit(main())
