"""Elastic restart demo: survive a node-count change mid-solve.

Checkpoints are mesh-agnostic (utils/checkpoint.py saves unsharded), so a
job that loses devices restarts on a smaller mesh and continues from the
same iterate — the recovery path a 1000-node deployment needs.  This driver
simulates it in-process by re-sharding the restored state onto a new mesh.

    python -m repro.launch.elastic   # (uses XLA_FLAGS to fake 8 devices)
"""

import os

if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import argparse
import shutil
import tempfile

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--gamma", type=float, default=0.995)
    args = ap.parse_args(argv)

    jax.config.update("jax_enable_x64", True)
    from repro.core import IPIOptions, generators
    from repro.core.driver import solve

    mdp = generators.garnet(args.n, 12, 6, gamma=args.gamma, seed=5)
    opts = IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64")
    ckpt_dir = tempfile.mkdtemp(prefix="elastic_")
    try:
        from repro.launch.mesh import mesh_kwargs
        mesh8 = jax.make_mesh((8, 1), ("data", "model"), **mesh_kwargs(2))
        short = IPIOptions(method="ipi_gmres", atol=1e-9, dtype="float64",
                           max_outer=3)
        r1 = solve(mdp, short, mesh=mesh8, checkpoint_dir=ckpt_dir, chunk=1)
        print(f"[elastic] phase 1 on 8 devices: k={r1.outer_iterations} "
              f"res={r1.residual:.3e} (simulated failure)")

        # "lose" half the fleet: resume on a 4-device mesh
        mesh4 = jax.make_mesh(
            (4, 1), ("data", "model"),
            **mesh_kwargs(2, devices=np.array(jax.devices()[:4])))
        r2 = solve(mdp, opts, mesh=mesh4, checkpoint_dir=ckpt_dir, chunk=16)
        print(f"[elastic] phase 2 on 4 devices: {r2.summary()}")

        r_ref = solve(mdp, opts)
        dv = np.abs(r2.v - r_ref.v).max()
        print(f"[elastic] |v - v_ref|_inf = {dv:.2e}")
        assert r2.converged and dv < 1e-9
        print("[elastic] OK: elastic restart preserved the solve exactly")
        return 0
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    raise SystemExit(main())
