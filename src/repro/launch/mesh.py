"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state; the 512-device dry-run sets XLA_FLAGS before any
jax import (see dryrun.py).
"""

from __future__ import annotations

import jax
import numpy as np


def mesh_kwargs(n_axes: int, **extra) -> dict:
    """``jax.make_mesh`` kwargs, with ``axis_types`` only where the
    installed jax supports it (absent pre-0.5: Auto is the default there)."""
    if hasattr(jax.sharding, "AxisType"):
        extra["axis_types"] = (jax.sharding.AxisType.Auto,) * n_axes
    return extra


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_kwargs(len(axes)))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return jax.make_mesh(shape, axes, **mesh_kwargs(len(axes)))


def make_fleet_mesh(fleet: int, *, layout: str = "fleet", devices=None):
    """Mesh with a leading ``fleet`` axis for fleet-sharded ``solve_many``.

    ``fleet`` devices shard the instance dim; the remaining ``n // fleet``
    devices shard states within each fleet slice (``layout="fleet"``), or
    states x actions (``layout="fleet2d"``: the trailing axis of size 2 —
    or 1 when indivisible — shards actions).
    """
    devs = list(devices) if devices is not None else jax.devices()
    n = len(devs)
    if fleet < 1 or n % fleet:
        raise ValueError(f"fleet-axis size {fleet} must divide the device "
                         f"count {n}")
    rest = n // fleet
    if layout == "fleet":
        shape, names = (fleet, rest), ("fleet", "data")
    elif layout == "fleet2d":
        am = 2 if rest % 2 == 0 and rest >= 2 else 1
        shape, names = (fleet, rest // am, am), ("fleet", "data", "model")
    else:
        raise ValueError(f"make_fleet_mesh serves the fleet layouts, "
                         f"got {layout!r}")
    extra = {} if devices is None else dict(devices=np.asarray(devs))
    return jax.make_mesh(shape, names, **mesh_kwargs(len(names), **extra))
