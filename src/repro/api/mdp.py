"""The user-facing MDP builder — madupite's ``MDP`` object.

madupite builds MDPs from arrays, from files, or from *Python callables*
(``setTransitionProbabilitiesFunc`` / ``setStageCostFunc``), and tags them
min-cost or max-reward.  This builder mirrors that surface over the core
containers (:class:`repro.core.mdp.EllMDP` / ``DenseMDP``):

* :meth:`MDP.from_arrays` — explicit ELL (``idx``/``val``/``cost``) or dense
  (``p``/``cost``) tensors;
* :meth:`MDP.from_file` — the block-manifest format of
  :mod:`repro.core.io` (each worker can load only its rows);
* :meth:`MDP.from_generator` — the built-in instance families
  (:data:`repro.core.generators.REGISTRY`);
* :meth:`MDP.from_functions` — the MDP is *defined by callables*
  ``P_fn(s, a) -> (successor ids, probabilities)`` and ``g_fn(s, a) ->
  stage cost`` and never materialized host-side as one tensor: the session
  layer materializes each device's ELL block **shard-locally on device**
  (``jax.make_array_from_callback``), so million-state MDPs fit in
  aggregate device memory even when no single host buffer could hold them.

``mode="mincost"`` (default) solves ``min_a``; ``mode="maxreward"`` reads
``cost`` as a reward and solves ``max_a`` — threaded through the solver as
:class:`repro.core.ipi.IPIOptions` ``.mode``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import io as core_io
from repro.core import partition
from repro.core.generators import REGISTRY as GENERATORS
from repro.core.ipi import MODES
from repro.core.mdp import DenseMDP, EllMDP
from repro.core.mdp import MDP as CoreMDP

__all__ = ["MDP"]

_BIG = 1e30


@dataclasses.dataclass(frozen=True)
class _FunctionSpec:
    """Deferred MDP definition: callables + shape, materialized per mesh."""

    p_fn: Callable
    g_fn: Callable
    n: int
    m: int
    nnz: int
    gamma: float
    vectorized: bool


class MDP:
    """A built (or deferred) MDP plus its solve semantics (``mode``).

    Hand it to :meth:`repro.api.Session.solve`; or call :meth:`build` for
    the raw core container.
    """

    def __init__(self, core: CoreMDP | None, *, mode: str = "mincost",
                 spec: _FunctionSpec | None = None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; pick one of {MODES}")
        if (core is None) == (spec is None):
            raise ValueError("MDP wants exactly one of a core container or "
                             "a function spec; use the from_* constructors")
        self._core = core
        self._spec = spec
        self.mode = mode
        self._device_cache: dict = {}

    # ---- constructors ------------------------------------------------------
    @classmethod
    def from_arrays(cls, *, idx=None, val=None, cost=None, p=None,
                    gamma: float = 0.99, mode: str = "mincost",
                    validate: bool = True) -> "MDP":
        """ELL (``idx`` + ``val`` + ``cost``) or dense (``p`` + ``cost``)."""
        import jax.numpy as jnp
        if cost is None:
            raise ValueError("from_arrays requires cost (the stage "
                             "cost/reward table g(s, a))")
        cost = jnp.asarray(cost, jnp.float32)
        if p is not None:
            if idx is not None or val is not None:
                raise ValueError("pass either dense p or ELL idx/val, "
                                 "not both")
            p = jnp.asarray(p, jnp.float32)
            core = DenseMDP(p=p, cost=cost, gamma=float(gamma),
                            n_global=p.shape[0], m_global=p.shape[1])
        elif idx is None or val is None:
            raise ValueError("from_arrays requires idx+val (ELL) or p "
                             "(dense)")
        else:
            idx = jnp.asarray(idx, jnp.int32)
            val = jnp.asarray(val, jnp.float32)
            core = EllMDP(idx=idx, val=val, cost=cost, gamma=float(gamma),
                          n_global=idx.shape[0], m_global=idx.shape[1])
        if validate:
            core.validate()
        return cls(core, mode=mode)

    @classmethod
    def from_file(cls, path: str, *, mode: str | None = None,
                  rows: tuple[int, int] | None = None) -> "MDP":
        """Load the block-manifest format of :mod:`repro.core.io`.  The
        manifest's stored ``mode`` (if any) is used unless overridden."""
        if mode is None:
            mode = core_io.load_manifest(path).get("mode") or "mincost"
        return cls(core_io.load_mdp(path, rows=rows), mode=mode)

    @classmethod
    def from_generator(cls, name: str, *, mode: str = "mincost",
                       **kw) -> "MDP":
        """One of the built-in instance families
        (``garnet``/``maze2d``/``sis``/``chain_walk``)."""
        if name not in GENERATORS:
            raise ValueError(f"unknown generator {name!r}; pick one of "
                             f"{sorted(GENERATORS)}")
        return cls(GENERATORS[name](**kw), mode=mode)

    @classmethod
    def from_functions(cls, P_fn: Callable, g_fn: Callable, n: int, m: int,
                       *, nnz: int, gamma: float = 0.99,
                       mode: str = "mincost",
                       vectorized: bool = False) -> "MDP":
        """Define the MDP by callables; materialize lazily, shard-locally.

        ``P_fn(s, a) -> (ids, probs)`` gives state ``s``'s successors under
        action ``a`` (at most ``nnz`` of them, probabilities summing to 1);
        ``g_fn(s, a) -> float`` the stage cost (or reward, for
        ``mode="maxreward"``).  With ``vectorized=True`` the callables take
        a whole *array* of states at once — ``P_fn(rows, a) -> (ids
        (len(rows), nnz), probs (len(rows), nnz))``, ``g_fn(rows, a) ->
        (len(rows),)`` — which is strongly recommended beyond ~10^5 states.

        Nothing is evaluated here.  At solve time the session materializes
        exactly the row block each device owns (padding included) directly
        into that device's shard, so no host-side ``(n, m, nnz)`` tensor is
        ever built.
        """
        if n < 1 or m < 1 or nnz < 1:
            raise ValueError(f"from_functions needs n, m, nnz >= 1, got "
                             f"n={n} m={m} nnz={nnz}")
        if not 0.0 < gamma < 1.0:
            raise ValueError(f"gamma must lie in (0, 1), got {gamma}")
        return cls(None, mode=mode,
                   spec=_FunctionSpec(P_fn, g_fn, int(n), int(m), int(nnz),
                                      float(gamma), bool(vectorized)))

    # ---- introspection -----------------------------------------------------
    @property
    def n(self) -> int:
        """True (unpadded) global state count."""
        return self._spec.n if self._spec else self._core.n_global

    @property
    def m(self) -> int:
        return self._spec.m if self._spec else self._core.m_global

    @property
    def gamma(self) -> float:
        return self._spec.gamma if self._spec else self._core.gamma

    @property
    def deferred(self) -> bool:
        """True for a function-backed MDP not yet materialized."""
        return self._spec is not None

    def __repr__(self) -> str:
        kind = "functions" if self.deferred else type(self._core).__name__
        return (f"MDP({kind}, n={self.n}, m={self.m}, "
                f"gamma={self.gamma}, mode={self.mode!r})")

    # ---- materialization ---------------------------------------------------
    def build(self) -> CoreMDP:
        """The core container, materialized host-side if function-backed."""
        if self._core is not None:
            return self._core
        if None not in self._device_cache:
            s = self._spec
            idx, val, cost = self._block(np.arange(s.n), np.arange(s.m),
                                         n_pad_to=s.n, m_pad_to=s.m)
            import jax.numpy as jnp
            self._device_cache[None] = EllMDP(
                idx=jnp.asarray(idx), val=jnp.asarray(val),
                cost=jnp.asarray(cost), gamma=s.gamma, n_global=s.n,
                m_global=s.m)
        return self._device_cache[None]

    def place(self, mesh, layout: str = "1d", *,
              mode: str | None = None) -> CoreMDP:
        """The core container placed on ``mesh`` under ``layout``.

        Array-backed MDPs are returned as-is (the driver pads + places
        them).  Function-backed MDPs are materialized **shard-locally**:
        each addressable device's padded ELL block is computed from the
        callables and written straight into that device's shard via
        ``jax.make_array_from_callback``, then the driver's placement
        detects the arrays as already placed
        (:func:`repro.core.partition.already_placed`) and passes them
        through.

        ``mode`` is the mode the *solve* will run under (defaults to this
        builder's) — padded action columns carry a sign-dependent
        never-greedy cost, so the padding must match the solve, not the
        builder, when a per-call override flips it.
        """
        if self._core is not None:
            return self._core
        if mesh is None:
            return self.build()
        key = (mesh, layout, mode or self.mode)
        if key not in self._device_cache:
            self._device_cache[key] = self._place_sharded(mesh, layout,
                                                          mode or self.mode)
        return self._device_cache[key]

    def _place_sharded(self, mesh, layout: str, mode: str) -> EllMDP:
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = partition.mesh_axes(mesh, layout)
        if axes.fleet is not None:
            raise ValueError(f"layout {layout!r} shards the fleet dim; a "
                             "single function-backed MDP places under "
                             "'1d'/'2d'")
        s = self._spec
        n_to = -(-s.n // partition._axis_size(mesh, axes.state)) \
            * partition._axis_size(mesh, axes.state)
        m_to = -(-s.m // partition._axis_size(mesh, axes.action)) \
            * partition._axis_size(mesh, axes.action)
        blocks: dict = {}

        def block(index) -> tuple:
            rs, as_ = index[0], index[1]
            lo, hi, _ = rs.indices(n_to)
            alo, ahi, _ = as_.indices(m_to)
            bkey = (lo, hi, alo, ahi)
            if bkey not in blocks:
                blocks[bkey] = self._block(
                    np.arange(lo, hi), np.arange(alo, ahi),
                    n_pad_to=n_to, m_pad_to=m_to, mode=mode)
            return blocks[bkey]

        sh3 = NamedSharding(mesh, P(axes.state, axes.action, None))
        sh2 = NamedSharding(mesh, P(axes.state, axes.action))
        idx = jax.make_array_from_callback(
            (n_to, m_to, s.nnz), sh3, lambda i: block(i)[0])
        val = jax.make_array_from_callback(
            (n_to, m_to, s.nnz), sh3, lambda i: block(i)[1])
        cost = jax.make_array_from_callback(
            (n_to, m_to), sh2, lambda i: block(i)[2])
        blocks.clear()
        return EllMDP(idx=idx, val=val, cost=cost, gamma=s.gamma,
                      n_global=n_to, m_global=m_to)

    def _block(self, rows: np.ndarray, acts: np.ndarray, *,
               n_pad_to: int, m_pad_to: int,
               mode: str | None = None) -> tuple:
        """One ELL block for global ``rows`` x ``acts`` (padding included).

        Padding mirrors :func:`repro.core.partition.pad_mdp` exactly:
        padded states are zero-cost absorbing self-loops; padded actions
        are never-greedy under the solve ``mode`` (cost ``+BIG`` for
        mincost, ``-BIG`` for maxreward).
        """
        s = self._spec
        big = _BIG if (mode or self.mode) == "mincost" else -_BIG
        nr, na, K = len(rows), len(acts), s.nnz
        idx = np.zeros((nr, na, K), np.int32)
        val = np.zeros((nr, na, K), np.float32)
        cost = np.zeros((nr, na), np.float32)
        # pad defaults: absorbing self-loop on slot 0 (padded rows), and
        # never-greedy cost on padded action columns
        idx[..., 0] = rows[:, None].astype(np.int32)
        val[..., 0] = 1.0
        pad_a = acts >= s.m
        cost[:, pad_a] = big
        idx[:, pad_a, 0] = 0          # padded actions point at state 0
        real_r = rows < s.n
        if not real_r.any():
            return idx, val, cost
        rr = rows[real_r]
        for j, a in enumerate(acts):
            if a >= s.m:
                continue
            if s.vectorized:
                ids, probs = s.p_fn(rr, int(a))
                ids = np.asarray(ids)
                probs = np.asarray(probs)
                if ids.shape != (len(rr), K) or probs.shape != ids.shape:
                    raise ValueError(
                        f"vectorized P_fn must return (ids, probs) of "
                        f"shape ({len(rr)}, {K}), got {ids.shape} / "
                        f"{probs.shape}")
                idx[real_r, j, :] = ids
                val[real_r, j, :] = probs
                cost[real_r, j] = np.asarray(s.g_fn(rr, int(a)))
            else:
                for i, r in zip(np.nonzero(real_r)[0], rr):
                    ids, probs = s.p_fn(int(r), int(a))
                    ids = np.atleast_1d(np.asarray(ids))
                    probs = np.atleast_1d(np.asarray(probs))
                    if len(ids) > K:
                        raise ValueError(
                            f"P_fn({r}, {a}) returned {len(ids)} "
                            f"successors > nnz={K}")
                    row_i = np.zeros(K, np.int32)
                    row_v = np.zeros(K, np.float32)
                    row_i[:len(ids)] = ids
                    row_v[:len(probs)] = probs
                    idx[i, j, :] = row_i
                    val[i, j, :] = row_v
                    cost[i, j] = float(s.g_fn(int(r), int(a)))
        # validate only the real (row, action) entries: padding self-loops
        # legitimately point at padded state ids >= s.n
        real = idx[real_r][:, acts < s.m]
        if real.size and ((real < 0).any() or (real >= s.n).any()):
            raise ValueError("P_fn produced successor ids outside "
                             f"[0, {s.n})")
        return idx, val, cost

    # ---- persistence -------------------------------------------------------
    def save(self, path: str, n_blocks: int = 1) -> None:
        """Write the block-manifest format (materializes if deferred)."""
        core = self.build()
        if not isinstance(core, EllMDP):
            raise ValueError("save() supports the ELL representation only")
        core_io.save_mdp(path, core, n_blocks=n_blocks, mode=self.mode)
