"""The user-facing MDP builder — madupite's ``MDP`` object.

madupite builds MDPs from arrays, from files, or from *Python callables*
(``setTransitionProbabilitiesFunc`` / ``setStageCostFunc``), and tags them
min-cost or max-reward.  This builder mirrors that surface over the core
containers (:class:`repro.core.mdp.EllMDP` / ``DenseMDP``):

* :meth:`MDP.from_arrays` — explicit ELL (``idx``/``val``/``cost``) or dense
  (``p``/``cost``) tensors;
* :meth:`MDP.from_file` — the block-manifest format of
  :mod:`repro.core.io` (each worker can load only its rows);
* :meth:`MDP.from_generator` — the built-in instance families
  (:data:`repro.core.generators.REGISTRY`), optionally *deferred*
  (``deferred=True``: jit-able device constructors from
  :data:`repro.core.generators.FN_REGISTRY`, so instances scale past host
  memory);
* :meth:`MDP.from_functions` — the MDP is *defined by callables*
  ``P_fn(s, a) -> (successor ids, probabilities)`` and ``g_fn(s, a) ->
  stage cost`` and never materialized host-side as one tensor.

Function-backed MDPs materialize through one of two pipelines:

* **device** (the scale path): the constructors are *jit-able* — traced
  over a state-index array with the action as a static Python int — and
  each shard's padded ELL block is produced **inside a compiled program**
  (index-space ``iota`` + ``vmap``, ``lax.map`` over row chunks), written
  straight into that device's shard.  No host numpy runs anywhere in the
  loop, so construction throughput is device-bound and million/billion
  state spaces never touch a host-global tensor.
* **host** (the compatibility path): plain-numpy callables are evaluated
  row-block at a time on the host and placed per shard via
  ``jax.make_array_from_callback`` — exactly the old behavior.

The pipeline is picked per materialization by
:meth:`MDP.materialization`: a ``device=True/False`` pin on
:meth:`from_functions` wins, then the session's ``-mdp_materialize``
option, then auto-detection (``jax.eval_shape`` on the constructors —
numpy callables fail tracing and fall back to host).

Fleets of function-backed MDPs place under the *fleet-sharded* layouts
too (:func:`place_function_fleet`): each device materializes only the
``(B_local, n_local, m_local)`` block of the instances it owns, so both
the instance dim and the state dim of the construction scale with the
mesh.

``mode="mincost"`` (default) solves ``min_a``; ``mode="maxreward"`` reads
``cost`` as a reward and solves ``max_a`` — threaded through the solver as
:class:`repro.core.ipi.IPIOptions` ``.mode``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import io as core_io
from repro.core import partition
from repro.core.generators import FN_REGISTRY as FN_GENERATORS
from repro.core.generators import REGISTRY as GENERATORS
from repro.core.ipi import MODES
from repro.core.mdp import DenseMDP, EllMDP, MatrixFreeMDP
from repro.core.mdp import MDP as CoreMDP
from repro.kernels import matrix_free

__all__ = ["MDP", "place_function_fleet"]

_BIG = 1e30

# rows per lax.map step in the device pipeline: bounds the constructor
# intermediates to a fixed chunk so a 100M-row shard runs the same per-step
# working set as a 1M-row one.  Large on purpose — the map carry machinery
# costs ~10x a fused whole-block build, so shards at or below the chunk
# (the common case) take the single-vmap fast path
_DEVICE_CHUNK = 1 << 20

MATERIALIZE_MODES = ("auto", "host", "device", "matrix_free")


@dataclasses.dataclass(frozen=True)
class _FunctionSpec:
    """Deferred MDP definition: callables + shape, materialized per mesh.

    ``device`` pins the pipeline (``None`` = resolve per materialization:
    option, then trace auto-detection)."""

    p_fn: Callable
    g_fn: Callable
    n: int
    m: int
    nnz: int
    gamma: float
    vectorized: bool
    device: bool | None = None
    band: int | None = None     # declared |successor - row| bound, or None


# --------------------------------------------------------------------------- #
# Device-side (jit) materialization pipeline                                  #
# --------------------------------------------------------------------------- #

def _device_rows_block(spec: _FunctionSpec, rows, acts: tuple, mode: str):
    """One traced ELL block: ``rows`` (traced global ids) x ``acts``
    (static global action ids, padding included).

    Delegates to the kernel-layer builder
    :func:`repro.kernels.matrix_free.build_rows_block`: the SAME traced
    code materializes device shards here and rebuilds transient row tiles
    inside the matrix-free backup, which is what makes the materialized
    and matrix-free paths bit-identical *by construction* — there is one
    builder, not two implementations to keep in sync.
    """
    return matrix_free.build_rows_block(spec, rows, acts, mode)


def _map_row_chunks(fn, rows, pad_id):
    """Apply ``fn`` over ``rows`` in fixed ``_DEVICE_CHUNK`` pieces via
    ``lax.map`` (rows padded with ``pad_id`` — a padding state id, whose
    block content is discarded — to the chunk multiple)."""
    import jax
    import jax.numpy as jnp

    n_rows = rows.shape[0]
    if n_rows <= _DEVICE_CHUNK:
        return fn(rows)
    pad = (-n_rows) % _DEVICE_CHUNK
    if pad:
        rows = jnp.concatenate(
            [rows, jnp.full((pad,), pad_id, rows.dtype)])
    out = jax.lax.map(fn, rows.reshape(-1, _DEVICE_CHUNK))
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n_rows + pad,) + x.shape[2:])[:n_rows], out)


# Compiled block builders are shared *across* MDP objects: a fleet sweep
# reusing one (P_fn, g_fn) pair with different gammas compiles exactly one
# program per (shape, action-block, mode).  Bounded like the driver's
# run-chunk cache.  Entries hold compiled code whose closures pin the
# constructor callables (and anything *they* close over), so a full
# ``MDP.evict()`` also drops this MDP's entries — long-lived serving
# processes would otherwise accumulate dead constructors' programs.
_BUILDER_CACHE: dict = {}


def _device_builder(spec: _FunctionSpec, n_rows: int, acts: tuple,
                    mode: str):
    """jit'd ``f(row0) -> (idx, val, cost)`` for ``n_rows`` rows starting
    at (traced) global row ``row0``, covering action ids ``acts``."""
    import jax
    import jax.numpy as jnp

    key = (dataclasses.replace(spec, gamma=0.0), n_rows, acts, mode)
    f = _BUILDER_CACHE.get(key)
    if f is None:
        if len(_BUILDER_CACHE) > 64:
            _BUILDER_CACHE.pop(next(iter(_BUILDER_CACHE)))

        def build(row0):
            rows = row0 + jnp.arange(n_rows, dtype=jnp.int32)
            idx, val, cost, bad = _map_row_chunks(
                lambda r: _device_rows_block(spec, r, acts, mode),
                rows, jnp.int32(min(spec.n, np.iinfo(np.int32).max)))
            return idx, val, cost, bad.sum(0)

        f = jax.jit(build)
        _BUILDER_CACHE[key] = f
    return f


def _checked_block(builder, row0, spec: _FunctionSpec) -> tuple:
    """Run a compiled block builder and surface its validation counters as
    the host-path errors (one scalar readback per block)."""
    import jax.numpy as jnp
    idx, val, cost, bad = builder(jnp.int32(row0))
    n_ids, n_sum = (int(x) for x in np.asarray(bad))
    if n_ids:
        raise ValueError(f"P_fn produced successor ids outside "
                         f"[0, {spec.n}) ({n_ids} offending entries)")
    if n_sum:
        raise ValueError(f"P_fn probability rows do not sum to ~1 "
                         f"({n_sum} offending (s, a) rows)")
    return idx, val, cost


def _dummy_fleet_block(lo: int, n_rows: int, n_acts: int, K: int):
    """A zero-cost dummy instance block (fleet padding): valid absorbing
    self-loops, optimal value identically 0 — frozen at k=0."""
    import jax.numpy as jnp
    rows = lo + jnp.arange(n_rows, dtype=jnp.int32)
    idx = jnp.zeros((n_rows, n_acts, K), jnp.int32).at[..., 0].set(
        rows[:, None])
    val = jnp.zeros((n_rows, n_acts, K), jnp.float32).at[..., 0].set(1.0)
    return idx, val, jnp.zeros((n_rows, n_acts), jnp.float32)


class MDP:
    """A built (or deferred) MDP plus its solve semantics (``mode``).

    Hand it to :meth:`repro.api.Session.solve`; or call :meth:`build` for
    the raw core container.
    """

    def __init__(self, core: CoreMDP | None, *, mode: str = "mincost",
                 spec: _FunctionSpec | None = None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; pick one of {MODES}")
        if (core is None) == (spec is None):
            raise ValueError("MDP wants exactly one of a core container or "
                             "a function spec; use the from_* constructors")
        self._core = core
        self._spec = spec
        self.mode = mode
        self._device_cache: dict = {}
        self._trace_ok: tuple | None = None   # lazily-probed (ok, reason)

    # ---- constructors ------------------------------------------------------
    @classmethod
    def from_arrays(cls, *, idx=None, val=None, cost=None, p=None,
                    gamma: float = 0.99, mode: str = "mincost",
                    validate: bool = True) -> "MDP":
        """ELL (``idx`` + ``val`` + ``cost``) or dense (``p`` + ``cost``)."""
        import jax.numpy as jnp
        if cost is None:
            raise ValueError("from_arrays requires cost (the stage "
                             "cost/reward table g(s, a))")
        cost = jnp.asarray(cost, jnp.float32)
        if p is not None:
            if idx is not None or val is not None:
                raise ValueError("pass either dense p or ELL idx/val, "
                                 "not both")
            p = jnp.asarray(p, jnp.float32)
            core = DenseMDP(p=p, cost=cost, gamma=float(gamma),
                            n_global=p.shape[0], m_global=p.shape[1])
        elif idx is None or val is None:
            raise ValueError("from_arrays requires idx+val (ELL) or p "
                             "(dense)")
        else:
            idx = jnp.asarray(idx, jnp.int32)
            val = jnp.asarray(val, jnp.float32)
            core = EllMDP(idx=idx, val=val, cost=cost, gamma=float(gamma),
                          n_global=idx.shape[0], m_global=idx.shape[1])
        if validate:
            core.validate()
        return cls(core, mode=mode)

    @classmethod
    def from_file(cls, path: str, *, mode: str | None = None,
                  rows: tuple[int, int] | None = None) -> "MDP":
        """Load the block-manifest format of :mod:`repro.core.io`.  The
        manifest's stored ``mode`` (if any) is used unless overridden."""
        if mode is None:
            mode = core_io.load_manifest(path).get("mode") or "mincost"
        return cls(core_io.load_mdp(path, rows=rows), mode=mode)

    @classmethod
    def from_generator(cls, name: str, *, mode: str = "mincost",
                       deferred: bool = False, **kw) -> "MDP":
        """One of the built-in instance families
        (``garnet``/``maze2d``/``sis``/``chain_walk``).

        ``deferred=True`` returns a *function-backed* MDP built on the
        family's jit-able device constructors
        (:data:`repro.core.generators.FN_REGISTRY`): nothing materializes
        until placement, and each shard's block is computed on device —
        the construction path that scales past host memory.
        """
        if deferred:
            if name not in FN_GENERATORS:
                raise ValueError(
                    f"unknown generator {name!r}; deferred families: "
                    f"{sorted(FN_GENERATORS)}")
            return cls.from_functions(**FN_GENERATORS[name](**kw),
                                      mode=mode, device=True)
        if name not in GENERATORS:
            raise ValueError(f"unknown generator {name!r}; pick one of "
                             f"{sorted(GENERATORS)}")
        return cls(GENERATORS[name](**kw), mode=mode)

    @classmethod
    def from_functions(cls, P_fn: Callable, g_fn: Callable, n: int, m: int,
                       *, nnz: int, gamma: float = 0.99,
                       mode: str = "mincost",
                       vectorized: bool = False,
                       device: bool | None = None,
                       band: int | None = None) -> "MDP":
        """Define the MDP by callables; materialize lazily, shard-locally.

        ``P_fn(s, a) -> (ids, probs)`` gives state ``s``'s successors under
        action ``a`` (at most ``nnz`` of them, probabilities summing to 1);
        ``g_fn(s, a) -> float`` the stage cost (or reward, for
        ``mode="maxreward"``).  With ``vectorized=True`` the callables take
        a whole *array* of states at once — ``P_fn(rows, a) -> (ids
        (len(rows), nnz), probs (len(rows), nnz))``, ``g_fn(rows, a) ->
        (len(rows),)``.

        ``device`` picks the materialization pipeline:

        * ``True`` — the callables are jit-able (written in ``jax.numpy``
          over a *traced* state-index input; the action stays a static
          Python int) and every shard's block is computed inside a
          compiled program.  Device constructors must return exactly
          ``nnz`` slots per row (zero-pad unused ones) and tolerate row
          ids ``>= n`` (shard padding; outputs masked).
        * ``False`` — plain-numpy callables, evaluated on the host per
          shard (the compatibility path).
        * ``None`` (default) — decided at materialization time by the
          ``-mdp_materialize`` option and trace auto-detection.

        ``band`` optionally declares the matrix bandwidth: every
        nonzero-weight successor satisfies ``|successor - row| <= band``.
        Matrix-free solves have no stored table to measure, so the banded
        halo exchange and the overlapped interior/frontier split are only
        available when the bandwidth is declared here (``None`` = rows
        reach globally; still solvable, via the all-gather layout).

        Nothing is evaluated here.  At solve time the session materializes
        exactly the row block each device owns (padding included) directly
        into that device's shard — or, under ``-mdp_materialize
        matrix_free``, never materializes at all and re-traces the
        constructors inside every Bellman backup.
        """
        if n < 1 or m < 1 or nnz < 1:
            raise ValueError(f"from_functions needs n, m, nnz >= 1, got "
                             f"n={n} m={m} nnz={nnz}")
        if not 0.0 < gamma < 1.0:
            raise ValueError(f"gamma must lie in (0, 1), got {gamma}")
        if band is not None and band < 0:
            raise ValueError(f"band must be >= 0 (or None), got {band}")
        return cls(None, mode=mode,
                   spec=_FunctionSpec(P_fn, g_fn, int(n), int(m), int(nnz),
                                      float(gamma), bool(vectorized),
                                      None if device is None else
                                      bool(device),
                                      None if band is None else int(band)))

    # ---- introspection -----------------------------------------------------
    @property
    def n(self) -> int:
        """True (unpadded) global state count."""
        return self._spec.n if self._spec else self._core.n_global

    @property
    def m(self) -> int:
        return self._spec.m if self._spec else self._core.m_global

    @property
    def gamma(self) -> float:
        return self._spec.gamma if self._spec else self._core.gamma

    @property
    def deferred(self) -> bool:
        """True for a function-backed MDP not yet materialized."""
        return self._spec is not None

    def __repr__(self) -> str:
        kind = "functions" if self.deferred else type(self._core).__name__
        return (f"MDP({kind}, n={self.n}, m={self.m}, "
                f"gamma={self.gamma}, mode={self.mode!r})")

    # ---- materialization pipeline selection --------------------------------
    def _device_traceable(self) -> tuple[bool, str | None]:
        """Probe (once) whether the constructors trace: ``eval_shape`` on a
        tiny abstract row block.  numpy callables raise a tracer-conversion
        error here and select the host pipeline."""
        if self._trace_ok is None:
            import jax
            import jax.numpy as jnp
            spec = self._spec
            try:
                jax.eval_shape(
                    lambda r: _device_rows_block(spec, r, (0,), "mincost"),
                    jax.ShapeDtypeStruct((4,), jnp.int32))
                self._trace_ok = (True, None)
            except Exception as e:          # noqa: BLE001 — any trace failure
                self._trace_ok = (False, f"{type(e).__name__}: {e}")
        return self._trace_ok

    def materialization(self, option: str = "auto") -> str:
        """Resolve the pipeline for this MDP: ``"device"``, ``"host"`` or
        ``"matrix_free"``.

        Precedence: the ``device=`` pin given to :meth:`from_functions`,
        then ``option`` (the ``-mdp_materialize`` database value), then
        auto-detection.  Raises when device (or matrix-free, which needs
        the same jit-ability) is *required* but the constructors do not
        trace.  ``"auto"`` never selects matrix-free: recompute-over-store
        is a deliberate memory/compute trade the user opts into.
        """
        if not self.deferred:
            raise ValueError("materialization() applies to function-backed "
                             "MDPs only")
        if option not in MATERIALIZE_MODES:
            raise ValueError(f"unknown materialization {option!r}; pick one "
                             f"of {MATERIALIZE_MODES}")
        pinned = self._spec.device
        if option == "matrix_free":
            if pinned is False:
                return "host"   # explicit host pin wins, like for "device"
            ok, why = self._device_traceable()
            if ok:
                return "matrix_free"
            raise ValueError(
                f"matrix-free solving re-traces P_fn/g_fn inside every "
                f"Bellman backup, but the constructors do not trace "
                f"({why}); write them in jax.numpy over the traced state "
                f"indices, or drop to -mdp_materialize auto/host")
        if pinned is False or (pinned is None and option == "host"):
            return "host"
        ok, why = self._device_traceable()
        if ok:
            return "device"
        if pinned is True or option == "device":
            raise ValueError(
                f"device materialization was requested but the constructors "
                f"do not trace ({why}); write P_fn/g_fn in jax.numpy over "
                f"the traced state indices, or drop to device=False / "
                f"-mdp_materialize host")
        return "host"

    def _row_spec(self) -> matrix_free.RowSpec:
        """This MDP's static row-constructor spec for the matrix-free
        operator (gamma-free: a sweep shares one spec, one program)."""
        s = self._spec
        return matrix_free.RowSpec(s.p_fn, s.g_fn, s.n, s.m, s.nnz,
                                   s.vectorized, s.band)

    # ---- materialization ---------------------------------------------------
    def build(self, materialize: str = "auto") -> CoreMDP:
        """The core container, fully materialized (single-device / host
        placement).  Function-backed MDPs run the device pipeline (one
        compiled program over the whole index space) when it applies."""
        if self._core is not None:
            return self._core
        key = ("built", self.materialization(materialize))
        if key not in self._device_cache:
            import jax.numpy as jnp
            s = self._spec
            if key[1] == "matrix_free":
                # the operator re-traces the constructors per sweep, where
                # a bad P_fn cannot raise host-side — validate a sampled
                # row block once, through the same checked builder the
                # materialized pipeline uses
                f = _device_builder(s, min(s.n, 4096),
                                    tuple(range(s.m)), self.mode)
                _checked_block(f, 0, s)
                self._device_cache[key] = MatrixFreeMDP(
                    tag=jnp.zeros((s.n,), jnp.int8), gamma=s.gamma,
                    n_global=s.n, m_global=s.m, spec=self._row_spec())
                return self._device_cache[key]
            if key[1] == "device":
                f = _device_builder(s, s.n, tuple(range(s.m)), "mincost")
                idx, val, cost = _checked_block(f, 0, s)
            else:
                idx, val, cost = self._block(np.arange(s.n), np.arange(s.m),
                                             n_pad_to=s.n, m_pad_to=s.m)
            self._device_cache[key] = EllMDP(
                idx=jnp.asarray(idx), val=jnp.asarray(val),
                cost=jnp.asarray(cost), gamma=s.gamma, n_global=s.n,
                m_global=s.m)
        return self._device_cache[key]

    def place(self, mesh, layout: str = "1d", *, mode: str | None = None,
              materialize: str = "auto") -> CoreMDP:
        """The core container placed on ``mesh`` under ``layout``.

        Array-backed MDPs are returned as-is (the driver pads + places
        them).  Function-backed MDPs are materialized **shard-locally**:
        each addressable device's padded ELL block is computed — by the
        compiled device pipeline or the host callbacks, per
        :meth:`materialization` — and written straight into that device's
        shard via ``jax.make_array_from_callback``, then the driver's
        placement detects the arrays as already placed
        (:func:`repro.core.partition.already_placed`) and passes them
        through.

        ``mode`` is the mode the *solve* will run under (defaults to this
        builder's) — padded action columns carry a sign-dependent
        never-greedy cost, so the padding must match the solve, not the
        builder, when a per-call override flips it.
        """
        if self._core is not None:
            return self._core
        if mesh is None:
            return self.build(materialize)
        if self.materialization(materialize) == "matrix_free":
            # nothing to pre-place: the operator container is O(n) metadata
            # and the driver's partition layer places its tag per layout
            # (so there is no mesh-keyed shard cache to manage either)
            return self.build(materialize)
        key = (mesh, layout, mode or self.mode,
               self.materialization(materialize))
        if key not in self._device_cache:
            self._device_cache[key] = self._place_sharded(
                mesh, layout, mode or self.mode, device=key[3] == "device")
        return self._device_cache[key]

    def evict(self, mesh=None, *, builders: bool = False) -> int:
        """Drop cached materializations — the shards placed on ``mesh``,
        or every cached container when ``mesh`` is None.  Returns the
        number of entries dropped.  The session layer calls this on close
        so reused builders do not pin device memory for dead meshes.

        ``builders=True`` additionally drops this MDP's compiled block
        builders from the shared program cache (their closures pin the
        constructor callables and whatever those close over) — for
        long-lived processes retiring a constructor pair for good.  The
        default keeps them: re-materializing after a plain evict is meant
        to hit the warm compiled builder."""
        if builders and self._spec is not None:
            skey = dataclasses.replace(self._spec, gamma=0.0)
            for k in [k for k in _BUILDER_CACHE if k[0] == skey]:
                del _BUILDER_CACHE[k]
        if mesh is None:
            n = len(self._device_cache)
            self._device_cache.clear()
            return n
        dead = [k for k in self._device_cache if k[0] == mesh]
        for k in dead:
            del self._device_cache[k]
        return len(dead)

    def _place_sharded(self, mesh, layout: str, mode: str, *,
                       device: bool) -> EllMDP:
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = partition.mesh_axes(mesh, layout)
        if axes.fleet is not None:
            raise ValueError(
                f"layout {layout!r} shards the fleet dim; a single "
                "function-backed MDP places under '1d'/'2d' — solve a "
                "fleet of them via Session.solve_fleet / "
                "place_function_fleet")
        s = self._spec
        n_to, m_to = partition.padded_extents(mesh, axes, s.n, s.m)
        blocks: dict = {}

        def block(index) -> tuple:
            (lo, hi), (alo, ahi) = partition.shard_block(
                index[:2], (n_to, m_to))
            bkey = (lo, hi, alo, ahi)
            if bkey not in blocks:
                if device:
                    f = _device_builder(s, hi - lo,
                                        tuple(range(alo, ahi)), mode)
                    blocks[bkey] = _checked_block(f, lo, s)
                else:
                    blocks[bkey] = self._block(
                        np.arange(lo, hi), np.arange(alo, ahi),
                        n_pad_to=n_to, m_pad_to=m_to, mode=mode)
            return blocks[bkey]

        sh3 = NamedSharding(mesh, P(axes.state, axes.action, None))
        sh2 = NamedSharding(mesh, P(axes.state, axes.action))
        idx = jax.make_array_from_callback(
            (n_to, m_to, s.nnz), sh3, lambda i: block(i)[0])
        val = jax.make_array_from_callback(
            (n_to, m_to, s.nnz), sh3, lambda i: block(i)[1])
        cost = jax.make_array_from_callback(
            (n_to, m_to), sh2, lambda i: block(i)[2])
        blocks.clear()
        return EllMDP(idx=idx, val=val, cost=cost, gamma=s.gamma,
                      n_global=n_to, m_global=m_to)

    def _block(self, rows: np.ndarray, acts: np.ndarray, *,
               n_pad_to: int, m_pad_to: int,
               mode: str | None = None) -> tuple:
        """One host-pipeline ELL block for global ``rows`` x ``acts``
        (padding included).

        Padding mirrors :func:`repro.core.partition.pad_mdp` exactly:
        padded states are zero-cost absorbing self-loops; padded actions
        are never-greedy under the solve ``mode`` (cost ``+BIG`` for
        mincost, ``-BIG`` for maxreward).
        """
        s = self._spec
        big = _BIG if (mode or self.mode) == "mincost" else -_BIG
        nr, na, K = len(rows), len(acts), s.nnz
        idx = np.zeros((nr, na, K), np.int32)
        val = np.zeros((nr, na, K), np.float32)
        cost = np.zeros((nr, na), np.float32)
        # pad defaults: absorbing self-loop on slot 0 (padded rows), and
        # never-greedy cost on padded action columns
        idx[..., 0] = rows[:, None].astype(np.int32)
        val[..., 0] = 1.0
        pad_a = acts >= s.m
        cost[:, pad_a] = big
        idx[:, pad_a, 0] = 0          # padded actions point at state 0
        real_r = rows < s.n
        if not real_r.any():
            return idx, val, cost
        rr = rows[real_r]
        for j, a in enumerate(acts):
            if a >= s.m:
                continue
            if s.vectorized:
                ids, probs = s.p_fn(rr, int(a))
                ids = np.asarray(ids)
                probs = np.asarray(probs)
                if ids.shape != (len(rr), K) or probs.shape != ids.shape:
                    raise ValueError(
                        f"vectorized P_fn must return (ids, probs) of "
                        f"shape ({len(rr)}, {K}), got {ids.shape} / "
                        f"{probs.shape}")
                rowsum = np.asarray(probs, np.float64).sum(-1)
                bad = np.nonzero(np.abs(rowsum - 1.0) > 1e-4)[0]
                if bad.size:
                    raise ValueError(
                        f"P_fn(s={int(rr[bad[0]])}, a={int(a)}) "
                        f"probabilities sum to {rowsum[bad[0]]:.6g}, "
                        f"expected ~1")
                idx[real_r, j, :] = ids
                val[real_r, j, :] = probs
                cost[real_r, j] = np.asarray(s.g_fn(rr, int(a)))
            else:
                for i, r in zip(np.nonzero(real_r)[0], rr):
                    ids, probs = s.p_fn(int(r), int(a))
                    ids = np.atleast_1d(np.asarray(ids))
                    probs = np.atleast_1d(np.asarray(probs))
                    if len(ids) > K:
                        raise ValueError(
                            f"P_fn({r}, {a}) returned {len(ids)} "
                            f"successors > nnz={K}")
                    if len(ids) != len(probs):
                        raise ValueError(
                            f"P_fn(s={int(r)}, a={int(a)}) returned "
                            f"{len(ids)} successor ids but {len(probs)} "
                            f"probabilities")
                    total = float(np.asarray(probs, np.float64).sum())
                    if abs(total - 1.0) > 1e-4:
                        raise ValueError(
                            f"P_fn(s={int(r)}, a={int(a)}) probabilities "
                            f"sum to {total:.6g}, expected ~1")
                    row_i = np.zeros(K, np.int32)
                    row_v = np.zeros(K, np.float32)
                    row_i[:len(ids)] = ids
                    row_v[:len(probs)] = probs
                    idx[i, j, :] = row_i
                    val[i, j, :] = row_v
                    cost[i, j] = float(s.g_fn(int(r), int(a)))
        # validate only the real (row, action) entries: padding self-loops
        # legitimately point at padded state ids >= s.n
        real = idx[real_r][:, acts < s.m]
        if real.size and ((real < 0).any() or (real >= s.n).any()):
            raise ValueError("P_fn produced successor ids outside "
                             f"[0, {s.n})")
        return idx, val, cost

    # ---- persistence -------------------------------------------------------
    def save(self, path: str, n_blocks: int = 1) -> None:
        """Write the block-manifest format (materializes if deferred)."""
        core = self.build()
        if not isinstance(core, EllMDP):
            raise ValueError("save() supports the ELL representation only")
        core_io.save_mdp(path, core, n_blocks=n_blocks, mode=self.mode)


# --------------------------------------------------------------------------- #
# Fleet-sharded materialization of function-backed fleets                      #
# --------------------------------------------------------------------------- #

def place_function_fleet(mdps: Sequence[MDP], mesh, layout: str,
                         mode: str = "mincost", *,
                         pad_fleet: bool = True) -> EllMDP:
    """Materialize a fleet of function-backed MDPs straight into the
    fleet-sharded layouts (``layout="fleet"/"fleet2d"``).

    Each device owns ``(B_local, n_local, m_local)`` — a slice of
    *instances* on top of its state/action slice — and materializes
    exactly that block from the owned instances' device constructors
    (each runs as a compiled program).  Neither the instance dim nor the
    state dim ever exists host-globally, so fleet construction scales
    with the mesh in both directions.

    Instances must share the action count and ``nnz``; heterogeneous
    state counts pad to the fleet maximum (absorbing zero-cost states,
    like :func:`repro.core.mdp.stack_mdps`).  ``B`` pads to the
    fleet-axis multiple with zero-cost dummy instances
    (``pad_fleet=False`` raises instead).  The returned batched container
    carries exactly the shardings :func:`repro.core.partition.shard_mdp`
    would assign, so the driver's placement passes it through untouched.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = partition.mesh_axes(mesh, layout)
    if axes.fleet is None:
        raise ValueError(f"place_function_fleet serves the fleet layouts, "
                         f"got {layout!r}; a single function-backed MDP "
                         f"places via MDP.place")
    mdps = list(mdps)
    specs = []
    for i, m_ in enumerate(mdps):
        if not isinstance(m_, MDP) or not m_.deferred:
            raise ValueError(f"place_function_fleet wants function-backed "
                             f"MDPs; instance {i} is "
                             f"{type(m_).__name__}")
        if m_.materialization("device") != "device":   # raises with reason
            raise ValueError(f"instance {i} cannot materialize on device")
        specs.append(m_._spec)
    K, m_acts = specs[0].nnz, specs[0].m
    if any(sp.nnz != K or sp.m != m_acts for sp in specs):
        raise ValueError(
            f"fleet instances must share the action count and nnz, got "
            f"m={sorted({sp.m for sp in specs})} "
            f"nnz={sorted({sp.nnz for sp in specs})}")
    n_to, m_to = partition.padded_extents(
        mesh, axes, max(sp.n for sp in specs), m_acts)
    b = len(mdps)
    b_to = partition.fleet_padded_batch(
        b, partition._axis_size(mesh, axes.fleet), pad_fleet)
    shape3 = (b_to, n_to, m_to)
    sh4 = NamedSharding(mesh, P(axes.fleet, axes.state, axes.action, None))
    sh3 = NamedSharding(mesh, P(axes.fleet, axes.state, axes.action))
    blocks: dict = {}

    def block(index) -> tuple:
        (b0, b1), (lo, hi), (alo, ahi) = partition.shard_block(
            index[:3], shape3)
        bkey = (b0, b1, lo, hi, alo, ahi)
        if bkey not in blocks:
            acts = tuple(range(alo, ahi))
            per = []
            for bi in range(b0, b1):
                if bi < b:
                    f = _device_builder(specs[bi], hi - lo, acts, mode)
                    per.append(_checked_block(f, lo, specs[bi]))
                else:
                    per.append(_dummy_fleet_block(lo, hi - lo, len(acts), K))
            blocks[bkey] = tuple(jnp.stack(arrs) for arrs in zip(*per))
        return blocks[bkey]

    idx = jax.make_array_from_callback(
        shape3 + (K,), sh4, lambda i: block(i)[0])
    val = jax.make_array_from_callback(
        shape3 + (K,), sh4, lambda i: block(i)[1])
    cost = jax.make_array_from_callback(shape3, sh3, lambda i: block(i)[2])
    blocks.clear()
    gammas = tuple(sp.gamma for sp in specs)
    gammas = gammas + (gammas[-1],) * (b_to - b)
    gamma = gammas[0] if len(set(gammas)) == 1 else gammas
    return EllMDP(idx=idx, val=val, cost=cost, gamma=gamma,
                  n_global=n_to, m_global=m_to)
