"""Ragged-fleet bucketing for :meth:`repro.api.Session.solve_fleet`.

A batched fleet pads every instance to the fleet's maximum state count
(:func:`repro.core.mdp.stack_mdps`): a fleet mixing a 100-state and a
100k-state MDP would spend ~99.9% of its FLOPs on padding.  Bucketing
groups instances by state count into *pad-efficient* buckets and solves
one compiled batched program per bucket — the ROADMAP "ragged fleets"
item, exposed through the options database as ``-fleet_bucketing
auto|off``.
"""

from __future__ import annotations

from typing import Sequence

__all__ = ["bucket_indices", "MAX_PAD_WASTE"]

# auto-bucketing splits whenever padding a bucket would waste more than
# this fraction of its (padded) state-row work
MAX_PAD_WASTE = 0.25


def bucket_indices(ns: Sequence[int], *, policy: str = "auto",
                   max_waste: float = MAX_PAD_WASTE) -> list[list[int]]:
    """Partition instance indices into pad-efficient buckets by state count.

    ``ns[i]`` is instance ``i``'s state count.  Returns a list of index
    buckets (every index exactly once).  ``policy="off"`` returns one
    bucket (the pre-bucketing behavior).  ``policy="auto"`` sorts by ``n``
    and greedily extends the current bucket while its *pad waste* — the
    fraction of padded state rows that are padding,
    ``1 - sum(n_i) / (len * max_n)`` — stays at most ``max_waste``.

    Instances with equal ``n`` always land in one bucket, and a fleet of
    near-equal sizes stays one bucket (one compiled program), so the
    common homogeneous case is unchanged.
    """
    if policy not in ("auto", "off"):
        raise ValueError(f"unknown bucketing policy {policy!r}; "
                         "pick 'auto' or 'off'")
    idx = list(range(len(ns)))
    if policy == "off" or len(idx) <= 1:
        return [idx] if idx else []
    order = sorted(idx, key=lambda i: (ns[i], i))
    buckets: list[list[int]] = [[order[0]]]
    total = ns[order[0]]                      # sum of n over current bucket
    for i in order[1:]:
        cand_total = total + ns[i]
        cand_len = len(buckets[-1]) + 1
        waste = 1.0 - cand_total / (cand_len * ns[i])   # ns[i] is the max
        if waste <= max_waste:
            buckets[-1].append(i)
            total = cand_total
        else:
            buckets.append([i])
            total = ns[i]
    return buckets
