"""PETSc-style options database — the madupite configuration surface.

madupite configures everything through a flat string-keyed options database
(``setOption("-ksp_type", "gmres")``), seeded from the command line and the
environment, and hands PETSc one consistent view of solver + placement +
output settings.  This module is that database for the JAX reproduction:

* a **typed registry** (:data:`OPTION_SPECS`) of every supported key with
  type, default, choices and documentation — unknown keys and badly-typed
  values raise errors that *name the offending key* (and suggest near
  misses);
* **ingestion** from the environment (``MADUPITE_OPTIONS="-method vi
  -atol 1e-6"``) and the CLI (repeated ``--option key=value``), with a
  fixed precedence: explicit :meth:`Options.set` > CLI > environment >
  registry default;
* a **lossless mapping** to/from the solver-core
  :class:`repro.core.ipi.IPIOptions` (:meth:`Options.to_ipi` /
  :meth:`Options.from_ipi`), so one options dict drives the solver, the
  session's mesh/layout placement and the output files.

    >>> opts = Options({"-method": "vi", "-atol": 1e-6})
    >>> opts.set("-file_stats", "run.json")
    >>> opts.to_ipi()
    IPIOptions(method='vi', ... atol=1e-06, ...)
"""

from __future__ import annotations

import dataclasses
import os
import shlex
from typing import Any, Callable, Iterator, Mapping

from repro.core import methods as _methods
from repro.core.ipi import IPIOptions, MODES
from repro.utils import xla_flags as _xla_flags

__all__ = ["OptionSpec", "OPTION_SPECS", "Options", "UnknownOptionError",
           "OptionTypeError", "option_table"]

ENV_VAR = "MADUPITE_OPTIONS"

# precedence levels (higher wins); `set()` without a source is "user"
_SOURCES = {"default": 0, "env": 1, "cli": 2, "user": 3}

_LAYOUT_CHOICES = ("auto", "single", "1d", "2d", "fleet", "fleet2d")
_PC_TYPES = ("none", "jacobi", "bjacobi")


class UnknownOptionError(KeyError):
    """Raised for a key absent from the registry; names the key and the
    closest registered spellings."""


class OptionTypeError(ValueError):
    """Raised when a value cannot be coerced to the key's declared type (or
    violates its choices/validator); names the key."""


@dataclasses.dataclass(frozen=True)
class OptionSpec:
    """One registered option: its type, default and constraints.

    ``choices_fn`` makes the legal values *live*: it is consulted at every
    coercion (and when rendering the docs table), so options validating
    against the method/KSP/stop-criterion registries accept names the user
    registered after import.  ``choices_doc`` is the stable builtin view
    rendered into the README table.
    """

    name: str                    # "-atol"
    type: type                   # float / int / bool / str
    default: Any
    doc: str
    choices: tuple | None = None
    choices_fn: Callable[[], tuple] | None = None   # live registry view
    choices_doc: str | None = None                  # table rendering
    nullable: bool = False       # None is a legal value ("unset")
    validate: Callable[[Any], str | None] | None = None  # -> error or None

    def _choices(self) -> tuple | None:
        if self.choices_fn is not None:
            return tuple(self.choices_fn())
        return self.choices

    def coerce(self, value: Any) -> Any:
        """Coerce (possibly a string from env/CLI) to the declared type."""
        choices = self._choices()
        if value is None:
            if self.nullable:
                return None
            raise OptionTypeError(
                f"option {self.name!r} does not accept None "
                f"(expected {self.type.__name__})")
        if self.nullable and isinstance(value, str) \
                and value.lower() in ("none", "") \
                and not (choices and value.lower() in choices):
            return None
        try:
            if self.type is bool:
                out = _coerce_bool(self.name, value)
            elif isinstance(value, str) and self.type is not str:
                out = self.type(value)
            elif self.type is float and isinstance(value, int) \
                    and not isinstance(value, bool):
                out = float(value)
            elif not isinstance(value, self.type) \
                    or isinstance(value, bool) is not (self.type is bool):
                raise TypeError(
                    f"got {type(value).__name__} {value!r}")
            else:
                out = value
        except OptionTypeError:
            raise
        except (TypeError, ValueError) as e:
            raise OptionTypeError(
                f"option {self.name!r} expects {self.type.__name__}, "
                f"{e}") from None
        if choices is not None and out not in choices:
            raise OptionTypeError(
                f"option {self.name!r} must be one of {choices}, "
                f"got {out!r}{_methods.suggest(out, choices)}")
        if self.validate is not None:
            err = self.validate(out)
            if err:
                raise OptionTypeError(f"option {self.name!r}: {err}")
        return out


def _coerce_bool(name: str, value: Any) -> bool:
    if isinstance(value, bool):
        return value
    if isinstance(value, int) and value in (0, 1):
        return bool(value)
    if isinstance(value, str):
        low = value.lower()
        if low in ("1", "true", "yes", "on"):
            return True
        if low in ("0", "false", "no", "off"):
            return False
    raise OptionTypeError(f"option {name!r} expects a bool "
                          f"(true/false/1/0), got {value!r}")


def _positive(what: str):
    return lambda v: None if v > 0 else f"must be > 0, got {v}"


def _non_negative(what: str):
    return lambda v: None if v >= 0 else f"must be >= 0, got {v}"


def _live_choices_doc(names: tuple, register_fn: str) -> str:
    shown = " \\| ".join(f"`{n}`" for n in names)
    return f"{shown} \\| user-registered (`{register_fn}`)"


_SPECS = [
    # ---- solver (maps losslessly onto IPIOptions) --------------------------
    OptionSpec("-method", str, "ipi_gmres",
               "outer/inner method (validates against the LIVE registry: "
               "repro.api.register_method)",
               choices_fn=lambda: _methods.method_names(),
               choices_doc=_live_choices_doc(
                   _methods.method_names(builtin_only=True),
                   "register_method")),
    OptionSpec("-mode", str, "mincost",
               "argmin (mincost) vs argmax (maxreward) Bellman backup",
               choices=MODES),
    OptionSpec("-ksp_type", str, None,
               "inner linear solver (PETSc-style sugar: picks -method "
               "ipi_<ksp> unless -method is set explicitly; live registry: "
               "repro.api.register_ksp)",
               choices_fn=lambda: ("none",) + _methods.ksp_names(),
               choices_doc=_live_choices_doc(
                   ("none",) + _methods.ksp_names(builtin_only=True),
                   "register_ksp"),
               nullable=True),
    OptionSpec("-atol", float, 1e-8,
               "stop when ||T v - v||_inf <= atol",
               validate=_positive("atol")),
    OptionSpec("-stop_criterion", str, "atol",
               "outer stopping predicate compiled into the loop; span "
               "certifies long-mixing VI far earlier than sup-norm "
               "residuals (live registry: repro.api."
               "register_stop_criterion)",
               choices_fn=lambda: _methods.stop_names(),
               choices_doc=_live_choices_doc(
                   _methods.stop_names(builtin_only=True),
                   "register_stop_criterion")),
    OptionSpec("-rtol", float, 1e-4,
               "threshold for -stop_criterion rtol (relative to the "
               "initial residual)",
               validate=lambda v: None if 0.0 < v < 1.0
               else f"must lie in (0, 1), got {v}"),
    OptionSpec("-max_outer", int, 500, "outer-iteration cap",
               validate=_positive("max_outer")),
    OptionSpec("-max_inner", int, 500, "inner-iteration cap per outer step",
               validate=_non_negative("max_inner")),
    OptionSpec("-inner_forcing", float, 0.05,
               "forcing factor eta: inner tol = eta * ||T v - v||_inf",
               validate=lambda v: None if 0.0 < v < 1.0
               else f"must lie in (0, 1), got {v}"),
    OptionSpec("-restart", int, 32, "GMRES restart length",
               validate=_positive("restart")),
    OptionSpec("-omega", float, 1.0,
               "Richardson damping factor (also the Anderson mixing "
               "parameter for ksp anderson)"),
    OptionSpec("-mpi_sweeps", int, 50, "Richardson sweeps for method=mpi",
               validate=_positive("mpi_sweeps")),
    OptionSpec("-anderson_window", int, 5,
               "Anderson-acceleration window for the anderson inner solver",
               validate=_positive("anderson_window")),
    OptionSpec("-monitor", bool, False,
               "stream per-outer-iteration records (residual, inner iters, "
               "elapsed) out of the compiled loop"),
    OptionSpec("-monitor_mode", str, "stream",
               "monitor delivery: stream (host callback per outer "
               "iteration) or chunk (records reconstructed from the "
               "residual trace once per run chunk — no per-iteration "
               "host sync)", choices=("stream", "chunk")),
    OptionSpec("-safeguard", bool, True,
               "monotone (VI-fallback) safeguard for Krylov steps"),
    OptionSpec("-deterministic_dots", bool, False,
               "pin the GMRES projection accumulation order so "
               "fleet-sharded Krylov values are bit-equal to the "
               "replicated layout"),
    OptionSpec("-pc_type", str, "none",
               "right preconditioner for Krylov inner solvers: jacobi "
               "(diagonal of I - gamma P_pi) or bjacobi (shard-local "
               "dense blocks, PETSc-style); matrix-free compatible",
               choices=_PC_TYPES),
    OptionSpec("-pc_block", int, 32,
               "bjacobi block size (states per dense block, per shard)",
               validate=_positive("pc_block")),
    OptionSpec("-divtol", float, 1e4,
               "declare divergence (sticky SolveState.diverged flag, "
               "loop bail-out) when the residual exceeds divtol x the "
               "initial residual",
               validate=lambda v: None if v > 1.0
               else f"must be > 1, got {v}"),
    OptionSpec("-probe_iters", int, 8,
               "-method auto: compiled probe iterations used to estimate "
               "contraction / residual decay before picking the method",
               validate=_positive("probe_iters")),
    OptionSpec("-adapt_on_stagnation", bool, False,
               "watch any solve (fixed -method too) for stagnation or "
               "divergence between chunks and hot-swap to the next method "
               "in the escalation chain, resuming from the current state"),
    OptionSpec("-kernel_impl", str, None,
               "kernel implementation (auto = blocked XLA on CPU, Pallas "
               "on TPU, with autotuned tiles); '-impl' is accepted as an "
               "alias",
               choices=("auto", "xla", "blocked", "pallas",
                        "pallas_interpret"),
               nullable=True),
    OptionSpec("-kernel_tune", str, "on",
               "tile autotuner: time tile candidates per (backend, shape, "
               "dtype) and persist the winners",
               choices=("on", "off")),
    OptionSpec("-kernel_tune_cache", str, None,
               "autotune cache path (default ~/.cache/madupite/"
               "autotune.json)", nullable=True),
    OptionSpec("-dtype", str, "float32", "value-vector dtype",
               choices=("float32", "float64")),
    OptionSpec("-halo", int, 0,
               "banded layout: exchange only +-halo boundary entries",
               validate=_non_negative("halo")),
    OptionSpec("-gather_dtype", str, None,
               "compressed (inexact) gather wire dtype for inner matvecs",
               nullable=True),
    OptionSpec("-comm_overlap", str, "auto",
               "overlap the value-window gather with interior-row backup "
               "compute and shrink the collective to the frontier reach "
               "when -halo is 0 (bitwise-identical to the synchronous "
               "path); auto enables it when the interior covers >= half "
               "the shard",
               choices=("auto", "on", "off")),
    OptionSpec("-async_sweeps", int, 1,
               "method=async_vi: local Bellman sweeps per value exchange "
               "(1 = synchronous VI)",
               validate=_positive("async_sweeps")),
    # ---- placement (owned by the session layer) ----------------------------
    OptionSpec("-xla_flag_bundle", str, None,
               "named XLA_FLAGS bundle applied at session start "
               "(repro.utils.xla_flags)",
               choices_fn=lambda: tuple(_xla_flags.bundle_names()),
               choices_doc=" \\| ".join(
                   f"`{n}`" for n in sorted(_xla_flags.BUNDLES)),
               nullable=True),
    OptionSpec("-layout", str, "auto",
               "mesh layout; 'auto' picks from problem shape and fleet "
               "size, 'single' forces single-device",
               choices=_LAYOUT_CHOICES),
    OptionSpec("-fleet", int, None,
               "fleet-axis size for the fleet layouts (default: largest "
               "device-count divisor <= B)", nullable=True,
               validate=_positive("fleet")),
    OptionSpec("-chunk", int, 64,
               "outer iterations per device chunk (checkpoint cadence)",
               validate=_positive("chunk")),
    OptionSpec("-pad_fleet", bool, True,
               "pad B up to the fleet-axis size with dummy instances"),
    OptionSpec("-fleet_bucketing", str, "auto",
               "group ragged fleets by state count into pad-efficient "
               "buckets (one compiled program per bucket)",
               choices=("auto", "off")),
    OptionSpec("-mdp_materialize", str, "auto",
               "function-backed MDP materialization: device (jit the row "
               "constructors, no host numpy), host (numpy callbacks), "
               "matrix_free (never store the table — re-trace the "
               "constructors inside every Bellman backup; O(n) per shard), "
               "or auto (device when the constructors trace; never "
               "matrix_free)",
               choices=("auto", "host", "device", "matrix_free")),
    OptionSpec("-checkpoint_dir", str, None,
               "persist solver state between chunks", nullable=True),
    OptionSpec("-verbose", bool, False, "per-chunk progress lines"),
    # ---- serving (repro.serve.Server) --------------------------------------
    OptionSpec("-serve_batch_window", float, 0.02,
               "serving: seconds the scheduler waits after the oldest "
               "queued request to coalesce compatible arrivals into one "
               "batched dispatch (0 = dispatch whatever is queued "
               "immediately)",
               validate=_non_negative("serve_batch_window")),
    OptionSpec("-serve_max_queue", int, 256,
               "serving: admission-control queue depth; submits beyond it "
               "are rejected with AdmissionError('queue_full')",
               validate=_positive("serve_max_queue")),
    OptionSpec("-serve_max_states", int, None,
               "serving: per-request state-count limit; larger MDPs are "
               "rejected with AdmissionError('too_large'). The limit names "
               "a materialized-table byte budget, so matrix-free requests "
               "(O(n) footprint) are admitted up to the same bytes — far "
               "more states (default: unlimited)", nullable=True,
               validate=_positive("serve_max_states")),
    OptionSpec("-serve_max_batch", int, 32,
               "serving: max requests per dispatched bucket (also caps the "
               "padded fleet-slot size)",
               validate=_positive("serve_max_batch")),
    OptionSpec("-serve_program_cache", int, 16,
               "serving: LRU capacity of the warm compiled-program cache "
               "keyed by shape bucket (hit/miss/eviction counters in "
               "Server.stats())",
               validate=_positive("serve_program_cache")),
    OptionSpec("-serve_deadline_ms", float, None,
               "serving: per-request latency budget; the scheduler cuts "
               "its coalescing linger short so the request dispatches "
               "before its deadline (default: no deadline)",
               nullable=True, validate=_positive("serve_deadline_ms")),
    OptionSpec("-serve_slot_policy", str, "mid2",
               "serving: fleet-slot sizing — mid2 pads each bucket's "
               "request count up on the pow2-with-midpoints grid "
               "(1,2,3,4,6,8,12,16,24,...; waste <= 1/3 of the slot), "
               "pow2 on the classic power-of-two grid, exact dispatches "
               "the raw count", choices=("mid2", "pow2", "exact")),
    # ---- output ------------------------------------------------------------
    OptionSpec("-file_stats", str, None,
               "write run statistics here after each solve",
               nullable=True),
    OptionSpec("-file_stats_format", str, "jsonl",
               "run-statistics format: jsonl (one line per solve, O(1) "
               "streamed appends) or json (single array, rewritten per "
               "solve)", choices=("jsonl", "json")),
    OptionSpec("-file_policy", str, None,
               "write the optimal policy (.npy/.npz) here", nullable=True),
    OptionSpec("-file_cost", str, None,
               "write the optimal value vector (.npy/.npz) here",
               nullable=True),
]

OPTION_SPECS: dict[str, OptionSpec] = {s.name: s for s in _SPECS}

# the IPIOptions field each solver option maps onto (lossless, 1:1)
_IPI_FIELDS = {
    "-method": "method", "-mode": "mode", "-atol": "atol",
    "-stop_criterion": "stop_criterion", "-rtol": "rtol",
    "-max_outer": "max_outer", "-max_inner": "max_inner",
    "-inner_forcing": "forcing_eta", "-restart": "restart",
    "-omega": "omega", "-mpi_sweeps": "mpi_sweeps",
    "-anderson_window": "anderson_window", "-monitor": "monitor",
    "-safeguard": "safeguard", "-deterministic_dots": "deterministic_dots",
    "-kernel_impl": "impl", "-dtype": "dtype",
    "-halo": "halo", "-gather_dtype": "gather_dtype",
    "-comm_overlap": "comm_overlap", "-async_sweeps": "async_sweeps",
    "-monitor_mode": "monitor_mode", "-pc_type": "pc_type",
    "-pc_block": "pc_block", "-divtol": "divtol",
}


# retired spellings accepted for compatibility
_ALIASES = {"-impl": "-kernel_impl"}


def _normalize(key: Any) -> str:
    if not isinstance(key, str) or not key:
        raise UnknownOptionError(f"option keys are strings like '-atol', "
                                 f"got {key!r}")
    name = key if key.startswith("-") else "-" + key
    name = _ALIASES.get(name, name)
    if name not in OPTION_SPECS:
        raise UnknownOptionError(
            f"unknown option {key!r}{_methods.suggest(name, OPTION_SPECS)} "
            f"(see repro.api.option_table() for the full registry)")
    return name


class Options:
    """The options database: a validated, precedence-aware flat key store.

    Construct empty, from a mapping, from the environment and/or CLI
    (:meth:`from_sources`), or from an :class:`IPIOptions`
    (:meth:`from_ipi`).  Keys may be given with or without the leading
    dash.  Reads return the registry default for unset keys.
    """

    def __init__(self, values: Mapping[str, Any] | None = None):
        # name -> (coerced value, source priority)
        self._values: dict[str, tuple[Any, int]] = {}
        for k, v in (values or {}).items():
            self.set(k, v)

    # ---- core accessors ----------------------------------------------------
    def set(self, key: str, value: Any, *, source: str = "user") -> "Options":
        """Set (and validate) one option.  A lower-precedence ``source``
        never overrides a higher-precedence value already present."""
        name = _normalize(key)
        prio = _SOURCES[source]
        coerced = OPTION_SPECS[name].coerce(value)
        if name in self._values and self._values[name][1] > prio:
            return self
        self._values[name] = (coerced, prio)
        return self

    def get(self, key: str) -> Any:
        name = _normalize(key)
        if name in self._values:
            return self._values[name][0]
        return OPTION_SPECS[name].default

    def is_set(self, key: str) -> bool:
        """True when the key was explicitly provided (any source)."""
        return _normalize(key) in self._values

    def unset(self, key: str) -> None:
        self._values.pop(_normalize(key), None)

    __getitem__ = get

    def __setitem__(self, key: str, value: Any) -> None:
        self.set(key, value)

    def __contains__(self, key: str) -> bool:
        try:
            return self.is_set(key)
        except UnknownOptionError:
            return False

    def __iter__(self) -> Iterator[str]:
        return iter(OPTION_SPECS)

    def __repr__(self) -> str:
        kv = ", ".join(f"{k}={v[0]!r}"
                       for k, v in sorted(self._values.items()))
        return f"Options({kv})"

    def __eq__(self, other) -> bool:
        if not isinstance(other, Options):
            return NotImplemented
        return self.as_dict() == other.as_dict()

    def copy(self) -> "Options":
        out = Options()
        out._values = dict(self._values)
        return out

    def as_dict(self, *, explicit_only: bool = False) -> dict[str, Any]:
        """Flat ``{name: value}`` view (all keys, or only explicitly-set)."""
        if explicit_only:
            return {k: v for k, (v, _) in sorted(self._values.items())}
        return {name: self.get(name) for name in OPTION_SPECS}

    # ---- ingestion ---------------------------------------------------------
    def ingest_env(self, env: Mapping[str, str] | None = None) -> "Options":
        """Parse ``MADUPITE_OPTIONS`` (shell-style ``-key value`` pairs, or
        ``-key=value`` tokens) at "env" precedence."""
        raw = (env if env is not None else os.environ).get(ENV_VAR, "")
        for key, value in _parse_pairs(shlex.split(raw), where=ENV_VAR):
            self.set(key, value, source="env")
        return self

    def ingest_cli(self, pairs) -> "Options":
        """Ingest ``--option key=value`` arguments (an iterable of
        ``"key=value"`` strings) at "cli" precedence."""
        for item in pairs or ():
            if "=" not in item:
                raise OptionTypeError(
                    f"--option expects key=value, got {item!r}")
            key, value = item.split("=", 1)
            self.set(key.strip(), value.strip(), source="cli")
        return self

    @classmethod
    def from_sources(cls, values: Mapping[str, Any] | None = None, *,
                     cli=None, env: Mapping[str, str] | None = None) -> \
            "Options":
        """Build a database from every source at once.  Precedence (low to
        high): registry defaults, environment, CLI, explicit ``values``."""
        out = cls()
        out.ingest_env(env)
        out.ingest_cli(cli)
        for k, v in (values or {}).items():
            out.set(k, v)
        return out

    # ---- IPIOptions mapping ------------------------------------------------
    def to_ipi(self) -> IPIOptions:
        """The solver-core view of this database (lossless for the solver
        keys).  ``-ksp_type`` picks the method when ``-method`` is unset."""
        kw = {field: self.get(name) for name, field in _IPI_FIELDS.items()}
        ksp = self.get("-ksp_type")
        if ksp is not None and not self.is_set("-method"):
            try:
                kw["method"] = _methods.method_for_ksp(ksp)
            except ValueError as e:
                # keep the module's error contract: bad values raise
                # OptionTypeError naming the offending key
                raise OptionTypeError(
                    f"option '-ksp_type': {e}") from None
        try:
            return IPIOptions(**kw)
        except ValueError as e:
            # IPIOptions cross-validates (e.g. gather_dtype vs dtype);
            # re-raise naming the options-database keys
            raise OptionTypeError(str(e)) from None

    @classmethod
    def from_ipi(cls, ipi: IPIOptions) -> "Options":
        """Database holding exactly ``ipi``'s settings (round-trips:
        ``Options.from_ipi(o).to_ipi() == o``)."""
        out = cls()
        for name, field in _IPI_FIELDS.items():
            out.set(name, getattr(ipi, field))
        return out

    def with_overrides(self, overrides: Mapping[str, Any]) -> "Options":
        """Copy with ``overrides`` applied at user precedence (keys with or
        without the leading dash)."""
        out = self.copy()
        for k, v in overrides.items():
            out.set(k, v)
        return out


def _parse_pairs(tokens, where: str):
    """``["-method", "vi", "-atol=1e-6"]`` -> ``[("-method", "vi"), ...]``."""
    out = []
    it = iter(tokens)
    for tok in it:
        if "=" in tok:
            key, value = tok.split("=", 1)
            out.append((key, value))
            continue
        try:
            out.append((tok, next(it)))
        except StopIteration:
            raise OptionTypeError(
                f"{where}: option {tok!r} is missing a value") from None
    return out


def option_table() -> str:
    """The full registry rendered as a markdown table (README / docs).

    Registry-backed options (``choices_fn``) render their stable builtin
    choice set (``choices_doc``) so the generated docs do not drift when a
    user registers extra solvers at runtime."""
    lines = ["| option | type | default | description |",
             "|--------|------|---------|-------------|"]
    for spec in OPTION_SPECS.values():
        typ = spec.type.__name__
        if spec.choices_doc:
            typ = spec.choices_doc
        elif spec.choices:
            typ = " \\| ".join(f"`{c}`" for c in spec.choices)
        default = "—" if spec.default is None else f"`{spec.default}`"
        doc = spec.doc.replace("|", "\\|")
        lines.append(f"| `{spec.name}` | {typ} | {default} | {doc} |")
    return "\n".join(lines)
