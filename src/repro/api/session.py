"""The session layer: mesh/layout placement, solve dispatch, run outputs.

madupite hides PETSc's communicator setup behind ``madupite.initialize()``;
this module is the analogue for the JAX mesh machinery.  A
:class:`Session` owns

* **placement** — it builds the device mesh from the visible devices and
  picks the layout (``1d``/``2d``/``fleet``/``fleet2d``) from the problem
  shape and fleet size, overridable via ``-layout`` / ``-fleet``;
* **dispatch** — :meth:`Session.solve` / :meth:`Session.solve_fleet` run
  the core engines (:mod:`repro.core.driver`) with one consistent options
  view, materializing function-backed MDPs shard-locally on the session's
  mesh;
* **bucketing** — ragged fleets are grouped by state count into
  pad-efficient buckets (``-fleet_bucketing auto``), one compiled program
  per bucket;
* **outputs** — JSON run statistics (``-file_stats``), the optimal policy
  (``-file_policy``) and value vector (``-file_cost``);
* the **run-chunk cache lifecycle** — closing the session releases the
  compiled ``run_chunk`` programs (:func:`repro.core.driver.clear_run_cache`).

    from repro.api import MDP, Options, madupite_session

    with madupite_session({"-method": "ipi_gmres", "-atol": 1e-8}) as s:
        result = s.solve(MDP.from_generator("garnet", n=10_000, m=16, k=8))
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import weakref
from typing import Any, Mapping, Sequence

import numpy as np

from repro.api.fleet import bucket_indices
from repro.api.mdp import MDP, place_function_fleet
from repro.api.options import Options
from repro.core import methods as _methods
from repro.core import partition
from repro.core import driver
from repro.core.driver import SolveResult
from repro.core.mdp import DenseMDP, EllMDP, MatrixFreeMDP
from repro.core.mdp import MDP as CoreMDP
from repro.utils.lru import LRUCache

__all__ = ["Session", "madupite_session"]

# capacity of the per-session device-fleet container cache: entries hold
# whole fleets of device shards, so the bound stays small
_FLEET_CACHE_CAPACITY = 8


class Session:
    """A solve context: options database + device placement + outputs.

    ``options`` may be an :class:`Options` database, a plain mapping of
    option keys, or ``None`` (registry defaults + ``MADUPITE_OPTIONS``
    from the environment).  ``mesh`` optionally pins an explicit
    ``jax.sharding.Mesh`` instead of the auto-built one.
    """

    def __init__(self, options: Options | Mapping[str, Any] | None = None,
                 *, mesh=None, clear_cache_on_close: bool = True):
        if isinstance(options, Options):
            self.options = options
        else:
            self.options = Options.from_sources(options)
        self._mesh_override = mesh
        self._mesh_cache: dict = {}
        self._stats: list[dict] = []
        # per -file_stats path: (format, entries already on disk) — the
        # jsonl format streams O(1) appends instead of re-serializing the
        # whole accumulated list on every solve
        self._stats_written: dict[str, tuple[str, int]] = {}
        self._closed = False
        self._clear_cache = clear_cache_on_close
        # function-backed builders this session placed on a mesh: their
        # mesh-keyed device shards are evicted on close (the builders may
        # outlive the session, but the meshes should not pin device memory)
        self._placed_mdps: weakref.WeakSet = weakref.WeakSet()
        # builders this session solved matrix-free: their O(n) operator
        # containers (and the compiled solve programs whose closures pin
        # the row constructors) are released on close — MDP.evict's
        # mesh-keyed cache only tracks materialized shards
        self._mf_mdps: weakref.WeakSet = weakref.WeakSet()
        # device-materialized fleet containers, keyed by (mesh, layout,
        # mode, pad_fleet, instance identities): warm repeated solve_fleet
        # calls skip re-construction, mirroring MDP.place's per-MDP cache.
        # A proper LRU — hit/miss/eviction counters land in the run stats
        # (and the serving program cache builds on the same mechanism).
        self._fleet_cache = LRUCache(_FLEET_CACHE_CAPACITY)
        # serializes stats recording + output-file writes: solves may run
        # concurrently from scheduler/client threads (repro.serve), and
        # interleaved -file_stats jsonl appends must stay line-atomic
        self._io_lock = threading.RLock()
        # -method auto probe results, keyed by the problem family
        # (n, m, gamma, mode): repeat solves of the same family skip the
        # probe phase and reuse the rule-table choice
        self._auto_cache: dict = {}
        _sync_x64(self.options)
        self._apply_kernel_options()

    def _apply_kernel_options(self) -> None:
        """Push kernel-facing options into their process-wide services:
        the XLA flag bundle (must precede backend init to take effect in
        this process) and the tile-autotuner configuration."""
        from repro.kernels import tuning as _tuning
        from repro.utils import xla_flags as _xla_flags

        bundle = self.options.get("-xla_flag_bundle")
        if bundle:
            _xla_flags.apply_bundle(bundle)
        _tuning.configure(
            enabled=self.options.get("-kernel_tune") != "off",
            cache_path=self.options.get("-kernel_tune_cache"))

    # ---- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Release the compiled run-chunk programs, cached meshes and the
        device MDP shards this session placed.

        ``clear_cache_on_close=False`` (the one-shot convenience wrappers)
        leaves the process-wide run-chunk cache alone so other live
        sessions keep their warm programs; the cache itself is bounded
        (:data:`repro.core.driver._RUN_CHUNK_CACHE` evicts past 64).
        Function-backed builders cache their materialized shards keyed by
        mesh (:attr:`repro.api.MDP._device_cache`); evicting the entries
        for this session's meshes stops reused builders from pinning
        device memory for meshes that no longer solve anything."""
        if not self._closed:
            mf = list(self._mf_mdps)
            if self._clear_cache:
                driver.clear_run_cache()
                if mf:
                    # matrix-free solves also compile through the
                    # module-level single-device jit caches, whose closures
                    # pin the RowSpec constructors (and whatever they close
                    # over) — clear_run_cache alone leaves them resident
                    driver._clear_compiled()
            meshes = set(self._mesh_cache.values())
            if self._mesh_override is not None:
                meshes.add(self._mesh_override)
            for mdp in list(self._placed_mdps):
                for mesh in meshes:
                    mdp.evict(mesh)
            for mdp in mf:
                # the O(n) operator container (placement tag + RowSpec);
                # cheap to rebuild, wrong to keep pinned past the session
                mdp._device_cache.pop(("built", "matrix_free"), None)
            self._mf_mdps = weakref.WeakSet()
            self._fleet_cache.clear()
            self._mesh_cache.clear()
            self._closed = True

    @property
    def stats(self) -> list[dict]:
        """Accumulated per-solve statistics (what ``-file_stats`` holds)."""
        with self._io_lock:
            return list(self._stats)

    @property
    def cache_stats(self) -> dict:
        """Counters of the session-owned caches: the device-fleet container
        LRU (hits/misses/evictions) and the current compiled run-chunk
        cache population."""
        return {
            "fleet": self._fleet_cache.stats(),
            "run_chunk_programs": len(driver._RUN_CHUNK_CACHE),
        }

    # ---- placement ---------------------------------------------------------
    def placement(self, opts: Options | None = None, *,
                  fleet_size: int | None = None):
        """``(mesh, layout)`` for a solve: auto-built unless overridden.

        Auto policy: one device -> single-device (no mesh); a single solve
        -> the paper-faithful ``1d`` layout over all devices; a fleet of
        B > 1 -> ``fleet`` layout, instance dim over a leading fleet axis
        whose size is the largest device-count divisor <= B.  ``-layout``
        forces a specific layout ('single' forces no mesh) and ``-fleet``
        the fleet-axis size.
        """
        import jax
        opts = opts or self.options
        layout = opts.get("-layout")
        if layout == "single":
            return None, "1d"
        if self._mesh_override is not None:
            mesh = self._mesh_override
            if layout == "auto":
                has_fleet = "fleet" in mesh.axis_names
                if has_fleet:
                    layout = "fleet2d" if len(mesh.axis_names) > 2 \
                        else "fleet"
                else:
                    layout = "1d"
            return mesh, layout
        n_dev = len(jax.devices())
        if n_dev == 1:
            if layout in ("fleet", "fleet2d"):
                raise ValueError(
                    f"-layout {layout} shards over a multi-device mesh but "
                    f"only one device is visible (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=N to fake a "
                    f"mesh on CPU)")
            return None, "1d"
        if layout == "auto":
            layout = "fleet" if (fleet_size or 0) > 1 else "1d"
        if layout in ("fleet", "fleet2d"):
            f = opts.get("-fleet")
            if f is None:
                f = _largest_divisor(n_dev, at_most=max(fleet_size or 1, 1))
            key = (layout, f)
            if key not in self._mesh_cache:
                from repro.launch.mesh import make_fleet_mesh
                self._mesh_cache[key] = make_fleet_mesh(f, layout=layout)
            return self._mesh_cache[key], layout
        shape = (n_dev // 2, 2) if layout == "2d" and n_dev >= 2 \
            else (n_dev, 1)
        key = (layout, shape)
        if key not in self._mesh_cache:
            from repro.launch.mesh import make_host_mesh
            self._mesh_cache[key] = make_host_mesh(shape)
        return self._mesh_cache[key], layout

    # ---- solving -----------------------------------------------------------
    def solve(self, mdp: MDP | CoreMDP, *, monitor=None, stop_criterion=None,
              **overrides) -> SolveResult:
        """Solve one MDP through the session's placement and options.

        ``overrides`` are per-call option overrides (keys with or without
        the leading dash): ``s.solve(mdp, method="vi", atol=1e-6)``.

        ``monitor`` streams one record per outer iteration out of the
        compiled loop — a callable receiving ``{"k", "res", "inner",
        "elapsed"}`` dicts (or pass ``-monitor`` / ``monitor=True`` for
        PETSc-style printed lines).  While monitoring is on, the records
        and the dense convergence-history arrays also land in
        :attr:`stats` / ``-file_stats``.

        ``stop_criterion`` overrides ``-stop_criterion``: a registered
        name (``"atol"`` / ``"rtol"`` / ``"span"`` / user-registered) or a
        traced predicate ``fn(m: repro.api.StopMetrics) -> bool`` compiled
        straight into the loop.
        """
        opts, mon_cb, mon_records = self._observe(overrides, monitor,
                                                  stop_criterion)
        mdp = self._wrap(mdp, opts)
        ipi = self._ipi(opts, mdp.mode)
        spec = _methods.get_method(ipi.method)
        adaptive_on = spec.virtual or bool(opts.get("-adapt_on_stagnation"))
        mesh, layout = self.placement(opts)
        core = mdp.place(mesh, layout, mode=ipi.mode,
                         materialize=opts.get("-mdp_materialize"))
        if mdp.deferred and mesh is not None:
            self._placed_mdps.add(mdp)
        if mdp.deferred and isinstance(core, MatrixFreeMDP):
            self._mf_mdps.add(mdp)
        t0 = time.time()
        report = None
        if adaptive_on:
            # virtual methods (-method auto) probe + select, then run
            # supervised; concrete methods under -adapt_on_stagnation skip
            # the probe but get the same stagnation hot-swap safety net
            from repro.adaptive import solve_adaptive
            key = None
            choice = None
            if spec.virtual:
                key = (int(mdp.n), int(mdp.m), float(mdp.gamma), ipi.mode)
                choice = self._auto_cache.get(key)
            r, report = solve_adaptive(
                core, ipi, mesh=mesh, layout=layout,
                probe_iters=opts.get("-probe_iters"), choice=choice,
                checkpoint_dir=opts.get("-checkpoint_dir"),
                chunk=opts.get("-chunk"), verbose=opts.get("-verbose"),
                monitor=mon_cb)
            if key is not None and report.choice is not None:
                self._auto_cache[key] = report.choice
        else:
            r = driver.solve(core, ipi, mesh=mesh, layout=layout,
                             checkpoint_dir=opts.get("-checkpoint_dir"),
                             chunk=opts.get("-chunk"),
                             verbose=opts.get("-verbose"), monitor=mon_cb)
        wall = time.time() - t0
        r = _trim(r, mdp.n)
        self._record([r], [mdp], ipi, opts, mesh, layout, wall, fleet=None,
                     monitor=mon_records, adaptive=report)
        self._write_outputs([r], opts)
        return r

    def solve_fleet(self, mdps: Sequence[MDP | CoreMDP], *, monitor=None,
                    stop_criterion=None, **overrides) -> list[SolveResult]:
        """Solve a fleet of MDPs in batched compiled programs.

        Ragged fleets (instances with very different state counts) are
        grouped into pad-efficient buckets (``-fleet_bucketing auto``) and
        each bucket runs one :func:`repro.core.driver.solve_many` program;
        results come back in input order.  All instances must share one
        ``mode``.

        A bucket of *function-backed* MDPs placed under a fleet-sharded
        layout skips host materialization entirely: each device
        materializes only the ``(B_local, n_local)`` block of the
        instances it owns from the jit'd constructors
        (:func:`repro.api.mdp.place_function_fleet`), so both the fleet
        and state dims of construction scale with the mesh.
        """
        if not mdps:
            return []
        opts, mon_cb, mon_records = self._observe(overrides, monitor,
                                                  stop_criterion)
        wrapped = [self._wrap(m, opts) for m in mdps]
        modes = {m.mode for m in wrapped}
        if len(modes) > 1:
            raise ValueError(f"solve_fleet needs one shared mode, got "
                             f"{sorted(modes)}; solve mixed-mode instances "
                             f"separately")
        ipi = self._ipi(opts, modes.pop())
        spec = _methods.get_method(ipi.method)
        buckets = bucket_indices([m.n for m in wrapped],
                                 policy=opts.get("-fleet_bucketing"))
        ckpt = opts.get("-checkpoint_dir")
        results: list[SolveResult | None] = [None] * len(wrapped)
        auto_choices: list[dict] | None = [] if spec.virtual else None
        t0 = time.time()
        for j, bucket in enumerate(buckets):
            mesh, layout = self.placement(opts, fleet_size=len(bucket))
            bucket_ckpt = ckpt if ckpt is None or len(buckets) == 1 \
                else os.path.join(ckpt, f"bucket{j}")
            bmdps = [wrapped[i] for i in bucket]
            bucket_ipi = ipi
            if spec.virtual:
                # fleets resolve the virtual method ONCE per bucket: probe
                # the bucket's largest instance on a single device and fix
                # the rule-table choice for the whole batched program (no
                # mid-solve supervision — a hot-swap would split the batch)
                bucket_ipi, choice = self._resolve_auto(bmdps, ipi, opts)
                auto_choices.append(dict(
                    bucket=j, method=choice.method, pc_type=choice.pc_type,
                    stop_criterion=choice.stop_criterion,
                    reason=choice.reason))
            payload = self._fleet_cores(bmdps, mesh, layout, ipi.mode, opts)
            origin = None if isinstance(payload, list) else \
                (len(bmdps), max(m.n for m in bmdps))
            # tag records by bucket so interleaved per-bucket streams stay
            # attributable in stats (each bucket restarts k at 0)
            bucket_cb = mon_cb if mon_cb is None or len(buckets) == 1 \
                else (lambda rec, _j=j: mon_cb({**rec, "bucket": _j}))
            rs = driver.solve_many(
                payload, bucket_ipi, mesh=mesh, layout=layout,
                pad_fleet=opts.get("-pad_fleet"), origin=origin,
                checkpoint_dir=bucket_ckpt, chunk=opts.get("-chunk"),
                verbose=opts.get("-verbose"), monitor=bucket_cb)
            for i, r in zip(bucket, rs):
                results[i] = _trim(r, wrapped[i].n)
        wall = time.time() - t0
        mesh, layout = self.placement(opts, fleet_size=len(wrapped))
        fleet_info = dict(size=len(wrapped),
                          buckets=[sorted(b) for b in buckets])
        if auto_choices is not None:
            fleet_info["auto"] = auto_choices
        self._record(results, wrapped, ipi, opts, mesh, layout, wall,
                     fleet=fleet_info, monitor=mon_records)
        self._write_outputs(results, opts)
        return results  # type: ignore[return-value]

    # ---- internals ---------------------------------------------------------
    def _observe(self, overrides, monitor, stop_criterion):
        """Resolve the per-call observability kwargs into the merged
        per-call options plus the monitor callback chain.

        Returns ``(opts, monitor_cb, records)`` — ``records`` is the list
        the callback appends every streamed record to (for :attr:`stats` /
        ``-file_stats``), or ``None`` when monitoring is off.  A callable
        ``stop_criterion`` is registered ad hoc (with span metrics
        enabled); ``monitor=False`` force-disables a session-level
        ``-monitor`` for this call."""
        overrides = dict(overrides)
        if stop_criterion is not None:
            if callable(stop_criterion):
                stop_criterion = _methods.adhoc_stop_criterion(stop_criterion)
            overrides.setdefault("-stop_criterion", stop_criterion)
        if monitor is False:
            overrides.setdefault("-monitor", False)
        elif monitor is not None:
            overrides.setdefault("-monitor", True)
        opts = self._opts(overrides)
        if not opts.get("-monitor"):
            return opts, None, None
        records: list[dict] = []
        sink = monitor if callable(monitor) else _methods.print_monitor

        def mon_cb(rec):
            records.append(rec)
            sink(rec)

        return opts, mon_cb, records

    def _opts(self, overrides: Mapping[str, Any]) -> Options:
        if self._closed:
            raise RuntimeError("this Session is closed; create a new one")
        if not overrides:
            return self.options
        opts = self.options.with_overrides(overrides)
        _sync_x64(opts)        # a per-call dtype override must flip x64 too
        return opts

    def _wrap(self, mdp: MDP | CoreMDP, opts: Options) -> MDP:
        if isinstance(mdp, MDP):
            return mdp
        if isinstance(mdp, (EllMDP, DenseMDP, MatrixFreeMDP)):
            return MDP(mdp, mode=opts.get("-mode"))
        raise TypeError(f"solve wants a repro.api.MDP (or a core "
                        f"EllMDP/DenseMDP/MatrixFreeMDP), got "
                        f"{type(mdp).__name__}")

    def _fleet_cores(self, bmdps: list[MDP], mesh, layout: str, mode: str,
                     opts: Options):
        """What one bucket hands :func:`repro.core.driver.solve_many`:
        the device-materialized batched container for an all-deferred
        bucket under a fleet-sharded layout, else per-instance builds —
        which under ``-mdp_materialize matrix_free`` are O(n) operator
        containers the driver stacks and places itself (no fleet-cache
        entry to manage: there are no device tables to pin)."""
        mat = opts.get("-mdp_materialize")
        if (mesh is not None and layout in partition.FLEET_LAYOUTS
                and mat != "host"
                and all(m.deferred for m in bmdps)
                and len({(m._spec.m, m._spec.nnz) for m in bmdps}) == 1
                and all(m.materialization(mat) == "device" for m in bmdps)):
            pad = opts.get("-pad_fleet")
            # weakly keyed on the builder identities: an entry whose fleet
            # the caller dropped can never be requested again, so purge it
            # (its device container would otherwise stay pinned till close)
            for k in self._fleet_cache.keys():
                if not all(r() is not None for r in k[4]):
                    self._fleet_cache.pop(k)
            key = (mesh, layout, mode, pad,
                   tuple(weakref.ref(m) for m in bmdps))
            batched = self._fleet_cache.get(key)
            if batched is None:
                batched = place_function_fleet(bmdps, mesh, layout, mode,
                                               pad_fleet=pad)
                self._fleet_cache.put(key, batched)
            return batched
        cores = [m.build(mat) for m in bmdps]
        for m, c in zip(bmdps, cores):
            if m.deferred and isinstance(c, MatrixFreeMDP):
                self._mf_mdps.add(m)
        return cores

    def _ipi(self, opts: Options, mdp_mode: str):
        """IPIOptions from the database; the MDP's mode wins unless the
        user explicitly set ``-mode``."""
        ipi = opts.to_ipi()
        if not opts.is_set("-mode") and ipi.mode != mdp_mode:
            ipi = dataclasses.replace(ipi, mode=mdp_mode)
        return ipi

    def _resolve_auto(self, bmdps: list[MDP], ipi, opts: Options):
        """Resolve a virtual method for one fleet bucket: probe the
        bucket's largest instance single-device, run the rule table, and
        return ``(concrete IPIOptions, MethodChoice)``.  Choices are cached
        per problem family (n, m, gamma, mode) so homogeneous fleets probe
        exactly once."""
        from repro.adaptive import probe, select_method
        rep = max(bmdps, key=lambda m: m.n)
        key = (int(rep.n), int(rep.m), float(rep.gamma), ipi.mode)
        choice = self._auto_cache.get(key)
        if choice is None:
            core = rep.place(None, "1d", mode=ipi.mode,
                             materialize=opts.get("-mdp_materialize"))
            profile, _ = probe(core, ipi,
                               probe_iters=opts.get("-probe_iters"))
            choice = select_method(
                profile, deterministic_dots=ipi.deterministic_dots)
            self._auto_cache[key] = choice
        resolved = dataclasses.replace(
            ipi, method=choice.method,
            stop_criterion=choice.stop_criterion,
            pc_type=choice.pc_type if ipi.pc_type == "none"
            else ipi.pc_type)
        return resolved, choice

    def _record(self, results, mdps, ipi, opts: Options, mesh, layout: str,
                wall: float, *, fleet, monitor=None, adaptive=None) -> None:
        entry = {
            "method": ipi.method,
            "mode": ipi.mode,
            "stop_criterion": ipi.stop_criterion,
            "layout": layout if mesh is not None else "single",
            "mesh": dict(mesh.shape) if mesh is not None else None,
            "options": _jsonable(opts.as_dict(explicit_only=True)),
            "wall_s": round(wall, 6),
            "fleet": fleet,
            "solves": [
                {
                    "n": int(m.n), "m": int(m.m),
                    "gamma": float(m.gamma),
                    "converged": bool(r.converged),
                    "diverged": bool(getattr(r, "diverged", False)),
                    "outer_iterations": int(r.outer_iterations),
                    "inner_iterations": int(r.inner_iterations),
                    "residual": float(r.residual),
                    "gap_bound": float(r.gap_bound),
                }
                for m, r in zip(mdps, results)
            ],
        }
        if adaptive is not None:
            entry["adaptive"] = adaptive.as_dict()
        if fleet is not None:
            fleet = dict(fleet, cache=self._fleet_cache.stats())
            entry["fleet"] = fleet
        if monitor is not None:
            # monitoring on: the streamed records plus the dense
            # convergence-history arrays land in the run stats
            entry["monitor"] = sorted(
                monitor, key=lambda r: (r.get("bucket", 0), r["k"]))
            for s, r in zip(entry["solves"], results):
                s["trace_residual"] = [float(x) for x in r.trace_residual]
                s["trace_inner"] = [int(x) for x in r.trace_inner]
        with self._io_lock:
            self._stats.append(entry)

    def _write_outputs(self, results, opts: Options) -> None:
        with self._io_lock:
            self._write_stats(opts)
            for key, field in (("-file_policy", "policy"),
                               ("-file_cost", "v")):
                path = opts.get(key)
                if not path:
                    continue
                _ensure_dir(path)
                arrays = [np.asarray(getattr(r, field)) for r in results]
                if len(arrays) == 1:
                    np.save(path, arrays[0])
                else:
                    np.savez(path, **{f"instance_{i}": a
                                      for i, a in enumerate(arrays)})

    def _write_stats(self, opts: Options) -> None:
        """Persist run statistics.  The default ``jsonl`` format appends
        only the entries written since the last solve — O(1) per solve
        instead of re-serializing the whole accumulated list (which made a
        long-lived serving session O(solves^2) in stats I/O).  ``json``
        keeps the original single-array format (rewritten per solve).
        Toggling the format on one path mid-session forces a full rewrite
        (appending JSONL lines after a JSON array would corrupt both).

        Callers hold ``self._io_lock`` (via :meth:`_write_outputs`):
        concurrent solves from scheduler/client threads append entries and
        advance the per-path ``(format, written)`` cursor under one lock,
        so each entry lands in the file exactly once and every jsonl line
        stays whole."""
        path = opts.get("-file_stats")
        if not path:
            return
        _ensure_dir(path)
        fmt = opts.get("-file_stats_format")
        if fmt == "json":
            with open(path, "w") as f:
                json.dump(self._stats, f, indent=1)
            self._stats_written[path] = ("json", len(self._stats))
            return
        prev_fmt, start = self._stats_written.get(path, ("jsonl", 0))
        if prev_fmt != "jsonl":
            start = 0
        with open(path, "a" if start else "w") as f:
            for entry in self._stats[start:]:
                f.write(json.dumps(entry) + "\n")
        self._stats_written[path] = ("jsonl", len(self._stats))


def madupite_session(options: Options | Mapping[str, Any] | None = None, *,
                     mesh=None) -> Session:
    """Open a solve session (the ``madupite.initialize()`` analogue)::

        with madupite_session({"-method": "vi"}) as s:
            r = s.solve(mdp)
    """
    return Session(options, mesh=mesh)


def _sync_x64(opts: Options) -> None:
    """``-dtype float64`` requires jax_enable_x64, or every array silently
    truncates to f32 while the result claims f64."""
    if opts.get("-dtype") == "float64":
        import jax
        jax.config.update("jax_enable_x64", True)


def _largest_divisor(n: int, *, at_most: int) -> int:
    for d in range(min(n, at_most), 0, -1):
        if n % d == 0:
            return d
    return 1


def _trim(r: SolveResult, n: int) -> SolveResult:
    """Trim a result solved on a padded (device-materialized) MDP back to
    the true state count."""
    if len(r.v) <= n:
        return r
    return dataclasses.replace(r, v=r.v[:n], policy=r.policy[:n])


def _jsonable(d: dict) -> dict:
    return {k: (v if isinstance(v, (int, float, str, bool, type(None)))
                else repr(v)) for k, v in d.items()}


def _ensure_dir(path: str) -> None:
    parent = os.path.dirname(os.path.abspath(path))
    if parent:
        os.makedirs(parent, exist_ok=True)
