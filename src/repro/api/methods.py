"""The pluggable solution-method surface (madupite / PETSc-KSP style).

This is the user-facing face of the live registries in
:mod:`repro.core.methods`: register an inner linear solver, an outer
method, or a stopping criterion once, and it becomes selectable everywhere
options are ingested — Python (``Options`` / ``Session``), the
``MADUPITE_OPTIONS`` environment variable, and the CLI ``--option k=v`` —
without touching repro internals.

    from repro.api import register_ksp, MDP, madupite_session

    def my_solver(matvec, b, x0, *, tol, maxiter, axes):
        ...pure lax control flow...
        return x, iters, resnorm

    register_ksp("mysolver", my_solver)       # also registers ipi_mysolver

    with madupite_session({"-ksp_type": "mysolver"}) as s:
        r = s.solve(MDP.from_generator("garnet", n=10_000, m=16, k=8))

Contracts
---------
* **KSP** — ``fn(matvec, b, x0, *, tol, maxiter, axes) -> (x, iters,
  resnorm)``; optionally accept ``opts`` (the static
  :class:`repro.core.ipi.IPIOptions`) and/or ``context`` (traced per-solve
  values, currently ``{"gamma": ...}``).  Must be ``lax`` control flow so
  it composes with jit / vmap (fleets) / shard_map (all mesh layouts).
* **Method** — a KSP name plus an inner-stopping policy: ``forcing``
  (iPI forcing term), ``sweeps`` (fixed ``mpi_sweeps``), ``tight``
  (``0.01 * atol``), ``none`` (pure VI).
* **Stop criterion** — ``fn(m: StopMetrics) -> bool array`` (True where
  converged), elementwise over fleet lanes; traced into the loop
  predicate.  ``Session.solve(stop_criterion=callable)`` registers
  anonymous predicates automatically.

The generated docs tables (:func:`method_table`, :func:`ksp_table`,
:func:`repro.api.option_table`) are the single source of truth for the
README — a test asserts they cannot drift.
"""

from __future__ import annotations

from repro.core.methods import (
    KSPSpec, MethodSpec, StopMetrics, StopSpec,
    check_ksp, check_method, check_stop,
    get_ksp, get_method, get_stop,
    ksp_names, method_names, method_for_ksp, print_monitor,
    register_ksp, register_method, register_stop_criterion, stop_names,
    unregister_ksp, unregister_method, unregister_stop_criterion,
)

__all__ = [
    "KSPSpec", "MethodSpec", "StopMetrics", "StopSpec",
    "check_ksp", "check_method", "check_stop",
    "get_ksp", "get_method", "get_stop",
    "ksp_names", "ksp_table", "method_for_ksp", "method_names",
    "method_table", "print_monitor",
    "register_ksp", "register_method", "register_stop_criterion",
    "stop_names", "stop_table",
    "unregister_ksp", "unregister_method", "unregister_stop_criterion",
]

_INNER_DOC = {
    "none": "—",
    "forcing": "forcing: `eta * res`",
    "sweeps": "fixed: `mpi_sweeps`",
    "tight": "tight: `0.01 * atol`",
}


def method_table(*, builtin_only: bool = True) -> str:
    """The method registry as a markdown table (README single source of
    truth; ``builtin_only`` keeps runtime registrations out of the docs)."""
    lines = ["| method | inner solver (ksp) | inner stop | safeguard "
             "| description |",
             "|--------|--------------------|------------|-----------"
             "|-------------|"]
    for name in method_names(builtin_only=builtin_only):
        s = get_method(name)
        ksp = "—" if s.ksp is None else f"`{s.ksp}`"
        guard = "yes" if (s.safeguarded and s.ksp is not None) else "—"
        lines.append(f"| `{s.name}` | {ksp} | {_INNER_DOC[s.inner]} | "
                     f"{guard} | {s.doc.replace('|', chr(92) + '|')} |")
    return "\n".join(lines)


def ksp_table(*, builtin_only: bool = True) -> str:
    """The inner-solver (KSP) registry as a markdown table."""
    lines = ["| ksp | deterministic_dots | precond | description |",
             "|-----|--------------------|---------|-------------|"]
    for name in ksp_names(builtin_only=builtin_only):
        s = get_ksp(name)
        det = "yes" if s.deterministic else "—"
        pc = "yes" if s.preconditioned else "—"
        lines.append(f"| `{s.name}` | {det} | {pc} | "
                     f"{s.doc.replace('|', chr(92) + '|')} |")
    return "\n".join(lines)


def stop_table(*, builtin_only: bool = True) -> str:
    """The stopping-criterion registry as a markdown table."""
    lines = ["| criterion | description |",
             "|-----------|-------------|"]
    for name in stop_names(builtin_only=builtin_only):
        s = get_stop(name)
        lines.append(f"| `{s.name}` | {s.doc.replace('|', chr(92) + '|')} |")
    return "\n".join(lines)
