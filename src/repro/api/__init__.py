"""repro.api — the supported user surface (madupite-style).

Three pillars over the solver core (:mod:`repro.core`):

* :class:`MDP` — build problems from arrays, files, generators, or Python
  callables (``MDP.from_functions`` materializes shard-locally on device),
  tagged ``mode="mincost"`` or ``"maxreward"``;
* :class:`Options` — the PETSc-style options database: validated string
  options (``-method``, ``-atol``, ``-layout``, ``-file_stats``, ...)
  ingested from code, ``MADUPITE_OPTIONS`` and ``--option k=v``, mapping
  losslessly onto :class:`repro.core.ipi.IPIOptions`;
* :class:`Session` / :func:`madupite_session` — owns mesh/layout placement,
  fleet bucketing, the run-chunk cache lifecycle and run outputs (streamed
  JSONL stats, policy/value files);
* the **method registries** (:mod:`repro.api.methods`) —
  :func:`register_ksp` / :func:`register_method` /
  :func:`register_stop_criterion` plug user inner solvers, outer methods
  and stopping criteria into the compiled loop, selectable from options
  everywhere (``-ksp_type`` / ``-method`` / ``-stop_criterion``), plus
  in-loop monitors (``-monitor`` / ``Session.solve(monitor=...)``).

    from repro.api import MDP, madupite_session

    mdp = MDP.from_generator("garnet", n=10_000, m=16, k=8, gamma=0.99)
    with madupite_session({"-method": "ipi_gmres", "-atol": 1e-8,
                           "-file_stats": "run.json"}) as s:
        result = s.solve(mdp)

Module-level :func:`solve` / :func:`solve_fleet` are one-shot conveniences
over a shared default session.
"""

from __future__ import annotations

from repro.api.fleet import bucket_indices
from repro.api.mdp import MDP, place_function_fleet
from repro.api.methods import (StopMetrics, ksp_names, ksp_table,
                               method_names, method_table, register_ksp,
                               register_method, register_stop_criterion,
                               stop_names, stop_table, unregister_ksp,
                               unregister_method, unregister_stop_criterion)
from repro.api.options import (OPTION_SPECS, Options, OptionTypeError,
                               UnknownOptionError, option_table)
from repro.api.session import Session, madupite_session

__all__ = ["MDP", "Options", "OptionTypeError", "OPTION_SPECS", "Session",
           "StopMetrics", "UnknownOptionError", "bucket_indices",
           "ksp_names", "ksp_table", "madupite_session", "method_names",
           "method_table", "option_table", "place_function_fleet",
           "register_ksp", "register_method", "register_stop_criterion",
           "solve", "solve_fleet", "stop_names", "stop_table",
           "unregister_ksp", "unregister_method",
           "unregister_stop_criterion"]

_default_session: Session | None = None


def _default() -> Session:
    global _default_session
    if _default_session is None or _default_session._closed:
        _default_session = Session()
    return _default_session


def solve(mdp, options=None, **overrides):
    """One-shot :meth:`Session.solve` on a shared default session."""
    if options is not None:
        # a throwaway session must not clear the process-wide run cache on
        # exit — that would evict the default session's warm programs
        with Session(options, clear_cache_on_close=False) as s:
            return s.solve(mdp, **overrides)
    return _default().solve(mdp, **overrides)


def solve_fleet(mdps, options=None, **overrides):
    """One-shot :meth:`Session.solve_fleet` on a shared default session."""
    if options is not None:
        with Session(options, clear_cache_on_close=False) as s:
            return s.solve_fleet(mdps, **overrides)
    return _default().solve_fleet(mdps, **overrides)
