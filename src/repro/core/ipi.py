"""Inexact policy iteration (iPI) — the paper's core algorithm.

Implements the outer loop of Gargiani et al. 2024, Algorithm 3, with the
inner policy-evaluation solve delegated to a selectable inner solver drawn
from the LIVE method/KSP registries (:mod:`repro.core.methods` — the
PETSc-KSP analogue; user solvers registered via
:func:`repro.api.register_ksp` dispatch through the same path).  The
builtin zoo maps onto one code path:

  ``vi``             value iteration          (inner = 0 Richardson sweeps)
  ``mpi``            modified policy iter.    (inner = fixed Richardson sweeps)
  ``ipi_richardson`` iPI + Richardson         (forcing-term stopping)
  ``ipi_gmres``      iPI + restarted GMRES    (the iGMRES-PI of the paper)
  ``ipi_bicgstab``   iPI + BiCGStab
  ``pi``             (near-)exact policy iteration (GMRES, tight tol)
  ``ipi_chebyshev``  iPI + Chebyshev semi-iteration (collective-free inner)
  ``ipi_anderson``   iPI + Anderson-accelerated VI

The outer stopping rule is equally pluggable (``opts.stop_criterion`` ->
the stop-criterion registry): ``atol`` (sup-norm residual), ``rtol``
(relative), ``span`` (span seminorm — certifies long-mixing VI far
earlier), or user-registered traced predicates; the chosen predicate
compiles into the ``lax.while_loop`` condition.  ``opts.monitor`` streams
one record per outer iteration out of the compiled loop via
``jax.debug.callback`` (fleet layouts gather per-instance rows and emit
exactly one host record via lead-shard gating).

Every outer iteration does exactly one Bellman backup (greedy step + residual)
and one inexact solve of ``(I - gamma P_pi) v = g_pi`` warm-started at
``T v_k``; with 0 inner iterations the update *is* ``T v_k`` so VI falls out
as the degenerate case.  A monotone safeguard (cheap, one extra backup on the
rare rejection path) falls back to the VI step whenever an inexact Krylov
step fails to reduce the sup-norm Bellman residual, which preserves global
convergence for any forcing factor.

The whole loop is device-side ``lax`` control flow; the host driver
(:mod:`repro.core.driver`) runs it in bounded *chunks* for checkpointing /
preemption tolerance.

Batched fleets
--------------
Every entry point accepts a *batched* MDP (leading ``B`` dim — see
:func:`repro.core.mdp.stack_mdps`): :func:`init_state` then returns a
batched :class:`SolveState` (per-instance residuals, iteration counters and
traces) and :func:`solve_chunk` runs ONE ``lax.while_loop`` for the whole
fleet, vmapping :func:`outer_step` over instances.  A per-instance *active
mask* (``res > atol`` and ``k < k_hi``) freezes converged instances: their
state fields stop updating, so per-instance ``k`` / ``inner_total`` / traces
are exactly what B independent solves would have produced, while the shared
loop keeps running on the instances still converging.  Homogeneous-gamma
fleets run the bit-identical static-gamma arithmetic of the unbatched path;
heterogeneous gammas thread a traced per-instance ``gamma_t`` through
:mod:`repro.core.bellman` (exact algebra, fp-level rounding differences).

Fleet-sharded layouts (``axes.fleet`` set) place only ``B / fleet_size``
instances on each shard.  Instances are independent, so the body needs no
new collectives — but the ``while_loop`` condition all-reduces the active
mask over the fleet axis (:meth:`Axes.any_fleet`) so every shard runs the
same iteration count: a shard whose lanes have all converged spins frozen
no-op iterations (the active mask keeps its state fixed) until the slowest
shard finishes, instead of desynchronizing the loop.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bellman, methods, solvers
from repro.core.comm import Axes
from repro.core.mdp import MDP, batch_parts

# Back-compat view of the builtin method zoo.  The zoo itself is a LIVE
# registry (repro.core.methods / repro.api.register_ksp): user-registered
# methods are equally valid IPIOptions.method values but do not appear here.
METHODS = tuple(methods.method_names(builtin_only=True))
MODES = ("mincost", "maxreward")


@dataclasses.dataclass(frozen=True)
class IPIOptions:
    """Static solver options (hashable -> usable as a jit static arg)."""

    method: str = "ipi_gmres"   # any name in the live method registry
                                # (repro.core.methods / api.register_method)
    mode: str = "mincost"       # "mincost" (argmin backup) | "maxreward"
                                # (argmax backup; cost is read as reward)
    atol: float = 1e-8          # stop when ||T v - v||_inf <= atol
    stop_criterion: str = "atol"  # outer stopping predicate compiled into
                                # the loop: atol | rtol | span | any name
                                # registered via api.register_stop_criterion
    rtol: float = 1e-4          # threshold for stop_criterion="rtol"
                                # (relative to the initial residual)
    max_outer: int = 500
    max_inner: int = 500        # inner-iteration cap per outer step
    forcing_eta: float = 0.05   # inner tol = eta * ||T v - v||_inf
    restart: int = 32           # GMRES restart length
    omega: float = 1.0          # Richardson damping
    mpi_sweeps: int = 50        # L for modified policy iteration
    anderson_window: int = 5    # AA depth for the anderson inner solver
    safeguard: bool = True      # monotone (VI-fallback) safeguard
    monitor: bool = False       # stream per-outer-iteration records out of
                                # the compiled loop (jax.debug.callback)
    deterministic_dots: bool = False  # pin the GMRES projection accumulation
                                # order (lane-at-a-time lax.map) so
                                # fleet-sharded Krylov values are bit-equal
                                # to the replicated layout
    impl: str | None = None     # kernel implementation override
    dtype: str = "float32"      # value-vector dtype; "float64" == PETSc default
                                # (requires jax_enable_x64)
    halo: int = 0               # banded layout: exchange only +-halo boundary
                                # entries instead of all-gathering v
    gather_dtype: str | None = None  # compressed (inexact) gather for INNER
                                # matvecs only; outer backups stay exact
    comm_overlap: str = "auto"  # overlap the backup's value-window movement
                                # with interior-row compute: "on" whenever an
                                # interior core exists, "auto" only when it
                                # covers >= half the local rows, "off" never
    async_sweeps: int = 1       # async_vi: local Bellman sweeps per value
                                # exchange (1 == synchronous vi)
    monitor_mode: str = "stream"  # "stream": one jax.debug.callback per
                                # outer iteration; "chunk": reconstruct the
                                # identical records host-side from the
                                # device traces once per run-chunk (no
                                # per-iteration host sync)
    overlap_plan: tuple | None = None  # resolved (f_lo, f_hi) frontier
                                # margins (driver-set from
                                # partition.overlap_margins; not a user
                                # option — compiled programs key on it)
    pc_type: str = "none"       # Krylov inner-solve preconditioner:
                                # none | jacobi (diag of I - gamma P_pi) |
                                # bjacobi (shard-local pc_block tiles)
    pc_block: int = 32          # bjacobi tile size
    divtol: float = 1e4         # declare divergence when the Bellman
                                # residual exceeds divtol * (initial
                                # residual) or goes NaN; the solve stops
                                # with SolveState.diverged set (the
                                # adaptive supervisor's hot-swap trigger)

    def __post_init__(self):
        # Raised (not assert'd): option validation must survive `python -O`.
        # Method / stop-criterion names validate against the LIVE registries
        # (user-registered solvers are first-class); error messages carry
        # close-spelling suggestions drawn from whatever is registered now.
        err = methods.check_method(self.method)
        if err:
            raise ValueError(err)
        err = methods.check_stop(self.stop_criterion)
        if err:
            raise ValueError(err)
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}; "
                             f"pick one of {MODES}")
        if self.dtype not in ("float32", "float64"):
            raise ValueError(f"dtype must be 'float32' or 'float64' (PETSc "
                             f"default), got {self.dtype!r}")
        if not self.atol > 0:
            raise ValueError(f"atol must be > 0, got {self.atol}")
        if not 0.0 < self.rtol < 1.0:
            raise ValueError(f"rtol must lie in (0, 1), got {self.rtol}")
        if self.max_outer < 1:
            raise ValueError(f"max_outer must be >= 1, got {self.max_outer}")
        if self.max_inner < 0:
            raise ValueError(f"max_inner must be >= 0, got {self.max_inner}")
        if not 0.0 < self.forcing_eta < 1.0:
            raise ValueError(f"forcing_eta must lie in (0, 1) for iPI "
                             f"convergence, got {self.forcing_eta}")
        spec = methods.get_method(self.method)
        if self.deterministic_dots and spec.ksp is not None \
                and not methods.get_ksp(spec.ksp).deterministic:
            raise ValueError(
                f"deterministic_dots pins batch-invariant accumulation "
                f"orders, which ksp {spec.ksp!r} (method {self.method!r}) "
                f"does not implement — its dots would still re-associate "
                f"by lane count; use a deterministic ksp (e.g. "
                f"gmres/richardson/chebyshev) or drop the flag")
        if self.pc_type not in ("none", "jacobi", "bjacobi"):
            raise ValueError(f"pc_type must be 'none', 'jacobi' or "
                             f"'bjacobi', got {self.pc_type!r}")
        if self.pc_type != "none" and not spec.virtual:
            if spec.ksp is None:
                raise ValueError(
                    f"pc_type {self.pc_type!r} preconditions the Krylov "
                    f"inner solve, but method {self.method!r} has no inner "
                    f"KSP; pick an ipi_* method (or -method auto) or drop "
                    f"-pc_type")
            if not methods.get_ksp(spec.ksp).preconditioned:
                raise ValueError(
                    f"ksp {spec.ksp!r} (method {self.method!r}) does not "
                    f"accept a preconditioner; register it with "
                    f"preconditioned=True (and a `precond` keyword) or use "
                    f"gmres/bicgstab")
            if self.pc_type == "bjacobi" and self.deterministic_dots:
                raise ValueError(
                    "pc_type 'bjacobi' applies batched tile inverses whose "
                    "accumulation order is not lane-count-pinned; under "
                    "deterministic_dots use pc_type 'jacobi' (elementwise) "
                    "or drop the flag")
        if self.pc_block < 1:
            raise ValueError(f"pc_block must be >= 1, got {self.pc_block}")
        if not self.divtol > 1.0:
            raise ValueError(f"divtol must be > 1 (residual growth factor "
                             f"declaring divergence), got {self.divtol}")
        if self.restart < 1:
            raise ValueError(f"restart must be >= 1, got {self.restart}")
        if self.mpi_sweeps < 1:
            raise ValueError(f"mpi_sweeps must be >= 1, got {self.mpi_sweeps}")
        if self.anderson_window < 1:
            raise ValueError(f"anderson_window must be >= 1, "
                             f"got {self.anderson_window}")
        if not isinstance(self.halo, int) or self.halo < 0:
            raise ValueError(f"halo must be a non-negative int (0 disables "
                             f"the banded layout), got {self.halo!r}")
        if self.comm_overlap not in ("auto", "on", "off"):
            raise ValueError(f"comm_overlap must be 'auto', 'on' or 'off', "
                             f"got {self.comm_overlap!r}")
        if not isinstance(self.async_sweeps, int) or self.async_sweeps < 1:
            raise ValueError(f"async_sweeps must be an int >= 1 (1 == "
                             f"synchronous vi), got {self.async_sweeps!r}")
        if self.monitor_mode not in ("stream", "chunk"):
            raise ValueError(f"monitor_mode must be 'stream' or 'chunk', "
                             f"got {self.monitor_mode!r}")
        if self.overlap_plan is not None and (
                not isinstance(self.overlap_plan, tuple)
                or len(self.overlap_plan) != 2
                or not all(isinstance(x, int) and x >= 0
                           for x in self.overlap_plan)):
            raise ValueError(f"overlap_plan is driver-internal: None or a "
                             f"(f_lo, f_hi) tuple of ints >= 0, got "
                             f"{self.overlap_plan!r}")
        if self.gather_dtype is not None:
            try:
                gd = jnp.dtype(self.gather_dtype)
            except TypeError as e:
                raise ValueError(f"gather_dtype {self.gather_dtype!r} is not "
                                 f"a dtype: {e}") from None
            if not jnp.issubdtype(gd, jnp.floating):
                raise ValueError(f"gather_dtype must be a floating dtype "
                                 f"(wire format for v), got {gd}")
            if gd.itemsize > jnp.dtype(self.dtype).itemsize:
                raise ValueError(
                    f"gather_dtype {gd} is wider than the value dtype "
                    f"{self.dtype}: the compressed gather would silently "
                    f"upcast the wire format; drop gather_dtype or widen "
                    f"dtype")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SolveState:
    """Device-side solver state (a pytree; checkpointable).

    Batched fleet: every field gains a leading ``B`` dim (``res`` / ``k`` /
    ``inner_total`` become per-instance ``(B,)`` vectors — the ``res > atol``
    mask is the fleet's per-instance active mask)."""

    v: jax.Array            # (n_local,) current value iterate
    tv: jax.Array           # (n_local,) T v (one backup ahead)
    pi: jax.Array           # (n_local,) int32 greedy policy (global ids)
    res: jax.Array          # scalar f32, ||T v - v||_inf (replicated)
    k: jax.Array            # scalar int32, outer iterations done
    inner_total: jax.Array  # scalar int32, cumulative inner iterations
    trace_res: jax.Array    # (max_outer + 1,) f32, residual after k outers
    trace_inner: jax.Array  # (max_outer,) int32, inner iters per outer
    res0: jax.Array         # scalar, residual at k=0 (rtol baseline)
    span: jax.Array         # scalar, sp(T v - v) over the TRUE states (inf
                            # unless the stop criterion declared needs_span)
    done: jax.Array         # scalar bool, stop criterion satisfied
    diverged: jax.Array     # scalar bool (sticky): residual went NaN or
                            # exceeded divtol * res0 — the loop stops and
                            # the flag surfaces through SolveResult /
                            # monitor records / run stats
    n_true: jax.Array       # scalar int32, unpadded state count: mesh-pad
                            # rows are absorbing zero-cost states whose 0
                            # residual must not enter the span min
    win: jax.Array          # last exchanged value window (async methods:
                            # invariant win == gather_v(v) at outer-step
                            # boundaries); empty (0,) for synchronous
                            # methods.  Checkpointed as empty and restored
                            # as zeros — the k=0 iterate, a valid (stale)
                            # async restart window.


def _local_gamma_t(gamma_t: jax.Array | None, batch: int,
                   axes: Axes) -> jax.Array | None:
    """This shard's block of the global per-instance discount vector.

    Under a fleet-sharded layout the device-local batched MDP view carries
    ``B_local = B / fleet_size`` instances, but ``gamma`` is static global
    metadata (a length-``B`` tuple), so the traced ``(B,)`` vector
    :func:`repro.core.mdp.batch_parts` builds from it must be sliced to the
    lanes this fleet shard owns.
    """
    if gamma_t is None or gamma_t.shape[0] == batch:
        return gamma_t
    return jax.lax.dynamic_slice_in_dim(
        gamma_t, axes.fleet_index() * batch, batch)


def init_state(mdp: MDP, axes: Axes, opts: IPIOptions,
               v0: jax.Array | None = None, *,
               gamma_t: jax.Array | None = None,
               n_true=None) -> SolveState:
    if mdp.batch is not None:
        view, in_ax, g_t = batch_parts(mdp)
        g_t = gamma_t if gamma_t is not None else g_t
        g_t = _local_gamma_t(g_t, mdp.batch, axes)
        nt = None if n_true is None else _local_gamma_t(
            jnp.asarray(n_true, jnp.int32), mdp.batch, axes)
        fn = lambda m, v, gt, t: init_state(m, axes, opts, v, gamma_t=gt,
                                            n_true=t)
        return jax.vmap(fn, in_axes=(in_ax, None if v0 is None else 0,
                                     None if g_t is None else 0,
                                     None if nt is None else 0))(view, v0,
                                                                 g_t, nt)
    dt = jnp.dtype(opts.dtype)
    nt = jnp.int32(mdp.n_global if n_true is None else n_true)
    v = jnp.zeros((mdp.n_local,), dt) if v0 is None else v0.astype(dt)
    tv, pi, v_g = bellman.gather_backup(mdp, v, axes,
                                        plan=opts.overlap_plan,
                                        impl=opts.impl, halo=opts.halo,
                                        gamma_t=gamma_t, mode=opts.mode)
    tv = tv.astype(dt)
    res = axes.pmax_state(jnp.max(jnp.abs(tv - v)))
    span = _span_of(tv - v, axes, opts, nt)
    g = gamma_t if gamma_t is not None else mdp.gamma
    done = methods.stop_done(opts, res=res, span=span, res0=res,
                             k=jnp.int32(0), gamma=g)
    trace_res = jnp.full((opts.max_outer + 1,), jnp.nan, dt)
    win = v_g.astype(dt) \
        if methods.get_method(opts.method).outer is not None \
        else jnp.zeros((0,), dt)
    return SolveState(
        v=v, tv=tv, pi=pi, res=res, k=jnp.int32(0),
        inner_total=jnp.int32(0),
        trace_res=trace_res.at[0].set(res),
        trace_inner=jnp.full((opts.max_outer,), -1, jnp.int32),
        res0=res, span=span, done=done, diverged=jnp.isnan(res),
        n_true=nt, win=win)


@partial(jax.jit, static_argnames=("opts", "axes"))
def init_state_jit(mdp: MDP, v0: jax.Array | None = None,
                   gamma_t: jax.Array | None = None, n_true=None, *,
                   opts: IPIOptions = None,
                   axes: Axes = None) -> SolveState:
    """Compiled :func:`init_state` for the single-device path: the vmapped
    eager init re-traces its op graph on every call, which dominates warm
    repeated solves (a serving fleet, bench reps).  The mesh path already
    wraps its init in jit+shard_map, so jitting here keeps both paths'
    numerics aligned."""
    return init_state(mdp, axes, opts, v0, gamma_t=gamma_t, n_true=n_true)


def _span_of(d: jax.Array, axes: Axes, opts: IPIOptions,
             n_true: jax.Array) -> jax.Array:
    """Span seminorm ``sp(d) = max(d) - min(d)`` over the TRUE states —
    computed (one extra pmax pair) only when the selected stop criterion
    declared ``needs_span``; otherwise a free +inf constant so the
    monitor-disabled hot path stays untouched.

    Mesh padding appends absorbing zero-cost states whose residual is
    exactly 0; left in the min they would pin ``sp(d)`` near ``max(d)``
    and silently erase the early-certification benefit on padded layouts
    (and break replicated-vs-sharded equality for non-divisible ``n``), so
    rows at global index >= ``n_true`` are masked to -inf on both sides.
    A shard that is entirely padding contributes -inf, which the cross-
    shard pmax discards; an all-padding dummy fleet lane yields span
    -inf (trivially "converged", matching its frozen res = 0)."""
    if not methods.get_stop(opts.stop_criterion).needs_span:
        return jnp.asarray(jnp.inf, d.dtype)
    rows = axes.state_index() * d.shape[0] + jnp.arange(d.shape[0])
    ninf = jnp.asarray(-jnp.inf, d.dtype)
    valid = rows < n_true
    dmax = axes.pmax_state(jnp.max(jnp.where(valid, d, ninf)))
    dmin = -axes.pmax_state(jnp.max(jnp.where(valid, -d, ninf)))
    return dmax - dmin


def _outer_core(mdp: MDP, state: SolveState, opts: IPIOptions,
                axes: Axes, gamma_t: jax.Array | None):
    """One outer iteration minus the k/trace bookkeeping.

    Returns ``(v1, tv1, pi1, res1, span1, inner_iters, win1)`` — shared by
    the unbatched :func:`outer_step` and the batched body of
    :func:`solve_chunk` (which does its bookkeeping fleet-wide, outside the
    vmap).  Methods with a custom ``outer`` (e.g. ``async_vi``) replace the
    inner-solve/backup core entirely; everyone else dispatches the inner
    policy-evaluation solve through the live KSP/method registry
    (:func:`repro.core.methods.inner_solve`).
    """
    spec = methods.get_method(opts.method)
    if spec.outer is not None:
        v1, tv1, pi1, res1, inner_iters, win1 = spec.outer(
            mdp, state, opts, axes, gamma_t)
        span1 = _span_of(tv1 - v1, axes, opts, state.n_true)
        return v1, tv1, pi1, res1, span1, inner_iters, win1
    rows = bellman.policy_rows(mdp, state.pi, axes)
    b = bellman.b_pi(rows, axes).astype(state.tv.dtype)
    gd = None if opts.gather_dtype is None else jnp.dtype(opts.gather_dtype)
    matvec = lambda x: bellman.a_pi_matvec(rows, x, axes, impl=opts.impl,
                                           mdp=mdp, halo=opts.halo,
                                           gather_dtype=gd, gamma_t=gamma_t)
    tol = jnp.maximum(opts.forcing_eta * state.res, jnp.float32(1e-30))
    gamma = gamma_t if gamma_t is not None else mdp.gamma
    precond = None
    if opts.pc_type != "none" and spec.ksp is not None:
        # rebuilt per outer iteration from the policy-rows transient the
        # matvec already needs — matrix-free MDPs pay no extra memory
        precond = solvers.build_precond(
            rows, axes=axes, n_local=mdp.n_local, gamma=gamma,
            pc_type=opts.pc_type, block=opts.pc_block,
            dtype=state.tv.dtype)
    v1, inner_iters, _ = methods.inner_solve(
        opts, matvec, b, state.tv, tol, axes, context=dict(gamma=gamma),
        precond=precond)

    def eval_at(v):
        # exact gather; opts.overlap_plan switches in the communication-
        # overlapped (result-identical) backup path
        tv, pi, _ = bellman.gather_backup(mdp, v, axes,
                                          plan=opts.overlap_plan,
                                          impl=opts.impl, halo=opts.halo,
                                          gamma_t=gamma_t, mode=opts.mode)
        res = axes.pmax_state(jnp.max(jnp.abs(tv - v)))
        return v, tv, pi, res

    cand = eval_at(v1)
    if opts.safeguard and spec.safeguarded and spec.ksp is not None:
        # Krylov-type steps are not contractions; reject any step that
        # increases the Bellman residual and take the (guaranteed) VI step
        # instead.  ``res`` is replicated across devices -> no control-flow
        # divergence.
        cand = jax.lax.cond(cand[3] <= state.res,
                            lambda: cand, lambda: eval_at(state.tv))
    v1, tv1, pi1, res1 = cand
    span1 = _span_of(tv1 - v1, axes, opts, state.n_true)
    return v1, tv1, pi1, res1, span1, inner_iters, state.win


def outer_step(mdp: MDP, state: SolveState, opts: IPIOptions,
               axes: Axes, *, gamma_t: jax.Array | None = None) -> SolveState:
    """One outer iPI iteration (greedy policy is already in ``state``)."""
    v1, tv1, pi1, res1, span1, inner_iters, win1 = _outer_core(
        mdp, state, opts, axes, gamma_t)
    k1 = state.k + 1
    g = gamma_t if gamma_t is not None else mdp.gamma
    done = methods.stop_done(opts, res=res1, span=span1, res0=state.res0,
                             k=k1, gamma=g)
    div1 = state.diverged | jnp.isnan(res1) | \
        (res1 > opts.divtol * jnp.maximum(state.res0, 1e-30))
    return SolveState(
        v=v1, tv=tv1, pi=pi1, res=res1, k=k1,
        inner_total=state.inner_total + inner_iters,
        trace_res=state.trace_res.at[k1].set(res1),
        trace_inner=state.trace_inner.at[state.k].set(inner_iters),
        res0=state.res0, span=span1, done=done, diverged=div1,
        n_true=state.n_true, win=win1)


def _lead_flag(axes: Axes) -> jax.Array:
    """True on exactly one mesh shard — the monitor callback fires on every
    device, so only the lead shard's (replicated) record is kept."""
    return (axes.state_index() == 0) & (axes.action_index() == 0) & \
        (axes.fleet_index() == 0)


@partial(jax.jit, static_argnames=("opts", "axes"))
def solve_chunk(mdp: MDP, state: SolveState, k_hi: jax.Array,
                mon_id: jax.Array = 0, opts: IPIOptions = None,
                axes: Axes = None) -> SolveState:
    """Run outer iterations until convergence or ``k == k_hi`` (device-side).

    With a batched ``mdp`` + batched ``state`` this is ONE while loop for the
    whole fleet: it spins while any instance is active and every iteration
    vmaps the outer-step core over instances, freezing the converged ones
    (their fields — including per-instance ``k`` / ``inner_total`` / traces —
    stop updating, so results match B independent solves).

    The fleet bookkeeping exploits a *lockstep invariant*: every state starts
    at ``k = 0`` and ``k`` only advances while a lane is active, so all
    active lanes always share one outer index.  Trace updates are therefore a
    single shared-column ``dynamic_update_slice`` instead of B per-lane
    scatters (much lighter to compile and run on every loop iteration).
    """
    if mdp.batch is None:
        def cond(s: SolveState):
            return (~s.done) & ~jnp.isnan(s.res) & (~s.diverged) & \
                (s.k < k_hi)

        def body(s: SolveState) -> SolveState:
            s1 = outer_step(mdp, s, opts, axes)
            if opts.monitor and opts.monitor_mode == "stream":
                methods.emit_monitor(mon_id, _lead_flag(axes), s1.k, s1.res,
                                     s1.inner_total - s.inner_total,
                                     s1.diverged)
            return s1

        return jax.lax.while_loop(cond, body, state)

    view, in_ax, gamma_t = batch_parts(mdp)
    gamma_t = _local_gamma_t(gamma_t, mdp.batch, axes)
    if gamma_t is not None:
        # pin the traced per-lane discounts to the solve dtype: under
        # jax_enable_x64 the vector defaults to float64 and every gamma*Pv
        # product would promote, breaking the float32 while-loop carry
        gamma_t = gamma_t.astype(jnp.dtype(opts.dtype))
    core = jax.vmap(
        lambda m, s, gt: _outer_core(m, s, opts, axes, gt),
        in_axes=(in_ax, 0, None if gamma_t is None else 0))

    def active(s: SolveState) -> jax.Array:
        return (~s.done) & ~jnp.isnan(s.res) & (~s.diverged) & (s.k < k_hi)

    def body(s: SolveState) -> SolveState:
        act = active(s)
        v1, tv1, pi1, res1, span1, inner, win1 = core(view, s, gamma_t)
        sel = lambda n, o: jnp.where(act[:, None] if n.ndim > 1 else act,
                                     n, o)
        k1 = s.k + act.astype(jnp.int32)
        g = gamma_t if gamma_t is not None else mdp.gamma
        done1 = methods.stop_done(opts, res=res1, span=span1, res0=s.res0,
                                  k=k1, gamma=g)
        div1 = s.diverged | (act & (jnp.isnan(res1) | (
            res1 > opts.divtol * jnp.maximum(s.res0, 1e-30))))
        # Lockstep: all active lanes write outer index k_col; frozen lanes
        # keep their old column value.
        k_col = jnp.max(jnp.where(act, k1, 0))
        res_col = jnp.where(act, res1, s.trace_res[:, k_col])
        inner_col = jnp.where(act, inner, s.trace_inner[:, k_col - 1])
        s1 = SolveState(
            v=sel(v1, s.v), tv=sel(tv1, s.tv), pi=sel(pi1, s.pi),
            res=sel(res1, s.res), k=k1,
            inner_total=s.inner_total + jnp.where(act, inner, 0),
            trace_res=jax.lax.dynamic_update_slice(
                s.trace_res, res_col[:, None], (jnp.int32(0), k_col)),
            trace_inner=jax.lax.dynamic_update_slice(
                s.trace_inner, inner_col[:, None], (jnp.int32(0),
                                                    k_col - 1)),
            res0=s.res0, span=sel(span1, s.span),
            done=jnp.where(act, done1, s.done), diverged=div1,
            n_true=s.n_true, win=sel(win1, s.win))
        if opts.monitor and opts.monitor_mode == "stream":
            # One fleet-wide record per outer iteration: gather the
            # per-instance rows over the fleet axis (every shard runs the
            # collective; only the lead shard's callback is kept).
            methods.emit_monitor(
                mon_id, _lead_flag(axes),
                axes.pmax_fleet(k_col), axes.allgather_fleet(s1.res),
                axes.allgather_fleet(jnp.where(act, inner, 0)),
                axes.allgather_fleet(s1.diverged))
        return s1

    # The loop condition is all-reduced over the fleet axis: every fleet
    # shard runs the same trip count (a shard whose lanes all converged
    # spins no-op iterations — `sel` keeps its state frozen), so collectives
    # may safely be added to the body later without desynchronizing SPMD
    # shards.  Identity when axes.fleet is None (replicated layouts).
    return jax.lax.while_loop(
        lambda s: axes.any_fleet(jnp.any(active(s))), body, state)
