"""repro.core — distributed inexact policy iteration for large-scale MDPs.

The solver *engine* layer.  The supported user surface is
:mod:`repro.api` (MDP builders, the options database, sessions)::

    from repro.api import MDP, madupite_session
    mdp = MDP.from_generator("garnet", n=10_000, m=16, k=8, gamma=0.99)
    with madupite_session({"-method": "ipi_gmres", "-atol": 1e-8}) as s:
        result = s.solve(mdp)

``repro.core.solve`` / ``repro.core.solve_many`` remain as deprecated
aliases of the engine entry points (:mod:`repro.core.driver`); they keep
working unchanged but emit a ``DeprecationWarning`` pointing at the new
API.
"""

import functools
import warnings

from repro.core.comm import Axes
from repro.core.driver import SolveResult
from repro.core.driver import solve as _driver_solve
from repro.core.driver import solve_many as _driver_solve_many
from repro.core.ipi import IPIOptions, METHODS, MODES, SolveState
from repro.core.mdp import DenseMDP, EllMDP, stack_mdps
from repro.core import bellman, generators, methods, partition

__all__ = ["Axes", "DenseMDP", "EllMDP", "IPIOptions", "METHODS", "MODES",
           "SolveResult", "SolveState", "bellman", "generators", "methods",
           "partition", "solve", "solve_many", "stack_mdps"]


def _deprecated_shim(fn, name):
    @functools.wraps(fn)
    def shim(*args, **kwargs):
        warnings.warn(
            f"repro.core.{name} is deprecated as a user entry point; use "
            f"repro.api (MDP builders + madupite_session / Session."
            f"{'solve_fleet' if name == 'solve_many' else 'solve'}), which "
            f"owns mesh/layout placement and the options database. "
            f"Internal callers should import repro.core.driver.{name}.",
            DeprecationWarning, stacklevel=2)
        return fn(*args, **kwargs)
    return shim


solve = _deprecated_shim(_driver_solve, "solve")
solve_many = _deprecated_shim(_driver_solve_many, "solve_many")
