"""repro.core — distributed inexact policy iteration for large-scale MDPs.

The JAX/TPU reimplementation of madupite's contribution.  Public surface:

    from repro.core import EllMDP, IPIOptions, solve, generators
    mdp = generators.garnet(n=10_000, m=16, k=8, gamma=0.99)
    result = solve(mdp, IPIOptions(method="ipi_gmres", atol=1e-8))
"""

from repro.core.comm import Axes
from repro.core.driver import SolveResult, solve, solve_many
from repro.core.ipi import IPIOptions, METHODS, SolveState
from repro.core.mdp import DenseMDP, EllMDP, stack_mdps
from repro.core import bellman, generators, partition

__all__ = ["Axes", "DenseMDP", "EllMDP", "IPIOptions", "METHODS",
           "SolveResult", "SolveState", "bellman", "generators",
           "partition", "solve", "solve_many", "stack_mdps"]
