"""Host driver: chunked, checkpointed, optionally distributed iPI solve.

This is the user-facing ``solve`` — the analogue of madupite's
``madupite.solve(mdp, options)``.  The device-side loop runs in bounded
chunks; between chunks the host persists the solver state (preemption /
node-failure tolerance) and reports progress.  Distribution wraps the same
device code in ``shard_map`` over the supplied mesh (1-D paper-faithful or
2-D state x action layout — see :mod:`repro.core.partition`).

Fleet solves — :func:`solve_many`
---------------------------------
Real workloads are *fleets* of related MDPs (seed ensembles, gamma sweeps,
scenario/robustness studies).  ``solve_many(mdps, opts)`` stacks them into
one batched container (:func:`repro.core.mdp.stack_mdps`), runs ONE compiled
chunked loop for the whole fleet (``jax.vmap`` of the outer iteration inside
the same ``lax.while_loop`` / ``shard_map`` machinery ``solve`` uses), and
returns per-instance :class:`SolveResult`\\ s.  Converged instances freeze via
a per-instance active mask, so each result carries the same ``k`` /
``inner_total`` / traces B independent ``solve`` calls would have produced —
while the fleet amortizes dispatch, compilation and kernel launches (the
``benchmarks/bench_batch.py`` claim).  Heterogeneous state counts are padded
(results are trimmed back); heterogeneous gammas run the traced-gamma path.

Under the *fleet-sharded* layouts (``layout="fleet"`` / ``"fleet2d"``) the
instance dim itself is partitioned over the mesh's leading ``fleet`` axis —
per-device fleet memory is ``B / fleet_size`` of the replicated layouts, so
fleet size scales with the mesh (``benchmarks/bench_fleet.py``).

Checkpoints are mesh-agnostic: the solver state is saved *unsharded and
unpadded* (state dims trimmed to the true ``n``, fleet dim to the true
``B``), and restore re-pads for whatever mesh the resumed job runs on — a
fleet solved on an 8-way fleet axis restores onto a 4-way one, and an
``n`` that pads differently per mesh size round-trips exactly.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ipi, methods, partition
from repro.core.comm import Axes
from repro.core.ipi import IPIOptions, SolveState
from repro.core.mdp import (DenseMDP, EllMDP, MatrixFreeMDP, MDP, gammas_of,
                            stack_mdps)
from repro.utils import checkpoint as ckpt
from repro.utils.jax_compat import shard_map as _shard_map


@dataclasses.dataclass
class SolveResult:
    v: np.ndarray                  # (n,) optimal values (padding trimmed)
    policy: np.ndarray             # (n,) int32 greedy policy
    residual: float                # final ||T v - v||_inf
    gap_bound: float               # ||v - v*||_inf certificate: res/(1-gamma)
                                   # (span stopping: gamma*sp/(2*(1-gamma))
                                   # on the midpoint-corrected v)
    converged: bool
    outer_iterations: int
    inner_iterations: int
    trace_residual: np.ndarray     # (outer+1,)
    trace_inner: np.ndarray        # (outer,)
    diverged: bool = False         # residual went NaN or blew past
                                   # opts.divtol * res0 — the solve stopped
                                   # early and v/policy are NOT certified
    span: float = float("inf")     # final sp(T v - v) (inf unless the stop
                                   # criterion declared needs_span)

    def summary(self) -> str:
        flag = " DIVERGED" if self.diverged else ""
        return (f"converged={self.converged} outer={self.outer_iterations} "
                f"inner={self.inner_iterations} residual={self.residual:.3e} "
                f"gap<= {self.gap_bound:.3e}{flag}")


def _result(state: SolveState, opts: IPIOptions, gamma: float,
            n_orig: int) -> SolveResult:
    k = int(state.k)
    res = float(state.res)
    converged = bool(state.done)  # the compiled stop criterion's verdict
    v = np.asarray(jax.device_get(state.v))[:n_orig]
    gap = res / (1.0 - gamma)
    if converged and opts.stop_criterion == "span" and gamma < 1.0:
        # Midpoint correction (Puterman §6.6): for any v with
        # d = T v - v,  T v + gamma/(1-gamma) * min(d) <= v* <=
        # T v + gamma/(1-gamma) * max(d)  (T is monotone and shifts
        # constants by gamma, for min- and max-backups alike), so the
        # midpoint-shifted T v carries the certified error bound
        # gamma * sp(d) / (2 * (1-gamma)) — the whole point of span
        # stopping, which the raw iterate (error only <= res/(1-gamma))
        # would squander.  A constant shift, so the policy is untouched.
        tv = np.asarray(jax.device_get(state.tv))[:n_orig]
        d = tv - v
        scale = gamma / (1.0 - gamma)
        v = tv + scale * (float(d.max()) + float(d.min())) / 2.0
        gap = scale * float(state.span) / 2.0
    return SolveResult(
        v=v,
        policy=np.asarray(jax.device_get(state.pi))[:n_orig],
        residual=res,
        gap_bound=gap,
        converged=converged,
        outer_iterations=k,
        inner_iterations=int(state.inner_total),
        trace_residual=np.asarray(state.trace_res)[:k + 1],
        trace_inner=np.asarray(state.trace_inner)[:k],
        diverged=bool(state.diverged),
        span=float(state.span))


def _validate_banded(mdp, halo: int, mesh, layout: str) -> None:
    """The halo layout is only exact when every transition stays within
    +-halo of its source row (matrix bandwidth <= halo) and the halo fits in
    one shard.  Raises ``ValueError`` (not assert: must survive -O)."""
    if isinstance(mdp, MatrixFreeMDP):
        # no arrays to measure: trust (and require) the declared bandwidth
        if mdp.spec.band is None:
            raise ValueError(
                "halo>0 on a matrix-free operator needs a declared matrix "
                "bandwidth — there is no stored table to measure; pass "
                "band=... to from_functions() (max |successor - row| over "
                "all nonzero transitions) or drop to halo=0")
        band = int(mdp.spec.band)
    elif not isinstance(mdp, EllMDP):
        raise ValueError("halo>0 requires the ELL representation; DenseMDP "
                         "columns are global — drop halo or convert the MDP")
    else:
        idx = np.asarray(mdp.idx)
        rows = np.arange(mdp.n_global).reshape(-1, 1, 1)
        band = int(np.abs(idx - rows).max())
    if band > halo:
        raise ValueError(
            f"matrix bandwidth {band} exceeds halo {halo}: the banded "
            f"exchange would silently drop transitions; set halo >= {band} "
            f"or use the all-gather layout (halo=0)")
    if mesh is not None:
        n_shards = int(np.prod([
            mesh.shape[a] for a in partition.mesh_axes(mesh, layout).state]))
        n_local = -(-mdp.n_global // n_shards)
        if halo > n_local:
            raise ValueError(
                f"halo {halo} exceeds the per-shard state count {n_local} "
                f"({n_shards} shards x {mdp.n_global} states): boundary "
                f"exchange would need >1 ring hop; use fewer shards or a "
                f"smaller halo")


def _resolve_overlap(opts: IPIOptions, dev_mdp, mesh, axes: Axes) \
        -> IPIOptions:
    """Resolve ``-comm_overlap auto|on|off`` into a static interior/frontier
    plan baked into ``opts`` (compiled programs key on ``opts`` as a jit
    static, so a changed plan retraces — exactly right, the row split is a
    compile-time constant).

    ``on`` overlaps whenever a contiguous interior core exists (banded /
    stencil instances); ``auto`` additionally requires the core to cover at
    least half the local rows (hiding the gather behind a sliver of interior
    compute would not pay for the split).  Dense-random instances have no
    interior core and silently stay on the synchronous path.

    When a plan exists and the user left ``-halo 0``, the planner also
    *shrinks the collective*: :func:`partition.frontier_reach` measures how
    far outside its own block any row's nonzero successors reach, and the
    solve runs on the banded halo layout at exactly that width — the value
    exchange drops from the full ``n_global`` all-gather to a ``2 * reach``
    ring exchange (exact by construction, so no `_validate_banded` pass is
    needed).  This is where the overlapped path wins on hardware without
    async collective support; with async collectives the remaining ring
    exchange additionally hides behind the interior compute.
    """
    plan, halo = None, opts.halo
    if opts.comm_overlap != "off" and mesh is not None:
        n_shards = partition._axis_size(mesh, axes.state)
        plan = partition.overlap_margins(dev_mdp, n_shards)
        if plan is not None and opts.comm_overlap == "auto":
            n_local = dev_mdp.n_global // n_shards
            if n_local - plan[0] - plan[1] < n_local // 2:
                plan = None
        if plan is not None and opts.halo == 0:
            reach = partition.frontier_reach(dev_mdp, n_shards)
            n_local = dev_mdp.n_global // n_shards
            # ring exchange reaches one neighbour: reach must fit a shard
            # (use half — beyond that the window approaches the gather)
            if reach is not None and reach <= n_local // 2:
                halo = max(int(reach), 1)
    if plan == opts.overlap_plan and halo == opts.halo:
        return opts
    return dataclasses.replace(opts, overlap_plan=plan, halo=halo)


def _drain_monitor(mid: int, state: SolveState, done_prev, k_prev) -> None:
    """``monitor_mode="chunk"``: reconstruct this run-chunk's per-iteration
    records host-side from the device traces — record-for-record (``k`` /
    ``res`` / ``inner``) what ``"stream"`` would have emitted, without one
    ``jax.debug.callback`` host sync per outer iteration (``elapsed`` is the
    drain time).  ``done_prev`` / ``k_prev`` are the pre-chunk done mask and
    iteration counts (``done_prev=None`` for a single-instance solve)."""
    k = np.asarray(jax.device_get(state.k))
    tr = np.asarray(jax.device_get(state.trace_res))
    ti = np.asarray(jax.device_get(state.trace_inner))
    div_f = np.asarray(jax.device_get(state.diverged))
    if k.ndim == 0:
        for kk in range(int(k_prev) + 1, int(k) + 1):
            # diverged flips exactly at the iteration the loop stopped on,
            # so only the final reconstructed record can carry it — same
            # sequence the stream emits
            methods.emit_host(mid, kk, float(tr[kk]),
                              max(int(ti[kk - 1]), 0),
                              bool(div_f) and kk == int(k))
        return
    act_prev = ~np.asarray(done_prev)
    if not act_prev.any():
        return
    res_f = np.asarray(jax.device_get(state.res))
    # lockstep invariant: all active lanes share one outer index, so the
    # stream's per-iteration k_col sequence is exactly this range
    k_lo = int(np.asarray(k_prev)[act_prev].max())
    k_hi = int(k[act_prev].max())
    for kk in range(k_lo + 1, k_hi + 1):
        col = tr[:, kk]
        # frozen lanes: the stream reports their (frozen) current residual —
        # pre-chunk-done lanes override their historical trace value, lanes
        # frozen mid-chunk have an unwritten (NaN) column
        col = np.where(~act_prev | np.isnan(col), res_f, col)
        inn = ti[:, kk - 1]
        inn = np.where(~act_prev | (inn < 0), 0, inn).astype(np.int32)
        methods.emit_host(mid, kk, col, inn,
                          div_f & (kk == k) if kk == k_hi
                          else np.zeros_like(div_f))


_RUN_CHUNK_CACHE: dict = {}


def clear_run_cache() -> None:
    """Drop every cached jit'd ``run_chunk`` wrapper.

    The session layer (:mod:`repro.api.session`) owns the cache lifecycle:
    a closing session releases the compiled programs (and the device MDPs
    they pin via their sharding closures) instead of letting them accumulate
    for the life of the process.  (The module-level ``ipi.solve_chunk`` jit
    cache is left alone — other live sessions share it; it is cleared
    automatically when a registry name is replaced with ``overwrite=True``,
    see the ``_clear_compiled`` hook below.)"""
    _RUN_CHUNK_CACHE.clear()


def _clear_compiled() -> None:
    """Registry hot-swap hook: a re-registered KSP/method/stop-criterion is
    looked up at trace time, so every compiled solve program — the shard_map
    run_chunk wrappers AND the module-level single-device ``solve_chunk``
    jit cache — must be dropped or the old code keeps running."""
    _RUN_CHUNK_CACHE.clear()
    ipi.solve_chunk.clear_cache()
    ipi.init_state_jit.clear_cache()


methods.on_overwrite_clear(_clear_compiled)


def _make_runners(dev_mdp, opts: IPIOptions, mesh, axes: Axes, batch,
                  n_true=None):
    """(run_chunk, init) closures for single-device or shard_map execution.

    ``n_true`` (int, or per-instance int sequence for fleets) is the
    unpadded state count baked into the initial :class:`SolveState` — the
    span stop criterion masks mesh-pad rows with it."""
    if mesh is None:
        run_chunk = partial(ipi.solve_chunk, opts=opts, axes=axes)
        init = lambda v0: ipi.init_state_jit(dev_mdp, v0, None, n_true,
                                             opts=opts, axes=axes)
        return run_chunk, init
    # Batched fleets: the leading instance dim (and the per-instance res / k
    # / trace vectors) shard over axes.fleet — which is None (replicated)
    # for the 1d/2d layouts, keeping their previous behavior.
    lead = () if batch is None else (axes.fleet,)
    scal = P() if batch is None else P(axes.fleet)
    mdp_specs = partition.mdp_pspecs(dev_mdp, axes)
    # win: the halo window is per-shard (overlapping windows concatenate
    # along the state axis); the all-gathered window is replicated
    win_spec = P(*lead, axes.state) if opts.halo else P(*lead)
    state_specs = SolveState(
        v=P(*lead, axes.state), tv=P(*lead, axes.state),
        pi=P(*lead, axes.state),
        res=scal, k=scal, inner_total=scal, trace_res=scal,
        trace_inner=scal, res0=scal, span=scal, done=scal, diverged=scal,
        n_true=scal, win=win_spec)
    # Reuse one jit wrapper per (mesh, opts, axes, specs) so repeated solves
    # of same-shaped problems — a serving fleet, bench reps, the chunked
    # restart loop — hit jax's compilation cache instead of re-tracing a
    # fresh wrapper every call.  The specs pytree (treedef includes the MDP
    # statics) is exactly what determines the wrapped program.
    in_specs = (mdp_specs, state_specs, P(), P())   # (..., k_hi, mon_id)
    flat, treedef = jax.tree_util.tree_flatten(in_specs)
    key = (mesh, opts, axes, treedef, tuple(flat))
    run_chunk = _RUN_CHUNK_CACHE.get(key)
    if run_chunk is None:
        if len(_RUN_CHUNK_CACHE) > 64:   # bound growth: drop the oldest
            _RUN_CHUNK_CACHE.pop(next(iter(_RUN_CHUNK_CACHE)))
        run_chunk = jax.jit(
            _shard_map(
                partial(ipi.solve_chunk, opts=opts, axes=axes),
                mesh=mesh,
                in_specs=in_specs,
                out_specs=state_specs),
        )
        _RUN_CHUNK_CACHE[key] = run_chunk

    def init(v0):
        if v0 is None:
            f = jax.jit(
                _shard_map(
                    lambda m: ipi.init_state(m, axes, opts, n_true=n_true),
                    mesh=mesh, in_specs=(mdp_specs,),
                    out_specs=state_specs))
            return f(dev_mdp)
        v_spec = P(*lead, axes.state)
        v0 = jax.device_put(jnp.asarray(v0), NamedSharding(mesh, v_spec))
        f = jax.jit(
            _shard_map(
                lambda m, v: ipi.init_state(m, axes, opts, v,
                                            n_true=n_true),
                mesh=mesh, in_specs=(mdp_specs, v_spec),
                out_specs=state_specs))
        return f(dev_mdp, v0)

    return run_chunk, init


def _trim_ckpt_state(state: SolveState, n_orig: int,
                     b_orig: int | None) -> SolveState:
    """Solver state in its mesh-agnostic checkpoint form: gathered to host
    and stripped of mesh padding (state dims trimmed to the true ``n_orig``,
    fleet dim to the true ``b_orig``).  Restore re-pads for the resuming
    mesh, so a job may restart on a mesh that pads differently (elastic
    restart across device counts / fleet-axis sizes)."""
    host = jax.device_get(state)
    lead = (lambda x: np.asarray(x)[:b_orig]) if b_orig is not None \
        else np.asarray
    return SolveState(
        v=lead(host.v)[..., :n_orig], tv=lead(host.tv)[..., :n_orig],
        pi=lead(host.pi)[..., :n_orig], res=lead(host.res),
        k=lead(host.k), inner_total=lead(host.inner_total),
        trace_res=lead(host.trace_res), trace_inner=lead(host.trace_inner),
        res0=lead(host.res0), span=lead(host.span), done=lead(host.done),
        diverged=lead(host.diverged), n_true=lead(host.n_true),
        # the exchanged window is mesh-dependent derived state (invariant
        # win == gather(v)); checkpoint it empty — restore zero-fills, i.e.
        # the k=0 iterate, a valid stale async restart window
        win=lead(host.win)[..., :0])


def _pad_restored(tree, like):
    """Zero-pad a restored (unpadded) checkpoint to the current mesh's
    padded shapes.  Zero is exact, not approximate: padded states are
    absorbing zero-cost self-loops (``v == tv == 0``, greedy action 0 —
    precisely the values the solver would have computed for them), and
    padded fleet lanes get ``res == 0``, freezing them under the active
    mask from the first restored iteration."""
    def pad(a, l):
        a = np.asarray(a)
        if a.shape != l.shape:
            if len(a.shape) != len(l.shape) or \
                    any(s > t for s, t in zip(a.shape, l.shape)):
                raise ValueError(
                    f"checkpoint leaf of shape {a.shape} does not fit this "
                    f"solve's {tuple(l.shape)}: the checkpoint was written "
                    f"by a different problem or options (e.g. a larger "
                    f"max_outer, n, or fleet size); point checkpoint_dir "
                    f"at a fresh directory or re-run with the original "
                    f"settings")
            # bool leaves are the `done` flags: padded fleet lanes are dummy
            # instances and must restore as already-converged (True), not as
            # active lanes the zero-fill would wake up
            fill = True if a.dtype == np.bool_ else 0
            a = np.pad(a, [(0, t - s) for s, t in zip(a.shape, l.shape)],
                       constant_values=fill)
        return a.astype(l.dtype)
    return jax.tree_util.tree_map(pad, tree, like)


def _restore_or_init(init, v0, checkpoint_dir, verbose, expect=None):
    """``expect`` maps checkpoint-meta keys (``n`` / ``batch``) to the
    values this solve requires — a mismatch means the directory holds some
    *other* problem's checkpoint, which zero-padding would otherwise
    silently absorb."""
    if checkpoint_dir and ckpt.latest_step(checkpoint_dir) is not None:
        like = jax.eval_shape(init, v0)
        restored = ckpt.restore(checkpoint_dir, like)
        if restored is not None:
            tree, _, meta = restored
            for key, want in (expect or {}).items():
                got = meta.get(key)
                if got is not None and got != want:
                    raise ValueError(
                        f"checkpoint in {checkpoint_dir!r} was written for "
                        f"{key}={got} but this solve has {key}={want}; "
                        f"refusing to resume from another problem's state")
            tree = _pad_restored(tree, like)
            if verbose:
                print(f"[driver] resumed at outer k="
                      f"{int(np.max(np.asarray(tree.k)))}")
            return tree
    return init(v0)


def _reject_virtual(opts: IPIOptions) -> None:
    if methods.get_method(opts.method).virtual:
        raise ValueError(
            f"method {opts.method!r} is a virtual (meta) method — the "
            f"adaptive layer resolves it to a concrete solver first; use "
            f"repro.api.Session.solve (which routes -method auto "
            f"automatically) or repro.adaptive.solve_adaptive")


def solve(mdp: MDP, opts: IPIOptions = IPIOptions(), *,
          mesh=None, layout: str = "1d", v0=None,
          checkpoint_dir: str | None = None, chunk: int = 64,
          checkpoint_mode: str = "chunk",
          verbose: bool = False, monitor=None, supervisor=None) \
        -> SolveResult:
    """Solve an MDP until ``opts.stop_criterion`` is satisfied (default:
    ``||T v - v||_inf <= opts.atol``).

    ``mesh=None`` runs single-device; otherwise the MDP is padded, sharded
    onto ``mesh`` and the identical loop runs SPMD under ``shard_map``.

    ``monitor`` (requires ``opts.monitor=True``) is a callable receiving one
    dict per outer iteration — ``{"k", "res", "inner", "diverged",
    "elapsed"}`` — streamed out of the compiled loop via
    ``jax.debug.callback``; when ``opts.monitor`` is set without a callable,
    records print PETSc-style (:func:`repro.core.methods.print_monitor`).

    ``supervisor`` is a between-chunks hook for the adaptive layer: a
    callable receiving ``{"k", "res", "k_prev", "res_prev", "diverged"}``
    once per completed chunk; returning truthy interrupts the solve (the
    current state is checkpointed when ``checkpoint_dir`` is set, so the
    caller can resume it under different options — the hot-swap path).  A
    diverged state interrupts the loop on its own.

    ``checkpoint_mode`` controls when ``checkpoint_dir`` is written:
    ``"chunk"`` (default) persists after every run chunk — the
    fault-tolerance contract; ``"interrupt"`` writes only when the solve is
    interrupted mid-flight (supervisor trigger or divergence), which is all
    the adaptive hot-swap needs — supervised solves then pay zero
    checkpoint overhead on the happy path.
    """
    if mdp.batch is not None:
        raise ValueError("solve() takes one MDP instance; for a batched "
                         "fleet use solve_many()")
    _reject_virtual(opts)
    if checkpoint_mode not in ("chunk", "interrupt"):
        raise ValueError(f"checkpoint_mode={checkpoint_mode!r}: expected "
                         f"'chunk' or 'interrupt'")
    if layout in partition.FLEET_LAYOUTS:
        raise ValueError(f"layout={layout!r} shards the fleet (instance) "
                         "dim, which a single solve() does not have; use "
                         "solve_many() or layout='1d'/'2d'")
    n_orig = mdp.n_global
    if opts.halo:
        _validate_banded(mdp, opts.halo, mesh, layout)
    if mesh is None:
        axes = Axes()
        dev_mdp = mdp
    else:
        dev_mdp, axes, n_orig = partition.shard_mdp(mdp, mesh, layout,
                                                    mode=opts.mode)
        if v0 is not None:
            v0 = jnp.pad(jnp.asarray(v0),
                         (0, dev_mdp.n_global - n_orig))
    opts = _resolve_overlap(opts, dev_mdp, mesh, axes)
    run_chunk, init = _make_runners(dev_mdp, opts, mesh, axes, None,
                                    n_true=n_orig)

    state = _restore_or_init(init, v0, checkpoint_dir, verbose,
                             expect=dict(n=n_orig))
    save_each = bool(checkpoint_dir) and checkpoint_mode == "chunk"

    def save_state() -> None:
        ckpt.save(checkpoint_dir, int(jax.device_get(state.k)),
                  _trim_ckpt_state(state, n_orig, None),
                  meta=dict(method=opts.method, n=n_orig))

    mid = 0
    if opts.monitor:
        mid = methods.monitor_handle(monitor or methods.print_monitor)
    try:
        if mid:   # the k=0 (or resume-point) record, emitted host-side
            k0, res0 = jax.device_get((state.k, state.res))
            methods.emit_host(mid, int(k0), float(res0), 0)
        prev = None
        while True:
            # one host round-trip for the whole control tuple: separate
            # device_gets multiply the per-chunk sync latency,
            # which dominates warm small-n solves
            k, res, done, div = jax.device_get(
                (state.k, state.res, state.done, state.diverged))
            k, res, done, div = int(k), float(res), bool(done), bool(div)
            if verbose:
                print(f"[driver] k={k} residual={res:.3e}"
                      + (" DIVERGED" if div else ""))
            # NaN residual (inner-solver breakdown): neither "active" on
            # device nor "converged" here — bail out, don't spin forever.
            # Likewise a diverged flag (residual past divtol * res0).
            if done or k >= opts.max_outer or np.isnan(res) or div:
                # a NaN-poisoned state is not worth persisting: the resume
                # path discards it anyway
                if div and not np.isnan(res) and checkpoint_dir \
                        and not save_each:
                    save_state()
                break
            if supervisor is not None and prev is not None and supervisor(
                    dict(k=k, res=res, k_prev=prev[0], res_prev=prev[1],
                         diverged=div)):
                if checkpoint_dir and not save_each:
                    save_state()
                break
            prev = (k, res)
            k_hi = jnp.int32(min(k + chunk, opts.max_outer))
            state = run_chunk(dev_mdp, state, k_hi, jnp.int32(mid))
            if mid and opts.monitor_mode == "chunk":
                _drain_monitor(mid, state, None, k)
            if save_each:
                save_state()
    finally:
        if mid:
            jax.effects_barrier()   # flush in-flight monitor callbacks
            methods.monitor_release(mid)

    if mesh is not None:
        # gather the sharded fields for the host-side result
        state = jax.device_get(state)
    return _result(state, opts, mdp.gamma, n_orig)


def solve_many(mdps: Sequence[MDP] | MDP, opts: IPIOptions = IPIOptions(), *,
               mesh=None, layout: str = "1d", v0s=None,
               pad_fleet: bool = True, origin: tuple[int, int] | None = None,
               checkpoint_dir: str | None = None, chunk: int = 64,
               verbose: bool = False, monitor=None) -> list[SolveResult]:
    """Solve a fleet of MDPs in one compiled batched program.

    ``mdps`` is a sequence of (unbatched) MDP instances — or an
    already-batched container from :func:`repro.core.mdp.stack_mdps`.  Every
    instance is solved to ``opts.atol`` exactly as an individual
    :func:`solve` call would (per-instance iteration counts and traces
    included — converged instances freeze under the batched active mask),
    but the whole fleet shares one device program: one ``lax.while_loop``,
    vmapped kernels, one ``shard_map`` when ``mesh`` is given.  Returns one
    :class:`SolveResult` per instance, padding trimmed.

    ``layout`` picks how the fleet maps onto ``mesh``:

    * ``"1d"`` / ``"2d"`` — the instance dim is *replicated*: every device
      owns its state (x action) slice of all B instances.  Simple, but
      per-device fleet memory grows with B.
    * ``"fleet"`` / ``"fleet2d"`` — the instance dim is *sharded* over the
      mesh's leading ``fleet`` axis (build one with
      :func:`repro.launch.mesh.make_fleet_mesh`); states (and actions, for
      ``"fleet2d"``) shard over the remaining axes within each fleet slice.
      Per-device fleet memory is ``B / fleet_size`` of the replicated
      layouts, so B scales with the mesh.  B is padded up to a multiple of
      the fleet-axis size with zero-cost dummy instances (trimmed from the
      results); ``pad_fleet=False`` turns the padding into a ``ValueError``
      for callers that need exact placement.

    ``v0s`` optionally warm-starts: a sequence of per-instance ``(n_i,)``
    vectors (zero-padded to the fleet width) or a stacked ``(B, n)`` array.

    ``checkpoint_dir`` persists the fleet state between chunks.  Checkpoints
    are saved **unsharded and unpadded** (true ``B`` and ``n``), so a fleet
    checkpoint is mesh-agnostic exactly like a single-instance one: a solve
    interrupted on an 8-way fleet axis resumes on a 4-way axis (or on a
    replicated layout, or single-device) bit-for-bit.

    ``origin=(B, n)`` names the *true* fleet size and state count of a
    pre-batched container that was built with mesh padding already applied
    (e.g. :func:`repro.api.place_function_fleet`): results and checkpoints
    are then trimmed to the true sizes — without it, a padded container's
    checkpoint meta would record the mesh-padded shapes and refuse an
    elastic resume on a differently-padding mesh.
    """
    _reject_virtual(opts)
    if isinstance(mdps, (EllMDP, DenseMDP, MatrixFreeMDP)):
        if mdps.batch is None:
            raise ValueError("solve_many() wants a fleet; for a single "
                             "instance use solve()")
        batched = mdps
        b_true, n_true = origin or (batched.batch, batched.n_global)
        if b_true > batched.batch or n_true > batched.n_global:
            raise ValueError(f"origin={origin} exceeds the container's "
                             f"(B={batched.batch}, n={batched.n_global})")
        n_origs = [n_true] * b_true
    else:
        if origin is not None:
            raise ValueError("origin= applies to a pre-batched container; "
                             "per-instance MDPs carry their own true n")
        mdps = list(mdps)
        n_origs = [m.n_global for m in mdps]
        batched = stack_mdps(mdps)
        b_true, n_true = batched.batch, batched.n_global
    b_orig = b_true
    gammas = gammas_of(batched)
    if layout in partition.FLEET_LAYOUTS and mesh is None:
        raise ValueError(f"layout={layout!r} shards the fleet dim over a "
                         "mesh; pass mesh=... (see "
                         "repro.launch.mesh.make_fleet_mesh)")
    if opts.halo:
        _validate_banded(batched, opts.halo, mesh, layout)

    v0 = None
    if v0s is not None:
        if isinstance(v0s, (list, tuple)):
            n_to = batched.n_local
            v0 = jnp.asarray(np.stack(
                [np.pad(np.asarray(x), (0, n_to - np.asarray(x).shape[0]))
                 for x in v0s]))
        else:
            v0 = jnp.asarray(v0s)

    if mesh is None:
        axes = Axes()
        dev_mdp = batched
    else:
        dev_mdp, axes, _ = partition.shard_mdp(batched, mesh, layout,
                                               pad_fleet=pad_fleet,
                                               mode=opts.mode)
        if v0 is not None:
            v0 = jnp.pad(v0, ((0, dev_mdp.batch - v0.shape[0]),
                              (0, dev_mdp.n_global - v0.shape[-1])))
    # per-instance unpadded state counts, 0 for padded dummy fleet lanes
    nt_vec = np.asarray(
        list(n_origs) + [0] * (dev_mdp.batch - len(n_origs)), np.int32)
    opts = _resolve_overlap(opts, dev_mdp, mesh, axes)
    run_chunk, init = _make_runners(dev_mdp, opts, mesh, axes,
                                    dev_mdp.batch, n_true=nt_vec)

    state = _restore_or_init(init, v0, checkpoint_dir, verbose,
                             expect=dict(n=n_true, batch=b_orig))
    mid = 0
    if opts.monitor:
        # trim=b_orig: monitor records carry the TRUE fleet rows, not the
        # mesh-padded dummy lanes
        mid = methods.monitor_handle(monitor or methods.print_monitor,
                                     trim=b_orig)
    try:
        if mid:
            k0, res0 = jax.device_get((state.k, state.res))
            methods.emit_host(mid, np.asarray(k0), np.asarray(res0),
                              np.zeros(dev_mdp.batch, np.int32))
        while True:
            # one host round-trip per chunk (see the solve() loop)
            k, res, crit, div = (np.asarray(x) for x in jax.device_get(
                (state.k, state.res, state.done, state.diverged)))
            # isnan / diverged: a broken-down lane is not device-active ->
            # count it done (its result reports diverged, not converged)
            done = crit | (k >= opts.max_outer) | np.isnan(res) | div
            if verbose:
                n_act = int((~done).sum())
                print(f"[driver] fleet B={len(k)} active={n_act} "
                      f"k_max={int(k.max())} res_max={float(res.max()):.3e}")
            if done.all():
                break
            k_hi = jnp.int32(min(int(k[~done].min()) + chunk,
                                 opts.max_outer))
            state = run_chunk(dev_mdp, state, k_hi, jnp.int32(mid))
            if mid and opts.monitor_mode == "chunk":
                _drain_monitor(mid, state, done, k)
            if checkpoint_dir:
                trimmed = _trim_ckpt_state(state, n_true, b_orig)
                ckpt.save(checkpoint_dir,
                          int(np.max(np.asarray(trimmed.k))), trimmed,
                          meta=dict(method=opts.method, batch=b_orig,
                                    n=n_true, layout=layout))
    finally:
        if mid:
            jax.effects_barrier()   # flush in-flight monitor callbacks
            methods.monitor_release(mid)

    state = jax.device_get(state)
    out = []
    for b in range(b_orig):
        sb = jax.tree_util.tree_map(lambda x: np.asarray(x)[b], state)
        out.append(_result(sb, opts, gammas[b], n_origs[b]))
    return out
