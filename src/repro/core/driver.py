"""Host driver: chunked, checkpointed, optionally distributed iPI solve.

This is the user-facing ``solve`` — the analogue of madupite's
``madupite.solve(mdp, options)``.  The device-side loop runs in bounded
chunks; between chunks the host persists the solver state (preemption /
node-failure tolerance) and reports progress.  Distribution wraps the same
device code in ``shard_map`` over the supplied mesh (1-D paper-faithful or
2-D state x action layout — see :mod:`repro.core.partition`).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ipi, partition
from repro.core.comm import Axes
from repro.core.ipi import IPIOptions, SolveState
from repro.core.mdp import EllMDP, MDP
from repro.utils import checkpoint as ckpt


@dataclasses.dataclass
class SolveResult:
    v: np.ndarray                  # (n,) optimal values (padding trimmed)
    policy: np.ndarray             # (n,) int32 greedy policy
    residual: float                # final ||T v - v||_inf
    gap_bound: float               # ||v - v*||_inf certificate: res / (1-gamma)
    converged: bool
    outer_iterations: int
    inner_iterations: int
    trace_residual: np.ndarray     # (outer+1,)
    trace_inner: np.ndarray        # (outer,)

    def summary(self) -> str:
        return (f"converged={self.converged} outer={self.outer_iterations} "
                f"inner={self.inner_iterations} residual={self.residual:.3e} "
                f"gap<= {self.gap_bound:.3e}")


def _result(state: SolveState, opts: IPIOptions, gamma: float,
            n_orig: int) -> SolveResult:
    k = int(state.k)
    res = float(state.res)
    return SolveResult(
        v=np.asarray(jax.device_get(state.v))[:n_orig],
        policy=np.asarray(jax.device_get(state.pi))[:n_orig],
        residual=res,
        gap_bound=res / (1.0 - gamma),
        converged=res <= opts.atol,
        outer_iterations=k,
        inner_iterations=int(state.inner_total),
        trace_residual=np.asarray(state.trace_res)[:k + 1],
        trace_inner=np.asarray(state.trace_inner)[:k])


def _validate_banded(mdp, halo: int, mesh, layout: str) -> None:
    """The halo layout is only exact when every transition stays within
    +-halo of its source row (matrix bandwidth <= halo) and the halo fits in
    one shard."""
    assert isinstance(mdp, EllMDP), "halo layout requires ELL"
    idx = np.asarray(mdp.idx)
    rows = np.arange(mdp.n_global)[:, None, None]
    band = int(np.abs(idx - rows).max())
    assert band <= halo, f"matrix bandwidth {band} exceeds halo {halo}"
    if mesh is not None:
        n_shards = int(np.prod([
            mesh.shape[a] for a in partition.mesh_axes(mesh, layout).state]))
        n_local = -(-mdp.n_global // n_shards)
        assert halo <= n_local, (halo, n_local)


def solve(mdp: MDP, opts: IPIOptions = IPIOptions(), *,
          mesh=None, layout: str = "1d", v0=None,
          checkpoint_dir: str | None = None, chunk: int = 64,
          verbose: bool = False) -> SolveResult:
    """Solve an MDP to ``||T v - v||_inf <= opts.atol``.

    ``mesh=None`` runs single-device; otherwise the MDP is padded, sharded
    onto ``mesh`` and the identical loop runs SPMD under ``shard_map``.
    """
    n_orig = mdp.n_global
    if opts.halo:
        _validate_banded(mdp, opts.halo, mesh, layout)
    if mesh is None:
        axes = Axes()
        dev_mdp = mdp
        run_chunk = partial(ipi.solve_chunk, opts=opts, axes=axes)
        init = lambda: ipi.init_state(dev_mdp, axes, opts, v0)
    else:
        dev_mdp, axes, n_orig = partition.shard_mdp(mdp, mesh, layout)
        mdp_specs = partition.mdp_pspecs(dev_mdp, axes)
        state_specs = SolveState(
            v=P(axes.state), tv=P(axes.state), pi=P(axes.state),
            res=P(), k=P(), inner_total=P(), trace_res=P(), trace_inner=P())
        run_chunk = jax.jit(
            jax.shard_map(
                partial(ipi.solve_chunk, opts=opts, axes=axes),
                mesh=mesh,
                in_specs=(mdp_specs, state_specs, P()),
                out_specs=state_specs,
                check_vma=False),
        )

        def init():
            f = jax.jit(
                jax.shard_map(
                    partial(ipi.init_state, axes=axes, opts=opts),
                    mesh=mesh, in_specs=(mdp_specs,), out_specs=state_specs,
                    check_vma=False))
            return f(dev_mdp)

    state = None
    if checkpoint_dir:
        like = jax.eval_shape(init)
        like = jax.tree_util.tree_map(
            lambda s: np.zeros(s.shape, s.dtype), like)
        restored = ckpt.restore(checkpoint_dir, like)
        if restored is not None:
            tree, _, _ = restored
            state = tree
            if verbose:
                print(f"[driver] resumed at outer k={int(state.k)}")
    if state is None:
        state = init()

    while True:
        k = int(jax.device_get(state.k))
        res = float(jax.device_get(state.res))
        if verbose:
            print(f"[driver] k={k} residual={res:.3e}")
        if res <= opts.atol or k >= opts.max_outer:
            break
        k_hi = jnp.int32(min(k + chunk, opts.max_outer))
        state = run_chunk(dev_mdp, state, k_hi)
        if checkpoint_dir:
            ckpt.save(checkpoint_dir, int(jax.device_get(state.k)), state,
                      meta=dict(method=opts.method))

    if mesh is not None:
        # gather the sharded fields for the host-side result
        state = jax.device_get(state)
    return _result(state, opts, mdp.gamma, n_orig)
