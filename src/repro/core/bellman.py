"""Distributed Bellman operators.

All functions operate on a *local* MDP block plus the :class:`~repro.core.comm.Axes`
describing the mesh axes it is sharded over.  They are pure and jit/shard_map
friendly; with ``Axes()`` (no axes) they are the single-device reference.

Conventions
-----------
* ``v_local``  — (n_local,) owned slice of the value vector.
* ``v_global`` — (n_global,) gathered value vector (``axes.allgather_state``).
* ``pi``       — (n_local,) int32 of **global** action ids.

Batched fleets
--------------
:func:`backup` and :func:`residual_norm` accept a batched MDP (leading ``B``
dim, see :func:`repro.core.mdp.stack_mdps`) with correspondingly batched
value vectors and vmap themselves over the unbatched path.  The per-instance
operators additionally take ``gamma_t``, an optional *traced* scalar discount
override, passed straight through to the kernels — the dispatch layer traces
``gamma`` (it is not a compile-time constant), so a heterogeneous-gamma fleet
(e.g. a gamma sweep) shares one compiled kernel across instances and computes
``cost + gamma * P v`` with exactly the same rounding as a replicated solve.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.comm import Axes
from repro.core.mdp import (DenseMDP, EllMDP, MatrixFreeMDP, MDP,
                            batch_parts)
from repro.kernels import matrix_free, ops


# --------------------------------------------------------------------------- #
# Value-vector movement (all-gather vs banded halo exchange)                   #
# --------------------------------------------------------------------------- #

def gather_v(v_local: jax.Array, axes: Axes, *, halo: int = 0,
             dtype=None) -> jax.Array:
    """Produce the column window the local rows reference: the full gathered
    vector (``halo=0``) or the banded ``[start-halo, stop+halo)`` window."""
    if halo:
        return axes.halo_exchange(v_local, halo, dtype)
    return axes.allgather_state(v_local, dtype)


def _shift_idx(idx: jax.Array, mdp: MDP, axes: Axes, halo: int) -> jax.Array:
    """Global successor ids -> window-relative ids for the halo layout.

    Coordinates are clamped into the window: the auto-halo planner admits
    MDPs whose *zero-weight* ELL fill (padded rows, short rows) references
    columns far outside the band, and those entries must read a defined
    value so 0*v[i] stays exactly 0 instead of poisoning the row with an
    out-of-bounds gather."""
    if not halo:
        return idx
    row_start = axes.state_index() * mdp.n_local
    return jnp.clip(idx - row_start + halo, 0, mdp.n_local + 2 * halo - 1)


# --------------------------------------------------------------------------- #
# Greedy step (policy improvement)                                            #
# --------------------------------------------------------------------------- #

def backup(mdp: MDP, v_global: jax.Array, axes: Axes, *,
           impl: str | None = None, halo: int = 0,
           gamma_t: jax.Array | None = None,
           mode: str = "mincost") -> tuple[jax.Array, jax.Array]:
    """One Bellman backup: ``Tv`` and the greedy policy on local rows.

    ``v_global`` is whatever :func:`gather_v` produced (full vector or halo
    window — ``halo`` must match).  Returns ``(tv_local (n_local,) f32,
    pi_local (n_local,) int32 global ids)``.  With an action axis, the
    min/argmin is completed with a pmin reduction; ties break to the
    smallest global action id (deterministic across layouts).

    A batched ``mdp`` (with ``v_global`` batched ``(B, n)``) vmaps over the
    instance dim and returns ``(B, n)`` outputs.  ``gamma_t`` (traced scalar)
    overrides the static ``mdp.gamma`` — see the module docstring.

    ``mode="maxreward"`` reads ``cost`` as a *reward* and takes the argmax
    backup ``Tv = max_a (r + gamma P v)`` instead of the argmin.  It is
    implemented by negation — the backup runs on ``(-cost, -v)`` and the
    result is negated — so a maxreward solve is bit-for-bit the negation of
    the mincost solve on negated costs (IEEE negation is exact), and the
    action-axis pmin/tie-break reduction is reused unchanged.
    """
    if mdp.batch is not None:
        view, in_ax, g_t = batch_parts(mdp)
        g_t = gamma_t if gamma_t is not None else g_t
        fn = lambda m, vg, gt: backup(m, vg, axes, impl=impl, halo=halo,
                                      gamma_t=gt, mode=mode)
        return jax.vmap(fn, in_axes=(in_ax, 0, None if g_t is None else 0))(
            view, v_global, g_t)
    gamma = mdp.gamma if gamma_t is None else gamma_t
    neg = mode == "maxreward"
    if isinstance(mdp, MatrixFreeMDP):
        # rebuild row tiles from the constructors inside the backup; the
        # negation happens inside mf_backup (there is no stored cost to
        # flip), and the returned (vmin, amin) live in the same negated
        # min-space as the materialized branch below
        row0 = axes.state_index() * mdp.n_local
        idx_map = (lambda i: _shift_idx(i, mdp, axes, halo)) if halo \
            else None
        vmin, amin = matrix_free.mf_backup(
            mdp.spec, row0, mdp.n_local, mdp.acts, gamma, v_global,
            mode=mode, idx_map=idx_map, impl=impl)
        return _finish_argmin(vmin, amin, mdp, axes, neg)
    cost = -mdp.cost if neg else mdp.cost
    if neg:
        v_global = -v_global
    if isinstance(mdp, EllMDP):
        idx = _shift_idx(mdp.idx, mdp, axes, halo)
        vmin, amin = ops.ell_backup(idx, mdp.val, cost, gamma,
                                    v_global, impl=impl)
    else:
        assert halo == 0, "halo layout requires the ELL representation"
        vmin, amin = ops.dense_backup(mdp.p, cost, gamma,
                                      v_global, impl=impl)
    return _finish_argmin(vmin, amin, mdp, axes, neg)


def _finish_argmin(vmin: jax.Array, amin: jax.Array, mdp: MDP, axes: Axes,
                   neg: bool) -> tuple[jax.Array, jax.Array]:
    """Complete a per-shard (min, argmin) into the global ``(Tv, pi)``:
    lift local action ids to global ids, reduce over the action axis with a
    deterministic smallest-global-id tie-break, and undo the maxreward
    negation."""
    a_glob = amin + mdp.m_local * axes.action_index()
    if axes.action is None:
        return (-vmin if neg else vmin), a_glob
    tv = axes.pmin_action(vmin)
    # argmin across shards: owner shards (vmin == tv exactly, since pmin picks
    # one of the exact local minima) propose their id, others propose m_global.
    cand = jnp.where(vmin == tv, a_glob, jnp.int32(mdp.m_global))
    pi = axes.pmin_action(cand)
    return (-tv if neg else tv), pi


def gather_backup(mdp: MDP, v_local: jax.Array, axes: Axes, *,
                  plan: tuple[int, int] | None = None,
                  impl: str | None = None, halo: int = 0,
                  gamma_t: jax.Array | None = None,
                  mode: str = "mincost") -> tuple[jax.Array, jax.Array,
                                                  jax.Array]:
    """Gather the value window and run one Bellman backup; returns
    ``(tv, pi, window)``.

    ``plan=(f_lo, f_hi)`` (from :func:`repro.core.partition.overlap_margins`)
    switches to the communication-overlapped path
    (:func:`backup_overlapped`); ``plan=None`` is the synchronous
    gather-then-backup reference.  Both produce identical results — the
    overlapped path only re-routes which buffer each row reads from.
    """
    if plan is not None:
        return backup_overlapped(mdp, v_local, axes, plan=plan, impl=impl,
                                 halo=halo, gamma_t=gamma_t, mode=mode)
    w = gather_v(v_local, axes, halo=halo)
    tv, pi = backup(mdp, w, axes, impl=impl, halo=halo, gamma_t=gamma_t,
                    mode=mode)
    return tv, pi, w


def backup_overlapped(mdp: MDP, v_local: jax.Array, axes: Axes, *,
                      plan: tuple[int, int], impl: str | None = None,
                      halo: int = 0, gamma_t: jax.Array | None = None,
                      mode: str = "mincost") -> tuple[jax.Array, jax.Array,
                                                      jax.Array]:
    """Communication-overlapped Bellman backup; returns ``(tv, pi, window)``.

    Launches the value-window collective (:meth:`Axes.gather_start`), backs
    up the *interior* rows ``[f_lo, n_local - f_hi)`` — whose nonzero-weight
    successors are all locally owned — directly against ``v_local`` while
    the window is in flight, then finishes the frontier rows against the
    arrived window.  With async collectives enabled the scheduler moves the
    interior compute between the collective's start/done pair.

    The per-row kernels are row-independent and the interior rows read the
    same values through ``v_local`` as they would through the gathered
    window, so the result is identical to the synchronous
    ``backup(gather_v(v), ...)`` path (zero-weight ELL fill entries may
    index outside the owned range; they are clamped and contribute exactly
    0 on both paths).
    """
    if mdp.batch is not None:
        view, in_ax, g_t = batch_parts(mdp)
        g_t = gamma_t if gamma_t is not None else g_t
        fn = lambda m, vl, gt: backup_overlapped(
            m, vl, axes, plan=plan, impl=impl, halo=halo, gamma_t=gt,
            mode=mode)
        return jax.vmap(fn, in_axes=(in_ax, 0, None if g_t is None else 0))(
            view, v_local, g_t)
    if isinstance(mdp, MatrixFreeMDP):
        return _mf_backup_overlapped(mdp, v_local, axes, plan=plan,
                                     impl=impl, halo=halo, gamma_t=gamma_t,
                                     mode=mode)
    if not isinstance(mdp, EllMDP):
        raise ValueError("comm overlap requires the ELL representation; "
                         "DenseMDP rows always reference global columns")
    f_lo, f_hi = plan
    n_loc = mdp.n_local
    window = axes.gather_start(v_local, halo=halo)

    gamma = mdp.gamma if gamma_t is None else gamma_t
    neg = mode == "maxreward"
    cost = -mdp.cost if neg else mdp.cost
    v_own = -v_local if neg else v_local
    row_start = axes.state_index() * n_loc
    sl = lambda a, lo, hi: jax.lax.slice_in_dim(a, lo, hi, axis=0)

    parts = []
    # interior rows: no data dependence on the in-flight window
    if f_lo + f_hi < n_loc:
        idx_c = jnp.clip(sl(mdp.idx, f_lo, n_loc - f_hi) - row_start,
                         0, n_loc - 1)
        parts.append((f_lo, ops.ell_backup(
            idx_c, sl(mdp.val, f_lo, n_loc - f_hi),
            sl(cost, f_lo, n_loc - f_hi), gamma, v_own, impl=impl)))

    # frontier rows: wait for the window, then finish the edges.  Slice the
    # raw idx BEFORE shifting into window coordinates — shifting the full
    # tensor would materialize O(n_local * m * nnz) ints per backup for a
    # few frontier rows' worth of use.
    win = axes.gather_finish(window)
    v_win = -win if neg else win
    shift = lambda lo, hi: _shift_idx(sl(mdp.idx, lo, hi), mdp, axes, halo)
    if f_lo:
        parts.insert(0, (0, ops.ell_backup(
            shift(0, f_lo), sl(mdp.val, 0, f_lo), sl(cost, 0, f_lo),
            gamma, v_win, impl=impl)))
    if f_hi:
        parts.append((n_loc - f_hi, ops.ell_backup(
            shift(n_loc - f_hi, n_loc), sl(mdp.val, n_loc - f_hi, n_loc),
            sl(cost, n_loc - f_hi, n_loc), gamma, v_win, impl=impl)))

    parts.sort(key=lambda p: p[0])
    vmin = jnp.concatenate([p[1][0] for p in parts])
    amin = jnp.concatenate([p[1][1] for p in parts])
    tv, pi = _finish_argmin(vmin, amin, mdp, axes, neg)
    return tv, pi, win


def _mf_backup_overlapped(mdp: "MatrixFreeMDP", v_local: jax.Array,
                          axes: Axes, *, plan: tuple[int, int],
                          impl: str | None, halo: int,
                          gamma_t: jax.Array | None,
                          mode: str) -> tuple[jax.Array, jax.Array,
                                              jax.Array]:
    """The interior/frontier split for the matrix-free operator: same
    structure as the materialized path above, but each part *rebuilds* its
    row range from the constructors instead of slicing stored tables.  The
    per-row math is unchanged, so the split is bitwise invisible exactly
    as for the materialized operator."""
    f_lo, f_hi = plan
    n_loc = mdp.n_local
    window = axes.gather_start(v_local, halo=halo)

    gamma = mdp.gamma if gamma_t is None else gamma_t
    neg = mode == "maxreward"
    row_start = axes.state_index() * n_loc
    spec, acts = mdp.spec, mdp.acts
    part = lambda lo, n_rows, idx_map, v: matrix_free.mf_backup(
        spec, row_start + lo, n_rows, acts, gamma, v, mode=mode,
        idx_map=idx_map, impl=impl)

    parts = []
    # interior rows: no data dependence on the in-flight window; their
    # nonzero successors are locally owned, so global ids shift by the
    # row offset (clamped: zero-weight fill contributes exactly 0)
    if f_lo + f_hi < n_loc:
        own_map = lambda i: jnp.clip(i - row_start, 0, n_loc - 1)
        parts.append((f_lo, part(f_lo, n_loc - f_lo - f_hi, own_map,
                                 v_local)))

    # frontier rows: wait for the window, then finish the edges against it
    win = axes.gather_finish(window)
    win_map = (lambda i: _shift_idx(i, mdp, axes, halo)) if halo else None
    if f_lo:
        parts.insert(0, (0, part(0, f_lo, win_map, win)))
    if f_hi:
        parts.append((n_loc - f_hi, part(n_loc - f_hi, f_hi, win_map, win)))

    parts.sort(key=lambda p: p[0])
    vmin = jnp.concatenate([p[1][0] for p in parts])
    amin = jnp.concatenate([p[1][1] for p in parts])
    tv, pi = _finish_argmin(vmin, amin, mdp, axes, neg)
    return tv, pi, win


def residual_norm(mdp: MDP, v_local: jax.Array, v_global: jax.Array,
                  axes: Axes, *, impl: str | None = None,
                  halo: int = 0,
                  gamma_t: jax.Array | None = None,
                  mode: str = "mincost") -> jax.Array:
    """Global sup-norm Bellman residual ``||T v - v||_inf`` (the optimality gap
    certificate: ``||v - v*||_inf <= residual / (1 - gamma)``).  Batched MDPs
    return per-instance residuals ``(B,)``."""
    tv, _ = backup(mdp, v_global, axes, impl=impl, halo=halo, gamma_t=gamma_t,
                   mode=mode)
    return axes.pmax_state(jnp.max(jnp.abs(tv - v_local), axis=-1))


# --------------------------------------------------------------------------- #
# Policy-restricted operators (policy evaluation)                             #
# --------------------------------------------------------------------------- #

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PolicyRows:
    """Rows of ``P_pi`` / ``g_pi`` owned by this shard, pre-masked.

    With a 2-D (state x action) layout each action shard owns the rows whose
    greedy action falls inside its slice; masked-out rows contribute zeros and
    the results are psum-reduced over the action axis (the beyond-paper 2-D
    layout; the paper-faithful 1-D layout has no action axis and the mask is
    all-ones).
    """

    idx: jax.Array | None   # (n_local, K) int32   (ELL)
    val: jax.Array | None   # (n_local, K) f32     (ELL, masked)
    p: jax.Array | None     # (n_local, n_global)  (dense, masked)
    g: jax.Array            # (n_local,) f32       (masked)
    gamma: float = dataclasses.field(metadata=dict(static=True))


def policy_rows(mdp: MDP, pi: jax.Array, axes: Axes) -> PolicyRows:
    """Extract the ``P_pi`` rows for a (global-id) policy ``pi``."""
    a_rel = pi - mdp.m_local * axes.action_index()
    own = (a_rel >= 0) & (a_rel < mdp.m_local)
    a_sel = jnp.clip(a_rel, 0, mdp.m_local - 1)
    if isinstance(mdp, MatrixFreeMDP):
        # rebuild row tiles and select the greedy action's slots in-tile:
        # the output is the same O(n_local * nnz) PolicyRows transient the
        # materialized selection produces, so the inner solvers (and their
        # halo/gather machinery) run on it completely unchanged
        row0 = axes.state_index() * mdp.n_local
        idx_pi, val_pi, g_pi = matrix_free.mf_policy_rows(
            mdp.spec, row0, mdp.n_local, mdp.acts, a_sel, own)
        return PolicyRows(idx=idx_pi, val=val_pi, p=None, g=g_pi,
                          gamma=mdp.gamma)
    if isinstance(mdp, EllMDP):
        take = lambda x: jnp.take_along_axis(
            x, a_sel[:, None, None], axis=1)[:, 0]
        idx_pi = take(mdp.idx)
        val_pi = take(mdp.val) * own[:, None].astype(mdp.val.dtype)
        g_pi = jnp.take_along_axis(mdp.cost, a_sel[:, None], axis=1)[:, 0]
        g_pi = g_pi * own.astype(g_pi.dtype)
        return PolicyRows(idx=idx_pi, val=val_pi, p=None, g=g_pi,
                          gamma=mdp.gamma)
    p_pi = jnp.take_along_axis(mdp.p, a_sel[:, None, None], axis=1)[:, 0]
    p_pi = p_pi * own[:, None].astype(mdp.p.dtype)
    g_pi = jnp.take_along_axis(mdp.cost, a_sel[:, None], axis=1)[:, 0]
    g_pi = g_pi * own.astype(g_pi.dtype)
    return PolicyRows(idx=None, val=None, p=p_pi, g=g_pi, gamma=mdp.gamma)


def _p_pi_matvec(rows: PolicyRows, x_eff: jax.Array, axes: Axes,
                 impl: str | None, idx_eff=None) -> jax.Array:
    """(P_pi @ x) on local rows, reduced over action shards."""
    if rows.idx is not None:
        idx = rows.idx if idx_eff is None else idx_eff
        y = ops.ell_matvec(idx, rows.val, x_eff, impl=impl)
    else:
        dt = jnp.result_type(jnp.float32, rows.p.dtype, x_eff.dtype)
        y = jnp.dot(rows.p.astype(dt), x_eff.astype(dt),
                    precision=jax.lax.Precision.HIGHEST)
    return axes.psum_action(y)


def _rows_idx_eff(rows: PolicyRows, mdp: MDP, axes: Axes, halo: int):
    if not halo or rows.idx is None:
        return None
    row_start = axes.state_index() * mdp.n_local
    # clamp like _shift_idx: zero-weight fill may reference far columns
    return jnp.clip(rows.idx - row_start + halo,
                    0, mdp.n_local + 2 * halo - 1)


def t_pi(rows: PolicyRows, x_local: jax.Array, axes: Axes, *,
         impl: str | None = None, mdp: MDP | None = None, halo: int = 0,
         gather_dtype=None, gamma_t: jax.Array | None = None) -> jax.Array:
    """Policy-restricted Bellman operator ``T_pi x = g_pi + gamma P_pi x``."""
    x_eff = gather_v(x_local, axes, halo=halo, dtype=gather_dtype)
    gamma = rows.gamma if gamma_t is None else gamma_t
    y = _p_pi_matvec(rows, x_eff, axes, impl,
                     _rows_idx_eff(rows, mdp, axes, halo))
    return axes.psum_action(rows.g) + gamma * y


def a_pi_matvec(rows: PolicyRows, x_local: jax.Array, axes: Axes, *,
                impl: str | None = None, mdp: MDP | None = None,
                halo: int = 0, gather_dtype=None,
                gamma_t: jax.Array | None = None) -> jax.Array:
    """Policy-evaluation system operator ``A_pi x = (I - gamma P_pi) x``.

    This is the matvec handed to the inner (Krylov) solvers; the value
    function of ``pi`` solves ``A_pi v = g_pi``.  ``gather_dtype`` turns on
    the compressed (inexact) gather — safe here because the forcing term of
    the outer iPI loop bounds the tolerable inner-system perturbation.
    """
    x_eff = gather_v(x_local, axes, halo=halo, dtype=gather_dtype)
    gamma = rows.gamma if gamma_t is None else gamma_t
    y = _p_pi_matvec(rows, x_eff, axes, impl,
                     _rows_idx_eff(rows, mdp, axes, halo))
    return x_local - gamma * y.astype(x_local.dtype)


def b_pi(rows: PolicyRows, axes: Axes) -> jax.Array:
    """Right-hand side ``g_pi`` of the policy-evaluation system."""
    return axes.psum_action(rows.g)
