"""Partitioning of a global MDP over the device mesh.

madupite/PETSc row-partitions states over MPI ranks (1-D).  We support that
layout and a beyond-paper 2-D (state x action) layout:

  * ``layout="1d"`` — states sharded over *all* mesh axes (paper-faithful);
  * ``layout="2d"`` — states over all-but-last axis, actions over the last
    (``model``) axis; the greedy min and the policy-evaluation matvec gain a
    reduction over the action axis (see :mod:`repro.core.bellman`).

Padding: states are padded with absorbing zero-cost self-loops (their value
is identically 0 and they are unreachable, so the solution and residuals on
real states are untouched); actions are padded with cost ``BIG`` rows that
can never be greedy.
"""

from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.comm import Axes
from repro.core.mdp import DenseMDP, EllMDP, MDP

_BIG_COST = 1e30


def mesh_axes(mesh, layout: str) -> Axes:
    names = tuple(mesh.axis_names)
    if layout == "1d":
        return Axes(state=names, action=None)
    if layout == "2d":
        assert len(names) >= 2, "2d layout needs >= 2 mesh axes"
        return Axes(state=names[:-1], action=names[-1])
    raise ValueError(layout)


def _axis_size(mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    return math.prod(mesh.shape[n] for n in names)


def _bcast_concat(arr: np.ndarray, pad_core: np.ndarray,
                  axis: int) -> np.ndarray:
    """Concatenate ``pad_core`` (unbatched) onto ``arr`` along a trailing
    ``axis``, broadcasting the pad over any leading batch dims of ``arr``."""
    lead = arr.shape[:arr.ndim - pad_core.ndim]
    pad = np.broadcast_to(pad_core, lead + pad_core.shape)
    return np.concatenate([arr, pad], axis=axis)


def pad_mdp(mdp: EllMDP, n_mult: int, m_mult: int) -> EllMDP:
    """Pad (host-side) to state/action multiples; exact-solution preserving.

    Batch-aware: a fleet container (leading ``B`` dim on ``val``/``cost``,
    shared or batched ``idx``) is padded identically on every instance.
    """
    idx, val, cost = (np.asarray(mdp.idx), np.asarray(mdp.val),
                      np.asarray(mdp.cost))
    n, m, k = val.shape[-3], val.shape[-2], val.shape[-1]
    n_pad = (-n) % n_mult
    m_pad = (-m) % m_mult
    if m_pad:
        idx = _bcast_concat(idx, np.zeros((n, m_pad, k), idx.dtype), -2)
        pv = np.zeros((n, m_pad, k), val.dtype)
        pv[..., 0] = 1.0  # self-transition placeholder (row sums to 1)
        val = _bcast_concat(val, pv, -2)
        cost = _bcast_concat(
            cost, np.full((n, m_pad), _BIG_COST, cost.dtype), -1)
    if n_pad:
        m_tot = m + m_pad
        pad_idx = np.zeros((n_pad, m_tot, k), idx.dtype)
        pad_idx[..., 0] = np.arange(n, n + n_pad, dtype=idx.dtype)[:, None]
        pad_val = np.zeros((n_pad, m_tot, k), val.dtype)
        pad_val[..., 0] = 1.0
        idx = _bcast_concat(idx, pad_idx, -3)
        val = _bcast_concat(val, pad_val, -3)
        # zero cost on the absorbing self-loop -> v_pad == 0 exactly; big cost
        # on padded actions stays (harmless: still never greedy).
        pad_cost = np.zeros((n_pad, m_tot), cost.dtype)
        pad_cost[:, m:] = _BIG_COST
        cost = _bcast_concat(cost, pad_cost, -2)
    return EllMDP(idx=jax.numpy.asarray(idx), val=jax.numpy.asarray(val),
                  cost=jax.numpy.asarray(cost), gamma=mdp.gamma,
                  n_global=n + n_pad, m_global=m + m_pad)


def mdp_pspecs(mdp: MDP, axes: Axes):
    """PartitionSpecs for the MDP container fields (as a matching pytree).

    Fleet containers get a leading unsharded (replicated-layout) batch dim.
    """
    s, a = axes.state, axes.action
    lead = () if mdp.batch is None else (None,)
    if isinstance(mdp, EllMDP):
        idx_spec = P(s, a, None) if mdp.idx.ndim == 3 else P(None, s, a, None)
        return EllMDP(idx=idx_spec, val=P(*lead, s, a, None),
                      cost=P(*lead, s, a),
                      gamma=mdp.gamma, n_global=mdp.n_global,
                      m_global=mdp.m_global)
    return DenseMDP(p=P(*lead, s, a, None), cost=P(*lead, s, a),
                    gamma=mdp.gamma,
                    n_global=mdp.n_global, m_global=mdp.m_global)


def shard_mdp(mdp: EllMDP, mesh, layout: str = "1d"):
    """Pad + place a host MDP (single instance or batched fleet) onto
    ``mesh``.

    Returns ``(mdp_device, axes, n_orig)``; device arrays carry
    ``NamedSharding`` so ``shard_map`` consumes them without resharding.
    States (and actions, 2-D layout) are sharded; the fleet dim, when
    present, stays unsharded — every shard owns its row slice of all B
    instances, which is what the vmapped solver consumes.
    """
    axes = mesh_axes(mesh, layout)
    n_mult = _axis_size(mesh, axes.state)
    m_mult = _axis_size(mesh, axes.action)
    n_orig = mdp.n_global
    padded = pad_mdp(mdp, n_mult, m_mult)
    specs = mdp_pspecs(padded, axes)
    place = lambda arr, spec: jax.device_put(arr, NamedSharding(mesh, spec))
    dev = EllMDP(idx=place(padded.idx, specs.idx),
                 val=place(padded.val, specs.val),
                 cost=place(padded.cost, specs.cost),
                 gamma=padded.gamma, n_global=padded.n_global,
                 m_global=padded.m_global)
    return dev, axes, n_orig
