"""Partitioning of a global MDP over the device mesh.

madupite/PETSc row-partitions states over MPI ranks (1-D).  We support that
layout, a beyond-paper 2-D (state x action) layout, and *fleet-sharded*
layouts that additionally partition the instance dim of a batched fleet:

  * ``layout="1d"`` — states sharded over *all* mesh axes (paper-faithful);
  * ``layout="2d"`` — states over all-but-last axis, actions over the last
    (``model``) axis; the greedy min and the policy-evaluation matvec gain a
    reduction over the action axis (see :mod:`repro.core.bellman`);
  * ``layout="fleet"`` — the leading (first) mesh axis shards the fleet's
    instance dim ``B``; states are sharded over the remaining axes *within*
    each fleet slice.  Per-device fleet memory drops from ``B x n_local`` to
    ``(B / fleet_size) x n_local`` — the layout that scales fleet size
    beyond single-device memory (``solve_many`` only);
  * ``layout="fleet2d"`` — instances over the first axis, states over the
    middle axes, actions over the last axis (fleet x state x action).

Padding: states are padded with absorbing zero-cost self-loops (their value
is identically 0 and they are unreachable, so the solution and residuals on
real states are untouched); actions are padded with cost ``BIG`` rows that
can never be greedy; fleet-sharded batches are padded with zero-cost dummy
instances whose optimal value is identically 0 — they converge at k=0 and
stay frozen under the solver's active mask, so they cost one no-op lane.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.comm import Axes
from repro.core.mdp import DenseMDP, EllMDP, MatrixFreeMDP, MDP

_BIG_COST = 1e30

LAYOUTS = ("1d", "2d", "fleet", "fleet2d")
FLEET_LAYOUTS = ("fleet", "fleet2d")


def mesh_axes(mesh, layout: str) -> Axes:
    # Raised (not assert'd): layout validation must survive `python -O`.
    names = tuple(mesh.axis_names)
    need = {"1d": 1, "2d": 2, "fleet": 2, "fleet2d": 3}.get(layout)
    if need is None:
        raise ValueError(f"unknown layout {layout!r}; pick one of {LAYOUTS}")
    if len(names) < need:
        hint = ("; see launch.mesh.make_fleet_mesh"
                if layout in FLEET_LAYOUTS else "")
        raise ValueError(f"layout {layout!r} needs >= {need} mesh axes, "
                         f"got {names}{hint}")
    if layout == "1d":
        return Axes(state=names, action=None)
    if layout == "2d":
        return Axes(state=names[:-1], action=names[-1])
    if layout == "fleet":
        return Axes(state=names[1:], action=None, fleet=names[0])
    return Axes(state=names[1:-1], action=names[-1], fleet=names[0])


def _axis_size(mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    return math.prod(mesh.shape[n] for n in names)


def padded_extents(mesh, axes: Axes, n: int, m: int) -> tuple[int, int]:
    """Global (state, action) extents after padding ``(n, m)`` up to the
    mesh's shard multiples under ``axes`` — the shapes a shard-locally
    materialized MDP must be built at."""
    ns = _axis_size(mesh, axes.state)
    ms = _axis_size(mesh, axes.action)
    return -(-n // ns) * ns, -(-m // ms) * ms


def shard_block(index, shape) -> tuple[tuple[int, int], ...]:
    """Concrete per-dim ``(start, stop)`` ranges of one device's shard.

    ``index`` is the slice tuple ``jax.make_array_from_callback`` (or
    ``Sharding.addressable_devices_indices_map``) hands out for a global
    ``shape``; the result names exactly the index ranges the owning device
    must materialize — including the leading instance range under the
    fleet layouts (instances x states x actions).
    """
    out = []
    for sl, dim in zip(index, shape):
        lo, hi, step = sl.indices(dim)
        if step != 1:
            raise ValueError(f"shard_block expects contiguous shards, got "
                             f"step={step}")
        out.append((lo, hi))
    return tuple(out)


def _bcast_concat(arr: np.ndarray, pad_core: np.ndarray,
                  axis: int) -> np.ndarray:
    """Concatenate ``pad_core`` (unbatched) onto ``arr`` along a trailing
    ``axis``, broadcasting the pad over any leading batch dims of ``arr``."""
    lead = arr.shape[:arr.ndim - pad_core.ndim]
    pad = np.broadcast_to(pad_core, lead + pad_core.shape)
    return np.concatenate([arr, pad], axis=axis)


def pad_mdp(mdp: EllMDP, n_mult: int, m_mult: int, *,
            mode: str = "mincost") -> EllMDP:
    """Pad (host-side) to state/action multiples; exact-solution preserving.

    Batch-aware: a fleet container (leading ``B`` dim on ``val``/``cost``,
    shared or batched ``idx``) is padded identically on every instance.

    ``mode`` matches the solve's :class:`~repro.core.ipi.IPIOptions.mode`:
    padded actions carry cost ``+BIG`` under the argmin (``"mincost"``)
    backup but ``-BIG`` under the argmax (``"maxreward"``) backup, so they
    can never be greedy in either mode.  State padding (zero-cost absorbing
    self-loops, value identically 0) is mode-independent.
    """
    big = _BIG_COST if mode == "mincost" else -_BIG_COST
    idx, val, cost = (np.asarray(mdp.idx), np.asarray(mdp.val),
                      np.asarray(mdp.cost))
    n, m, k = val.shape[-3], val.shape[-2], val.shape[-1]
    n_pad = (-n) % n_mult
    m_pad = (-m) % m_mult
    if m_pad:
        idx = _bcast_concat(idx, np.zeros((n, m_pad, k), idx.dtype), -2)
        pv = np.zeros((n, m_pad, k), val.dtype)
        pv[..., 0] = 1.0  # self-transition placeholder (row sums to 1)
        val = _bcast_concat(val, pv, -2)
        cost = _bcast_concat(
            cost, np.full((n, m_pad), big, cost.dtype), -1)
    if n_pad:
        m_tot = m + m_pad
        pad_idx = np.zeros((n_pad, m_tot, k), idx.dtype)
        pad_idx[..., 0] = np.arange(n, n + n_pad, dtype=idx.dtype)[:, None]
        pad_val = np.zeros((n_pad, m_tot, k), val.dtype)
        pad_val[..., 0] = 1.0
        idx = _bcast_concat(idx, pad_idx, -3)
        val = _bcast_concat(val, pad_val, -3)
        # zero cost on the absorbing self-loop -> v_pad == 0 exactly; big cost
        # on padded actions stays (harmless: still never greedy).
        pad_cost = np.zeros((n_pad, m_tot), cost.dtype)
        pad_cost[:, m:] = big
        cost = _bcast_concat(cost, pad_cost, -2)
    return EllMDP(idx=jax.numpy.asarray(idx), val=jax.numpy.asarray(val),
                  cost=jax.numpy.asarray(cost), gamma=mdp.gamma,
                  n_global=n + n_pad, m_global=m + m_pad)


def fleet_padded_batch(b: int, fleet_size: int, pad: bool = True) -> int:
    """Fleet size after padding ``b`` up to a multiple of ``fleet_size``.

    Raises an actionable ``ValueError`` (instead of letting ``shard_map``
    fail on shapes later) when ``b`` is incompatible and padding is off.
    """
    b_pad = -(-b // fleet_size) * fleet_size
    if b_pad != b and not pad:
        raise ValueError(
            f"fleet of B={b} instances does not divide over the "
            f"{fleet_size}-way fleet axis and fleet padding is disabled; "
            f"either pass pad_fleet=True (adds {b_pad - b} zero-cost dummy "
            f"instance(s), trimmed from the results), solve a B divisible "
            f"by {fleet_size}, or build the mesh with a fleet axis that "
            f"divides {b}")
    return b_pad


def pad_fleet_dim(mdp: MDP, b_to: int) -> MDP:
    """Pad a batched fleet (host-side) to ``b_to`` instances.

    Dummy instances reuse instance 0's (valid, row-stochastic) transitions
    with identically-zero costs, so their optimal value is exactly 0: at the
    solver's ``v0 = 0`` start their Bellman residual is 0 and the active
    mask freezes them immediately — they never do real work and are trimmed
    from the results.
    """
    b = mdp.batch
    if b is None:
        raise ValueError("pad_fleet_dim() requires a batched MDP")
    if b_to == b:
        return mdp
    if b_to < b:
        raise ValueError(f"cannot pad fleet of {b} down to {b_to}")
    rep = lambda arr: np.broadcast_to(
        np.asarray(arr)[:1], (b_to - b,) + arr.shape[1:])
    cat = lambda arr, pad: jax.numpy.asarray(
        np.concatenate([np.asarray(arr), pad], axis=0))
    gamma = mdp.gamma
    if isinstance(gamma, tuple):
        gamma = gamma + (gamma[-1],) * (b_to - b)
    zero_cost = np.zeros((b_to - b,) + mdp.cost.shape[1:],
                         np.asarray(mdp.cost).dtype)
    if isinstance(mdp, EllMDP):
        idx = mdp.idx if mdp.shared_topology else cat(mdp.idx, rep(mdp.idx))
        return EllMDP(idx=idx, val=cat(mdp.val, rep(mdp.val)),
                      cost=cat(mdp.cost, zero_cost), gamma=gamma,
                      n_global=mdp.n_global, m_global=mdp.m_global)
    return DenseMDP(p=cat(mdp.p, rep(mdp.p)),
                    cost=cat(mdp.cost, zero_cost), gamma=gamma,
                    n_global=mdp.n_global, m_global=mdp.m_global)


def mdp_pspecs(mdp: MDP, axes: Axes):
    """PartitionSpecs for the MDP container fields (as a matching pytree).

    Fleet containers get a leading batch dim sharded over ``axes.fleet``
    (``None`` — replicated — for the non-fleet layouts).
    """
    s, a = axes.state, axes.action
    lead = () if mdp.batch is None else (axes.fleet,)
    if isinstance(mdp, MatrixFreeMDP):
        # the tag's sharding IS the placement: states sharded, nothing else
        return dataclasses.replace(mdp, tag=P(*lead, s))
    if isinstance(mdp, EllMDP):
        idx_spec = P(s, a, None) if mdp.idx.ndim == 3 \
            else P(*lead, s, a, None)
        return EllMDP(idx=idx_spec, val=P(*lead, s, a, None),
                      cost=P(*lead, s, a),
                      gamma=mdp.gamma, n_global=mdp.n_global,
                      m_global=mdp.m_global)
    return DenseMDP(p=P(*lead, s, a, None), cost=P(*lead, s, a),
                    gamma=mdp.gamma,
                    n_global=mdp.n_global, m_global=mdp.m_global)


def already_placed(mdp: MDP, mesh, axes: Axes) -> bool:
    """True when every MDP array is a committed device array carrying
    exactly the ``NamedSharding`` :func:`shard_mdp` would assign and the
    global shape needs no padding — the fast path for MDPs materialized
    shard-locally on device (``repro.api.MDP.from_functions``) or re-solved
    after a previous placement: ``shard_mdp`` then skips the host-side
    ``np.asarray`` round-trip that would gather the whole MDP."""
    if mdp.n_global % _axis_size(mesh, axes.state):
        return False
    if mdp.m_global % _axis_size(mesh, axes.action):
        return False
    if (mdp.batch or 1) % _axis_size(mesh, axes.fleet):
        return False
    specs = mdp_pspecs(mdp, axes)
    if isinstance(mdp, MatrixFreeMDP):
        fields = ("tag",)
    else:
        fields = (("idx", "val", "cost") if isinstance(mdp, EllMDP)
                  else ("p", "cost"))
    for f in fields:
        arr = getattr(mdp, f)
        sh = getattr(arr, "sharding", None)
        if sh is None or not getattr(arr, "committed", False):
            return False
        want = NamedSharding(mesh, getattr(specs, f))
        try:
            if not sh.is_equivalent_to(want, arr.ndim):
                return False
        except (AttributeError, TypeError):
            if sh != want:
                return False
    return True


def _eff_extents(mdp: EllMDP) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row ``(min, max)`` *nonzero-weight* ELL successor ids, reduced
    over (action, slot) and any leading batch dims — the effective column
    extents the communication planner reasons about.  Rows with no nonzero
    successors report the empty extents ``(n, -1)``."""
    n = mdp.n_global
    nz = mdp.val != 0
    eff_max = jnp.max(jnp.where(nz, mdp.idx, -1), axis=(-2, -1))
    eff_min = jnp.min(jnp.where(nz, mdp.idx, n), axis=(-2, -1))
    if eff_max.ndim > 1:
        eff_max = jnp.max(eff_max.reshape(-1, n), axis=0)
        eff_min = jnp.min(eff_min.reshape(-1, n), axis=0)
    return eff_min, eff_max


def frontier_reach(mdp: MDP, n_shards: int) -> int | None:
    """Smallest halo ``h`` such that every row's nonzero-weight successors
    fall inside the owning shard's ``[start - h, stop + h)`` window — i.e.
    the exchange width that makes the banded halo layout exact for this
    matrix at this shard count.  ``0`` means the partition is block-diagonal
    (no cross-shard transitions at all); ``None`` when the reach is
    undefined (dense representation, single shard, ragged partition).

    Unlike the matrix bandwidth this is measured *relative to shard
    boundaries*, so it is exactly the window the frontier rows of the
    communication-overlapped backup need — the driver uses it to shrink
    the full value all-gather (``n`` floats) to a ring exchange
    (``2 * reach`` floats) when ``-comm_overlap`` finds an interior core
    and the user left ``-halo 0``.
    """
    if isinstance(mdp, MatrixFreeMDP):
        # no arrays to measure: the reach comes from the declared matrix
        # bandwidth (|successor - row| <= band), a valid — if conservative
        # near shard centers — halo width for every shard boundary
        if n_shards <= 1 or mdp.n_global % n_shards:
            return None
        return None if mdp.spec.band is None else int(mdp.spec.band)
    if not isinstance(mdp, EllMDP) or n_shards <= 1:
        return None
    n = mdp.n_global
    if n % n_shards:
        return None
    n_local = n // n_shards
    eff_min, eff_max = _eff_extents(mdp)
    start = jnp.arange(n, dtype=jnp.int32) // n_local * n_local
    lo = jnp.max(start - eff_min)
    hi = jnp.max(eff_max - (start + n_local) + 1)
    return int(jnp.maximum(jnp.maximum(lo, hi), 0))


def overlap_margins(mdp: MDP, n_shards: int) -> tuple[int, int] | None:
    """Frontier margins ``(f_lo, f_hi)`` for the communication-overlapped
    backup, or ``None`` when no contiguous interior core exists.

    A row is *interior* when every nonzero-weight ELL successor falls inside
    the owning shard's ``[start, stop)`` range — its backup can run against
    ``v_local`` before the gather/halo window arrives.  The plan must be a
    compile-time constant shared by every SPMD shard, so the margins are the
    smallest ``(f_lo, f_hi)`` such that local rows ``[f_lo, n_local - f_hi)``
    are interior on *every* shard (and every instance of a batched fleet).
    Banded/stencil instances yield margins ~ the bandwidth; dense-random
    instances have no interior core and return ``None``.

    Runs as one device-side reduction pass over ``idx``/``val`` (no host
    gather of the MDP); call after mesh padding, with ``n_shards`` the
    state-axis size.
    """
    if isinstance(mdp, MatrixFreeMDP):
        # margins from the declared bandwidth: rows >= band away from both
        # shard edges are provably interior.  Conservative vs the measured
        # margins of a materialized table — harmless, since the overlap
        # split is bitwise invisible for any valid margins
        band = mdp.spec.band
        if band is None or n_shards <= 1 or mdp.n_global % n_shards:
            return None
        n_local = mdp.n_global // n_shards
        if 2 * int(band) >= n_local:
            return None
        return int(band), int(band)
    if not isinstance(mdp, EllMDP) or n_shards <= 1:
        return None
    n = mdp.n_global
    if n % n_shards:
        return None
    n_local = n // n_shards
    eff_min, eff_max = _eff_extents(mdp)
    i_loc = jnp.arange(n, dtype=jnp.int32) % n_local
    start = jnp.arange(n, dtype=jnp.int32) - i_loc
    bad = ~((eff_min >= start) & (eff_max < start + n_local))
    half = n_local // 2
    lo_bad = jnp.max(jnp.where(bad & (i_loc < half), i_loc, -1))
    hi_bad = jnp.min(jnp.where(bad & (i_loc >= half), i_loc,
                               jnp.int32(n_local)))
    f_lo = int(lo_bad) + 1
    f_hi = n_local - int(hi_bad)
    if f_lo + f_hi >= n_local:
        return None
    return f_lo, f_hi


def shard_mdp(mdp: EllMDP, mesh, layout: str = "1d", *,
              pad_fleet: bool = True, mode: str = "mincost"):
    """Pad + place a host MDP (single instance or batched fleet) onto
    ``mesh``.

    Returns ``(mdp_device, axes, n_orig)``; device arrays carry
    ``NamedSharding`` so ``shard_map`` consumes them without resharding.
    States (and actions, 2-D layout) are sharded.  The fleet dim of a
    batched container is replicated under the 1d/2d layouts (every shard
    owns its row slice of all B instances) and sharded over the leading
    mesh axis under the fleet layouts — padded to the fleet-axis size first
    (``pad_fleet=False`` raises instead of padding).

    An MDP whose arrays are already device-placed with exactly the target
    sharding (and no padding needed) passes through untouched — see
    :func:`already_placed`.
    """
    axes = mesh_axes(mesh, layout)
    if axes.fleet is not None and mdp.batch is None:
        raise ValueError(f"layout {layout!r} shards the fleet (batch) dim "
                         "but the MDP is unbatched; use layout='1d'/'2d' "
                         "or solve a fleet via solve_many()")
    if already_placed(mdp, mesh, axes):
        return mdp, axes, mdp.n_global
    if isinstance(mdp, MatrixFreeMDP):
        return _shard_matrix_free(mdp, mesh, axes, layout,
                                  pad_fleet=pad_fleet)
    n_mult = _axis_size(mesh, axes.state)
    m_mult = _axis_size(mesh, axes.action)
    n_orig = mdp.n_global
    padded = pad_mdp(mdp, n_mult, m_mult, mode=mode)
    if axes.fleet is not None:
        b_to = fleet_padded_batch(padded.batch, _axis_size(mesh, axes.fleet),
                                  pad_fleet)
        padded = pad_fleet_dim(padded, b_to)
    specs = mdp_pspecs(padded, axes)
    place = lambda arr, spec: jax.device_put(arr, NamedSharding(mesh, spec))
    dev = EllMDP(idx=place(padded.idx, specs.idx),
                 val=place(padded.val, specs.val),
                 cost=place(padded.cost, specs.cost),
                 gamma=padded.gamma, n_global=padded.n_global,
                 m_global=padded.m_global)
    return dev, axes, n_orig


def _shard_matrix_free(mdp: MatrixFreeMDP, mesh, axes: Axes, layout: str, *,
                       pad_fleet: bool = True):
    """Pad + place a matrix-free container: there are no tables to move,
    so placement is one ``device_put`` of the (padded) zero tag.

    State padding is free — the row builder masks ``rows >= spec.n`` into
    zero-cost absorbing self-loops, exactly :func:`pad_mdp`'s padding.
    Fleet padding duplicates the (single, static) spec with the last
    lane's gamma; the dummy lanes re-solve that lane's problem and
    converge in lockstep with it, then are trimmed from the results.
    """
    if _axis_size(mesh, axes.action) > 1:
        raise ValueError(
            f"matrix-free operators shard states only (every shard traces "
            f"the full static action tuple); layout {layout!r} shards the "
            f"action dim — use layout '1d'/'fleet', or materialize via "
            f"-mdp_materialize device")
    n_mult = _axis_size(mesh, axes.state)
    n_orig = mdp.n_global
    n_to = -(-n_orig // n_mult) * n_mult
    gamma = mdp.gamma
    shape: tuple = (n_to,)
    lead: tuple = ()
    if mdp.batch is not None:
        b_to = mdp.batch
        if axes.fleet is not None:
            b_to = fleet_padded_batch(mdp.batch,
                                      _axis_size(mesh, axes.fleet),
                                      pad_fleet)
            if isinstance(gamma, tuple) and b_to > mdp.batch:
                gamma = gamma + (gamma[-1],) * (b_to - mdp.batch)
        shape = (b_to, n_to)
        lead = (axes.fleet,)
    tag = jax.device_put(jnp.zeros(shape, jnp.int8),
                         NamedSharding(mesh, P(*lead, axes.state)))
    dev = MatrixFreeMDP(tag=tag, gamma=gamma, n_global=n_to,
                        m_global=mdp.m_global, spec=mdp.spec)
    return dev, axes, n_orig
