"""MDP instance generators (the solver's "data pipeline").

madupite creates MDPs either from offline files or from online, fully
distributed simulation.  We mirror that: every generator is deterministic in
``(seed, row_range)`` so any state-block can be produced independently on the
device that owns it (``rows=(start, stop)``) — no global materialization is
ever required.  Instances follow the experiment families of Gargiani et al.
2023/2024:

  * ``garnet``     — random GARNET MDPs (branching factor ``k``);
  * ``maze2d``     — slippery grid-world navigation (sparse, structured);
  * ``sis``        — SIS epidemic birth–death chain with intervention levels;
  * ``chain_walk`` — slow-mixing random walk (gamma -> 1 stress case where
                     Krylov iPI dominates VI/mPI — the paper's motivation).
"""

from __future__ import annotations

import numpy as np

from repro.core.mdp import EllMDP


def _rng(seed: int, start: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, start]))


def _finish(idx, val, cost, gamma, n, m) -> EllMDP:
    import jax.numpy as jnp
    return EllMDP(idx=jnp.asarray(idx, jnp.int32),
                  val=jnp.asarray(val, jnp.float32),
                  cost=jnp.asarray(cost, jnp.float32),
                  gamma=float(gamma), n_global=int(n), m_global=int(m))


def garnet(n: int, m: int, k: int = 8, gamma: float = 0.95, seed: int = 0,
           rows: tuple[int, int] | None = None) -> EllMDP:
    """GARNET(n, m, k): k random successors with Dirichlet(1) probabilities."""
    start, stop = rows or (0, n)
    rng = _rng(seed, start)
    nr = stop - start
    idx = rng.integers(0, n, size=(nr, m, k), dtype=np.int64)
    raw = rng.random((nr, m, k)).astype(np.float64) + 1e-6
    val = raw / raw.sum(-1, keepdims=True)
    cost = rng.random((nr, m))
    return _finish(idx, val, cost, gamma, n, m)


def maze2d(size: int, gamma: float = 0.99, slip: float = 0.1, seed: int = 0,
           rows: tuple[int, int] | None = None) -> EllMDP:
    """size x size grid; actions (stay,N,S,E,W); goal = last cell, absorbing.

    Each move succeeds w.p. 1-slip and slips back to the current cell w.p.
    ``slip``; walls (boundary) bounce.  Unit cost per step, 0 at the goal.
    """
    n, m, k = size * size, 5, 2
    start, stop = rows or (0, n)
    s = np.arange(start, stop)
    r, c = s // size, s % size
    moves = np.array([[0, 0], [-1, 0], [1, 0], [0, 1], [0, -1]])
    idx = np.zeros((stop - start, m, k), np.int64)
    val = np.zeros((stop - start, m, k), np.float64)
    cost = np.ones((stop - start, m), np.float64)
    goal = n - 1
    for a in range(m):
        nr_ = np.clip(r + moves[a, 0], 0, size - 1)
        nc = np.clip(c + moves[a, 1], 0, size - 1)
        tgt = nr_ * size + nc
        idx[:, a, 0] = tgt
        idx[:, a, 1] = s
        val[:, a, 0] = 1.0 - slip
        val[:, a, 1] = slip
    at_goal = s == goal
    idx[at_goal] = goal            # absorbing
    val[at_goal, :, 0] = 1.0
    val[at_goal, :, 1] = 0.0
    cost[at_goal] = 0.0
    return _finish(idx, val, cost, gamma, n, m)


def sis(pop: int, n_actions: int = 4, gamma: float = 0.99, seed: int = 0,
        rows: tuple[int, int] | None = None) -> EllMDP:
    """SIS epidemic: state = #infected in [0, pop]; action = intervention level.

    Birth–death chain: infections up w.p. beta_a * i * (pop - i) / pop^2,
    recoveries down w.p. mu * i / pop.  Cost = infection load + intervention
    cost.  State 0 is absorbing (disease eradicated).
    """
    n, m, k = pop + 1, n_actions, 3
    start, stop = rows or (0, n)
    i = np.arange(start, stop, dtype=np.float64)
    beta = np.linspace(0.9, 0.05, m)         # stronger action -> lower spread
    act_cost = np.linspace(0.0, 0.15, m)     # intervention much cheaper than
    mu = 0.3                                 # a full-blown epidemic
    up = np.clip(beta[None, :] * (i[:, None] * (pop - i[:, None])) / pop**2,
                 0, 0.49)
    down = np.broadcast_to(np.clip(mu * i[:, None] / pop, 0, 0.49),
                           up.shape).copy()
    stay = 1.0 - up - down
    s = np.arange(start, stop)
    idx = np.stack([np.clip(s + 1, 0, n - 1)[:, None].repeat(m, 1),
                    np.clip(s - 1, 0, n - 1)[:, None].repeat(m, 1),
                    s[:, None].repeat(m, 1)], axis=-1)
    val = np.stack([up, down, stay], axis=-1)
    cost = 2.0 * i[:, None] / pop + act_cost[None, :]
    at_zero = s == 0
    val[at_zero] = np.array([0.0, 0.0, 1.0])
    cost[at_zero] = act_cost[None, :]
    return _finish(idx, val, cost, gamma, n, m)


def chain_walk(n: int, gamma: float = 0.9999, p_fwd: float = 0.7,
               seed: int = 0, rows: tuple[int, int] | None = None) -> EllMDP:
    """Slow-mixing 1-D chain; target = state 0.  Conditioning ~ 1/(1-gamma):
    the instance family where VI stalls and Krylov iPI shines."""
    m, k = 2, 2
    start, stop = rows or (0, n)
    s = np.arange(start, stop)
    left = np.clip(s - 1, 0, n - 1)
    right = np.clip(s + 1, 0, n - 1)
    # action 0: try left; action 1: try right
    idx = np.stack([np.stack([left, right], -1),
                    np.stack([right, left], -1)], axis=1)
    val = np.broadcast_to(np.array([p_fwd, 1 - p_fwd]), (stop - start, m, k))
    cost = np.where((s == 0)[:, None], 0.0, 1.0) * np.ones((1, m))
    return _finish(idx, val.copy(), np.broadcast_to(cost, (stop - start, m)).copy(),
                   gamma, n, m)


REGISTRY = {"garnet": garnet, "maze2d": maze2d, "sis": sis,
            "chain_walk": chain_walk}


# --------------------------------------------------------------------------- #
# Device-side (jit-able) constructor variants                                  #
# --------------------------------------------------------------------------- #
#
# Each ``*_functions`` builder returns the keyword dict
# ``{"P_fn", "g_fn", "n", "m", "nnz", "gamma", "vectorized", "band"}`` for
# ``repro.api.MDP.from_functions(**spec, device=True)``: the constructors are
# written in jax.numpy over a *traced* row-index array (the action is a
# static Python int), so the session layer materializes each device's ELL
# block inside a compiled program — no host numpy anywhere, which is what
# lets ``from_generator`` instances scale past host memory.  Constructors
# must tolerate row ids >= n (shard padding rows; their outputs are masked).
#
# maze2d / chain_walk reproduce the host generators' tables bit-for-bit;
# garnet draws from a counter-based jax PRNG (fold_in per (seed, row,
# action)) instead of numpy's Generator, and sis computes in f32 on device,
# so those two match their host counterparts in distribution / to rounding,
# not bitwise.
#
# The closure-producing helpers are memoized (lru_cache) on everything
# EXCEPT gamma: a sweep like ``[from_generator(name, deferred=True,
# gamma=g) for g in gammas]`` then hands every instance the *same*
# (P_fn, g_fn) pair, so the device pipeline's builder cache
# (repro.api.mdp._BUILDER_CACHE, keyed on constructor identity) compiles
# exactly one block program for the whole fleet.

from functools import lru_cache


@lru_cache(maxsize=64)
def _garnet_fns(n: int, m: int, k: int, seed: int):
    import jax
    import jax.numpy as jnp

    def _row_key(r, a):
        return jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(seed), r), a)

    def P_fn(rows, a):
        def one(r):
            kk = _row_key(r, a)
            ids = jax.random.randint(jax.random.fold_in(kk, 0), (k,), 0, n)
            raw = jax.random.uniform(jax.random.fold_in(kk, 1), (k,)) + 1e-6
            return ids.astype(jnp.int32), (raw / raw.sum()).astype(jnp.float32)
        return jax.vmap(one)(rows)

    def g_fn(rows, a):
        return jax.vmap(lambda r: jax.random.uniform(
            jax.random.fold_in(_row_key(r, a), 2), ()))(rows)

    return P_fn, g_fn


def garnet_functions(n: int, m: int, k: int = 8, gamma: float = 0.95,
                     seed: int = 0) -> dict:
    """GARNET via a counter-based PRNG: any row block is generated
    independently on the device that owns it."""
    P_fn, g_fn = _garnet_fns(n, m, k, seed)
    # band=None: successors are drawn globally — no banded structure
    return dict(P_fn=P_fn, g_fn=g_fn, n=n, m=m, nnz=k, gamma=gamma,
                vectorized=True, band=None)


@lru_cache(maxsize=64)
def _maze2d_fns(size: int, slip: float):
    import jax.numpy as jnp
    n, m = size * size, 5
    moves = ((0, 0), (-1, 0), (1, 0), (0, 1), (0, -1))
    goal = n - 1

    def P_fn(rows, a):
        r, c = rows // size, rows % size
        nr = jnp.clip(r + moves[a][0], 0, size - 1)
        nc = jnp.clip(c + moves[a][1], 0, size - 1)
        tgt = nr * size + nc
        at_goal = rows == goal
        i0 = jnp.where(at_goal, goal, tgt)
        i1 = jnp.where(at_goal, goal, rows)
        v0 = jnp.where(at_goal, 1.0, 1.0 - slip)
        v1 = jnp.where(at_goal, 0.0, slip)
        return (jnp.stack([i0, i1], -1).astype(jnp.int32),
                jnp.stack([v0, v1], -1).astype(jnp.float32))

    def g_fn(rows, a):
        return jnp.where(rows == goal, 0.0, 1.0).astype(jnp.float32)

    return P_fn, g_fn


def maze2d_functions(size: int, gamma: float = 0.99, slip: float = 0.1,
                     seed: int = 0) -> dict:
    """Device maze2d; bit-identical tables to :func:`maze2d`."""
    P_fn, g_fn = _maze2d_fns(size, slip)
    # band=size: a row move shifts the flat index by +-size (N/S moves)
    return dict(P_fn=P_fn, g_fn=g_fn, n=size * size, m=5, nnz=2,
                gamma=gamma, vectorized=True, band=size)


@lru_cache(maxsize=64)
def _sis_fns(pop: int, n_actions: int):
    import jax.numpy as jnp
    n, m = pop + 1, n_actions
    beta = np.linspace(0.9, 0.05, m)
    act_cost = np.linspace(0.0, 0.15, m)
    mu = 0.3

    def P_fn(rows, a):
        i = rows.astype(jnp.float32)
        up = jnp.clip(float(beta[a]) * i * (pop - i) / pop**2, 0, 0.49)
        down = jnp.clip(mu * i / pop, 0, 0.49)
        at_zero = rows == 0
        up = jnp.where(at_zero, 0.0, up)
        down = jnp.where(at_zero, 0.0, down)
        stay = 1.0 - up - down
        ids = jnp.stack([jnp.clip(rows + 1, 0, n - 1),
                         jnp.clip(rows - 1, 0, n - 1), rows], -1)
        return (ids.astype(jnp.int32),
                jnp.stack([up, down, stay], -1).astype(jnp.float32))

    def g_fn(rows, a):
        load = 2.0 * rows.astype(jnp.float32) / pop
        return (jnp.where(rows == 0, 0.0, load)
                + float(act_cost[a])).astype(jnp.float32)

    return P_fn, g_fn


def sis_functions(pop: int, n_actions: int = 4, gamma: float = 0.99,
                  seed: int = 0) -> dict:
    """Device SIS chain (f32 on-device arithmetic: matches :func:`sis` to
    rounding, not bitwise — the host generator computes in f64)."""
    P_fn, g_fn = _sis_fns(pop, n_actions)
    # band=1: birth-death chain, transitions only to i-1 / i / i+1
    return dict(P_fn=P_fn, g_fn=g_fn, n=pop + 1, m=n_actions, nnz=3,
                gamma=gamma, vectorized=True, band=1)


@lru_cache(maxsize=64)
def _chain_walk_fns(n: int, p_fwd: float):
    import jax.numpy as jnp

    def P_fn(rows, a):
        left = jnp.clip(rows - 1, 0, n - 1)
        right = jnp.clip(rows + 1, 0, n - 1)
        fwd, bwd = (left, right) if a == 0 else (right, left)
        probs = jnp.broadcast_to(
            jnp.asarray([p_fwd, 1 - p_fwd], jnp.float32),
            (rows.shape[0], 2))
        return jnp.stack([fwd, bwd], -1).astype(jnp.int32), probs

    def g_fn(rows, a):
        return jnp.where(rows == 0, 0.0, 1.0).astype(jnp.float32)

    return P_fn, g_fn


def chain_walk_functions(n: int, gamma: float = 0.9999, p_fwd: float = 0.7,
                         seed: int = 0) -> dict:
    """Device chain walk; bit-identical tables to :func:`chain_walk`."""
    P_fn, g_fn = _chain_walk_fns(n, p_fwd)
    # band=1: random walk steps at most one state left/right
    return dict(P_fn=P_fn, g_fn=g_fn, n=n, m=2, nnz=2, gamma=gamma,
                vectorized=True, band=1)


FN_REGISTRY = {"garnet": garnet_functions, "maze2d": maze2d_functions,
               "sis": sis_functions, "chain_walk": chain_walk_functions}


def generate_many(kind: str, batch: int, *, sweep=None, **kw) -> list[EllMDP]:
    """Generate a fleet of ``batch`` related instances in one call.

    By default this is a *seed ensemble*: instance ``b`` gets
    ``seed = kw.get("seed", 0) + b``.  ``sweep`` maps parameter names to
    length-``batch`` value sequences and overrides the per-instance kwargs
    instead (the seed stays fixed unless swept), e.g. a gamma-conditioning
    sweep::

        generate_many("chain_walk", 4, n=300,
                      sweep={"gamma": [0.9, 0.99, 0.999, 0.9999]})

    The result feeds :func:`repro.core.mdp.stack_mdps` /
    :func:`repro.core.driver.solve_many`.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    fn = REGISTRY[kind]
    for name, vals in (sweep or {}).items():
        if len(vals) != batch:
            raise ValueError(f"sweep[{name!r}] has {len(vals)} values for "
                             f"batch={batch}")
    out = []
    for b in range(batch):
        kwb = dict(kw)
        if sweep:
            for name, vals in sweep.items():
                kwb[name] = vals[b]
        else:
            kwb["seed"] = int(kw.get("seed", 0)) + b
        out.append(fn(**kwb))
    return out
