"""Collective-axis abstraction for the distributed MDP solver.

madupite distributes states across MPI ranks and lets PETSc insert the
communication (VecScatter for SpMV halo exchange, MPI_Allreduce for Krylov
dot products).  The TPU adaptation expresses the same pattern with named mesh
axes inside ``shard_map``:

* ``state`` axis — states are row-partitioned; moving ``v`` is an
  ``all_gather``; norms / dots are ``psum`` / ``pmax``.
* ``action`` axis — optional 2-D layout (beyond the paper): actions are
  column-partitioned; the greedy step finishes with a min/argmin reduction.
* ``fleet`` axis — fleet-sharded batched solves: the leading instance dim of
  a :func:`repro.core.driver.solve_many` fleet is partitioned across this
  axis (each device owns ``B / fleet_size`` instances on top of its state
  slice).  The solver body needs no fleet collectives — instances are
  independent — except the loop-convergence decision, which all-reduces the
  per-instance active mask so every fleet shard runs the same number of
  ``lax.while_loop`` iterations (frozen shards spin no-op iterations).

When an axis name is ``None`` the collective degenerates to the identity, so
the identical solver code runs on a single device (tests, small problems).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.utils import jax_compat

AxisName = Union[str, Sequence[str], None]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Axes:
    """Mesh axis names used by the solver (all static metadata)."""

    state: AxisName = dataclasses.field(default=None, metadata=dict(static=True))
    action: AxisName = dataclasses.field(default=None, metadata=dict(static=True))
    fleet: AxisName = dataclasses.field(default=None, metadata=dict(static=True))

    # ---- state-axis collectives -------------------------------------------------
    def allgather_state(self, x: jax.Array, dtype=None) -> jax.Array:
        """Gather the value vector across state shards (PETSc VecScatter
        analogue).  ``dtype`` optionally compresses the wire format (e.g.
        bf16): the inexact-gather optimization — the iPI forcing term absorbs
        the quantization error in *inner* matvecs (EXPERIMENTS.md §Perf)."""
        if dtype is not None:
            x = x.astype(dtype)
        if self.state is None:
            return x
        return jax.lax.all_gather(x, self.state, axis=0, tiled=True)

    def halo_exchange(self, x: jax.Array, halo: int, dtype=None) -> jax.Array:
        """Exchange ``halo`` boundary entries with ring neighbours instead of
        all-gathering the full vector — the TPU analogue of PETSc's
        VecScatter moving only the referenced columns.  Valid when the
        transition matrix is banded with bandwidth <= halo (validated at
        partition time).  Returns the local window
        ``[start - halo, stop + halo)`` (ends wrap with garbage that banded
        instances never reference).  Collective volume: 2*halo vs n_global.
        """
        if dtype is not None:
            x = x.astype(dtype)
        if halo == 0:
            return x
        if self.state is None:
            # single-shard window with the same ring semantics (edges unused)
            return jnp.concatenate([x[-halo:], x, x[:halo]], axis=0)
        n = self.state_size()
        fwd = [(i, (i + 1) % n) for i in range(n)]   # data flows ->
        bwd = [(i, (i - 1) % n) for i in range(n)]
        # my left halo = left neighbour's tail (neighbour sends forward)
        left = jax.lax.ppermute(x[-halo:], self.state, fwd)
        right = jax.lax.ppermute(x[:halo], self.state, bwd)
        return jnp.concatenate([left, x, right], axis=0)

    # ---- split-phase window movement (communication/computation overlap) --------
    def gather_start(self, x: jax.Array, *, halo: int = 0, dtype=None) -> jax.Array:
        """Issue the value-window collective (all-gather, or halo ring when
        ``halo > 0``) and return the in-flight window.

        JAX has no explicit request object; the split-phase contract is
        structural: the returned array is the *only* data dependence on the
        collective, so any compute issued between :meth:`gather_start` and
        :meth:`gather_finish` that does not touch it is free to overlap.
        With async collectives enabled (``-xla_flag_bundle
        cpu-overlap`` / ``tpu-collectives``) XLA splits the op into a
        ``-start``/``-done`` pair and the latency-hiding scheduler moves the
        independent compute between them.
        """
        if halo:
            return self.halo_exchange(x, halo, dtype=dtype)
        return self.allgather_state(x, dtype=dtype)

    def gather_finish(self, window: jax.Array) -> jax.Array:
        """Close the split-phase window started by :meth:`gather_start`.

        A no-op data-wise (the dependence edge on ``window`` is the real
        synchronization); kept as an explicit call so call sites read like
        MPI_Isend/MPI_Wait and so a future backend can hang a barrier here.
        """
        return window

    def psum_state(self, x):
        if self.state is None:
            return x
        return jax.lax.psum(x, self.state)

    def pmax_state(self, x):
        if self.state is None:
            return x
        return jax.lax.pmax(x, self.state)

    def state_index(self) -> jax.Array:
        if self.state is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.state)

    def state_size(self) -> int:
        if self.state is None:
            return 1
        if isinstance(self.state, str):
            return jax_compat.axis_size(self.state)
        out = 1
        for name in self.state:
            out *= jax_compat.axis_size(name)
        return out

    # ---- fleet-axis collectives -------------------------------------------------
    def any_fleet(self, x: jax.Array) -> jax.Array:
        """Logical OR of a boolean across fleet shards (keeps the shared
        ``lax.while_loop`` in lockstep when instances converge on some shards
        before others)."""
        if self.fleet is None:
            return x
        return jax.lax.psum(x.astype(jnp.int32), self.fleet) > 0

    def fleet_index(self) -> jax.Array:
        if self.fleet is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.fleet)

    def pmax_fleet(self, x):
        if self.fleet is None:
            return x
        return jax.lax.pmax(x, self.fleet)

    def allgather_fleet(self, x: jax.Array) -> jax.Array:
        """Gather per-instance rows across fleet shards (the monitor's
        fleet-wide record; instances are otherwise independent)."""
        if self.fleet is None:
            return x
        return jax.lax.all_gather(x, self.fleet, axis=0, tiled=True)

    # ---- action-axis collectives ------------------------------------------------
    def pmin_action(self, x):
        if self.action is None:
            return x
        return jax.lax.pmin(x, self.action)

    def psum_action(self, x):
        if self.action is None:
            return x
        return jax.lax.psum(x, self.action)

    def action_index(self) -> jax.Array:
        if self.action is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.action)

    # ---- derived linear-algebra helpers ------------------------------------------
    def dot(self, x: jax.Array, y: jax.Array) -> jax.Array:
        """Distributed <x, y> over state shards (MPI_Allreduce analogue)."""
        return self.psum_state(jnp.dot(x, y, precision=jax.lax.Precision.HIGHEST))

    def norm2(self, x: jax.Array) -> jax.Array:
        return jnp.sqrt(jnp.maximum(self.dot(x, x), 0.0))

    def norm_inf(self, x: jax.Array) -> jax.Array:
        return self.pmax_state(jnp.max(jnp.abs(x)))
