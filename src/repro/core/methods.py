"""Pluggable solution-method registries — the PETSc-KSP analogue.

madupite's core selling point is *flexibility in solution methods*: the C++
core delegates the inexact policy-evaluation step to PETSc's pluggable KSP
solvers and lets users pick methods and stopping conditions at runtime.
This module is that extension surface for the JAX reproduction.  Three live
registries replace the former frozen ``METHODS`` tuple and if/elif dispatch:

* **KSP registry** — inner linear solvers for ``(I - gamma P_pi) x = g_pi``
  with the uniform signature ``fn(matvec, b, x0, *, tol, maxiter, axes)``
  (optionally also accepting ``opts`` — the static
  :class:`~repro.core.ipi.IPIOptions` — and ``context`` — per-solve traced
  values, currently ``{"gamma": ...}``).  Registering ``name`` also
  auto-registers the outer method ``ipi_<name>`` (forcing-term stopping,
  monotone safeguard), so a user solver is immediately selectable with
  ``-ksp_type name`` / ``-method ipi_name`` from Python, ``MADUPITE_OPTIONS``
  and the CLI without touching repro internals.
* **Method registry** — outer iterations: which KSP runs the inexact
  policy-evaluation step and under which inner-stopping policy
  (``forcing`` / ``sweeps`` / ``tight`` / ``none``), and whether the
  monotone (VI-fallback) safeguard applies.
* **Stop-criterion registry** — outer stopping predicates compiled into the
  device loop: builtin ``atol`` (sup-norm residual), ``rtol`` (relative to
  the initial residual) and ``span`` (span seminorm — certifies long-mixing
  VI far earlier than sup-norm residuals), plus user-registered traced
  predicates over :class:`StopMetrics`.

All registered callables are traced into compiled programs, so they must be
``lax``-compatible (jit / vmap / shard_map safe).  Re-registering a name
with different code (``overwrite=True``) automatically clears the compiled
solve caches (the driver registers its cache-clearers via
:func:`on_overwrite_clear`) — a stale program would silently keep running
the old solver otherwise.

The monitor dispatch table also lives here: compiled solve loops stream
per-iteration records through one fixed ``jax.debug.callback`` trampoline
(:func:`emit_monitor`) keyed by a *traced* monitor id, so turning a monitor
on never retraces or recompiles a cached program for a different callback.
"""

from __future__ import annotations

import dataclasses
import difflib
import inspect
import itertools
import time
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import Axes
from repro.core.solvers import (anderson, async_vi_outer, bicgstab, chebyshev,
                                gmres, richardson)

__all__ = [
    "KSPSpec", "MethodSpec", "StopMetrics", "StopSpec",
    "register_ksp", "register_method", "register_stop_criterion",
    "unregister_ksp", "unregister_method", "unregister_stop_criterion",
    "ksp_names", "method_names", "stop_names",
    "get_ksp", "get_method", "get_stop", "method_for_ksp",
    "check_ksp", "check_method", "check_stop",
    "inner_solve", "stop_done", "adhoc_stop_criterion",
    "monitor_handle", "monitor_release", "emit_monitor", "emit_host",
    "print_monitor",
]

INNER_POLICIES = ("none", "forcing", "sweeps", "tight")


# --------------------------------------------------------------------------- #
# Registry records                                                            #
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class KSPSpec:
    """One registered inner linear solver."""

    name: str
    fn: Callable                 # normalized: fn(matvec, b, x0, tol, maxiter,
    #                              axes, opts, context, precond)
    #                              -> (x, iters, res)
    doc: str = ""
    deterministic: bool = False  # honors -deterministic_dots (its arithmetic
    #                              is invariant to the vmapped lane count)
    builtin: bool = False
    preconditioned: bool = False  # accepts a `precond` apply (-pc_type)

    def call(self, matvec, b, x0, *, tol, maxiter, axes, opts, context,
             precond=None):
        return self.fn(matvec, b, x0, tol, maxiter, axes, opts, context,
                       precond)


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """One registered outer method: a KSP plus an inner-stopping policy —
    or a whole custom outer iteration (``outer``)."""

    name: str
    ksp: str | None              # KSP registry name; None -> no inner solve
    inner: str = "forcing"       # none | forcing (eta * res) | sweeps
    #                              (mpi_sweeps fixed) | tight (0.01 * atol)
    safeguarded: bool = True     # monotone VI-fallback applies (Krylov-type
    #                              steps are not contractions)
    doc: str = ""
    builtin: bool = False
    outer: Callable | None = None  # full outer-iteration replacement:
    #                              fn(mdp, state, opts, axes, gamma_t) ->
    #                              (v1, tv1, pi1, res1, inner_iters, win1);
    #                              span/stop bookkeeping stays shared.  Such
    #                              methods get SolveState.win maintained
    #                              (the last exchanged value window).
    virtual: bool = False        # meta-method (e.g. "auto"): validates in the
    #                              options layer but is resolved to a concrete
    #                              method by repro.adaptive before any compiled
    #                              loop runs; driver.solve rejects it directly.


@dataclasses.dataclass(frozen=True)
class StopMetrics:
    """Per-outer-iteration quantities a stopping criterion may read.

    All array fields are elementwise-broadcastable: scalars for a single
    solve, per-instance ``(B,)`` vectors for a batched fleet — criteria
    must use elementwise ops only so one predicate serves both.  Padded
    dummy fleet lanes carry ``res == span == 0``; a criterion should stop
    them (every builtin does).
    """

    res: jax.Array          # ||T v - v||_inf (the Bellman residual)
    span: jax.Array         # sp(T v - v) = max - min (inf unless the
    #                         criterion declared needs_span)
    res0: jax.Array         # residual at k = 0 (rtol baseline)
    k: jax.Array            # outer iterations done
    gamma: Any              # discount (python float, or traced per-instance)
    atol: float
    rtol: float


@dataclasses.dataclass(frozen=True)
class StopSpec:
    """One registered outer stopping criterion."""

    name: str
    fn: Callable[[StopMetrics], jax.Array]   # True -> converged (stop)
    needs_span: bool = False   # compute the span seminorm each iteration
    doc: str = ""
    builtin: bool = False


_KSPS: dict[str, KSPSpec] = {}
_METHODS: dict[str, MethodSpec] = {}
_STOPS: dict[str, StopSpec] = {}


# --------------------------------------------------------------------------- #
# Registration                                                                #
# --------------------------------------------------------------------------- #

def _normalize_ksp_fn(fn: Callable) -> Callable:
    """Adapt a user solver to the internal calling convention.

    ``fn(matvec, b, x0, *, tol, maxiter, axes)`` is the minimal contract;
    ``opts`` (static :class:`IPIOptions`), ``context`` (traced per-solve
    values, e.g. ``gamma``) and ``precond`` (the optional ``-pc_type``
    apply) are forwarded only when the signature accepts them (or has
    ``**kwargs``).
    """
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):       # builtins / C callables: send all
        params = None
    var_kw = params is not None and any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())
    accepts = (lambda name: True) if (params is None or var_kw) else \
        (lambda name: name in params)

    def call(matvec, b, x0, tol, maxiter, axes, opts, context, precond=None):
        kw = dict(tol=tol, maxiter=maxiter, axes=axes)
        if accepts("opts"):
            kw["opts"] = opts
        if accepts("context"):
            kw["context"] = context
        if accepts("precond"):
            kw["precond"] = precond
        return fn(matvec, b, x0, **kw)

    return call


# Cache-clearers invoked when a registered name is REPLACED (overwrite=True):
# registry lookups happen at trace time, so already-compiled programs would
# silently keep running the old code.  The driver registers its compiled-
# program caches here at import (it imports this module, not vice versa).
_CACHE_CLEARERS: list[Callable[[], None]] = []


def on_overwrite_clear(fn: Callable[[], None]) -> None:
    _CACHE_CLEARERS.append(fn)


def _check_free(registry: Mapping[str, Any], kind: str, name: str,
                overwrite: bool) -> None:
    if not isinstance(name, str) or not name or not name.strip() == name:
        raise ValueError(f"{kind} names are non-empty strings, got {name!r}")
    prior = registry.get(name)
    if prior is not None and not overwrite:
        who = "builtin" if prior.builtin else "already-registered"
        raise ValueError(
            f"{kind} {name!r} is {who}; pass overwrite=True to replace it "
            f"(compiled solve caches are cleared automatically)")
    if prior is not None:
        for clear in _CACHE_CLEARERS:
            clear()


def register_ksp(name: str, fn: Callable | None = None, *, doc: str = "",
                 deterministic: bool = False, auto_method: bool = True,
                 preconditioned: bool = False,
                 overwrite: bool = False, _builtin: bool = False):
    """Register an inner linear solver (usable as a decorator).

    ``fn(matvec, b, x0, *, tol, maxiter, axes)`` must return
    ``(x, iters, resnorm)`` and be pure ``lax`` control flow.  With
    ``auto_method=True`` (default) the outer method ``ipi_<name>`` is also
    registered (forcing-term inner stopping, safeguarded), making the
    solver selectable via ``-ksp_type name`` everywhere options are
    ingested.  ``deterministic=True`` declares the solver's arithmetic
    batch-invariant (legal under ``-deterministic_dots``).
    ``preconditioned=True`` declares that the solver accepts a ``precond``
    keyword (an apply ``x -> M x``) and therefore honors ``-pc_type``.
    """
    if fn is None:
        return lambda f: register_ksp(name, f, doc=doc,
                                      deterministic=deterministic,
                                      auto_method=auto_method,
                                      preconditioned=preconditioned,
                                      overwrite=overwrite, _builtin=_builtin)
    _check_free(_KSPS, "ksp", name, overwrite)
    spec = KSPSpec(name=name, fn=_normalize_ksp_fn(fn),
                   doc=doc or (fn.__doc__ or "").strip().split("\n")[0],
                   deterministic=deterministic, builtin=_builtin,
                   preconditioned=preconditioned)
    _KSPS[name] = spec
    if auto_method and f"ipi_{name}" not in _METHODS:
        register_method(f"ipi_{name}", ksp=name, inner="forcing",
                        safeguarded=True,
                        doc=f"iPI with {name} inner solves (auto-registered)",
                        _builtin=_builtin)
    return fn


def register_method(name: str, *, ksp: str | None, inner: str = "forcing",
                    safeguarded: bool = True, doc: str = "",
                    outer: Callable | None = None, virtual: bool = False,
                    overwrite: bool = False, _builtin: bool = False) \
        -> MethodSpec:
    """Register an outer method: which KSP runs the policy-evaluation step
    and under which inner-stopping policy (see :data:`INNER_POLICIES`) —
    or, with ``outer``, a full custom outer iteration (e.g. ``async_vi``)
    that replaces the inner-solve/backup core entirely.  ``virtual=True``
    marks a meta-method (like the builtin ``auto``) that never reaches a
    compiled loop itself: the adaptive layer resolves it to a concrete
    method first."""
    _check_free(_METHODS, "method", name, overwrite)
    if inner not in INNER_POLICIES:
        raise ValueError(f"inner policy must be one of {INNER_POLICIES}, "
                         f"got {inner!r}")
    if ksp is not None and ksp not in _KSPS:
        raise ValueError(check_ksp(ksp))
    if outer is not None and ksp is not None:
        raise ValueError(f"method {name!r}: a custom outer iteration "
                         f"replaces the inner solve — pass ksp=None")
    if virtual and (ksp is not None or outer is not None):
        raise ValueError(f"method {name!r}: virtual methods carry no "
                         f"solver — pass ksp=None, outer=None")
    if (ksp is None) != (inner == "none"):
        raise ValueError(f"method {name!r}: ksp=None requires inner='none' "
                         f"(and vice versa), got ksp={ksp!r} inner={inner!r}")
    spec = MethodSpec(name=name, ksp=ksp, inner=inner,
                      safeguarded=safeguarded, doc=doc, builtin=_builtin,
                      outer=outer, virtual=virtual)
    _METHODS[name] = spec
    return spec


def register_stop_criterion(name: str, fn: Callable[[StopMetrics], jax.Array]
                            | None = None, *, needs_span: bool = False,
                            doc: str = "", overwrite: bool = False,
                            _builtin: bool = False):
    """Register an outer stopping criterion (usable as a decorator).

    ``fn(metrics: StopMetrics) -> bool array`` returns True where the solve
    has converged; it is traced into the compiled loop, so elementwise
    ``jnp`` ops only.  NaN residuals never count as converged (enforced
    outside the predicate).
    """
    if fn is None:
        return lambda f: register_stop_criterion(
            name, f, needs_span=needs_span, doc=doc, overwrite=overwrite,
            _builtin=_builtin)
    _check_free(_STOPS, "stop criterion", name, overwrite)
    _STOPS[name] = StopSpec(name=name, fn=fn, needs_span=needs_span,
                            doc=doc or (fn.__doc__ or "").strip()
                            .split("\n")[0], builtin=_builtin)
    return fn


def _unregister(registry: dict, kind: str, name: str) -> None:
    spec = registry.get(name)
    if spec is None:
        return
    if spec.builtin:
        raise ValueError(f"refusing to unregister builtin {kind} {name!r}")
    del registry[name]


def unregister_ksp(name: str) -> None:
    """Remove a user-registered KSP (and its auto-method, if still its)."""
    _unregister(_KSPS, "ksp", name)
    auto = _METHODS.get(f"ipi_{name}")
    if auto is not None and not auto.builtin and auto.ksp == name:
        del _METHODS[f"ipi_{name}"]


def unregister_method(name: str) -> None:
    _unregister(_METHODS, "method", name)


def unregister_stop_criterion(name: str) -> None:
    _unregister(_STOPS, "stop criterion", name)


# --------------------------------------------------------------------------- #
# Lookup / validation                                                         #
# --------------------------------------------------------------------------- #

def ksp_names(*, builtin_only: bool = False) -> tuple[str, ...]:
    return tuple(n for n, s in _KSPS.items()
                 if s.builtin or not builtin_only)


def method_names(*, builtin_only: bool = False) -> tuple[str, ...]:
    return tuple(n for n, s in _METHODS.items()
                 if s.builtin or not builtin_only)


def stop_names(*, builtin_only: bool = False) -> tuple[str, ...]:
    return tuple(n for n, s in _STOPS.items()
                 if s.builtin or not builtin_only)


def suggest(name, candidates) -> str:
    """Shared '; did you mean ...?' hint (difflib over the live candidate
    names), or '' when nothing is close — used by every unknown-name error
    in the registries and the options database."""
    close = difflib.get_close_matches(str(name),
                                      [str(c) for c in candidates], n=3)
    return f"; did you mean {' / '.join(repr(c) for c in close)}?" \
        if close else ""


def _unknown(kind: str, name, names, register_hint: str) -> str:
    return (f"unknown {kind} {name!r}{suggest(name, names)} (registered: "
            f"{', '.join(sorted(names))}; extend with "
            f"repro.api.{register_hint})")


def check_ksp(name) -> str | None:
    """None if registered, else an actionable error message with
    close-spelling suggestions drawn from the *live* registry."""
    if name in _KSPS:
        return None
    return _unknown("ksp", name, list(_KSPS), "register_ksp")


def check_method(name) -> str | None:
    if name in _METHODS:
        return None
    return _unknown("method", name, list(_METHODS), "register_method")


def check_stop(name) -> str | None:
    if name in _STOPS:
        return None
    return _unknown("stop criterion", name, list(_STOPS),
                    "register_stop_criterion")


def get_ksp(name: str) -> KSPSpec:
    err = check_ksp(name)
    if err:
        raise ValueError(err)
    return _KSPS[name]


def get_method(name: str) -> MethodSpec:
    err = check_method(name)
    if err:
        raise ValueError(err)
    return _METHODS[name]


def get_stop(name: str) -> StopSpec:
    err = check_stop(name)
    if err:
        raise ValueError(err)
    return _STOPS[name]


def method_for_ksp(ksp: str) -> str:
    """The ``-ksp_type`` sugar: the outer method a bare KSP choice picks
    (``none`` -> ``vi``, else ``ipi_<ksp>``)."""
    if ksp == "none":
        return "vi"
    err = check_ksp(ksp)
    if err:
        raise ValueError(err)
    name = f"ipi_{ksp}"
    if name not in _METHODS:     # registered with auto_method=False
        raise ValueError(
            f"ksp {ksp!r} has no ipi_{ksp} method registered; register one "
            f"with repro.api.register_method(ksp={ksp!r}, ...) or select a "
            f"-method directly")
    return name


# --------------------------------------------------------------------------- #
# Dispatch: the inner solve and the outer stopping decision                   #
# --------------------------------------------------------------------------- #

def inner_solve(opts, matvec, b, x0, forcing_tol, axes: Axes, *,
                context: Mapping[str, Any] | None = None, precond=None):
    """Run ``opts.method``'s inner policy-evaluation solve.

    Returns ``(x, iters, resnorm)``.  ``forcing_tol`` is the iPI forcing
    term ``eta * ||T v - v||_inf`` (already floored); the method's inner
    policy decides whether it, a fixed sweep count, or a tight absolute
    tolerance bounds the KSP.  ``precond`` (the ``-pc_type`` apply for the
    current policy's system) is forwarded to KSPs that declared
    ``preconditioned=True``.
    """
    spec = get_method(opts.method)
    if spec.ksp is None:
        return x0, jnp.int32(0), jnp.float32(jnp.inf)
    ksp = get_ksp(spec.ksp)
    if spec.inner == "sweeps":
        tol, maxiter = jnp.float32(0.0), max(opts.mpi_sweeps - 1, 0)
    elif spec.inner == "tight":
        tol, maxiter = jnp.float32(opts.atol) * 0.01, opts.max_inner
    else:
        tol, maxiter = forcing_tol, opts.max_inner
    return ksp.call(matvec, b, x0, tol=tol, maxiter=maxiter, axes=axes,
                    opts=opts, context=dict(context or {}),
                    precond=precond if ksp.preconditioned else None)


def stop_done(opts, *, res, span, res0, k, gamma) -> jax.Array:
    """Evaluate ``opts.stop_criterion`` -> boolean "converged" (elementwise
    over fleet lanes).  NaN residuals never converge."""
    spec = get_stop(opts.stop_criterion)
    m = StopMetrics(res=res, span=span, res0=res0, k=k, gamma=gamma,
                    atol=opts.atol, rtol=opts.rtol)
    return jnp.asarray(spec.fn(m)) & ~jnp.isnan(res)


_ADHOC_STOPS: dict[int, str] = {}
_ADHOC_SEQ = itertools.count()


_ADHOC_LIMIT = 64


def adhoc_stop_criterion(fn: Callable[[StopMetrics], jax.Array], *,
                         needs_span: bool = True) -> str:
    """Register (once) an anonymous user predicate and return its registry
    name — how ``Session.solve(stop_criterion=callable)`` threads a traced
    predicate through the string-keyed options/jit machinery.

    The same callable maps to the same name (and therefore the same
    compiled program), so pass a *stable* function reference when solving
    in a loop — a fresh inline lambda per call gets a fresh name and a
    fresh compile.  Names are monotonic and never recycled onto different
    code; the table is bounded (oldest entries beyond ``_ADHOC_LIMIT`` are
    evicted, their compiled programs simply go cold).  ``needs_span``
    defaults to True so a predicate reading ``m.span`` sees real values
    (named registration via :func:`register_stop_criterion` opts out)."""
    key = id(fn)
    name = _ADHOC_STOPS.get(key)
    if name is not None and _STOPS.get(name) is not None \
            and _STOPS[name].fn is fn:
        return name
    while len(_ADHOC_STOPS) >= _ADHOC_LIMIT:
        old_key, old_name = next(iter(_ADHOC_STOPS.items()))
        del _ADHOC_STOPS[old_key]
        _STOPS.pop(old_name, None)
    name = f"custom_{next(_ADHOC_SEQ)}"
    register_stop_criterion(name, fn, needs_span=needs_span,
                            doc="ad-hoc user predicate")
    _ADHOC_STOPS[key] = name
    return name


# --------------------------------------------------------------------------- #
# Monitor dispatch (host side of the in-loop observability API)              #
# --------------------------------------------------------------------------- #

_MONITORS: dict[int, tuple[Callable, float, int | None]] = {}
_MONITOR_SEQ = itertools.count(1)        # 0 is reserved: "no monitor"


def monitor_handle(fn: Callable[[dict], None], *,
                   trim: int | None = None) -> int:
    """Activate a monitor callable; returns the integer id the compiled
    loop streams records to (pass it as the traced ``mon_id``).  ``trim``
    truncates fleet vectors to the true instance count (mesh padding)."""
    mid = next(_MONITOR_SEQ)
    _MONITORS[mid] = (fn, time.perf_counter(), trim)
    return mid


def monitor_release(mid: int) -> None:
    _MONITORS.pop(mid, None)


def _record(mid_entry, k, res, inner, diverged=False) -> dict:
    fn, t0, trim = mid_entry
    res = np.asarray(res)
    inner = np.asarray(inner)
    div = np.asarray(diverged)
    if res.ndim:                           # batched fleet: per-instance rows
        if div.ndim == 0:
            div = np.broadcast_to(div, res.shape)
        if trim is not None:
            res, inner, div = res[:trim], inner[:trim], div[:trim]
        return dict(k=int(np.max(k)), res=[float(x) for x in res],
                    inner=[int(x) for x in inner],
                    diverged=[bool(x) for x in div],
                    elapsed=time.perf_counter() - t0)
    return dict(k=int(k), res=float(res), inner=int(inner),
                diverged=bool(div), elapsed=time.perf_counter() - t0)


def _monitor_cb(mid, lead, k, res, inner, diverged=False) -> None:
    try:
        if not bool(lead):
            return                         # non-lead shard: drop (the record
        #                                    is replicated device-side)
        entry = _MONITORS.get(int(mid))
        if entry is None:
            return
        entry[0](_record(entry, k, res, inner, diverged))
    except Exception as e:  # noqa: BLE001 — a monitor bug must not kill the
        print(f"[monitor] callback error (record dropped): "  # compiled solve
              f"{type(e).__name__}: {e}")


def emit_monitor(mon_id, lead, k, res, inner, diverged=False) -> None:
    """Device-side: stream one per-iteration record to the active monitor.

    One fixed trampoline for every monitor (``mon_id`` is traced data), so
    compiled programs are monitor-agnostic and cache across solves.
    Unordered callback: records arrive in program order on synchronous
    backends (CPU), but an async accelerator may deliver them out of order —
    consumers needing strict order should sort by ``k`` (``Session.stats``
    does; each record carries its ``k``).  ``diverged`` (bool, elementwise
    for fleets) flags lanes whose residual blew past ``-divtol`` or went
    NaN — the adaptive supervisor's trigger signal."""
    jax.debug.callback(_monitor_cb, mon_id, lead, k, res, inner, diverged)


def emit_host(mid: int, k, res, inner, diverged=False) -> None:
    """Host-side record emission (the k=0 / resume record, outside jit);
    same never-kill-the-solve guard as the device trampoline."""
    _monitor_cb(mid, True, k, res, inner, diverged)


def print_monitor(rec: dict) -> None:
    """The default ``-monitor`` sink (PETSc ``-ksp_monitor`` style lines)."""
    if isinstance(rec["res"], list):
        res = rec["res"]
        div = rec.get("diverged") or []
        flag = f" DIVERGED={sum(bool(d) for d in div)}" if any(div) else ""
        print(f"[monitor] k={rec['k']} res_max={max(res):.6e} "
              f"inner={sum(rec['inner'])} B={len(res)} "
              f"elapsed={rec['elapsed']:.3f}s{flag}", flush=True)
    else:
        flag = " DIVERGED" if rec.get("diverged") else ""
        print(f"[monitor] k={rec['k']} res={rec['res']:.6e} "
              f"inner={rec['inner']} elapsed={rec['elapsed']:.3f}s{flag}",
              flush=True)


# --------------------------------------------------------------------------- #
# Builtins                                                                    #
# --------------------------------------------------------------------------- #

register_ksp(
    "richardson",
    lambda mv, b, x0, *, tol, maxiter, axes, opts=None:
        richardson(mv, b, x0, tol=tol, maxiter=maxiter, axes=axes,
                   omega=opts.omega if opts is not None else 1.0),
    doc="(damped) Richardson iteration == repeated T_pi sweeps",
    deterministic=True, auto_method=False, _builtin=True)

register_ksp(
    "gmres",
    lambda mv, b, x0, *, tol, maxiter, axes, opts=None, precond=None:
        gmres(mv, b, x0, tol=tol, maxiter=maxiter, axes=axes,
              restart=opts.restart if opts is not None else 32,
              deterministic=bool(opts.deterministic_dots) if opts is not None
              else False, precond=precond),
    doc="restarted GMRES (CGS2 + Givens) — the iGMRES-PI inner solver",
    deterministic=True, auto_method=False, preconditioned=True,
    _builtin=True)

register_ksp(
    "bicgstab",
    lambda mv, b, x0, *, tol, maxiter, axes, precond=None:
        bicgstab(mv, b, x0, tol=tol, maxiter=maxiter, axes=axes,
                 precond=precond),
    doc="BiCGStab — O(1)-memory Krylov alternative",
    deterministic=False, auto_method=False, preconditioned=True,
    _builtin=True)

register_ksp(
    "chebyshev",
    lambda mv, b, x0, *, tol, maxiter, axes, context=None:
        chebyshev(mv, b, x0, tol=tol, maxiter=maxiter, axes=axes,
                  lo=1.0 - (context or {}).get("gamma", 0.999),
                  hi=1.0 + (context or {}).get("gamma", 0.999)),
    doc="Chebyshev semi-iteration on [1-gamma, 1+gamma] — no inner products",
    deterministic=True, auto_method=False, _builtin=True)

register_ksp(
    "anderson",
    lambda mv, b, x0, *, tol, maxiter, axes, opts=None:
        anderson(mv, b, x0, tol=tol, maxiter=maxiter, axes=axes,
                 window=opts.anderson_window if opts is not None else 5,
                 mixing=opts.omega if opts is not None else 1.0,
                 deterministic=bool(opts.deterministic_dots)
                 if opts is not None else False),
    doc="Anderson-accelerated VI (windowed residual extrapolation)",
    deterministic=True, auto_method=False, _builtin=True)

register_method("vi", ksp=None, inner="none", safeguarded=False,
                doc="value iteration (0 inner sweeps)", _builtin=True)
register_method("mpi", ksp="richardson", inner="sweeps", safeguarded=False,
                doc="modified policy iteration (mpi_sweeps fixed sweeps)",
                _builtin=True)
register_method("ipi_richardson", ksp="richardson", inner="forcing",
                safeguarded=False,
                doc="iPI + Richardson to the forcing tolerance",
                _builtin=True)
register_method("ipi_gmres", ksp="gmres", inner="forcing", safeguarded=True,
                doc="iPI + restarted GMRES (the paper's iGMRES-PI)",
                _builtin=True)
register_method("ipi_bicgstab", ksp="bicgstab", inner="forcing",
                safeguarded=True, doc="iPI + BiCGStab", _builtin=True)
register_method("pi", ksp="gmres", inner="tight", safeguarded=True,
                doc="(near-)exact policy iteration (GMRES at 0.01 * atol)",
                _builtin=True)
register_method("ipi_chebyshev", ksp="chebyshev", inner="forcing",
                safeguarded=True,
                doc="iPI + Chebyshev semi-iteration (collective-free inner)",
                _builtin=True)
register_method("ipi_anderson", ksp="anderson", inner="forcing",
                safeguarded=True, doc="iPI + Anderson-accelerated VI",
                _builtin=True)
register_method("async_vi", ksp=None, inner="none", safeguarded=False,
                outer=async_vi_outer,
                doc="asynchronous VI: async_sweeps stale local sweeps per "
                    "value exchange (span-certified)",
                _builtin=True)
register_method("auto", ksp=None, inner="none", safeguarded=False,
                virtual=True,
                doc="adaptive: probe the instance, then pick method / stop "
                    "criterion / preconditioner (repro.adaptive)",
                _builtin=True)


@register_stop_criterion("atol", _builtin=True)
def _stop_atol(m: StopMetrics):
    """sup-norm residual: ||T v - v||_inf <= atol."""
    return m.res <= m.atol


@register_stop_criterion("rtol", _builtin=True)
def _stop_rtol(m: StopMetrics):
    """relative residual: ||T v - v||_inf <= rtol * (initial residual)."""
    return m.res <= m.rtol * m.res0


@register_stop_criterion("probe", needs_span=True, _builtin=True)
def _stop_probe(m: StopMetrics):
    """adaptive probe phase: never stop early — fixed-length residual traces.

    Running exactly ``-probe_iters`` outers keeps traces comparable across
    instances.  Padded dummy fleet lanes carry ``res == 0`` and do stop;
    span is recorded so the probe can read the span-vs-residual ratio."""
    return m.res <= 0.0


@register_stop_criterion("span", needs_span=True, _builtin=True)
def _stop_span(m: StopMetrics):
    """span seminorm: sp(T v - v) = max - min <= atol.

    Once the Bellman residual vector is nearly constant (long-mixing chains
    reach that regime geometrically at the *mixing* rate, far faster than
    the gamma-rate sup-norm decay) the greedy policy has stabilized: after
    the standard midpoint correction the value error is bounded by
    gamma * sp / (2 * (1 - gamma)), so span stopping certifies VI in far
    fewer outer iterations than ``atol`` at matched certificate scale."""
    return m.span <= m.atol
