"""Offline MDP storage (madupite's load-from-file mode).

Format: one ``.npz`` per state-block (ELL fields) + a JSON manifest holding
the global shape, discount and block table — the moral equivalent of PETSc
binary matrices.  Blocks can be written/read independently (each rank loads
only its rows)."""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core.mdp import EllMDP


def save_mdp(path: str, mdp: EllMDP, n_blocks: int = 1,
             mode: str | None = None) -> None:
    """``mode`` optionally records the solve semantics ("mincost" /
    "maxreward") in the manifest, so ``repro.api.MDP.from_file`` restores
    the full builder state."""
    os.makedirs(path, exist_ok=True)
    n = mdp.n_global
    idx, val, cost = (np.asarray(mdp.idx), np.asarray(mdp.val),
                      np.asarray(mdp.cost))
    assert idx.shape[0] == n, "save_mdp expects the full MDP"
    bounds = np.linspace(0, n, n_blocks + 1, dtype=int)
    blocks = []
    for b in range(n_blocks):
        lo, hi = int(bounds[b]), int(bounds[b + 1])
        np.savez(os.path.join(path, f"block_{b:05d}.npz"),
                 idx=idx[lo:hi], val=val[lo:hi], cost=cost[lo:hi])
        blocks.append(dict(block=b, row_lo=lo, row_hi=hi))
    manifest = dict(n=int(n), m=int(mdp.m_global), k=int(mdp.nnz_per_row),
                    gamma=float(mdp.gamma), n_blocks=n_blocks, blocks=blocks)
    if mode is not None:
        manifest["mode"] = mode
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_manifest(path: str) -> dict:
    """The manifest (global shape / gamma / mode / block table) alone —
    cheap metadata reads without touching the blocks."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def load_mdp(path: str, rows: tuple[int, int] | None = None) -> EllMDP:
    """Load the full MDP or just the ``rows=(lo, hi)`` slice (block-aligned
    reads; each distributed worker calls this with its own range)."""
    import jax.numpy as jnp
    man = load_manifest(path)
    lo, hi = rows or (0, man["n"])
    parts = []
    for blk in man["blocks"]:
        if blk["row_hi"] <= lo or blk["row_lo"] >= hi:
            continue
        with np.load(os.path.join(path, f"block_{blk['block']:05d}.npz")) as z:
            s = slice(max(lo - blk["row_lo"], 0),
                      min(hi, blk["row_hi"]) - blk["row_lo"])
            parts.append((z["idx"][s], z["val"][s], z["cost"][s]))
    idx = np.concatenate([p[0] for p in parts])
    val = np.concatenate([p[1] for p in parts])
    cost = np.concatenate([p[2] for p in parts])
    return EllMDP(idx=jnp.asarray(idx), val=jnp.asarray(val),
                  cost=jnp.asarray(cost), gamma=man["gamma"],
                  n_global=man["n"], m_global=man["m"])
