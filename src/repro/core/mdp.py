"""MDP containers.

Two storage layouts, both row-partitionable by state (madupite / PETSc stores
MPIAIJ CSR rows per rank; on TPU we use layouts with static per-row shapes):

* :class:`EllMDP` — padded ELLPACK sparsity: every (state, action) row keeps
  exactly ``K`` (index, value) slots.  Padding slots carry ``val == 0`` and an
  arbitrary in-range index (we use 0), so gathers stay in bounds and the maths
  is exact.  This replaces CSR: fixed row shape == BlockSpec-tileable, and the
  gather over ``v`` vectorizes on the VPU.
* :class:`DenseMDP` — dense transition tensor ``P[(s, a), s']`` for small /
  benchmark instances; backups become MXU matmuls.

A *block* holds the locally-owned slice: ``n_local`` state rows starting at
``row_offset`` and ``m_local`` actions starting at ``act_offset``.  Successor
indices (``idx`` / the dense column dim) are always **global** state ids, as
in PETSc MPIAIJ.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllMDP:
    """Padded-ELL sparse MDP block.

    idx:  (n_local, m_local, K) int32 — global successor ids (pad: 0)
    val:  (n_local, m_local, K) f32   — transition probabilities (pad: 0)
    cost: (n_local, m_local)    f32   — stage costs g(s, a)
    """

    idx: jax.Array
    val: jax.Array
    cost: jax.Array
    gamma: float = dataclasses.field(metadata=dict(static=True))
    n_global: int = dataclasses.field(metadata=dict(static=True))
    m_global: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_local(self) -> int:
        return self.idx.shape[0]

    @property
    def m_local(self) -> int:
        return self.idx.shape[1]

    @property
    def nnz_per_row(self) -> int:
        return self.idx.shape[2]

    def validate(self) -> None:
        """Host-side sanity checks (probability rows, index ranges)."""
        idx = np.asarray(self.idx)
        val = np.asarray(self.val)
        assert idx.shape == val.shape, (idx.shape, val.shape)
        assert self.cost.shape == idx.shape[:2]
        assert idx.min() >= 0 and idx.max() < self.n_global
        rowsum = val.sum(-1)
        np.testing.assert_allclose(rowsum, 1.0, atol=1e-5)
        assert (val >= -1e-7).all()
        assert 0.0 < self.gamma < 1.0

    def as_dense(self) -> "DenseMDP":
        """Materialize the dense tensor (small instances / oracles only)."""
        n, m, k = self.idx.shape
        p = jnp.zeros((n, m, self.n_global), self.val.dtype)
        s = jnp.arange(n)[:, None, None]
        a = jnp.arange(m)[None, :, None]
        p = p.at[s, a, self.idx].add(self.val)
        return DenseMDP(p=p, cost=self.cost, gamma=self.gamma,
                        n_global=self.n_global, m_global=self.m_global)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseMDP:
    """Dense MDP block.

    p:    (n_local, m_local, n_global) f32
    cost: (n_local, m_local)           f32
    """

    p: jax.Array
    cost: jax.Array
    gamma: float = dataclasses.field(metadata=dict(static=True))
    n_global: int = dataclasses.field(metadata=dict(static=True))
    m_global: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_local(self) -> int:
        return self.p.shape[0]

    @property
    def m_local(self) -> int:
        return self.p.shape[1]

    def validate(self) -> None:
        p = np.asarray(self.p)
        np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)
        assert (p >= -1e-7).all()
        assert 0.0 < self.gamma < 1.0


MDP = EllMDP | DenseMDP
