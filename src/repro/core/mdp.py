"""MDP containers.

Two storage layouts, both row-partitionable by state (madupite / PETSc stores
MPIAIJ CSR rows per rank; on TPU we use layouts with static per-row shapes):

* :class:`EllMDP` — padded ELLPACK sparsity: every (state, action) row keeps
  exactly ``K`` (index, value) slots.  Padding slots carry ``val == 0`` and an
  arbitrary in-range index (we use 0), so gathers stay in bounds and the maths
  is exact.  This replaces CSR: fixed row shape == BlockSpec-tileable, and the
  gather over ``v`` vectorizes on the VPU.
* :class:`DenseMDP` — dense transition tensor ``P[(s, a), s']`` for small /
  benchmark instances; backups become MXU matmuls.

A *block* holds the locally-owned slice: ``n_local`` state rows starting at
``row_offset`` and ``m_local`` actions starting at ``act_offset``.  Successor
indices (``idx`` / the dense column dim) are always **global** state ids, as
in PETSc MPIAIJ.

Batched fleets
--------------
Both containers optionally carry a leading batch dimension ``B`` (a *fleet*
of same-shape MDP instances solved in one compiled program —
:func:`repro.core.driver.solve_many`).  :func:`stack_mdps` builds the batched
container from per-instance MDPs, padding heterogeneous state counts with
absorbing zero-cost states and keeping a *shared-topology fast path*: when
every instance has the same sparsity pattern (e.g. a gamma sweep or a
cost-perturbation ensemble over one graph), ``idx`` is stored once,
unbatched, and broadcast under ``vmap``.  ``gamma`` is a single float for a
homogeneous fleet or a tuple of per-instance floats (still static /
hashable); :func:`batch_parts` decomposes a batched MDP into the pieces the
solver needs to compose ``jax.vmap`` over the unbatched code path.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllMDP:
    """Padded-ELL sparse MDP block.

    idx:  (n_local, m_local, K) int32 — global successor ids (pad: 0)
    val:  (n_local, m_local, K) f32   — transition probabilities (pad: 0)
    cost: (n_local, m_local)    f32   — stage costs g(s, a)

    Batched (``B``-instance fleet): ``val`` / ``cost`` gain a leading batch
    dim; ``idx`` is either batched ``(B, n, m, K)`` or shared ``(n, m, K)``
    (same topology for every instance); ``gamma`` is a float or a length-B
    tuple of per-instance floats.
    """

    idx: jax.Array
    val: jax.Array
    cost: jax.Array
    gamma: float | tuple = dataclasses.field(metadata=dict(static=True))
    n_global: int = dataclasses.field(metadata=dict(static=True))
    m_global: int = dataclasses.field(metadata=dict(static=True))

    @property
    def batch(self) -> int | None:
        """Fleet size ``B``, or ``None`` for an unbatched instance."""
        return self.val.shape[0] if self.val.ndim == 4 else None

    @property
    def shared_topology(self) -> bool:
        """Batched with one ``idx`` shared by every instance."""
        return self.batch is not None and self.idx.ndim == 3

    @property
    def n_local(self) -> int:
        return self.val.shape[-3]

    @property
    def m_local(self) -> int:
        return self.val.shape[-2]

    @property
    def nnz_per_row(self) -> int:
        return self.idx.shape[-1]

    def instance(self, b: int) -> "EllMDP":
        """Extract (host-side) the unbatched instance ``b`` of a fleet."""
        if self.batch is None:
            raise ValueError("instance() is only defined on a batched MDP")
        return EllMDP(idx=self.idx if self.shared_topology else self.idx[b],
                      val=self.val[b], cost=self.cost[b],
                      gamma=gammas_of(self)[b], n_global=self.n_global,
                      m_global=self.m_global)

    def validate(self) -> None:
        """Host-side sanity checks (probability rows, index ranges)."""
        idx = np.asarray(self.idx)
        val = np.asarray(self.val)
        assert idx.shape[-3:] == val.shape[-3:], (idx.shape, val.shape)
        assert self.cost.shape == val.shape[:-1]
        assert idx.min() >= 0 and idx.max() < self.n_global
        rowsum = val.sum(-1)
        np.testing.assert_allclose(rowsum, 1.0, atol=1e-5)
        assert (val >= -1e-7).all()
        for g in gammas_of(self):
            assert 0.0 < g < 1.0

    def as_dense(self) -> "DenseMDP":
        """Materialize the dense tensor (small instances / oracles only)."""
        if self.batch is not None:
            raise ValueError("as_dense() is unbatched-only; use instance(b)")
        n, m, k = self.idx.shape
        p = jnp.zeros((n, m, self.n_global), self.val.dtype)
        s = jnp.arange(n)[:, None, None]
        a = jnp.arange(m)[None, :, None]
        p = p.at[s, a, self.idx].add(self.val)
        return DenseMDP(p=p, cost=self.cost, gamma=self.gamma,
                        n_global=self.n_global, m_global=self.m_global)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseMDP:
    """Dense MDP block.

    p:    (n_local, m_local, n_global) f32
    cost: (n_local, m_local)           f32

    Batched fleet: leading ``B`` dim on both arrays; ``gamma`` as in
    :class:`EllMDP`.
    """

    p: jax.Array
    cost: jax.Array
    gamma: float | tuple = dataclasses.field(metadata=dict(static=True))
    n_global: int = dataclasses.field(metadata=dict(static=True))
    m_global: int = dataclasses.field(metadata=dict(static=True))

    @property
    def batch(self) -> int | None:
        return self.p.shape[0] if self.p.ndim == 4 else None

    @property
    def shared_topology(self) -> bool:
        return False

    @property
    def n_local(self) -> int:
        return self.p.shape[-3]

    @property
    def m_local(self) -> int:
        return self.p.shape[-2]

    def instance(self, b: int) -> "DenseMDP":
        if self.batch is None:
            raise ValueError("instance() is only defined on a batched MDP")
        return DenseMDP(p=self.p[b], cost=self.cost[b],
                        gamma=gammas_of(self)[b], n_global=self.n_global,
                        m_global=self.m_global)

    def validate(self) -> None:
        p = np.asarray(self.p)
        np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)
        assert (p >= -1e-7).all()
        for g in gammas_of(self):
            assert 0.0 < g < 1.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MatrixFreeMDP:
    """Matrix-free MDP block: no stored tables, rows are rebuilt on the fly.

    The only array leaf is ``tag`` — a zero int8 vector of the local state
    extent whose *sharding* carries the placement (which rows each device
    owns); everything else is static metadata.  ``spec`` is a
    :class:`repro.kernels.matrix_free.RowSpec` holding the jit-able row
    constructors; the Bellman layer re-traces them inside every backup /
    policy-row extraction (recompute-over-store), so per-shard memory is
    O(n_local) instead of O(n_local * m * nnz).

    Batched fleet: ``tag`` gains a leading ``B`` dim.  All lanes share the
    single static ``spec`` (identical constructors and shape — the
    gamma-sweep fleet); per-lane discounts ride in the ``gamma`` tuple
    exactly as for the array containers.
    """

    tag: jax.Array
    gamma: float | tuple = dataclasses.field(metadata=dict(static=True))
    n_global: int = dataclasses.field(metadata=dict(static=True))
    m_global: int = dataclasses.field(metadata=dict(static=True))
    spec: object = dataclasses.field(metadata=dict(static=True))

    @property
    def batch(self) -> int | None:
        return self.tag.shape[0] if self.tag.ndim == 2 else None

    @property
    def shared_topology(self) -> bool:
        return False

    @property
    def n_local(self) -> int:
        return self.tag.shape[-1]

    @property
    def m_local(self) -> int:
        # matrix-free shards states only: every shard traces all actions
        return self.m_global

    @property
    def nnz_per_row(self) -> int:
        return self.spec.nnz

    @property
    def acts(self) -> tuple:
        """The static global action ids every backup covers."""
        return tuple(range(self.m_global))

    def instance(self, b: int) -> "MatrixFreeMDP":
        if self.batch is None:
            raise ValueError("instance() is only defined on a batched MDP")
        return MatrixFreeMDP(tag=self.tag[b], gamma=gammas_of(self)[b],
                             n_global=self.n_global, m_global=self.m_global,
                             spec=self.spec)

    def validate(self) -> None:
        assert self.tag.dtype == jnp.int8, self.tag.dtype
        assert self.n_global >= self.spec.n
        assert self.m_global == self.spec.m
        for g in gammas_of(self):
            assert 0.0 < g < 1.0


MDP = EllMDP | DenseMDP | MatrixFreeMDP


# --------------------------------------------------------------------------- #
# Fleet (batched multi-instance) construction                                 #
# --------------------------------------------------------------------------- #

def gammas_of(mdp: MDP) -> tuple:
    """Per-instance discount factors as a tuple (length B, or 1 unbatched)."""
    if isinstance(mdp.gamma, tuple):
        return mdp.gamma
    return (mdp.gamma,) * (mdp.batch or 1)


def stack_mdps(mdps: Sequence[MDP]) -> MDP:
    """Stack per-instance MDPs into one batched fleet container.

    All instances must share the container type, action count and (for ELL)
    nnz/row; heterogeneous ELL state counts are padded to the max with
    absorbing zero-cost states (trim results with the per-instance
    ``n_global`` you kept).  When every instance shares the sparsity pattern
    the single ``idx`` is stored unbatched (shared-topology fast path: one
    gather table, broadcast under ``vmap``).  Heterogeneous ``gamma`` is kept
    as a static per-instance tuple.
    """
    mdps = list(mdps)
    if not mdps:
        raise ValueError("stack_mdps needs at least one MDP")
    first = mdps[0]
    if any(type(m) is not type(first) for m in mdps):
        raise ValueError("stack_mdps: all instances must share one container "
                         f"type, got {sorted({type(m).__name__ for m in mdps})}")
    if any(m.batch is not None for m in mdps):
        raise ValueError("stack_mdps takes unbatched instances")
    if any(m.m_global != first.m_global for m in mdps):
        raise ValueError("stack_mdps: action counts differ "
                         f"({[m.m_global for m in mdps]}); pad actions first")
    gammas = tuple(float(m.gamma) for m in mdps)
    gamma = gammas[0] if len(set(gammas)) == 1 else gammas
    if isinstance(first, MatrixFreeMDP):
        # one static spec per batched container: lanes must share the
        # constructors and shape (the gamma-sweep fleet); anything else
        # would need per-lane re-tracing inside one compiled program
        if any(m.spec != first.spec or m.n_global != first.n_global
               for m in mdps):
            raise ValueError(
                "stack_mdps(MatrixFreeMDP): all lanes must share one row "
                "spec (identical P_fn/g_fn and n/m/nnz — gamma may "
                "differ); heterogeneous matrix-free fleets must be "
                "materialized (-mdp_materialize device) or solved "
                "separately")
        return MatrixFreeMDP(
            tag=jnp.zeros((len(mdps), first.n_global), jnp.int8),
            gamma=gamma, n_global=first.n_global,
            m_global=first.m_global, spec=first.spec)
    if isinstance(first, DenseMDP):
        if any(m.n_global != first.n_global for m in mdps):
            raise ValueError("stack_mdps(DenseMDP): state counts must match")
        return DenseMDP(p=jnp.stack([m.p for m in mdps]),
                        cost=jnp.stack([m.cost for m in mdps]),
                        gamma=gamma, n_global=first.n_global,
                        m_global=first.m_global)
    if any(m.nnz_per_row != first.nnz_per_row for m in mdps):
        raise ValueError("stack_mdps(EllMDP): nnz/row differ "
                         f"({[m.nnz_per_row for m in mdps]})")
    n_to = max(m.n_global for m in mdps)
    # one bulk device->host transfer for every lane, pad + stack in numpy,
    # one upload per field: per-lane device_get/jnp.stack round-trips make
    # host sync latency scale with B, which dominates warm serving dispatch
    host = jax.device_get([(m.idx, m.val, m.cost) for m in mdps])
    k, m_g = first.nnz_per_row, first.m_global
    idxs, vals, costs = [], [], []
    for m, (hi, hv, hc) in zip(mdps, host):
        hi, hv, hc = np.asarray(hi), np.asarray(hv), np.asarray(hc)
        if m.n_global < n_to:
            # absorbing zero-cost self-loops, exactly pad_mdp's state
            # padding (value identically 0, unreachable from real states)
            n_pad = n_to - m.n_global
            pad_idx = np.zeros((n_pad, m_g, k), hi.dtype)
            pad_idx[..., 0] = np.arange(m.n_global, n_to,
                                        dtype=hi.dtype)[:, None]
            pad_val = np.zeros((n_pad, m_g, k), hv.dtype)
            pad_val[..., 0] = 1.0
            hi = np.concatenate([hi, pad_idx])
            hv = np.concatenate([hv, pad_val])
            hc = np.concatenate([hc, np.zeros((n_pad, m_g), hc.dtype)])
        idxs.append(hi)
        vals.append(hv)
        costs.append(hc)
    shared = all(np.array_equal(i, idxs[0]) for i in idxs[1:])
    idx = jnp.asarray(idxs[0]) if shared else jnp.asarray(np.stack(idxs))
    return EllMDP(idx=idx, val=jnp.asarray(np.stack(vals)),
                  cost=jnp.asarray(np.stack(costs)),
                  gamma=gamma, n_global=n_to, m_global=first.m_global)


def batch_parts(mdp: MDP):
    """Decompose a batched MDP for ``jax.vmap`` over the unbatched solver.

    Returns ``(view, in_axes, gamma_t)``:

    * ``view``    — the same arrays with ``gamma`` collapsed to one static
      float (``1.0`` when per-instance gammas differ: the caller then applies
      ``gamma_t`` by scaling the gathered value window, which is algebraically
      exact because gamma only ever multiplies ``P v`` terms);
    * ``in_axes`` — a matching pytree of vmap axes (0 for batched leaves,
      ``None`` for a shared-topology ``idx``);
    * ``gamma_t`` — ``(B,)`` per-instance discount array, or ``None`` for a
      homogeneous fleet (which then runs the bit-identical static-gamma
      arithmetic of the unbatched path).
    """
    if mdp.batch is None:
        raise ValueError("batch_parts() requires a batched MDP")
    het = isinstance(mdp.gamma, tuple) and len(set(mdp.gamma)) > 1
    gamma_static = 1.0 if het else float(gammas_of(mdp)[0])
    gamma_t = jnp.asarray(np.asarray(mdp.gamma)) if het else None
    view = dataclasses.replace(mdp, gamma=gamma_static)
    if isinstance(mdp, EllMDP):
        in_axes = dataclasses.replace(
            view, idx=None if mdp.shared_topology else 0, val=0, cost=0)
    elif isinstance(mdp, MatrixFreeMDP):
        in_axes = dataclasses.replace(view, tag=0)
    else:
        in_axes = dataclasses.replace(view, p=0, cost=0)
    return view, in_axes, gamma_t
