"""BiCGStab (van der Vorst) — short-recurrence Krylov inner solver.

madupite exposes PETSc's full KSP catalogue; BiCGStab is the other workhorse
for the nonsymmetric system ``(I - gamma P_pi) x = g_pi``: two matvecs per
iteration but O(1) memory (no stored basis), which matters when the Arnoldi
basis of GMRES would not fit (very large state shards).  All inner products
are distributed via ``axes.dot`` (psum over the state axis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.comm import Axes

_EPS = 1e-30


def bicgstab(matvec, b: jax.Array, x0: jax.Array, *, tol, maxiter: int,
             axes: Axes, precond=None):
    """Returns ``(x, iters, ||b - A x||_2)``.

    ``precond`` is an optional right preconditioner apply ``x -> M x``
    (``M ~= A^-1``); the recurrences below keep ``r`` the TRUE residual
    ``b - A x``, so stopping semantics are unchanged.  ``None`` keeps the
    plain path bit-for-bit.
    """
    M = precond if precond is not None else (lambda v: v)
    r0 = b - matvec(x0)
    rhat = r0
    res0 = axes.norm2(r0)
    zeros = jnp.zeros_like(x0)
    one = jnp.ones((), x0.dtype)

    # state: x, r, p, v, rho, alpha, omega, res, it, breakdown
    init = (x0, r0, zeros, zeros, one, one, one, res0, jnp.int32(0),
            jnp.bool_(False))

    def cond(s):
        *_, res, it, breakdown = s
        return (res > tol) & (it < maxiter) & (~breakdown)

    def body(s):
        x, r, p, v, rho, alpha, omega, res, it, _ = s
        rho_new = axes.dot(rhat, r)
        breakdown = (jnp.abs(rho_new) < _EPS) | (jnp.abs(omega) < _EPS)
        beta = (rho_new / jnp.where(jnp.abs(rho) < _EPS, _EPS, rho)) * \
               (alpha / jnp.where(jnp.abs(omega) < _EPS, _EPS, omega))
        p = r + beta * (p - omega * v)
        phat = M(p)
        v = matvec(phat)
        denom = axes.dot(rhat, v)
        breakdown |= jnp.abs(denom) < _EPS
        alpha = rho_new / jnp.where(jnp.abs(denom) < _EPS, _EPS, denom)
        sres = r - alpha * v
        shat = M(sres)
        t = matvec(shat)
        tt = axes.dot(t, t)
        omega = axes.dot(t, sres) / jnp.where(tt < _EPS, _EPS, tt)
        x = x + alpha * phat + omega * shat
        r = sres - omega * t
        if precond is None:
            res = axes.norm2(r)
        else:
            # the recurrence residual drifts from the truth when M is
            # ill-conditioned (||M|| ~ 1/(1-gamma) amplifies the rounding
            # of the x update); stop on the measured residual so the iPI
            # safeguard never sees a falsely-converged candidate
            res = axes.norm2(b - matvec(x))
        return x, r, p, v, rho_new, alpha, omega, res, it + 1, breakdown

    x, r, *_, res, iters, _ = jax.lax.while_loop(cond, body, init)
    return x, iters, res
