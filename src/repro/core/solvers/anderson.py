"""Anderson-accelerated value iteration as an inner linear solver.

Plain Richardson on ``(I - gamma P_pi) x = g_pi`` is exactly repeated
application of the policy-restricted Bellman operator ``T_pi`` (see
:mod:`repro.core.solvers.richardson`).  Anderson acceleration (AA) keeps a
sliding window of the last ``m`` iterate/residual differences and replaces
each fixed-point step with the extrapolation that minimizes the linearized
residual over their span — on linear problems AA(m) is equivalent to a
truncated GMRES restarted implicitly every step (Walker & Ni 2011), but
with O(m) memory and two small collectives per iteration instead of a
stored Arnoldi basis.  This is the "Anderson VI" family of accelerated
dynamic-programming methods, exposed here madupite-style as just another
registered inner solver.

Distribution: the window Gram matrix ``DF DF^T`` (m x m) and projection
``DF r`` (m,) are computed shard-locally and ``psum``-reduced over the
state axis — two collectives per iteration, like CGS2 GMRES.  The tiny
regularized m x m solve is replicated on every device, exactly like the
GMRES Hessenberg solve.

The history buffers start at zero, which makes the first iteration a pure
(damped) Richardson step with no special-casing: zero rows contribute zero
Gram rows and a zero right-hand side, so their mixing coefficients vanish
through the Tikhonov term.

``deterministic=True`` composes every reduction the way
:mod:`repro.core.solvers.gmres` does in deterministic mode: the Gram matrix
and projection are lane-at-a-time ``lax.map``s of fixed-shape reductions,
the extrapolation combine is an ordered AXPY loop, and the tiny regularized
``m x m`` solve is a fixed-order (pivot-free) Gaussian elimination instead
of ``jnp.linalg.solve`` — no dot-general or LAPACK call whose tiling could
depend on the vmapped fleet width — so a fleet-sharded Anderson solve is
bit-identical to the replicated layout at equal state-shard count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.comm import Axes

_TINY = 1e-30


def _det_gram(axes: Axes, df):
    """``DF DF^T`` one (i, j) lane at a time: every entry is the same
    fixed-shape elementwise-multiply + reduce regardless of fleet width."""
    return axes.psum_state(
        jax.lax.map(lambda di: jax.lax.map(lambda dj: jnp.sum(di * dj), df),
                    df))


def _det_rhs(axes: Axes, df, r):
    """``DF r`` as a lane-at-a-time map of fixed-shape reductions."""
    return axes.psum_state(jax.lax.map(lambda di: jnp.sum(di * r), df))


def _det_combine(w, dx, df, beta):
    """``(DX + beta DF)^T w`` as an ordered AXPY loop (fixed slot order)."""
    return jax.lax.fori_loop(
        0, dx.shape[0],
        lambda j, acc: acc + w[j] * (dx[j] + beta * df[j]),
        jnp.zeros_like(dx[0]))


def _det_solve(A, rhs):
    """Fixed-order Gaussian elimination + back-substitution.

    No pivoting: ``A`` is the Tikhonov-regularized window Gram matrix (SPD
    with a strictly positive diagonal), so the pivot is never zero.  The
    fixed elimination/substitution order replaces the batched LAPACK path of
    ``jnp.linalg.solve``, whose algorithm choice may differ under vmap.
    """
    m = A.shape[0]

    def elim(i, state):
        A, b = state
        f = (A[:, i] / A[i, i]) * (jnp.arange(m) > i).astype(A.dtype)
        return A - f[:, None] * A[i][None, :], b - f * b[i]

    A, b = jax.lax.fori_loop(0, m, elim, (A, rhs))

    def back(t, y):
        j = m - 1 - t
        # y[k] == 0 for k <= j (not yet assigned), so the full-row reduce
        # only picks up the k > j terms back-substitution needs.
        return y.at[j].set((b[j] - jnp.sum(A[j] * y)) / A[j, j])

    return jax.lax.fori_loop(0, m, back, jnp.zeros_like(rhs))


def anderson(matvec, b: jax.Array, x0: jax.Array, *, tol, maxiter: int,
             axes: Axes, window: int = 5, mixing: float = 1.0,
             reg: float = 1e-10, deterministic: bool = False):
    """Returns ``(x, iters, ||b - A x||_inf)``.

    ``window`` is the AA depth ``m`` (memory: two ``(m, n_local)``
    buffers); ``mixing`` is the damped-Richardson mixing parameter beta
    (the registry wrapper maps ``-omega`` onto it, like Richardson's
    damping); ``reg`` scales the relative Tikhonov term on the window
    Gram matrix.  ``deterministic`` pins every accumulation order (see the
    module docstring) so fleet-sharded and replicated solves are bit-equal.
    """
    dt = x0.dtype
    m = int(window)
    beta = jnp.asarray(mixing, dt)
    r0 = b - matvec(x0)
    n0 = axes.norm_inf(r0)
    dx = jnp.zeros((m,) + x0.shape, dt)
    df = jnp.zeros((m,) + x0.shape, dt)
    eye = jnp.eye(m, dtype=dt)

    def cond(s):
        _, _, _, _, res, it = s
        return (res > tol) & (it < maxiter)

    def body(s):
        x, r, dx, df, _, it = s
        if deterministic:
            gram = _det_gram(axes, df)                       # (m, m)
            rhs = _det_rhs(axes, df, r)                      # (m,)
        else:
            gram = axes.psum_state(df @ df.T)                # (m, m)
            rhs = axes.psum_state(df @ r)                    # (m,)
        lam = reg * (jnp.trace(gram) / m) + jnp.asarray(_TINY, dt)
        if deterministic:
            gamma = _det_solve(gram + lam * eye, rhs)
            x_new = x + beta * r - _det_combine(gamma, dx, df, beta)
        else:
            gamma = jnp.linalg.solve(gram + lam * eye, rhs)
            x_new = x + beta * r - (dx + beta * df).T @ gamma
        r_new = b - matvec(x_new)
        slot = it % m
        dx = dx.at[slot].set(x_new - x)
        df = df.at[slot].set(r_new - r)
        return x_new, r_new, dx, df, axes.norm_inf(r_new), it + 1

    x, _, _, _, res, iters = jax.lax.while_loop(
        cond, body, (x0, r0, dx, df, n0, jnp.int32(0)))
    return x, iters, res
