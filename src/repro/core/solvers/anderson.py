"""Anderson-accelerated value iteration as an inner linear solver.

Plain Richardson on ``(I - gamma P_pi) x = g_pi`` is exactly repeated
application of the policy-restricted Bellman operator ``T_pi`` (see
:mod:`repro.core.solvers.richardson`).  Anderson acceleration (AA) keeps a
sliding window of the last ``m`` iterate/residual differences and replaces
each fixed-point step with the extrapolation that minimizes the linearized
residual over their span — on linear problems AA(m) is equivalent to a
truncated GMRES restarted implicitly every step (Walker & Ni 2011), but
with O(m) memory and two small collectives per iteration instead of a
stored Arnoldi basis.  This is the "Anderson VI" family of accelerated
dynamic-programming methods, exposed here madupite-style as just another
registered inner solver.

Distribution: the window Gram matrix ``DF DF^T`` (m x m) and projection
``DF r`` (m,) are computed shard-locally and ``psum``-reduced over the
state axis — two collectives per iteration, like CGS2 GMRES.  The tiny
regularized m x m solve is replicated on every device, exactly like the
GMRES Hessenberg solve.

The history buffers start at zero, which makes the first iteration a pure
(damped) Richardson step with no special-casing: zero rows contribute zero
Gram rows and a zero right-hand side, so their mixing coefficients vanish
through the Tikhonov term.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.comm import Axes

_TINY = 1e-30


def anderson(matvec, b: jax.Array, x0: jax.Array, *, tol, maxiter: int,
             axes: Axes, window: int = 5, mixing: float = 1.0,
             reg: float = 1e-10):
    """Returns ``(x, iters, ||b - A x||_inf)``.

    ``window`` is the AA depth ``m`` (memory: two ``(m, n_local)``
    buffers); ``mixing`` is the damped-Richardson mixing parameter beta
    (the registry wrapper maps ``-omega`` onto it, like Richardson's
    damping); ``reg`` scales the relative Tikhonov term on the window
    Gram matrix.
    """
    dt = x0.dtype
    m = int(window)
    beta = jnp.asarray(mixing, dt)
    r0 = b - matvec(x0)
    n0 = axes.norm_inf(r0)
    dx = jnp.zeros((m,) + x0.shape, dt)
    df = jnp.zeros((m,) + x0.shape, dt)
    eye = jnp.eye(m, dtype=dt)

    def cond(s):
        _, _, _, _, res, it = s
        return (res > tol) & (it < maxiter)

    def body(s):
        x, r, dx, df, _, it = s
        gram = axes.psum_state(df @ df.T)                    # (m, m)
        rhs = axes.psum_state(df @ r)                        # (m,)
        lam = reg * (jnp.trace(gram) / m) + jnp.asarray(_TINY, dt)
        gamma = jnp.linalg.solve(gram + lam * eye, rhs)
        x_new = x + beta * r - (dx + beta * df).T @ gamma
        r_new = b - matvec(x_new)
        slot = it % m
        dx = dx.at[slot].set(x_new - x)
        df = df.at[slot].set(r_new - r)
        return x_new, r_new, dx, df, axes.norm_inf(r_new), it + 1

    x, _, _, _, res, iters = jax.lax.while_loop(
        cond, body, (x0, r0, dx, df, n0, jnp.int32(0)))
    return x, iters, res
