"""Inner solvers for the inexact policy-evaluation step.

Each solver approximately solves ``A_pi x = g_pi`` with
``A_pi = I - gamma * P_pi`` given as a distributed matvec closure, and has
the uniform signature::

    x, iters, resnorm = solve(matvec, b, x0, tol=..., maxiter=..., axes=...)

``tol`` is an *absolute* residual tolerance (the iPI forcing term);
``iters`` is the number of matvec-bearing iterations actually executed.
All solvers are ``lax`` control flow (jit / shard_map safe); distributed
reductions go through :class:`repro.core.comm.Axes`.
"""

from repro.core.solvers.richardson import richardson
from repro.core.solvers.gmres import gmres
from repro.core.solvers.bicgstab import bicgstab
from repro.core.solvers.chebyshev import chebyshev
from repro.core.solvers.anderson import anderson
from repro.core.solvers.async_vi import async_vi_outer
from repro.core.solvers.direct import dense_policy_value
from repro.core.solvers.precond import PC_TYPES, build_precond

__all__ = ["PC_TYPES", "anderson", "async_vi_outer", "bicgstab",
           "build_precond", "chebyshev", "dense_policy_value", "gmres",
           "richardson"]
