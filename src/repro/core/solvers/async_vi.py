"""Asynchronous value iteration — shards run ahead between value exchanges.

The bulk-synchronous methods pay one global value-vector movement (all-gather
or halo exchange) per Bellman backup.  Asynchronous VI (Bertsekas & Tsitsiklis
style) relaxes that: each shard runs ``opts.async_sweeps`` local Bellman
sweeps against a *stale* window — the last exchanged value vector, with only
its own block kept fresh — and exchanges values once per outer iteration.
Per outer iteration the communication volume is that of plain VI while the
value-improvement work is ``async_sweeps`` backups.

Convergence stays certified: the residual/span handed to the stop criterion
is always computed from the *synchronous* backup at the exchange point
(fresh window everywhere), so the span-seminorm gap certificate
``gamma * sp(Tv - v) / (2 (1 - gamma))`` holds exactly as for synchronous
VI — the stale sweeps only change which iterate the certificate is evaluated
at, never the certificate itself.  Stale sweeps use genuine earlier iterates
(the classic total-asynchronism convergence condition), so the intermediate
values are legitimate async-VI iterates.

The stale window lives in ``SolveState.win`` with the invariant
``win == gather_v(v)`` at every outer-iteration boundary, so checkpoints and
monitors work unchanged (a restored checkpoint re-enters with a zero window,
i.e. the k=0 iterate — a valid, if maximally stale, async start).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bellman


def async_vi_outer(mdp, state, opts, axes, gamma_t):
    """One async-VI outer iteration.

    The :data:`repro.core.methods.MethodSpec.outer` contract: called by
    :func:`repro.core.ipi._outer_core` in place of the inner-solve/backup
    core, returns ``(v1, tv1, pi1, res1, inner_iters, win1)`` (span and stop
    bookkeeping stay in the shared outer-step code).  ``state.tv`` is
    already one synchronous backup ahead, so ``async_sweeps - 1`` stale
    sweeps + the certifying synchronous backup give ``async_sweeps`` Bellman
    updates per value exchange; ``async_sweeps=1`` is exactly ``vi``.
    """
    dt = state.v.dtype
    halo = opts.halo
    # own block's offset in the window: [start-halo, stop+halo) layout puts
    # it at `halo`; the full gathered vector at this shard's row start
    off = jnp.int32(halo) if halo else axes.state_index() * mdp.n_local

    def sweep(_, v_loc):
        w = jax.lax.dynamic_update_slice(state.win, v_loc, (off,))
        tv, _ = bellman.backup(mdp, w, axes, impl=opts.impl, halo=halo,
                               gamma_t=gamma_t, mode=opts.mode)
        return tv.astype(dt)

    v1 = jax.lax.fori_loop(0, opts.async_sweeps - 1, sweep, state.tv)
    tv1, pi1, win1 = bellman.gather_backup(
        mdp, v1, axes, plan=opts.overlap_plan, impl=opts.impl, halo=halo,
        gamma_t=gamma_t, mode=opts.mode)
    tv1 = tv1.astype(dt)
    res1 = axes.pmax_state(jnp.max(jnp.abs(tv1 - v1)))
    return v1, tv1, pi1, res1, jnp.int32(opts.async_sweeps - 1), \
        win1.astype(dt)
