"""Restarted GMRES with CGS2 orthogonalization and Givens rotations.

This is the inner solver behind madupite's iGMRES-PI method (Gargiani et al.
2023): for stiff / weakly-diagonally-dominant ``I - gamma P_pi`` (gamma -> 1,
long mixing chains) Krylov acceleration beats Richardson sweeps by orders of
magnitude in iteration count.

Distribution notes (the PETSc-KSP -> JAX adaptation):
  * basis vectors are state-sharded rows; every inner product is a
    ``psum`` over the state axis (``axes.dot``);
  * orthogonalization is classical Gram-Schmidt with one re-orthogonalization
    pass (CGS2).  Unlike MGS, CGS2 needs only two ``(j, n_local) @ (n_local,)``
    matmuls per Arnoldi step -> two collectives instead of ``j`` of them, and
    the matmuls batch nicely on the MXU.  CGS2 is as stable as MGS in
    practice (Giraud et al. 2005).
  * the (restart+1, restart) Hessenberg solve is replicated on every device
    (it is tiny), exactly like PETSc replicates it on every rank.

Stopping is on the 2-norm residual estimate maintained by the Givens
rotations; since ``||r||_inf <= ||r||_2`` this is conservative for the
sup-norm forcing condition used by iPI.

Deterministic mode (``deterministic=True``) pins the floating-point
*accumulation order* of every projection and combination so the computed
values are independent of how many fleet lanes share a device: the batched
``V @ w`` matmuls XLA emits under ``vmap`` are free to tile (and therefore
associate) their contractions by the device-local lane count, which is
exactly the cross-layout reproducibility hazard CGS2 analyses warn about
(Giraud et al. 2005 — the *values* are equally accurate, just not
bit-equal).  In deterministic mode each projection is a lane-at-a-time
``lax.map`` of fixed-shape reductions, basis combinations are ordered AXPY
loops, and the Hessenberg solve is an explicit back-substitution — no
dot-general anywhere XLA could re-tile by batch width — so a fleet-sharded
solve is bit-identical to the replicated layout *at equal state-shard
count*.  (Across different state-shard counts the distributed sum is split
at different boundaries; no fixed elementwise order makes that invariant —
the same caveat as MPI_Allreduce reproducibility being per-communicator
in PETSc.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.comm import Axes

_TINY = 1e-30


def _det_dot(axes: Axes, x, y):
    """<x, y> with a batch-invariant accumulation: elementwise multiply +
    single-axis reduce (never a dot-general XLA may re-tile per vmap
    width), then one psum over the state shards."""
    return axes.psum_state(jnp.sum(x * y))


def _det_norm2(axes: Axes, x):
    return jnp.sqrt(jnp.maximum(_det_dot(axes, x, x), 0.0))


def _det_projections(axes: Axes, V, w):
    """The CGS2 projection vector ``V @ w`` computed one basis lane at a
    time (``lax.map``): every inner product is the same fixed-shape
    reduction regardless of how many fleet instances are vmapped onto this
    device, so the accumulation order — and hence the bits — match between
    the replicated and fleet-sharded layouts."""
    return axes.psum_state(jax.lax.map(lambda vj: jnp.sum(vj * w), V))


def _det_combine(h, V):
    """``h @ V`` as an ordered AXPY loop (fixed j-order accumulation)."""
    return jax.lax.fori_loop(
        0, V.shape[0], lambda j, acc: acc + h[j] * V[j],
        jnp.zeros_like(V[0]))


def _det_backsolve(R, g):
    """Upper-triangular solve by explicit back-substitution (fixed
    accumulation order; replaces the batched ``solve_triangular``)."""
    n = R.shape[0]

    def step(i, y):
        j = n - 1 - i
        # y[k] == 0 for k <= j (not yet assigned), so the full-row reduce
        # only picks up the k > j terms back-substitution needs.
        return y.at[j].set((g[j] - jnp.sum(R[j] * y)) / R[j, j])

    return jax.lax.fori_loop(0, n, step, jnp.zeros_like(g))


def _arnoldi_cycle(matvec, b, x, *, restart: int, tol, axes: Axes,
                   deterministic: bool = False, precond=None):
    """One restart cycle. Returns (x_new, resnorm, iters_done)."""
    n_local = x.shape[0]
    dt = x.dtype
    M = precond if precond is not None else (lambda v: v)
    norm2 = (lambda v: _det_norm2(axes, v)) if deterministic else axes.norm2
    r = b - matvec(x)
    beta = norm2(r)
    v0 = r / jnp.where(beta > _TINY, beta, 1.0)

    V = jnp.zeros((restart + 1, n_local), dt).at[0].set(v0)
    R = jnp.zeros((restart, restart), dt)   # rotated (triangular) H
    cs = jnp.zeros((restart,), dt)
    sn = jnp.zeros((restart,), dt)
    g = jnp.zeros((restart + 1,), dt).at[0].set(beta)
    row_ids = jnp.arange(restart + 1)

    def body(j, carry):
        V, R, cs, sn, g, res, it, done = carry
        # right preconditioning: Krylov space of A M, solution mapped back
        # through M at cycle end -> the Givens residual estimate stays the
        # TRUE residual ||b - A x||, so forcing-term semantics are unchanged
        w = matvec(M(V[j]))
        # CGS2: two masked classical GS passes (2 collectives total).  The
        # mask is cast to the solve dtype: a float32 mask would silently
        # promote (or downcast) non-f32 inner solves through h1/h2.
        mask = (row_ids <= j).astype(dt)
        if deterministic:
            h1 = mask * _det_projections(axes, V, w)
            w = w - _det_combine(h1, V)
            h2 = mask * _det_projections(axes, V, w)
            w = w - _det_combine(h2, V)
        else:
            h1 = mask * axes.psum_state(V @ w)
            w = w - h1 @ V
            h2 = mask * axes.psum_state(V @ w)
            w = w - h2 @ V
        h = h1 + h2
        hnorm = norm2(w)
        v_next = w / jnp.where(hnorm > _TINY, hnorm, 1.0)

        # Apply the j previous Givens rotations to the new column.  Rotation i
        # touches positions (i, i+1), all <= j, so position j+1 (== hnorm)
        # stays untouched.
        def rot(i, hv):
            hi, hi1 = hv[i], hv[i + 1]
            hv = hv.at[i].set(cs[i] * hi + sn[i] * hi1)
            return hv.at[i + 1].set(-sn[i] * hi + cs[i] * hi1)

        h = h.at[j + 1].set(hnorm)
        h = jax.lax.fori_loop(
            0, restart,
            lambda i, hv: jnp.where(i < j, rot(i, hv), hv), h)
        hj = jnp.take(h, j)
        hj1 = hnorm

        denom = jnp.sqrt(hj * hj + hj1 * hj1)
        safe = denom > _TINY
        c_new = jnp.where(safe, hj / jnp.where(safe, denom, 1.0), 1.0)
        s_new = jnp.where(safe, hj1 / jnp.where(safe, denom, 1.0), 0.0)
        gj = jnp.take(g, j)
        g_new = g.at[j + 1].set(-s_new * gj).at[j].set(c_new * gj)
        res_new = jnp.abs(-s_new * gj)

        # Column j of R: rotated h (positions < j already rotated; j -> denom;
        # the subdiagonal entry j+1 is annihilated by the new rotation).
        col = h.at[j].set(denom).at[j + 1].set(0.0)
        R_new = R.at[:, j].set(col[:restart])
        V_new = V.at[j + 1].set(v_next)

        keep = lambda new, old: jax.tree_util.tree_map(
            lambda a, o: jnp.where(done, o, a), new, old)
        V, R, cs_o, sn_o, g, res, it = keep(
            (V_new, R_new, cs.at[j].set(c_new), sn.at[j].set(s_new), g_new,
             res_new, it + 1),
            (V, R, cs, sn, g, res, it))
        done = done | (res <= tol)
        return V, R, cs_o, sn_o, g, res, it, done

    init = (V, R, cs, sn, g, beta, jnp.int32(0), beta <= tol)
    V, R, _, _, g, res, iters, _ = jax.lax.fori_loop(0, restart, body, init)

    # Solve the (iters x iters) triangular system; mask out unused columns.
    active = jnp.arange(restart) < iters
    diag_fix = jnp.diag(jnp.where(active, 0.0, 1.0)).astype(R.dtype)
    R_m = jnp.where(active[None, :] & active[:, None], R, 0.0) + diag_fix
    g_m = jnp.where(active, g[:restart], 0.0)
    if deterministic:
        y = _det_backsolve(R_m, g_m)
        x_new = x + M(_det_combine(y, V[:restart]))
    else:
        y = jax.scipy.linalg.solve_triangular(R_m, g_m, lower=False)
        x_new = x + M(y @ V[:restart])
    if precond is not None:
        # With an ill-conditioned M (near-singular blocks at gamma -> 1,
        # ||M|| ~ 1/(1-gamma)) the f32 rounding of x + M(V y) can leave the
        # TRUE residual orders above the Givens estimate — the solver would
        # report convergence the iPI safeguard then rejects every outer
        # step.  Measure honestly; the next cycle restarts from the true
        # residual anyway, so this self-corrects at one matvec per cycle.
        # The plain path keeps the estimate (bit-identical to no-precond).
        res = norm2(b - matvec(x_new))
    return x_new, res, iters


def gmres(matvec, b: jax.Array, x0: jax.Array, *, tol, maxiter: int,
          axes: Axes, restart: int = 32, deterministic: bool = False,
          precond=None):
    """Restarted GMRES.  Returns ``(x, iters, resnorm_2)``.

    ``deterministic=True`` pins every accumulation order (see the module
    docstring): fleet-sharded solves become bit-identical to replicated
    ones, at the cost of serializing the CGS2 projections lane-at-a-time.

    ``precond`` is an optional right preconditioner apply ``x -> M x``
    (``M ~= A^-1``, local shard in / local shard out).  ``None`` keeps the
    plain path bit-for-bit (the identity map adds no arithmetic).
    """
    restart = int(restart)

    def cycle(s):
        x, _, it = s
        x, res, done_iters = _arnoldi_cycle(
            matvec, b, x, restart=restart, tol=tol, axes=axes,
            deterministic=deterministic, precond=precond)
        return x, res, it + done_iters

    r0 = b - matvec(x0)
    res0 = _det_norm2(axes, r0) if deterministic else axes.norm2(r0)

    def cond(s):
        _, res, it = s
        return (res > tol) & (it < maxiter)

    x, res, iters = jax.lax.while_loop(
        cond, cycle, (x0, res0, jnp.int32(0)))
    return x, iters, res
