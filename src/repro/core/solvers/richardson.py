"""(Damped) Richardson iteration.

For the policy-evaluation system ``(I - gamma P_pi) x = g_pi`` with
``omega = 1`` one Richardson sweep is exactly one application of the
policy-restricted Bellman operator ``T_pi``:

    x <- x + (b - A x) = g_pi + gamma P_pi x = T_pi x

so Richardson(0 sweeps from the warm start Tv) == value iteration and
Richardson(L-1 sweeps) == modified policy iteration with L evaluations —
the two methods mdpsolver offers are strict special cases (this is the
madupite/iPI unification).  Stopping is on the sup-norm residual, the
natural norm for contraction arguments.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.comm import Axes


def richardson(matvec, b: jax.Array, x0: jax.Array, *, tol, maxiter: int,
               axes: Axes, omega: float = 1.0):
    """Returns ``(x, iters, ||b - A x||_inf)``."""

    def resid(x):
        r = b - matvec(x)
        return r, axes.pmax_state(jnp.max(jnp.abs(r)))

    r0, n0 = resid(x0)

    def cond(s):
        _, _, norm, it = s
        return (norm > tol) & (it < maxiter)

    def body(s):
        x, r, _, it = s
        x = x + omega * r
        r, norm = resid(x)
        return x, r, norm, it + 1

    x, _, norm, iters = jax.lax.while_loop(
        cond, body, (x0, r0, n0, jnp.int32(0)))
    return x, iters, norm
