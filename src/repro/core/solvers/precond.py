"""Jacobi / block-Jacobi preconditioners for the policy-evaluation system.

The Krylov inner solvers attack ``A_pi x = g_pi`` with
``A_pi = I - gamma P_pi``.  For gamma -> 1 the system loses diagonal
dominance and restarted GMRES stalls (the bench outliers: ``chain_0.9999``,
``sis_20k``).  PETSc's answer — and madupite's, since it inherits the whole
``-pc_type`` catalogue — is cheap one-shot preconditioning; this module
provides the two classics that need nothing beyond the rows each shard
already owns:

* ``jacobi`` — ``M = diag(A_pi)^-1``.  The diagonal is extracted per shard
  from the :class:`~repro.core.bellman.PolicyRows` transient (ELL: match
  global column ids against the shard's own global row ids; dense: gather
  the diagonal band), psum-reduced over action shards so 2-D layouts see the
  full row.  Application is elementwise, hence trivially
  ``-deterministic_dots``-safe and bitwise independent of fleet packing.

* ``bjacobi`` — shard-local block Jacobi with block size ``-pc_block``.
  Blocks are defined on the *local* row ordering (like PETSc's per-process
  ``bjacobi``): entries of ``P_pi`` whose column falls in the same local
  block as their row are scattered into ``(b x b)`` tiles, the tiles
  ``I - gamma B_r`` are inverted in one batched ``linalg.inv`` at setup, and
  application is one batched tile matvec.  Rows past the last full block are
  padded with identity rows, so trailing partial blocks are exact.  Off-shard
  and off-block couplings are dropped — that only weakens the preconditioner,
  never its correctness (GMRES/BiCGStab iterate on the true operator).

Both builders work unchanged for matrix-free MDPs: ``policy_rows`` hands the
same ELL-shaped transient whether the table was materialized or rebuilt
on the fly (PR 9), so preconditioning costs O(n_local * nnz) setup and no
extra persistent memory beyond the inverted tiles.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.comm import Axes

_TINY = 1e-30

PC_TYPES = ("none", "jacobi", "bjacobi")


def _diag_p_pi(rows, axes: Axes, n_local: int) -> jax.Array:
    """Local diagonal of ``P_pi`` (psum-reduced over action shards)."""
    row0 = axes.state_index() * n_local
    gids = row0 + jnp.arange(n_local)
    if rows.idx is not None:
        hit = rows.idx == gids[:, None]
        d = jnp.sum(jnp.where(hit, rows.val, 0.0), axis=-1)
    else:
        cols = jnp.clip(gids, 0, rows.p.shape[-1] - 1)
        d = jnp.take_along_axis(rows.p, cols[:, None], axis=-1)[..., 0]
    return axes.psum_action(d)


def _block_rows_p_pi(rows, axes: Axes, n_local: int, block: int) -> jax.Array:
    """``(n_local, block)`` strip: column ``c`` of row ``i`` holds
    ``P_pi[i, (i // block) * block + c]`` in *local* ids (zeros elsewhere)."""
    row0 = axes.state_index() * n_local
    li = jnp.arange(n_local)
    if rows.idx is not None:
        loc = rows.idx - row0
        ok = (loc >= 0) & (loc < n_local) & \
             ((loc // block) == (li // block)[:, None])
        # scatter-add into a (block + 1)-wide strip; masked entries land in
        # the dump column so no O(n * nnz * block) one-hot is materialized
        pos = jnp.where(ok, loc % block, block)
        strip = jnp.zeros((n_local, block + 1), rows.val.dtype)
        strip = strip.at[li[:, None], pos].add(jnp.where(ok, rows.val, 0.0))
        strip = strip[:, :block]
    else:
        cols = row0 + (li // block) * block
        cols = cols[:, None] + jnp.arange(block)[None, :]
        ok = (cols < rows.p.shape[-1]) & (cols - row0 < n_local)
        strip = jnp.take_along_axis(
            rows.p, jnp.clip(cols, 0, rows.p.shape[-1] - 1), axis=-1)
        strip = jnp.where(ok, strip, 0.0)
    return axes.psum_action(strip)


def build_precond(rows, *, axes: Axes, n_local: int, gamma,
                  pc_type: str, block: int = 32,
                  dtype=None) -> Callable[[jax.Array], jax.Array] | None:
    """Build an approximate inverse ``M ~= A_pi^-1`` for the current policy.

    Returns an apply callable ``x -> M x`` (local shard in, local shard
    out; no collectives at apply time), or ``None`` for ``pc_type='none'``.
    ``gamma`` may be a traced scalar (fleet solves with heterogeneous
    discounts rebuild the tiles per lane under ``vmap``).
    """
    if pc_type == "none":
        return None
    if pc_type == "jacobi":
        d = 1.0 - gamma * _diag_p_pi(rows, axes, n_local)
        inv_d = 1.0 / jnp.where(jnp.abs(d) > _TINY, d, 1.0)
        if dtype is not None:
            inv_d = inv_d.astype(dtype)
        return lambda x: x * inv_d.astype(x.dtype)
    if pc_type == "bjacobi":
        b = int(block)
        strip = gamma * _block_rows_p_pi(rows, axes, n_local, b)
        nb = -(-n_local // b)
        pad = nb * b - n_local
        if pad:
            strip = jnp.pad(strip, ((0, pad), (0, 0)))
        tiles = jnp.eye(b, dtype=strip.dtype)[None] - strip.reshape(nb, b, b)
        # padded rows are zero in `strip` -> identity rows in `tiles`, so the
        # trailing partial block stays invertible and acts as plain Jacobi
        # on the real rows it contains
        inv = jnp.linalg.inv(tiles)
        if dtype is not None:
            inv = inv.astype(dtype)

        def apply(x):
            xr = jnp.pad(x, (0, pad)) if pad else x
            xr = xr.reshape(nb, b)
            y = jnp.einsum("rij,rj->ri", inv.astype(x.dtype), xr)
            y = y.reshape(nb * b)
            return y[:n_local] if pad else y

        return apply
    raise ValueError(
        f"unknown pc_type {pc_type!r}; expected one of {PC_TYPES}")
