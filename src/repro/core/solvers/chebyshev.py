"""Chebyshev semi-iteration — a collective-free Krylov-grade inner solver.

For the policy-evaluation system ``(I - gamma P_pi) x = g_pi`` the spectrum
of ``A = I - gamma P_pi`` lies in the disk centered at 1 with radius
``gamma``; for reversible / birth-death policy chains (``chain_walk``-like
instances) it is *real* and contained in ``[1 - gamma, 1 + gamma]``, where
the Chebyshev recursion is the optimal polynomial iteration.  Unlike GMRES
or BiCGStab it needs **no inner products** — the only collective per
iteration is the sup-norm residual check (one ``pmax``), which makes it
attractive on wide meshes where Krylov dot-product ``psum`` latency
dominates, and trivially *batch-invariant*: there is no accumulation a
``vmap`` width could re-associate, so it composes with
``-deterministic_dots`` and the fleet-sharded layouts bit-for-bit.

The iteration is Saad, *Iterative Methods for Sparse Linear Systems*,
Alg. 12.1, with interval center ``theta = (hi + lo) / 2`` and half-width
``delta = (hi - lo) / 2``.  The caller supplies the spectral bounds — the
iPI registry wrapper passes ``lo = 1 - gamma, hi = 1 + gamma`` (``gamma``
may be a traced per-instance scalar in heterogeneous fleets).  On spectra
with large imaginary parts the interval iteration may stall; the outer iPI
monotone safeguard (VI fallback) keeps the outer loop globally convergent
regardless.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.comm import Axes

_TINY = 1e-30


def chebyshev(matvec, b: jax.Array, x0: jax.Array, *, tol, maxiter: int,
              axes: Axes, lo, hi, divtol: float = 1e4):
    """Returns ``(x, iters, ||b - A x||_inf)``.

    ``lo`` / ``hi`` bound the (real part of the) spectrum of ``A``; both may
    be traced scalars.  Stopping is on the sup-norm residual, consistent
    with the iPI forcing condition.  ``divtol`` is the PETSc-style
    divergence guard: the iteration bails out once the residual exceeds
    ``divtol`` times the initial one (spectra with large imaginary parts
    sit outside the interval — returning early hands the outer safeguard a
    cheap rejection instead of ``maxiter`` diverging sweeps).
    """
    dt = x0.dtype
    theta = jnp.asarray((hi + lo) * 0.5, dt)
    delta = jnp.maximum(jnp.asarray((hi - lo) * 0.5, dt),
                        jnp.asarray(_TINY, dt))
    sigma1 = theta / delta

    r0 = b - matvec(x0)
    n0 = axes.norm_inf(r0)
    d0 = r0 / theta
    rho0 = delta / theta

    def cond(s):
        _, _, _, _, res, it = s
        return (res > tol) & (it < maxiter) & (res <= divtol * n0 + _TINY)

    def body(s):
        x, r, d, rho, _, it = s
        x = x + d
        r = r - matvec(d)
        rho_new = 1.0 / (2.0 * sigma1 - rho)
        d = rho_new * rho * d + (2.0 * rho_new / delta) * r
        return x, r, d, rho_new, axes.norm_inf(r), it + 1

    x, _, _, _, res, iters = jax.lax.while_loop(
        cond, body, (x0, r0, d0, rho0, n0, jnp.int32(0)))
    return x, iters, res
