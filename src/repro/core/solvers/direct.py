"""Dense direct policy evaluation — the single-device oracle.

Used by exact policy iteration on small instances and by the test suite to
cross-check every iterative inner solver: ``v_pi = (I - gamma P_pi)^{-1} g_pi``
via LU.  Not distributed (materializes the dense n x n system).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mdp import DenseMDP, EllMDP, MDP


def dense_policy_value(mdp: MDP, pi: jax.Array) -> jax.Array:
    """Exact value of policy ``pi`` (global action ids) on an unsharded MDP."""
    n = mdp.n_local
    assert n == mdp.n_global, "direct solve requires the full (unsharded) MDP"
    dense = mdp.as_dense() if isinstance(mdp, EllMDP) else mdp
    rows = jnp.arange(n)
    dt = jnp.result_type(jnp.float32, dense.p.dtype)
    p_pi = dense.p[rows, pi]            # (n, n)
    g_pi = dense.cost[rows, pi]         # (n,)
    a = jnp.eye(n, dtype=dt) - dense.gamma * p_pi.astype(dt)
    return jnp.linalg.solve(a, g_pi.astype(dt))
